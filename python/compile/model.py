"""L2: the JAX compute graphs that AOT-lower into the rust-served artifacts.

Everything here composes the L1 Pallas kernels (`kernels.fft`,
`kernels.spectrum`, `kernels.harmonic`) into the computations the paper
measures:

  * batched C2C FFT (single-kernel and four-step multi-kernel plans),
  * Bluestein FFT for non-power-of-two lengths,
  * the pulsar-search pipeline of section 5.3
    (FFT -> power spectrum -> mean/std normalize -> harmonic sum).

These functions are traced exactly once per artifact by `aot.py`; python is
never on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fft as kfft
from .kernels import harmonic as kharmonic
from .kernels import spectrum as kspectrum


def fft_batch(re, im, *, inverse: bool = False, interpret: bool = True):
    """Batched C2C FFT with automatic plan selection (the cuFFT analogue)."""
    return kfft.fft_c2c_auto(re, im, inverse=inverse, interpret=interpret)


def _next_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


def bluestein_fft(re, im, *, inverse: bool = False, interpret: bool = True):
    """C2C FFT of arbitrary length via Bluestein's chirp-z algorithm.

    cuFFT falls back to Bluestein when N has prime factors > 127; the rust
    plan model charges the same structure modelled here: two forward FFTs,
    a pointwise multiply, and an inverse FFT, all of length M = next power
    of two >= 2N - 1.
    """
    batch, n = re.shape
    if n & (n - 1) == 0:
        return kfft.fft_c2c_auto(re, im, inverse=inverse, interpret=interpret)
    m = _next_pow2(2 * n - 1)
    sign = 1.0 if inverse else -1.0

    # Chirp c_n = exp(sign * i * pi * n^2 / N). Computed in float64 numpy at
    # trace time, so it becomes a constant in the artifact.
    idx = np.arange(n, dtype=np.float64)
    phase = sign * np.pi * ((idx * idx) % (2 * n)) / n
    cr = np.cos(phase)
    ci = np.sin(phase)

    # a = x * c, zero-padded to M.
    ar = re * jnp.asarray(cr, re.dtype) - im * jnp.asarray(ci, re.dtype)
    ai = re * jnp.asarray(ci, re.dtype) + im * jnp.asarray(cr, re.dtype)
    ar = jnp.pad(ar, ((0, 0), (0, m - n)))
    ai = jnp.pad(ai, ((0, 0), (0, m - n)))

    # b = conj(chirp), wrapped: b_k = conj(c)_{|k|} for k in (-N, N).
    br = np.zeros(m)
    bi = np.zeros(m)
    br[:n] = cr
    bi[:n] = -ci
    br[m - n + 1:] = cr[1:][::-1]
    bi[m - n + 1:] = -ci[1:][::-1]

    # Circular convolution through the power-of-two Pallas FFT.
    far, fai = kfft.fft_c2c_auto(ar, ai, interpret=interpret)
    fbr, fbi = kfft.fft_c2c_auto(
        jnp.asarray(br, re.dtype)[None, :], jnp.asarray(bi, re.dtype)[None, :],
        interpret=interpret)
    pr = far * fbr - fai * fbi
    pi = far * fbi + fai * fbr
    yr, yi = kfft.fft_c2c_auto(pr, pi, inverse=True, interpret=interpret)

    # Multiply by the chirp again and truncate to N.
    outr = yr[:, :n] * jnp.asarray(cr, re.dtype) - yi[:, :n] * jnp.asarray(ci, re.dtype)
    outi = yr[:, :n] * jnp.asarray(ci, re.dtype) + yi[:, :n] * jnp.asarray(cr, re.dtype)
    if inverse:
        outr = outr / n
        outi = outi / n
    return outr, outi


def fft2d(re, im, *, inverse: bool = False, interpret: bool = True):
    """2D C2C FFT of a (B, R, C) batch via row/column 1D passes.

    The paper (section 2.1) notes cuFFT computes higher-dimensional
    transforms exactly this way — two sets of batched 1D FFTs — which is
    why its 1D energy study covers the 2D/3D cases too. Both passes reuse
    the same Pallas Stockham kernel.
    """
    b, r, c = re.shape
    # rows: batch the R dimension
    xr, xi = kfft.fft_c2c_auto(re.reshape(b * r, c), im.reshape(b * r, c),
                               inverse=inverse, interpret=interpret)
    xr = xr.reshape(b, r, c).transpose(0, 2, 1)
    xi = xi.reshape(b, r, c).transpose(0, 2, 1)
    # columns: batch the C dimension
    yr, yi = kfft.fft_c2c_auto(xr.reshape(b * c, r), xi.reshape(b * c, r),
                               inverse=inverse, interpret=interpret)
    yr = yr.reshape(b, c, r).transpose(0, 2, 1)
    yi = yi.reshape(b, c, r).transpose(0, 2, 1)
    return yr, yi


def pulsar_pipeline(re, im, *, harmonics: int, interpret: bool = True):
    """The section 5.3 pipeline on a batch of complex time series.

    Returns (harmonic_sums, spectrum_mean, spectrum_std).  The harmonic sum
    is taken over the normalized power spectrum, so a pulsar at bin k shows
    up as a large positive S/N value at k.
    """
    fr, fi = fft_batch(re, im, interpret=interpret)
    p = kspectrum.power_spectrum(fr, fi, interpret=interpret)
    norm, mean, std = kspectrum.normalize_spectrum(p, interpret=interpret)
    hs = kharmonic.harmonic_sum(norm, harmonics=harmonics, interpret=interpret)
    return hs, mean, std


def spectrum_only(re, im, *, interpret: bool = True):
    """FFT + power spectrum (the pipeline's first two stages)."""
    fr, fi = fft_batch(re, im, interpret=interpret)
    return kspectrum.power_spectrum(fr, fi, interpret=interpret)


# ---------------------------------------------------------------------------
# Artifact catalogue: every HLO module the rust runtime can load.
# ---------------------------------------------------------------------------

def make_fft_fn(inverse: bool = False):
    return functools.partial(fft_batch, inverse=inverse)


def make_pipeline_fn(harmonics: int):
    return functools.partial(pulsar_pipeline, harmonics=harmonics)


def artifact_catalogue():
    """(name, fn, [input ShapeDtypeStructs], output arity, metadata) tuples.

    Batch sizes keep each artifact's element count at 2^16 (fp32) so the CPU
    runtime stays fast; the GPU simulator scales the *modelled* batch to the
    paper's fixed 2 GB working set independently of what the CPU executes.
    """
    f32 = jnp.float32
    f64 = jnp.float64
    entries = []

    def fft_entry(n, batch, dtype, tag):
        spec = jax.ShapeDtypeStruct((batch, n), dtype)
        entries.append((
            f"fft_{tag}_n{n}_b{batch}", make_fft_fn(), [spec, spec], 2,
            {"kind": "fft", "n": n, "batch": batch, "dtype": tag},
        ))

    fft_entry(256, 256, f32, "f32")
    fft_entry(1024, 64, f32, "f32")
    fft_entry(4096, 16, f32, "f32")
    fft_entry(16384, 4, f32, "f32")      # four-step multi-kernel plan
    fft_entry(1024, 64, f64, "f64")

    spec = jax.ShapeDtypeStruct((16, 4096), f32)
    entries.append((
        "spectrum_f32_n4096_b16", spectrum_only, [spec, spec], 1,
        {"kind": "spectrum", "n": 4096, "batch": 16, "dtype": "f32"},
    ))

    for h in (2, 4, 8, 16, 32):
        spec = jax.ShapeDtypeStruct((4, 16384), f32)
        entries.append((
            f"pipeline_n16384_h{h}", make_pipeline_fn(h), [spec, spec], 3,
            {"kind": "pipeline", "n": 16384, "batch": 4, "dtype": "f32",
             "harmonics": h},
        ))
    return entries
