"""L1 Pallas kernel: harmonic sum.

The harmonic sum (paper section 5.3) boosts the S/N of a periodic signal by
adding the power at integer multiples of each trial fundamental frequency:

    S_H[k] = sum_{h=1..H} P[h * k],   k < N // H

so a pulsar whose fundamental falls on bin k collects its first H harmonics.
The pipeline in the paper sums up to 32 harmonics; the kernel takes H as a
static parameter so each H lowers to its own artifact, matching the paper's
per-configuration measurements (Table 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _harmonic_kernel(p_ref, out_ref, *, harmonics: int, n_out: int):
    p = p_ref[...]
    k = jnp.arange(n_out)
    acc = jnp.zeros(p.shape[:-1] + (n_out,), dtype=p.dtype)
    for h in range(1, harmonics + 1):
        acc = acc + jnp.take(p, k * h, axis=-1)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("harmonics", "tile_b", "interpret"))
def harmonic_sum(p, *, harmonics: int, tile_b: int = 64, interpret: bool = True):
    """Harmonic-summed spectrum: out[b, k] = sum_{h=1..H} p[b, h*k]."""
    if p.ndim != 2:
        raise ValueError(f"expected (B, N), got {p.shape}")
    if harmonics < 1:
        raise ValueError(f"harmonics must be >= 1, got {harmonics}")
    batch, n = p.shape
    n_out = n // harmonics
    if n_out < 1:
        raise ValueError(f"harmonics={harmonics} too large for N={n}")
    tile = min(tile_b, batch)
    while batch % tile != 0:
        tile -= 1
    in_spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile, n_out), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_harmonic_kernel, harmonics=harmonics, n_out=n_out),
        grid=(batch // tile,),
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n_out), p.dtype),
        interpret=interpret,
    )(p)
