"""L1 Pallas kernels: power spectrum and spectral normalization.

These are the non-FFT stages of the paper's pulsar-search pipeline
(section 5.3): power-spectrum calculation and mean/std normalization of the
spectrum before harmonic summing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_kernel(re_ref, im_ref, out_ref):
    re = re_ref[...]
    im = im_ref[...]
    out_ref[...] = re * re + im * im


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def power_spectrum(re, im, *, tile_b: int = 64, interpret: bool = True):
    """P[b, k] = |X[b, k]|^2 for a batch of complex spectra (re/im planes)."""
    if re.shape != im.shape or re.ndim != 2:
        raise ValueError(f"expected matching (B, N) planes, got {re.shape}/{im.shape}")
    batch, n = re.shape
    tile = min(tile_b, batch)
    while batch % tile != 0:
        tile -= 1
    spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    return pl.pallas_call(
        _power_kernel,
        grid=(batch // tile,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((batch, n), re.dtype),
        interpret=interpret,
    )(re, im)


def _normalize_kernel(p_ref, out_ref, mean_ref, std_ref, *, n: int):
    p = p_ref[...]
    mean = jnp.mean(p, axis=-1, keepdims=True)
    centred = p - mean
    var = jnp.mean(centred * centred, axis=-1, keepdims=True)
    std = jnp.sqrt(var)
    safe = jnp.where(std > 0, std, jnp.ones_like(std))
    out_ref[...] = centred / safe
    mean_ref[...] = mean[..., 0]
    std_ref[...] = std[..., 0]


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def normalize_spectrum(p, *, tile_b: int = 64, interpret: bool = True):
    """Zero-mean / unit-std normalization of each spectrum row.

    Returns (normalized, mean, std); mean/std are the per-row moments the
    paper's pipeline computes as its "mean and standard deviation" stage.
    """
    if p.ndim != 2:
        raise ValueError(f"expected (B, N), got {p.shape}")
    batch, n = p.shape
    tile = min(tile_b, batch)
    while batch % tile != 0:
        tile -= 1
    spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    out, mean, std = pl.pallas_call(
        functools.partial(_normalize_kernel, n=n),
        grid=(batch // tile,),
        in_specs=[spec],
        out_specs=[spec, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n), p.dtype),
            jax.ShapeDtypeStruct((batch,), p.dtype),
            jax.ShapeDtypeStruct((batch,), p.dtype),
        ],
        interpret=interpret,
    )(p)
    return out, mean, std
