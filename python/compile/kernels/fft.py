"""L1 Pallas kernels: batched complex-to-complex Stockham FFT.

The paper studies cuFFT, whose single-kernel regime keeps an FFT of length
N <= ~2^13 (fp32) resident in shared memory: one device-memory read, all
log2(N) butterfly stages on-chip, one write back.  The TPU-thinking analogue
implemented here is a Pallas kernel whose BlockSpec moves a (TILE_B, N) tile
of the batch HBM->VMEM once, runs every Stockham stage on the VMEM-resident
tile, and writes back once.  Complex data travels as separate re/im planes
(VPU-friendly; avoids complex-dtype layout pitfalls in the AOT path).

`interpret=True` everywhere: the kernel lowers to plain HLO so the rust PJRT
CPU client can execute it; real-TPU lowering would emit a Mosaic custom call
the CPU plugin cannot run.  Correctness is pinned against `kernels.ref`
(pure jnp) by pytest/hypothesis.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Maximum FFT length handled by a single VMEM-resident kernel, per dtype.
# Mirrors the cuFFT shared-memory single-kernel capacity modelled by the
# rust `cufft::plan` module (fp32: 2^13, fp64: 2^12, fp16: 2^14).
MAX_SINGLE_KERNEL = {
    jnp.dtype("float32"): 1 << 13,
    jnp.dtype("float64"): 1 << 12,
    jnp.dtype("float16"): 1 << 14,
}

# Perf (EXPERIMENTS.md §Perf): on the CPU PJRT path the whole batch in one
# grid step (tile = batch) is uniformly fastest — the per-stage concatenate
# amortizes best in a single fused loop (256x256: 3.4 ms @ tile 16 ->
# 2.6 ms @ full batch). `None` means "full batch". On real TPUs the tile is
# bounded by VMEM instead — see analysis::roofline::max_tile_b.
DEFAULT_TILE_B = None


def _check_pow2(n: int) -> int:
    if n < 2 or (n & (n - 1)) != 0:
        raise ValueError(f"stockham kernel requires power-of-two length, got {n}")
    return int(math.log2(n))


def _stockham_stages(re, im, n: int, sign: float, dtype):
    """Run all log2(n) radix-2 Stockham (DIF, autosort) stages on a tile.

    State is kept as (..., cur, s) with cur * s == n; cur halves and s
    doubles each stage.  No bit-reversal pass is needed.
    """
    stages = _check_pow2(n)
    batch = re.shape[:-1]
    re = re.reshape(batch + (n, 1))
    im = im.reshape(batch + (n, 1))
    cur, s = n, 1
    for _ in range(stages):
        m = cur // 2
        ar, ai = re[..., :m, :], im[..., :m, :]
        br, bi = re[..., m:, :], im[..., m:, :]
        # Twiddles for this stage: w_p = exp(sign * 2*pi*i * p / cur).
        # Generated *inside* the kernel via iota (pallas forbids captured
        # traced constants). Perf (EXPERIMENTS.md §Perf): computing them in
        # the data dtype instead of f64+cast is -36% on the fp32 path; the
        # extra twiddle rounding stays ~1e-6 relative over 13 stages, well
        # inside the fp32 test tolerances. fp64 (and fp16, which needs the
        # f32 headroom) keep wide twiddles.
        tw_dtype = {
            jnp.dtype("float64"): jnp.float64 if jax.config.jax_enable_x64 else jnp.float32,
            jnp.dtype("float32"): jnp.float32,
            jnp.dtype("float16"): jnp.float32,
        }[jnp.dtype(dtype)]
        p = jax.lax.broadcasted_iota(tw_dtype, (m, 1), 0)
        theta = p * (sign * 2.0 * np.pi / cur)
        wr = jnp.cos(theta).astype(dtype)
        wi = jnp.sin(theta).astype(dtype)
        sum_r, sum_i = ar + br, ai + bi
        dif_r, dif_i = ar - br, ai - bi
        tw_r = dif_r * wr - dif_i * wi
        tw_i = dif_r * wi + dif_i * wr
        # y[..., p, 0, q] = a+b ; y[..., p, 1, q] = (a-b) * w_p
        yr = jnp.stack([sum_r, tw_r], axis=-2)
        yi = jnp.stack([sum_i, tw_i], axis=-2)
        cur, s = m, s * 2
        re = yr.reshape(batch + (cur, s))
        im = yi.reshape(batch + (cur, s))
    return re.reshape(batch + (n,)), im.reshape(batch + (n,))


def _fft_kernel(re_ref, im_ref, or_ref, oi_ref, *, n: int, sign: float, scale: float):
    re = re_ref[...]
    im = im_ref[...]
    rr, ri = _stockham_stages(re, im, n, sign, re.dtype)
    if scale != 1.0:
        rr = rr * jnp.asarray(scale, dtype=rr.dtype)
        ri = ri * jnp.asarray(scale, dtype=ri.dtype)
    or_ref[...] = rr
    oi_ref[...] = ri


def _pick_tile(batch: int, tile_b: int | None) -> int:
    tile = tile_b if tile_b is not None else (DEFAULT_TILE_B or batch)
    tile = min(tile, batch)
    while batch % tile != 0:
        tile -= 1
    return max(tile, 1)


@functools.partial(
    jax.jit, static_argnames=("inverse", "tile_b", "interpret", "normalize")
)
def fft_c2c(re, im, *, inverse: bool = False, tile_b: int | None = None,
            interpret: bool = True, normalize: bool = True):
    """Batched power-of-two C2C FFT of a (B, N) re/im pair via one Pallas call.

    Forward: X_l = sum_n x_n exp(-2*pi*i*n*l/N)      (paper eq. 1)
    Inverse: x_n = (1/N) sum_l X_l exp(+2*pi*i*n*l/N) (scaled iff normalize)
    """
    if re.shape != im.shape or re.ndim != 2:
        raise ValueError(f"expected matching (B, N) planes, got {re.shape}/{im.shape}")
    batch, n = re.shape
    sign = 1.0 if inverse else -1.0
    scale = (1.0 / n) if (inverse and normalize) else 1.0
    tile = _pick_tile(batch, tile_b)
    grid = (batch // tile,)
    spec = pl.BlockSpec((tile, n), lambda i: (i, 0))
    kernel = functools.partial(_fft_kernel, n=n, sign=sign, scale=scale)
    out_shape = [
        jax.ShapeDtypeStruct((batch, n), re.dtype),
        jax.ShapeDtypeStruct((batch, n), im.dtype),
    ]
    return tuple(
        pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=[spec, spec],
            out_shape=out_shape,
            interpret=interpret,
        )(re, im)
    )


def _twiddle_kernel(re_ref, im_ref, wr_ref, wi_ref, or_ref, oi_ref):
    re, im = re_ref[...], im_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    or_ref[...] = re * wr - im * wi
    oi_ref[...] = re * wi + im * wr


@functools.partial(jax.jit, static_argnames=("interpret",))
def twiddle_mul(re, im, wr, wi, *, interpret: bool = True):
    """Pointwise complex multiply of a (B, R, C) tile by a (R, C) twiddle grid.

    This is the inter-pass twiddle of the four-step (multi-kernel) plan —
    the analogue of the separate twiddle kernels NVVP shows between cuFFT
    passes for large N.
    """
    b, r, c = re.shape
    spec = pl.BlockSpec((1, r, c), lambda i: (i, 0, 0))
    wspec = pl.BlockSpec((r, c), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(re.shape, re.dtype),
        jax.ShapeDtypeStruct(im.shape, im.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _twiddle_kernel,
            grid=(b,),
            in_specs=[spec, spec, wspec, wspec],
            out_specs=[spec, spec],
            out_shape=out_shape,
            interpret=interpret,
        )(re, im, wr, wi)
    )


def split_four_step(n: int, dtype=jnp.float32) -> tuple[int, int]:
    """Factor N = N1 * N2 for the four-step plan with both factors within the
    single-kernel capacity.  Prefers a balanced split (N1 >= N2)."""
    cap = MAX_SINGLE_KERNEL[jnp.dtype(dtype)]
    log_n = _check_pow2(n)
    n1 = 1 << ((log_n + 1) // 2)
    n2 = n // n1
    if n1 > cap or n2 > cap:
        raise ValueError(
            f"N={n} does not split into two single-kernel passes (cap={cap})"
        )
    return n1, n2


def fft_c2c_four_step(re, im, *, inverse: bool = False, interpret: bool = True,
                      tile_b: int | None = None, normalize: bool = True):
    """Large-N C2C FFT via the four-step decomposition N = N1*N2.

    Mirrors cuFFT's multi-kernel plan: column FFT pass, twiddle kernel,
    row FFT pass, transposed write-out — each pass a full HBM round trip,
    which is exactly what the rust `cufft::plan` traffic model charges.
    """
    batch, n = re.shape
    n1, n2 = split_four_step(n, re.dtype)
    sign = 1.0 if inverse else -1.0

    # Pass 1: FFT of length n1 down the columns (n1-major layout).
    xr = re.reshape(batch, n1, n2).transpose(0, 2, 1).reshape(batch * n2, n1)
    xi = im.reshape(batch, n1, n2).transpose(0, 2, 1).reshape(batch * n2, n1)
    xr, xi = fft_c2c(xr, xi, inverse=inverse, tile_b=tile_b,
                     interpret=interpret, normalize=False)

    # Twiddle: w[k1, n2] = exp(sign * 2*pi*i * k1 * n2 / N).
    k1 = np.arange(n1, dtype=np.float64)[:, None]
    j2 = np.arange(n2, dtype=np.float64)[None, :]
    theta = sign * 2.0 * np.pi * k1 * j2 / n
    wr = jnp.asarray(np.cos(theta), dtype=re.dtype)
    wi = jnp.asarray(np.sin(theta), dtype=re.dtype)
    xr = xr.reshape(batch, n2, n1).transpose(0, 2, 1)  # (B, k1, n2)
    xi = xi.reshape(batch, n2, n1).transpose(0, 2, 1)
    xr, xi = twiddle_mul(xr, xi, wr, wi, interpret=interpret)

    # Pass 2: FFT of length n2 along the rows.
    xr, xi = fft_c2c(xr.reshape(batch * n1, n2), xi.reshape(batch * n1, n2),
                     inverse=inverse, tile_b=tile_b, interpret=interpret,
                     normalize=False)

    # Write-out transpose: X[k1 + N1*k2] lives at out[k2, k1].
    xr = xr.reshape(batch, n1, n2).transpose(0, 2, 1).reshape(batch, n)
    xi = xi.reshape(batch, n1, n2).transpose(0, 2, 1).reshape(batch, n)
    if inverse and normalize:
        xr = xr / n
        xi = xi / n
    return xr, xi


def fft_c2c_auto(re, im, *, inverse: bool = False, interpret: bool = True,
                 tile_b: int | None = None):
    """Dispatch to the single-kernel or four-step plan by length, as the
    cuFFT planner would."""
    n = re.shape[-1]
    cap = MAX_SINGLE_KERNEL[jnp.dtype(re.dtype)]
    if n <= cap:
        return fft_c2c(re, im, inverse=inverse, tile_b=tile_b, interpret=interpret)
    return fft_c2c_four_step(re, im, inverse=inverse, tile_b=tile_b,
                             interpret=interpret)
