"""Pure-jnp oracles for every L1 kernel.

These never go through Pallas; pytest/hypothesis pin the kernels against
them, and the rust `dsp` module re-implements the same math as a second,
independent oracle on the runtime side.
"""

from __future__ import annotations

import jax.numpy as jnp


def fft_c2c_ref(re, im, *, inverse: bool = False, normalize: bool = True):
    """Reference C2C FFT on re/im planes via jnp.fft (complex128 internally)."""
    x = re.astype(jnp.complex128) + 1j * im.astype(jnp.complex128)
    if inverse:
        y = jnp.fft.ifft(x, axis=-1)
        if not normalize:
            y = y * x.shape[-1]
    else:
        y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(re.dtype), jnp.imag(y).astype(im.dtype)


def power_spectrum_ref(re, im):
    return (re.astype(jnp.float64) ** 2 + im.astype(jnp.float64) ** 2).astype(re.dtype)


def normalize_spectrum_ref(p):
    p64 = p.astype(jnp.float64)
    mean = jnp.mean(p64, axis=-1, keepdims=True)
    centred = p64 - mean
    std = jnp.sqrt(jnp.mean(centred * centred, axis=-1, keepdims=True))
    safe = jnp.where(std > 0, std, jnp.ones_like(std))
    out = (centred / safe).astype(p.dtype)
    return out, mean[..., 0].astype(p.dtype), std[..., 0].astype(p.dtype)


def harmonic_sum_ref(p, *, harmonics: int):
    n = p.shape[-1]
    n_out = n // harmonics
    k = jnp.arange(n_out)
    acc = jnp.zeros(p.shape[:-1] + (n_out,), dtype=jnp.float64)
    for h in range(1, harmonics + 1):
        acc = acc + jnp.take(p.astype(jnp.float64), k * h, axis=-1)
    return acc.astype(p.dtype)


def pipeline_ref(re, im, *, harmonics: int):
    """Full pulsar-pipeline oracle: FFT -> power -> normalize -> harmonic sum."""
    fr, fi = fft_c2c_ref(re, im)
    p = power_spectrum_ref(fr, fi)
    norm, mean, std = normalize_spectrum_ref(p)
    hs = harmonic_sum_ref(norm, harmonics=harmonics)
    return hs, mean, std
