"""AOT lowering: trace every catalogue entry once, emit HLO *text* + manifest.

HLO text (NOT `lowered.compile()`/`.serialize()`) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla_extension 0.5.1 the rust `xla` crate links against rejects
(`proto.id() <= INT_MAX`).  The text parser on the rust side reassigns ids,
so text round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the rust
    side always unwraps a tuple, whatever the output arity).

    print_large_constants=True is ESSENTIAL: the default printer elides big
    dense constants as `constant({...})`, which the rust-side HLO text
    parser silently materializes as zeros — the four-step FFT's twiddle
    table would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constants in HLO text"
    return text


def lower_entry(name, fn, specs):
    return jax.jit(fn).lower(*specs)


def _dtype_tag(spec) -> str:
    return {"float32": "f32", "float64": "f64", "float16": "f16"}[str(spec.dtype)]


def emit(out_dir: str, only: str | None = None, verbose: bool = True) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_rows = []
    written = []
    for name, fn, specs, n_outputs, meta in model.artifact_catalogue():
        if only and only not in name:
            continue
        lowered = lower_entry(name, fn, specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        inputs = ";".join(
            f"{_dtype_tag(s)}:{'x'.join(str(d) for d in s.shape)}" for s in specs
        )
        manifest_rows.append(
            "\t".join([
                name,
                f"{name}.hlo.txt",
                meta["kind"],
                str(meta["n"]),
                str(meta["batch"]),
                meta["dtype"],
                str(meta.get("harmonics", 0)),
                inputs,
                str(n_outputs),
                digest,
            ])
        )
        written.append(path)
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}", file=sys.stderr)
    manifest = os.path.join(out_dir, "manifest.tsv")
    header = "\t".join([
        "name", "file", "kind", "n", "batch", "dtype", "harmonics",
        "inputs", "n_outputs", "sha256_16",
    ])
    with open(manifest, "w") as f:
        f.write(header + "\n")
        for row in manifest_rows:
            f.write(row + "\n")
    if verbose:
        print(f"  manifest: {len(manifest_rows)} artifacts -> {manifest}",
              file=sys.stderr)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    emit(args.out_dir, only=args.only)


if __name__ == "__main__":
    main()
