"""L1 correctness: Pallas Stockham FFT vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed parametrizations pin the exact
artifact configurations the rust runtime loads.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import fft as kfft
from compile.kernels.ref import fft_c2c_ref

RTOL = {jnp.float32: 2e-4, jnp.float64: 1e-10, jnp.float16: 2e-2}
ATOL = {jnp.float32: 2e-4, jnp.float64: 1e-10, jnp.float16: 5e-2}


def _rand_planes(rng, b, n, dtype):
    re = jnp.asarray(rng.standard_normal((b, n)), dtype)
    im = jnp.asarray(rng.standard_normal((b, n)), dtype)
    return re, im


def _assert_close(a, b, dtype, scale=1.0):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b),
        rtol=RTOL[dtype] * scale, atol=ATOL[dtype] * scale,
    )


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_forward_matches_ref(n, dtype):
    rng = np.random.default_rng(n)
    re, im = _rand_planes(rng, 4, n, dtype)
    kr, ki = kfft.fft_c2c(re, im)
    rr, ri = fft_c2c_ref(re, im)
    scale = math.sqrt(n)
    _assert_close(kr, rr, dtype, scale)
    _assert_close(ki, ri, dtype, scale)


@pytest.mark.parametrize("n", [8, 64, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_inverse_roundtrip(n, dtype):
    rng = np.random.default_rng(n + 1)
    re, im = _rand_planes(rng, 8, n, dtype)
    fr, fi = kfft.fft_c2c(re, im)
    br, bi = kfft.fft_c2c(fr, fi, inverse=True)
    _assert_close(br, re, dtype, math.sqrt(n))
    _assert_close(bi, im, dtype, math.sqrt(n))


def test_inverse_unnormalized_scales_by_n():
    rng = np.random.default_rng(7)
    re, im = _rand_planes(rng, 4, 64, jnp.float32)
    fr, fi = kfft.fft_c2c(re, im)
    ur, ui = kfft.fft_c2c(fr, fi, inverse=True, normalize=False)
    _assert_close(ur, re * 64, jnp.float32, 64.0)
    _assert_close(ui, im * 64, jnp.float32, 64.0)


def test_impulse_gives_flat_spectrum():
    n = 256
    re = jnp.zeros((1, n), jnp.float32).at[0, 0].set(1.0)
    im = jnp.zeros((1, n), jnp.float32)
    fr, fi = kfft.fft_c2c(re, im)
    np.testing.assert_allclose(np.asarray(fr), np.ones((1, n)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fi), np.zeros((1, n)), atol=1e-5)


def test_single_tone_lands_on_its_bin():
    n, k = 512, 37
    t = np.arange(n)
    re = jnp.asarray(np.cos(2 * np.pi * k * t / n)[None, :], jnp.float32)
    im = jnp.asarray(np.sin(2 * np.pi * k * t / n)[None, :], jnp.float32)
    fr, fi = kfft.fft_c2c(re, im)
    mag = np.hypot(np.asarray(fr), np.asarray(fi))[0]
    assert int(np.argmax(mag)) == k
    assert mag[k] == pytest.approx(n, rel=1e-4)
    mag[k] = 0.0
    assert np.max(mag) < 1e-2


def test_linearity():
    rng = np.random.default_rng(11)
    n = 128
    a_re, a_im = _rand_planes(rng, 2, n, jnp.float32)
    b_re, b_im = _rand_planes(rng, 2, n, jnp.float32)
    fa = kfft.fft_c2c(a_re, a_im)
    fb = kfft.fft_c2c(b_re, b_im)
    fsum = kfft.fft_c2c(a_re + 2.0 * b_re, a_im + 2.0 * b_im)
    _assert_close(fsum[0], fa[0] + 2.0 * fb[0], jnp.float32, math.sqrt(n) * 3)
    _assert_close(fsum[1], fa[1] + 2.0 * fb[1], jnp.float32, math.sqrt(n) * 3)


def test_parseval():
    rng = np.random.default_rng(13)
    n = 1024
    re, im = _rand_planes(rng, 4, n, jnp.float64)
    fr, fi = kfft.fft_c2c(re, im)
    time_e = np.sum(np.asarray(re) ** 2 + np.asarray(im) ** 2, axis=-1)
    freq_e = np.sum(np.asarray(fr) ** 2 + np.asarray(fi) ** 2, axis=-1) / n
    np.testing.assert_allclose(time_e, freq_e, rtol=1e-9)


@pytest.mark.parametrize("tile_b", [1, 2, 3, 4, 8, 16, 32])
def test_tile_size_does_not_change_result(tile_b):
    rng = np.random.default_rng(17)
    re, im = _rand_planes(rng, 12, 64, jnp.float32)
    base = kfft.fft_c2c(re, im, tile_b=1)
    out = kfft.fft_c2c(re, im, tile_b=tile_b)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(base[0]), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(base[1]), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("n", [16384, 32768])
def test_four_step_matches_ref(n):
    rng = np.random.default_rng(n)
    re, im = _rand_planes(rng, 2, n, jnp.float32)
    kr, ki = kfft.fft_c2c_four_step(re, im)
    rr, ri = fft_c2c_ref(re, im)
    scale = float(np.max(np.abs(np.asarray(rr))))
    assert float(np.max(np.abs(np.asarray(kr - rr)))) / scale < 1e-5
    assert float(np.max(np.abs(np.asarray(ki - ri)))) / scale < 1e-5


def test_four_step_inverse_roundtrip():
    rng = np.random.default_rng(23)
    re, im = _rand_planes(rng, 2, 16384, jnp.float32)
    fr, fi = kfft.fft_c2c_four_step(re, im)
    br, bi = kfft.fft_c2c_four_step(fr, fi, inverse=True)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=2e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=2e-4)


def test_auto_dispatch_matches_both_plans():
    rng = np.random.default_rng(29)
    small = _rand_planes(rng, 4, 2048, jnp.float32)
    large = _rand_planes(rng, 2, 16384, jnp.float32)
    s_auto = kfft.fft_c2c_auto(*small)
    s_single = kfft.fft_c2c(*small)
    np.testing.assert_array_equal(np.asarray(s_auto[0]), np.asarray(s_single[0]))
    l_auto = kfft.fft_c2c_auto(*large)
    l_four = kfft.fft_c2c_four_step(*large)
    np.testing.assert_array_equal(np.asarray(l_auto[0]), np.asarray(l_four[0]))


def test_split_four_step_respects_capacity():
    n1, n2 = kfft.split_four_step(1 << 20, jnp.float32)
    assert n1 * n2 == 1 << 20
    cap = kfft.MAX_SINGLE_KERNEL[jnp.dtype(jnp.float32)]
    assert n1 <= cap and n2 <= cap
    with pytest.raises(ValueError):
        kfft.split_four_step(1 << 27, jnp.float64)


def test_non_pow2_rejected():
    re = jnp.zeros((2, 12), jnp.float32)
    with pytest.raises(ValueError, match="power-of-two"):
        kfft.fft_c2c(re, re)


def test_shape_mismatch_rejected():
    re = jnp.zeros((2, 16), jnp.float32)
    im = jnp.zeros((2, 8), jnp.float32)
    with pytest.raises(ValueError, match="matching"):
        kfft.fft_c2c(re, im)


def test_fp16_small_sizes():
    rng = np.random.default_rng(31)
    re, im = _rand_planes(rng, 4, 64, jnp.float16)
    kr, ki = kfft.fft_c2c(re, im)
    rr, ri = fft_c2c_ref(re.astype(jnp.float64), im.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(kr, np.float64), np.asarray(rr), atol=0.5)
    np.testing.assert_allclose(np.asarray(ki, np.float64), np.asarray(ri), atol=0.5)


@settings(max_examples=40, deadline=None)
@given(
    log_n=st.integers(min_value=1, max_value=11),
    batch=st.integers(min_value=1, max_value=17),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    inverse=st.booleans(),
)
def test_hypothesis_fft_matches_ref(log_n, batch, seed, dtype, inverse):
    n = 1 << log_n
    rng = np.random.default_rng(seed)
    re, im = _rand_planes(rng, batch, n, dtype)
    kr, ki = kfft.fft_c2c(re, im, inverse=inverse)
    rr, ri = fft_c2c_ref(re, im, inverse=inverse)
    scale = math.sqrt(n) * (1.0 if not inverse else 1.0 / math.sqrt(n))
    _assert_close(kr, rr, dtype, max(scale, 1.0))
    _assert_close(ki, ri, dtype, max(scale, 1.0))


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=8),
    tile_b=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=999),
)
def test_hypothesis_tiling_invariance(batch, tile_b, seed):
    rng = np.random.default_rng(seed)
    re, im = _rand_planes(rng, batch, 32, jnp.float32)
    a = kfft.fft_c2c(re, im, tile_b=tile_b)
    b = kfft.fft_c2c(re, im, tile_b=1)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=1e-6, atol=1e-5)
