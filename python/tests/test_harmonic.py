"""L1 correctness: harmonic-sum kernel vs oracle + S/N boosting property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import harmonic as kharm
from compile.kernels.ref import harmonic_sum_ref


def _rand(rng, b, n):
    return jnp.asarray(rng.standard_normal((b, n)), jnp.float32)


@pytest.mark.parametrize("h", [1, 2, 3, 4, 8, 16, 32])
def test_matches_ref(h):
    rng = np.random.default_rng(h)
    p = _rand(rng, 4, 1024)
    out = kharm.harmonic_sum(p, harmonics=h)
    ref = harmonic_sum_ref(p, harmonics=h)
    assert out.shape == (4, 1024 // h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_h1_truncates_only():
    rng = np.random.default_rng(0)
    p = _rand(rng, 2, 64)
    out = kharm.harmonic_sum(p, harmonics=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(p))


def test_collects_harmonics_of_injected_tone():
    # A comb at bins k0, 2*k0, 4*k0, ... should pile up at k0 after summing.
    n, k0, h = 4096, 100, 8
    p = np.zeros((1, n), np.float32)
    for m in range(1, h + 1):
        p[0, k0 * m] = 1.0
    out = np.asarray(kharm.harmonic_sum(jnp.asarray(p), harmonics=h))[0]
    assert int(np.argmax(out)) == k0
    assert out[k0] == pytest.approx(h)


def test_dc_bin_sums_h_copies():
    p = jnp.ones((1, 128), jnp.float32)
    out = np.asarray(kharm.harmonic_sum(p, harmonics=4))
    np.testing.assert_allclose(out, 4.0)


def test_rejects_bad_args():
    p = jnp.zeros((2, 16), jnp.float32)
    with pytest.raises(ValueError):
        kharm.harmonic_sum(p, harmonics=0)
    with pytest.raises(ValueError):
        kharm.harmonic_sum(p, harmonics=32)  # n_out would be 0
    with pytest.raises(ValueError):
        kharm.harmonic_sum(jnp.zeros((2, 2, 2), jnp.float32), harmonics=2)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=9),
    log_n=st.integers(min_value=4, max_value=11),
    h=st.sampled_from([1, 2, 3, 4, 5, 8, 16]),
    seed=st.integers(min_value=0, max_value=999),
)
def test_hypothesis_matches_ref(b, log_n, h, seed):
    n = 1 << log_n
    if n // h < 1:
        return
    rng = np.random.default_rng(seed)
    p = _rand(rng, b, n)
    out = kharm.harmonic_sum(p, harmonics=h)
    ref = harmonic_sum_ref(p, harmonics=h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
