"""Optional-dependency shim for `hypothesis`.

The offline image does not always ship hypothesis; importing it at module
scope turned every property-test file into a collection error, taking the
fixed-parametrization tests down with it. Route imports through this shim:
with hypothesis installed the real objects are re-exported; without it the
`@given` tests turn into pytest skips and strategy expressions evaluate to
inert placeholders.
"""

try:  # pragma: no cover - exercised only when hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Absorbs any strategy construction (st.integers(...), .map(...))."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    strategies = _Strategy()
