"""Snapshot test for the CLI's backend capability header.

`fftsweep telemetry` and `fftsweep govern` print the active backend's
`BackendCaps::summary()` line before their tables, so every report names
the backend that produced it (DESIGN.md §4g). This pins the header's
shape from the outside — the rust-side contract suite checks the same
string via `summary()`, this checks the operator actually sees it.

Runs only when a release binary exists (the python CI job has no cargo);
`cd rust && cargo build --release` first.
"""

import re
import subprocess
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
BINARY = REPO / "rust" / "target" / "release" / "fftsweep"

HEADER_RE = re.compile(
    r"^backend (?P<name>[a-z0-9-]+): kinds \[[a-z,]+\], "
    r"n \d+\.\.=(?:\d+|inf)( \(pow2 only\))?, "
    r"precisions \[[a-z0-9,]+\], "
    r"locked-clocks (?:true|false), nvml (?:true|false), l2 \d+ KiB$",
    re.MULTILINE,
)


def run_cli(*args: str) -> str:
    out = subprocess.run(
        [str(BINARY), *args],
        capture_output=True,
        text=True,
        timeout=300,
        check=True,
    )
    return out.stdout


@pytest.fixture(autouse=True)
def require_binary():
    if not BINARY.exists():
        pytest.skip("rust release binary not built (cargo build --release)")


@pytest.mark.parametrize(
    "argv",
    [
        ("telemetry", "--jobs", "16", "--lengths", "1024"),
        ("govern", "--quick"),
    ],
    ids=["telemetry", "govern"],
)
def test_header_names_backend_and_envelope(argv):
    stdout = run_cli(*argv)
    m = HEADER_RE.search(stdout)
    assert m, f"no capability header in output:\n{stdout[:2000]}"
    # The default build resolves --backend default to the sim oracle.
    assert m.group("name") == "sim"
    # The header precedes the report body, not trails it.
    body = stdout.index(m.group(0))
    assert body == stdout.find("backend "), "header must lead the report"


def test_cufft_profile_header_is_fft_only():
    stdout = run_cli(
        "telemetry", "--backend", "cufft-profile", "--jobs", "16", "--lengths", "1024"
    )
    m = HEADER_RE.search(stdout)
    assert m, f"no capability header in output:\n{stdout[:2000]}"
    assert m.group("name") == "cufft-profile"
    assert "kinds [fft]" in m.group(0)


def test_unknown_backend_is_refused_listing_compiled_names():
    proc = subprocess.run(
        [str(BINARY), "telemetry", "--backend", "warp-drive"],
        capture_output=True,
        text=True,
        timeout=60,
    )
    assert proc.returncode != 0
    err = proc.stderr
    assert "unknown backend" in err
    assert "cufft-profile" in err
