"""L2 correctness: Bluestein, pipeline composition, pulsar detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import pipeline_ref


def _rand(rng, b, n, dtype=jnp.float32):
    return (jnp.asarray(rng.standard_normal((b, n)), dtype),
            jnp.asarray(rng.standard_normal((b, n)), dtype))


@pytest.mark.parametrize("n", [3, 5, 12, 100, 139, 1000, 19321 // 139])
def test_bluestein_matches_jnp_fft(n):
    rng = np.random.default_rng(n)
    re, im = _rand(rng, 2, n)
    br, bi = model.bluestein_fft(re, im)
    ref = jnp.fft.fft((re + 1j * im).astype(jnp.complex128), axis=-1)
    scale = float(jnp.max(jnp.abs(ref))) or 1.0
    assert float(jnp.max(jnp.abs(br - jnp.real(ref)))) / scale < 5e-5
    assert float(jnp.max(jnp.abs(bi - jnp.imag(ref)))) / scale < 5e-5


def test_bluestein_pow2_dispatches_to_stockham():
    rng = np.random.default_rng(1)
    re, im = _rand(rng, 2, 64)
    br, bi = model.bluestein_fft(re, im)
    sr, si = model.fft_batch(re, im)
    np.testing.assert_array_equal(np.asarray(br), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(si))


def test_bluestein_inverse_roundtrip():
    rng = np.random.default_rng(2)
    re, im = _rand(rng, 2, 100)
    fr, fi = model.bluestein_fft(re, im)
    br, bi = model.bluestein_fft(fr, fi, inverse=True)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=1e-4)


@pytest.mark.parametrize("h", [2, 8, 32])
def test_pipeline_matches_ref(h):
    rng = np.random.default_rng(h)
    re, im = _rand(rng, 4, 4096)
    hs, mean, std = model.pulsar_pipeline(re, im, harmonics=h)
    rhs, rmean, rstd = pipeline_ref(re, im, harmonics=h)
    assert hs.shape == (4, 4096 // h)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(rhs), rtol=1e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(rmean), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(std), np.asarray(rstd), rtol=1e-3)


def test_pipeline_detects_injected_pulsar():
    """End-to-end science check: a weak periodic comb, invisible in a single
    spectrum bin, is recovered by the harmonic sum (the paper's section 5.3
    use case)."""
    rng = np.random.default_rng(42)
    n, h, k0 = 16384, 8, 321
    t = np.arange(n)
    sig = np.zeros(n)
    for m in range(1, h + 1):
        sig += 0.08 * np.cos(2 * np.pi * (k0 * m) * t / n + 0.3 * m)
    noise = rng.standard_normal(n)
    re = jnp.asarray((sig + noise)[None, :], jnp.float32)
    im = jnp.zeros_like(re)
    hs, _, _ = model.pulsar_pipeline(re, im, harmonics=h)
    hs = np.asarray(hs)[0]
    # Exclude the noisy DC/low bins from the search, as a real pipeline does.
    cand = int(np.argmax(hs[8:])) + 8
    assert cand == k0, f"pulsar found at {cand}, injected at {k0}"
    # Detection significance: the peak should stand far above the noise floor.
    rest = np.delete(hs[8:], cand - 8)
    z = (hs[cand] - rest.mean()) / rest.std()
    assert z > 8.0


def test_spectrum_only_is_fft_power():
    rng = np.random.default_rng(9)
    re, im = _rand(rng, 4, 1024)
    p = model.spectrum_only(re, im)
    x = (re + 1j * im).astype(jnp.complex128)
    ref = jnp.abs(jnp.fft.fft(x, axis=-1)) ** 2
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref), rtol=1e-3)


def test_catalogue_is_well_formed():
    entries = model.artifact_catalogue()
    names = [e[0] for e in entries]
    assert len(names) == len(set(names)), "duplicate artifact names"
    assert any(e[4]["kind"] == "fft" for e in entries)
    assert any(e[4]["kind"] == "pipeline" for e in entries)
    for name, fn, specs, n_out, meta in entries:
        assert n_out >= 1
        assert meta["n"] * meta["batch"] > 0
        for s in specs:
            assert s.shape == (meta["batch"], meta["n"])
    # pipeline harmonic configs must match Table 4 of the paper
    hs = sorted(e[4]["harmonics"] for e in entries if e[4]["kind"] == "pipeline")
    assert hs == [2, 4, 8, 16, 32]


@pytest.mark.parametrize("r,c", [(8, 8), (32, 64), (64, 16)])
def test_fft2d_matches_jnp(r, c):
    rng = np.random.default_rng(r * c)
    re = jnp.asarray(rng.standard_normal((2, r, c)), jnp.float32)
    im = jnp.asarray(rng.standard_normal((2, r, c)), jnp.float32)
    fr, fi = model.fft2d(re, im)
    ref = jnp.fft.fft2((re + 1j * im).astype(jnp.complex128))
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(fr - jnp.real(ref)))) / scale < 2e-5
    assert float(jnp.max(jnp.abs(fi - jnp.imag(ref)))) / scale < 2e-5


def test_fft2d_inverse_roundtrip():
    rng = np.random.default_rng(77)
    re = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    im = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    fr, fi = model.fft2d(re, im)
    br, bi = model.fft2d(fr, fi, inverse=True)
    np.testing.assert_allclose(np.asarray(br), np.asarray(re), atol=2e-4)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(im), atol=2e-4)
