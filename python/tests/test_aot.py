"""AOT path: HLO text emission, manifest shape, determinism."""

import os

import jax
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    paths = aot.emit(str(out), only="fft_f32_n256", verbose=False)
    return out, paths


def test_emit_writes_hlo_text(emitted):
    out, paths = emitted
    assert len(paths) == 1
    text = open(paths[0]).read()
    # HLO text module, not a serialized proto
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # two input planes (re, im)
    assert "parameter(0)" in text and "parameter(1)" in text
    # output is a tuple (return_tuple=True contract with the rust loader)
    assert "tuple(" in text or "ROOT" in text


def test_manifest_row_per_artifact(emitted):
    out, _ = emitted
    lines = open(os.path.join(out, "manifest.tsv")).read().strip().splitlines()
    header, rows = lines[0], lines[1:]
    assert header.split("\t")[0] == "name"
    assert len(rows) == 1
    cols = rows[0].split("\t")
    assert cols[0] == "fft_f32_n256_b256"
    assert cols[2] == "fft"
    assert cols[3] == "256" and cols[4] == "256"
    assert cols[7] == "f32:256x256;f32:256x256"
    assert cols[8] == "2"


def test_emission_is_deterministic(tmp_path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    aot.emit(str(a), only="fft_f32_n1024", verbose=False)
    aot.emit(str(b), only="fft_f32_n1024", verbose=False)
    ta = open(a / "fft_f32_n1024_b64.hlo.txt").read()
    tb = open(b / "fft_f32_n1024_b64.hlo.txt").read()
    assert ta == tb


def test_catalogue_covers_paper_table4_pipeline_configs():
    kinds = {}
    for name, _, _, _, meta in model.artifact_catalogue():
        kinds.setdefault(meta["kind"], []).append(name)
    assert len(kinds["pipeline"]) == 5  # h in {2,4,8,16,32} — Table 4 rows
    assert len(kinds["fft"]) >= 4


def test_no_serialized_proto_output(emitted):
    """Guard against regressing to .serialize() (xla_extension 0.5.1 rejects
    jax>=0.5 64-bit-id protos; text is the only safe interchange)."""
    out, paths = emitted
    for p in paths:
        with open(p, "rb") as f:
            head = f.read(9)
        assert head == b"HloModule"
