"""L1 correctness: power-spectrum and normalization kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import spectrum as kspec
from compile.kernels.ref import normalize_spectrum_ref, power_spectrum_ref


def _rand(rng, b, n, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal((b, n)), dtype)


@pytest.mark.parametrize("b,n", [(1, 8), (4, 256), (16, 1024), (5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_power_spectrum_matches_ref(b, n, dtype):
    rng = np.random.default_rng(b * n)
    re, im = _rand(rng, b, n, dtype), _rand(rng, b, n, dtype)
    out = kspec.power_spectrum(re, im)
    ref = power_spectrum_ref(re, im)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_power_spectrum_nonnegative():
    rng = np.random.default_rng(3)
    re, im = _rand(rng, 8, 128), _rand(rng, 8, 128)
    out = np.asarray(kspec.power_spectrum(re, im))
    assert (out >= 0).all()


def test_power_spectrum_zero_input():
    z = jnp.zeros((4, 32), jnp.float32)
    np.testing.assert_array_equal(np.asarray(kspec.power_spectrum(z, z)), 0.0)


@pytest.mark.parametrize("b,n", [(1, 16), (4, 512), (16, 4096)])
def test_normalize_matches_ref(b, n):
    rng = np.random.default_rng(b + n)
    p = jnp.abs(_rand(rng, b, n)) + 0.1
    out, mean, std = kspec.normalize_spectrum(p)
    rout, rmean, rstd = normalize_spectrum_ref(p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(rmean), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(std), np.asarray(rstd), rtol=1e-4)


def test_normalize_output_moments():
    rng = np.random.default_rng(5)
    p = jnp.abs(_rand(rng, 8, 2048)) * 3.0 + 1.0
    out, _, _ = kspec.normalize_spectrum(p)
    out = np.asarray(out, np.float64)
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, rtol=1e-3)


def test_normalize_constant_row_is_safe():
    p = jnp.full((2, 64), 7.5, jnp.float32)
    out, mean, std = kspec.normalize_spectrum(p)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mean), 7.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(std), 0.0, atol=1e-6)


def test_rejects_bad_rank():
    p = jnp.zeros((2, 3, 4), jnp.float32)
    with pytest.raises(ValueError):
        kspec.normalize_spectrum(p)
    with pytest.raises(ValueError):
        kspec.power_spectrum(p, p)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=13),
    log_n=st.integers(min_value=2, max_value=10),
    seed=st.integers(min_value=0, max_value=999),
)
def test_hypothesis_power_and_normalize(b, log_n, seed):
    n = 1 << log_n
    rng = np.random.default_rng(seed)
    re, im = _rand(rng, b, n), _rand(rng, b, n)
    p = kspec.power_spectrum(re, im)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(power_spectrum_ref(re, im)), rtol=1e-5
    )
    out, mean, std = kspec.normalize_spectrum(p)
    rout, rmean, rstd = normalize_spectrum_ref(p)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(rmean), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(std), np.asarray(rstd), rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout), rtol=1e-3, atol=1e-3)
