//! Bench: Table 4 + Fig 19 — the pulsar-pipeline energy-efficiency
//! increase per harmonic configuration with NVML clock bracketing.

mod common;

use fftsweep::pipeline::{run_pipeline, table4};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::bench::{black_box, Bench};
use fftsweep::util::table::{fnum, Table};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("table4_fig19").with_iters(1, 10);
    let gpu = tesla_v100();

    let mut rows = None;
    b.run("table4_v100_n5e5", || {
        rows = Some(table4(&gpu, 500_000, 945.0));
    });
    let rows = rows.unwrap();

    let paper = [
        (2u64, 60.85, 1.291),
        (4, 58.56, 1.290),
        (8, 55.92, 1.267),
        (16, 53.73, 1.260),
        (32, 51.34, 1.240),
    ];
    let mut t = Table::new(
        "Table 4: pipeline efficiency increase (measured vs paper)",
        &["harmonics", "fft_time_pct", "paper_pct", "eff_increase", "paper_increase"],
    );
    for (r, (h, pf, pe)) in rows.iter().zip(paper) {
        assert_eq!(r.harmonics, h);
        t.push_row(vec![
            r.harmonics.to_string(),
            fnum(r.fft_time_pct, 2),
            fnum(pf, 2),
            fnum(r.eff_increase, 3),
            fnum(pe, 3),
        ]);
    }
    t.write_csv(&out.join("table4.csv")).unwrap();
    println!("\n{}", t.to_ascii());

    // Fig 19 trace generation speed (per pipeline run).
    b.run("fig19_pipeline_run", || {
        black_box(run_pipeline(&gpu, 500_000, 8, Some(945.0)));
    });
    let run = run_pipeline(&gpu, 500_000, 8, Some(945.0));
    let mut fig19 = Table::new(
        "Fig 19: pipeline power/clock trace",
        &["t_ms", "stage", "clock_mhz", "power_w"],
    );
    let mut tt = 0.0;
    for s in &run.stages {
        fig19.push_row(vec![
            fnum(tt * 1e3, 3),
            s.name.to_string(),
            fnum(s.clock_mhz, 0),
            fnum(s.energy_j / s.time_s.max(1e-12), 1),
        ]);
        tt += s.time_s;
    }
    fig19.write_csv(&out.join("fig19.csv")).unwrap();
    println!("{}", b.summary());
}
