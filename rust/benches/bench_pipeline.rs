//! Bench: Table 4 + Fig 19 — the pulsar-pipeline energy-efficiency
//! increase per clock governor, with NVML clock bracketing.

mod common;

use fftsweep::governor::GovernorKind;
use fftsweep::pipeline::{run_pipeline, table4};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::bench::{black_box, Bench};
use fftsweep::util::table::{fnum, Table};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("table4_fig19").with_iters(1, 10);
    let gpu = tesla_v100();

    let mut rows = None;
    b.run("table4_v100_n5e5", || {
        rows = Some(table4(&gpu, 500_000, &GovernorKind::FixedClock(945.0)));
    });
    let rows = rows.unwrap();

    let paper = [
        (2u64, 60.85, 1.291),
        (4, 58.56, 1.290),
        (8, 55.92, 1.267),
        (16, 53.73, 1.260),
        (32, 51.34, 1.240),
    ];
    let mut t = Table::new(
        "Table 4: pipeline efficiency increase (measured vs paper)",
        &["harmonics", "fft_time_pct", "paper_pct", "eff_increase", "paper_increase"],
    );
    for (r, (h, pf, pe)) in rows.iter().zip(paper) {
        assert_eq!(r.harmonics, h);
        t.push_row(vec![
            r.harmonics.to_string(),
            fnum(r.fft_time_pct, 2),
            fnum(pf, 2),
            fnum(r.eff_increase, 3),
            fnum(pe, 3),
        ]);
    }
    t.write_csv(&out.join("table4.csv")).unwrap();
    println!("\n{}", t.to_ascii());

    // Every governor through the full pipeline at h=8: the policy menu's
    // relative cost, plus the per-run latency of the governed runner.
    let mut menu = Table::new(
        "Pipeline energy by governor (V100, N=5e5, h=8, vs all-boost)",
        &["governor", "energy_j", "saving_pct", "time_s"],
    );
    let mut boost_gov = GovernorKind::FixedBoost.make();
    let baseline = run_pipeline(&gpu, 500_000, 8, &mut *boost_gov);
    for kind in GovernorKind::all(945.0) {
        let mut gov = kind.make();
        let label = kind.label();
        let mut last = None;
        b.run(&format!("pipeline_h8_{label}"), || {
            last = Some(run_pipeline(&gpu, 500_000, 8, &mut *gov));
        });
        let run = last.unwrap();
        menu.push_row(vec![
            label,
            fnum(run.total_energy_j(), 1),
            fnum((1.0 - run.total_energy_j() / baseline.total_energy_j()) * 100.0, 1),
            fnum(run.total_time_s(), 4),
        ]);
    }
    menu.write_csv(&out.join("pipeline_governors.csv")).unwrap();
    println!("{}", menu.to_ascii());

    // Fig 19 trace generation speed (per governed pipeline run).
    let mut fixed = GovernorKind::FixedClock(945.0).make();
    b.run("fig19_pipeline_run", || {
        black_box(run_pipeline(&gpu, 500_000, 8, &mut *fixed));
    });
    let run = run_pipeline(&gpu, 500_000, 8, &mut *fixed);
    let mut fig19 = Table::new(
        "Fig 19: pipeline power/clock trace",
        &["t_ms", "stage", "clock_mhz", "power_w"],
    );
    let mut tt = 0.0;
    for s in &run.stages {
        fig19.push_row(vec![
            fnum(tt * 1e3, 3),
            s.name.to_string(),
            fnum(s.clock_mhz, 0),
            fnum(s.energy_j / s.time_s.max(1e-12), 1),
        ]);
        tt += s.time_s;
    }
    fig19.write_csv(&out.join("fig19.csv")).unwrap();
    println!("{}", b.summary());
}
