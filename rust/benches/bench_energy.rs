//! Bench: Figs 7/8 — energy-per-batch and averaged-power curves vs clock,
//! plus the sensor-sampling + energy-integration hot path.

mod common;

use fftsweep::analysis::figures;
use fftsweep::harness::measure::{measure_point, Protocol};
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::gpu::{all_gpus, jetson_nano, tesla_v100};
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::bench::{black_box, Bench};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig7_8").with_iters(1, 8);

    let cfg = common::bench_cfg();
    let mut fig7 = None;
    b.run("fig7_energy_n16384_5gpus", || {
        fig7 = Some(figures::figure7(&all_gpus(), &cfg));
    });
    fig7.unwrap().write_csv(&out.join("fig7.csv")).unwrap();

    for gpu in [tesla_v100(), jetson_nano()] {
        let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
        let tag = gpu.name.to_lowercase().replace(' ', "_");
        figures::figure8(&gpu, &sweep)
            .write_csv(&out.join(format!("fig8_{tag}.csv")))
            .unwrap();
    }

    // Micro: one full measured point (timeline + sensor + merge + eq. 3).
    let g = tesla_v100();
    let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
    let proto = Protocol::default();
    b.run("measure_point_n16384", || {
        black_box(measure_point(&g, &w, 945.0, &proto));
    });

    println!("\n{}", b.summary());
}
