//! Bench: Figs 2/3 — the measurement-log excerpt with kernel localization
//! (V100 + Titan V, including the driver-cap detection) and the
//! measurement-error surface.

mod common;

use fftsweep::analysis::figures;
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::gpu::{jetson_nano, tesla_v100, titan_v};
use fftsweep::types::Precision;
use fftsweep::util::bench::Bench;
use fftsweep::util::stats;

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig2_3").with_iters(1, 10);

    // Fig 2: V100 @ 1020 MHz and Titan V @ 1912 MHz (capped to 1335).
    let mut logs = None;
    b.run("fig2_logs", || {
        let v = figures::figure2(&tesla_v100(), 16384, 1020.0, 0xF16);
        let t = figures::figure2(&titan_v(), 16384, 1912.0, 0xF16);
        logs = Some((v, t));
    });
    let ((v_table, _), (t_table, _)) = logs.unwrap();
    v_table.write_csv(&out.join("fig2_v100.csv")).unwrap();
    t_table.write_csv(&out.join("fig2_titanv.csv")).unwrap();
    // Titan V log must report the capped clock, not the requested one.
    assert!(t_table.rows.iter().all(|r| r[2] == "1335"));

    // Fig 3: error surfaces for V100 + Jetson.
    let cfg = common::bench_cfg();
    for gpu in [tesla_v100(), jetson_nano()] {
        let tag = gpu.name.to_lowercase().replace(' ', "_");
        let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
        let t = figures::figure3(&gpu, &sweep);
        // paper: errors ~5% on discrete cards, <=15% on the Nano
        let errs: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        let med = stats::median(&errs);
        println!("  {}: median measurement error {med:.2}%", gpu.name);
        if gpu.name == "Jetson Nano" {
            assert!(med > 3.0 && med < 20.0, "nano median {med}");
        } else {
            assert!(med > 0.5 && med < 10.0, "v100 median {med}");
        }
        t.write_csv(&out.join(format!("fig3_{tag}.csv"))).unwrap();
    }
    println!("\n{}", b.summary());
}
