//! Bench: the L3 hot path — PJRT execute latency per artifact, literal
//! construction, end-to-end coordinator throughput (jobs/s), and batcher
//! packing. These are the paper-independent serving numbers EXPERIMENTS.md
//! §Perf tracks. Skips gracefully when artifacts are absent.

mod common;

use std::sync::Arc;
use std::time::Duration;

use fftsweep::coordinator::{Engine, EngineConfig};
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::{Manifest, Runtime};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::bench::{black_box, Bench};
use fftsweep::util::rng::Rng;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("bench_runtime: no artifacts (run `make artifacts`); skipping");
        return;
    }
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    let mut b = Bench::new("runtime").with_iters(3, 30);

    // Compile cost (first load) vs cache hit.
    let t0 = std::time::Instant::now();
    let m1024 = rt.load("fft_f32_n1024_b64").expect("load");
    println!("cold compile fft_f32_n1024_b64: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    b.run("load_cached", || {
        black_box(rt.load("fft_f32_n1024_b64").unwrap());
    });

    // Literal construction + execute, per artifact size.
    let mut rng = Rng::new(1);
    for name in ["fft_f32_n256_b256", "fft_f32_n1024_b64", "fft_f32_n4096_b16", "fft_f32_n16384_b4"] {
        let module = rt.load(name).expect("load");
        let total = (module.meta.batch * module.meta.n) as usize;
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        b.run_with_elements(&format!("literals_{name}"), Some(total as u64), &mut || {
            black_box(module.literals_f32(&[&re, &im]).unwrap());
        });
        b.run_with_elements(&format!("execute_{name}"), Some(total as u64), &mut || {
            black_box(module.run_f32(&[&re, &im]).unwrap());
        });
    }

    // Pipeline artifact end to end.
    let pipe = rt.load("pipeline_n16384_h8").expect("load");
    let total = (pipe.meta.batch * pipe.meta.n) as usize;
    let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
    let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
    b.run_with_elements("execute_pipeline_h8", Some(total as u64), &mut || {
        black_box(pipe.run_f32(&[&re, &im]).unwrap());
    });
    drop((m1024, pipe));

    // Coordinator throughput: 256 jobs of N=1024 through the batcher.
    let engine = Engine::start_single(
        rt.clone(),
        tesla_v100(),
        GovernorKind::FixedClock(945.0),
        EngineConfig::default(),
    )
    .expect("engine");
    let n = 1024usize;
    let payloads: Vec<(Vec<f32>, Vec<f32>)> = (0..256)
        .map(|_| {
            (
                (0..n).map(|_| rng.gauss() as f32).collect(),
                (0..n).map(|_| rng.gauss() as f32).collect(),
            )
        })
        .collect();
    let mut coord = Bench::new("coordinator").with_iters(1, 5);
    coord.run_with_elements("serve_256_jobs_n1024", Some(256 * n as u64), &mut || {
        let rxs: Vec<_> = payloads
            .iter()
            .map(|(re, im)| engine.submit(re.clone(), im.clone()).unwrap())
            .collect();
        assert!(engine.drain(Duration::from_secs(60)).complete, "bench drain timed out");
        for rx in rxs {
            black_box(rx.recv().unwrap().unwrap());
        }
    });
    println!("engine metrics: {}", engine.metrics.summary());
    println!("{}", engine.shutdown());

    println!("\n{}", b.summary());
    println!("{}", coord.summary());
}
