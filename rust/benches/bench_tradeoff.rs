//! Bench: Figs 17/18 — the efficiency-increase vs time-increase trade-off
//! heatmaps for the V100 and the Jetson Nano.

mod common;

use fftsweep::analysis::figures;
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::gpu::{jetson_nano, tesla_v100};
use fftsweep::types::Precision;
use fftsweep::util::bench::Bench;

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig17_18").with_iters(0, 1);
    let cfg = common::bench_cfg();

    for (gpu, fig) in [(tesla_v100(), 17), (jetson_nano(), 18)] {
        b.run(&format!("fig{fig}_{}", gpu.name.to_lowercase().replace(' ', "_")), || {
            let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
            let t = figures::figure17_18(&gpu, &sweep);
            t.write_csv(&out.join(format!("fig{fig}.csv"))).unwrap();
            // sanity: the non-linear trade-off the paper highlights —
            // some cell gains >20% efficiency for <10% time cost
            let good = t.rows.iter().any(|r| {
                let eff: f64 = r[2].parse().unwrap_or(0.0);
                let dt: f64 = r[3].parse().unwrap_or(100.0);
                eff > 20.0 && dt < 10.0
            });
            assert!(good, "{}: no cheap-efficiency cell found", gpu.name);
        });
    }
    println!("\n{}", b.summary());
}
