//! Bench: the planned-FFT serving engine, end to end — the first point on
//! the repo's committed perf trajectory (`BENCH_serving.json`).
//!
//! The measurements:
//!   1. pre-PR sim path (per-row `Vec<C64>` + per-butterfly trig via
//!      `dsp::fft`) in rows/s — the baseline the planner replaces,
//!   2. planned path (`dsp::planner`, cached twiddles, reused scratch,
//!      row-parallel) on the identical workload in rows/s,
//!   2b. the opened workload shapes: mixed-radix non-pow2 (n=1536),
//!      Bluestein prime (n=1009) and real-input rFFT (n=4096) rows/s,
//!   2c. native precision & persistent pool (schema 4): f32-native vs
//!      f64-convert rows/s on the n=1024 workload (the f64-convert leg
//!      reproduces the pre-PR cost structure: widen, run f64 kernels,
//!      narrow), a plane-inspection proof that the f32 path allocates no
//!      f64 planes, and pool vs scoped-spawn batches/s at the standard
//!      device batch (the smallest batch the serial cutoff parallelizes),
//!   3. fleet end-to-end throughput: jobs/s through a 2-card engine on the
//!      n=1024 workload (open loop), plus an allocation-frequency proxy
//!      from a counting global allocator,
//!   4. closed-loop `execute()` latency (p50/p99 ms),
//!   4b. large-N tier (schema 5): the cache-blocked four-step path vs a
//!      monolithic plan at n=2^18 in rows/s, their pass counts and
//!      twiddle-table bytes (the schedule-inspection numbers the gate
//!      pins), and overlap-save conv jobs/s end to end through the fleet,
//!   5. power telemetry: the same seeded trace served uncapped (boost)
//!      vs under a `--power-budget-w` cap at 70% of the measured draw —
//!      simulated energy/job, simulated p99 and the rolling 1 s fleet
//!      draw land in the JSON `power` section the CI gate validates,
//!   6. robustness (schema 6): a 3-card fleet with one card fail-stopped
//!      a few batches in, offered 2x the fault-free job count — goodput,
//!      shed rate, lost-job count (must be zero) and simulated p99 vs an
//!      identical fault-free control leg, in the JSON `robustness`
//!      section the CI gate pins,
//!   7. observability (schema 7): the identical open-loop serve measured
//!      with request tracing off, then on — the tracing-overhead budget
//!      (<5%) the CI gate pins — plus the cost of one full histogram
//!      summary readout, in the JSON `observability` section,
//!   8. overload (schema 8): bursty mixed-class arrivals offered at
//!      1x/2x/4x/8x the fleet's measured capacity through a bounded
//!      fleet — per-leg goodput, realtime-class goodput and p99, shed
//!      rate and the brownout peak, in the JSON `overload` section. The
//!      QoS contract the gate pins: at 4x offered load realtime goodput
//!      holds >= 0.95x the 1x-load throughput and every refused job is
//!      a typed shed (zero untyped drops).
//!
//! All latency percentiles here come from the serving stack's one
//! histogram implementation (`telemetry::histogram::LogHistogram`), not
//! a sort — the same readout the tracer and the exporters use.
//!
//! Regenerate with:
//!   cd rust && cargo bench --bench bench_serving            # full
//!   cd rust && cargo bench --bench bench_serving -- --quick # CI smoke
//! The JSON lands in ./BENCH_serving.json (override: --out <path>); the
//! committed trajectory baseline lives at the repo root and is gated by
//! the `bench-smoke` CI job (scripts/check_bench.py).

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use fftsweep::analysis::telemetry as telemetry_analysis;
use fftsweep::analysis::trace::load_spans;
use fftsweep::coordinator::admission::TenantClass;
use fftsweep::coordinator::health::{HealthPolicy, HealthState};
use fftsweep::coordinator::{CardConfig, CoordError, Engine, EngineConfig, RetryPolicy};
use fftsweep::dsp;
use fftsweep::dsp::planner::{self, Direction};
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::default_backend;
use fftsweep::sim::fault::{ArrivalKind, ArrivalPlan, FaultPlan};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::telemetry::{LogHistogram, SpanOutcome, TraceConfig};
use fftsweep::util::bench::black_box;
use fftsweep::util::json::Json;
use fftsweep::util::rng::Rng;

/// Counting allocator: the "allocs-frequency proxy". Counts every alloc and
/// realloc so a serving phase can report allocations per job served.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const N: usize = 1024;
const DEVICE_BATCH: usize = 64;
const CARDS: usize = 2;

fn rand_planes(total: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    (
        (0..total).map(|_| rng.gauss() as f32).collect(),
        (0..total).map(|_| rng.gauss() as f32).collect(),
    )
}

/// The pre-PR sim execution path, preserved here as the comparison
/// baseline: per row, build a `Vec<C64>` and call the trig-recomputing
/// oracle (exactly what `runtime::sim_client::row_fft` used to do).
fn naive_rows(re: &[f32], im: &[f32], rows: usize) -> f64 {
    let mut sink = 0.0f64;
    for r in 0..rows {
        let off = r * N;
        let x: Vec<dsp::C64> = (0..N)
            .map(|i| dsp::C64::new(re[off + i] as f64, im[off + i] as f64))
            .collect();
        let y = dsp::fft(&x);
        sink += y[0].re;
    }
    sink
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.to_string())
        .unwrap_or_else(|| "BENCH_serving.json".to_string());

    let dft_rows = if quick { 640 } else { 4096 };
    let fleet_jobs = if quick { 512 } else { 4096 };
    let latency_iters = if quick { 50 } else { 200 };

    let mut rng = Rng::new(0xf00d);
    let (re, im) = rand_planes(dft_rows * N, &mut rng);

    // 1. Pre-PR path: per-row allocation + per-butterfly trig.
    let t0 = Instant::now();
    black_box(naive_rows(&re, &im, dft_rows));
    let naive_s = t0.elapsed().as_secs_f64();
    let naive_rows_per_s = dft_rows as f64 / naive_s;

    // 2. Planned path, identical workload (warm the plan cache first so
    //    this measures steady state, as a serving loop sees it). Measured
    //    twice: serial isolates the planning win (twiddle cache + scratch
    //    reuse, apples-to-apples vs the serial naive path), then the
    //    row-parallel entry point the serving engine actually calls.
    let plan = planner::plan_for(N);
    let mut out_re = vec![0.0f32; dft_rows * N];
    let mut out_im = vec![0.0f32; dft_rows * N];
    planner::run_rows(&plan, Direction::Forward, &re, &im, DEVICE_BATCH, &mut out_re, &mut out_im);

    let mut scratch = planner::FftScratch::new();
    let t0 = Instant::now();
    plan.run_rows_serial(
        Direction::Forward,
        &re,
        &im,
        dft_rows,
        &mut out_re,
        &mut out_im,
        &mut scratch,
    );
    let serial_s = t0.elapsed().as_secs_f64();
    black_box(&out_re);
    let planned_serial_rows_per_s = dft_rows as f64 / serial_s;
    let serial_speedup = planned_serial_rows_per_s / naive_rows_per_s;

    let t0 = Instant::now();
    planner::run_rows(&plan, Direction::Forward, &re, &im, dft_rows, &mut out_re, &mut out_im);
    let planned_s = t0.elapsed().as_secs_f64();
    black_box(&out_re);
    let planned_rows_per_s = dft_rows as f64 / planned_s;
    let speedup = planned_rows_per_s / naive_rows_per_s;

    println!(
        "planner: naive {naive_rows_per_s:.0} rows/s, planned serial \
         {planned_serial_rows_per_s:.0} rows/s ({serial_speedup:.1}x), planned parallel \
         {planned_rows_per_s:.0} rows/s ({speedup:.1}x, n={N})"
    );

    // 2b. The opened workload shapes, through the same planned row-parallel
    // path: a smooth non-pow2 length (mixed-radix 2/3/5), a prime length
    // (Bluestein chirp-z) and the real-input transform.
    let cplx_rows_per_s = |n: usize, rows: usize, rng: &mut Rng| -> f64 {
        let plan = planner::plan_for(n);
        let (re, im) = rand_planes(rows * n, rng);
        let mut o_re = vec![0.0f32; rows * n];
        let mut o_im = vec![0.0f32; rows * n];
        // warm plan + scratch, then measure steady state
        planner::run_rows(&plan, Direction::Forward, &re, &im, rows, &mut o_re, &mut o_im);
        let t0 = Instant::now();
        planner::run_rows(&plan, Direction::Forward, &re, &im, rows, &mut o_re, &mut o_im);
        let dt = t0.elapsed().as_secs_f64();
        black_box(&o_re);
        rows as f64 / dt
    };
    const N_NONPOW2: usize = 1536; // 2^9 · 3
    const N_BLUESTEIN: usize = 1009; // prime
    const N_RFFT: usize = 4096;
    let nonpow2_rows = if quick { 512 } else { 2048 };
    let nonpow2_rows_per_s = cplx_rows_per_s(N_NONPOW2, nonpow2_rows, &mut rng);
    let bluestein_rows_per_s = cplx_rows_per_s(N_BLUESTEIN, nonpow2_rows, &mut rng);

    let rfft_rows = if quick { 256 } else { 1024 };
    let rplan = planner::rfft_plan_for(N_RFFT);
    let o_len = rplan.out_len();
    let (rfft_in, _) = rand_planes(rfft_rows * N_RFFT, &mut rng);
    let mut r_re = vec![0.0f32; rfft_rows * o_len];
    let mut r_im = vec![0.0f32; rfft_rows * o_len];
    planner::run_rfft_rows(&rplan, &rfft_in, rfft_rows, &mut r_re, &mut r_im);
    let t0 = Instant::now();
    planner::run_rfft_rows(&rplan, &rfft_in, rfft_rows, &mut r_re, &mut r_im);
    let rfft_s = t0.elapsed().as_secs_f64();
    black_box(&r_re);
    let rfft_rows_per_s = rfft_rows as f64 / rfft_s;
    let complex_4096_rows_per_s = cplx_rows_per_s(N_RFFT, rfft_rows, &mut rng);
    let rfft_vs_complex = rfft_rows_per_s / complex_4096_rows_per_s;

    println!(
        "off-grid: n={N_NONPOW2} mixed-radix {nonpow2_rows_per_s:.0} rows/s, n={N_BLUESTEIN} \
         bluestein {bluestein_rows_per_s:.0} rows/s, n={N_RFFT} rfft {rfft_rows_per_s:.0} rows/s \
         ({rfft_vs_complex:.2}x vs complex)"
    );

    // 2c. Native precision: f32-native vs f64-convert rows/s on the
    // standard workload. The f64-convert leg reproduces the pre-PR cost
    // structure exactly — widen both f32 input planes to f64, run the f64
    // kernels, narrow the outputs back — so the delta is the tentpole's
    // win (half the plane traffic + f32 SIMD width), measured honestly
    // with the conversion cost inside the timed region.
    let t0 = Instant::now();
    planner::run_rows(&plan, Direction::Forward, &re, &im, dft_rows, &mut out_re, &mut out_im);
    let f32_native_rows_per_s = dft_rows as f64 / t0.elapsed().as_secs_f64();
    black_box(&out_re);

    let mut cvt_re = vec![0.0f64; dft_rows * N];
    let mut cvt_im = vec![0.0f64; dft_rows * N];
    let mut cvt_out_re = vec![0.0f64; dft_rows * N];
    let mut cvt_out_im = vec![0.0f64; dft_rows * N];
    // warm the f64 planes/scratch so both legs measure steady state
    planner::run_rows(&plan, Direction::Forward, &cvt_re, &cvt_im, DEVICE_BATCH, &mut cvt_out_re, &mut cvt_out_im);
    let t0 = Instant::now();
    for (dst, src) in cvt_re.iter_mut().zip(&re) {
        *dst = *src as f64;
    }
    for (dst, src) in cvt_im.iter_mut().zip(&im) {
        *dst = *src as f64;
    }
    planner::run_rows(&plan, Direction::Forward, &cvt_re, &cvt_im, dft_rows, &mut cvt_out_re, &mut cvt_out_im);
    for (dst, src) in out_re.iter_mut().zip(&cvt_out_re) {
        *dst = *src as f32;
    }
    for (dst, src) in out_im.iter_mut().zip(&cvt_out_im) {
        *dst = *src as f32;
    }
    let f64_convert_rows_per_s = dft_rows as f64 / t0.elapsed().as_secs_f64();
    black_box(&out_re);
    let f32_vs_f64_convert = f32_native_rows_per_s / f64_convert_rows_per_s;

    // Plane inspection: a fresh scratch serving only f32 work must never
    // allocate an f64 plane — the structural no-conversion proof the CI
    // gate checks (any nonzero value here fails the bench gate).
    let f32_f64_plane_bytes = {
        let mut inspect = planner::FftScratch::new();
        plan.run_rows_serial(
            Direction::Forward,
            &re,
            &im,
            DEVICE_BATCH,
            &mut out_re,
            &mut out_im,
            &mut inspect,
        );
        inspect.capacity_of::<f64>() * std::mem::size_of::<f64>()
    };
    assert_eq!(f32_f64_plane_bytes, 0, "f32 serving path grew f64 planes");

    // Persistent pool vs per-call scoped spawns, in batches/s at the
    // standard device batch (64×1024 = the smallest shape the serial
    // cutoff parallelizes — exactly where per-call spawn overhead bites).
    let spawn_rows = |plan: &planner::FftPlan,
                      re: &[f32],
                      im: &[f32],
                      out_re: &mut [f32],
                      out_im: &mut [f32]| {
        // The pre-PR execution shape: scoped std threads spawned per call.
        let threads = planner::pool_threads().min(DEVICE_BATCH);
        let chunk_rows = DEVICE_BATCH.div_ceil(threads);
        std::thread::scope(|scope| {
            let chunks = out_re[..DEVICE_BATCH * N]
                .chunks_mut(chunk_rows * N)
                .zip(out_im[..DEVICE_BATCH * N].chunks_mut(chunk_rows * N))
                .enumerate();
            for (ci, (o_re, o_im)) in chunks {
                let start = ci * chunk_rows;
                let rows_here = o_re.len() / N;
                let re_chunk = &re[start * N..(start + rows_here) * N];
                let im_chunk = &im[start * N..(start + rows_here) * N];
                scope.spawn(move || {
                    planner::with_scratch(|s| {
                        plan.run_rows_serial(
                            Direction::Forward,
                            re_chunk,
                            im_chunk,
                            rows_here,
                            o_re,
                            o_im,
                            s,
                        )
                    });
                });
            }
        });
    };
    let pool_iters = if quick { 200 } else { 800 };
    planner::run_rows(&plan, Direction::Forward, &re, &im, DEVICE_BATCH, &mut out_re, &mut out_im);
    let t0 = Instant::now();
    for _ in 0..pool_iters {
        planner::run_rows(&plan, Direction::Forward, &re, &im, DEVICE_BATCH, &mut out_re, &mut out_im);
    }
    let pool_batches_per_s = pool_iters as f64 / t0.elapsed().as_secs_f64();
    black_box(&out_re);
    spawn_rows(&plan, &re, &im, &mut out_re, &mut out_im);
    let t0 = Instant::now();
    for _ in 0..pool_iters {
        spawn_rows(&plan, &re, &im, &mut out_re, &mut out_im);
    }
    let spawn_batches_per_s = pool_iters as f64 / t0.elapsed().as_secs_f64();
    black_box(&out_re);
    let pool_vs_spawn = pool_batches_per_s / spawn_batches_per_s;
    let pool = planner::pool_stats();

    println!(
        "native: f32 {f32_native_rows_per_s:.0} rows/s vs f64-convert \
         {f64_convert_rows_per_s:.0} rows/s ({f32_vs_f64_convert:.2}x), f64 plane bytes on f32 \
         path: {f32_f64_plane_bytes}; pool {pool_batches_per_s:.0} vs scoped-spawn \
         {spawn_batches_per_s:.0} batches/s ({pool_vs_spawn:.2}x, {} workers, {} spawned)",
        pool.workers, pool.spawned_total
    );

    // 3. Fleet end to end: open-loop throughput + allocation proxy.
    let backend = default_backend(Path::new("/nonexistent-artifacts")).expect("sim backend");
    let fleet = (0..CARDS)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedClock(945.0)))
        .collect();
    let engine = Engine::start(backend, fleet, EngineConfig::default()).expect("engine");
    let payloads: Vec<(Vec<f32>, Vec<f32>)> =
        (0..fleet_jobs).map(|_| rand_planes(N, &mut rng)).collect();
    // Warmup: one round trip per card so module/plan/scratch caches are hot.
    for _ in 0..2 * DEVICE_BATCH {
        let (re, im) = payloads[0].clone();
        engine.submit(re, im).expect("warmup submit");
    }
    assert!(engine.drain(Duration::from_secs(120)).complete, "warmup drain");

    let allocs_before = ALLOC_CALLS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(fleet_jobs);
    for (re, im) in payloads {
        rxs.push(engine.submit(re, im).expect("submit"));
    }
    assert!(engine.drain(Duration::from_secs(600)).complete, "drain timed out");
    for rx in rxs {
        black_box(rx.recv().expect("recv").expect("job ok"));
    }
    let fleet_s = t0.elapsed().as_secs_f64();
    let allocs = ALLOC_CALLS.load(Ordering::Relaxed) - allocs_before;
    let jobs_per_s = fleet_jobs as f64 / fleet_s;
    let allocs_per_job = allocs as f64 / fleet_jobs as f64;

    println!(
        "fleet: {jobs_per_s:.0} jobs/s over {CARDS} cards ({fleet_jobs} jobs of n={N}), \
         {allocs_per_job:.1} allocs/job"
    );

    // 4. Closed-loop execute() latency.
    let lat_ms = LogHistogram::new();
    for _ in 0..latency_iters {
        let (re, im) = rand_planes(N, &mut rng);
        let t0 = Instant::now();
        black_box(engine.execute(re, im).expect("execute"));
        lat_ms.record(t0.elapsed().as_secs_f64() * 1e3);
    }
    let lat_ms = lat_ms.snapshot();
    let p50 = lat_ms.percentile(50.0);
    let p99 = lat_ms.percentile(99.0);
    println!("latency: p50 {p50:.3} ms, p99 {p99:.3} ms ({latency_iters} closed-loop jobs)");

    // 4b. Large-N tier: the cache-blocked four-step decomposition vs a
    // monolithic plan at n=2^18 (both forced explicitly so the numbers
    // are independent of the FFTSWEEP_FFT_FOURSTEP knob), plus the
    // schedule-inspection values the gate pins, and conv jobs/s through
    // the same 2-card fleet.
    const N_LARGE: usize = 1 << 18;
    let four = planner::FftPlan::new_four_step(N_LARGE).expect("2^18 has a four-step split");
    let mono = planner::FftPlan::new_monolithic(N_LARGE);
    let large_rows = if quick { 4 } else { 16 };
    let (lre, lim) = rand_planes(large_rows * N_LARGE, &mut rng);
    let mut lo_re = vec![0.0f32; large_rows * N_LARGE];
    let mut lo_im = vec![0.0f32; large_rows * N_LARGE];
    let mut time_large = |plan: &planner::FftPlan| -> f64 {
        // warm plan scratch/twiddles, then measure steady state
        planner::run_rows(plan, Direction::Forward, &lre, &lim, large_rows, &mut lo_re, &mut lo_im);
        let t0 = Instant::now();
        planner::run_rows(plan, Direction::Forward, &lre, &lim, large_rows, &mut lo_re, &mut lo_im);
        let dt = t0.elapsed().as_secs_f64();
        black_box(&lo_re);
        large_rows as f64 / dt
    };
    let four_step_rows_per_s = time_large(&four);
    let monolithic_rows_per_s = time_large(&mono);
    let four_step_vs_monolithic = four_step_rows_per_s / monolithic_rows_per_s;
    println!(
        "large_n: n={N_LARGE} four-step {four_step_rows_per_s:.1} rows/s \
         ({} passes, {} tw bytes) vs monolithic {monolithic_rows_per_s:.1} rows/s \
         ({} passes, {} tw bytes) — {four_step_vs_monolithic:.2}x",
        four.pass_count(),
        four.twiddle_bytes(),
        mono.pass_count(),
        mono.twiddle_bytes()
    );

    let conv_jobs = if quick { 128 } else { 512 };
    const CONV_N: usize = 4096;
    const CONV_TAPS: u64 = 129;
    // One closed-loop job warms the conv route, module and plan cache.
    let x0: Vec<f32> = (0..CONV_N).map(|_| rng.gauss() as f32).collect();
    black_box(engine.execute_conv(x0, CONV_TAPS).expect("conv warmup"));
    let conv_payloads: Vec<Vec<f32>> = (0..conv_jobs)
        .map(|_| (0..CONV_N).map(|_| rng.gauss() as f32).collect())
        .collect();
    let t0 = Instant::now();
    let mut crxs = Vec::with_capacity(conv_jobs);
    for x in conv_payloads {
        crxs.push(engine.submit_conv(x, CONV_TAPS).expect("conv submit"));
    }
    assert!(engine.drain(Duration::from_secs(600)).complete, "conv drain timed out");
    for rx in crxs {
        black_box(rx.recv().expect("conv recv").expect("conv job ok"));
    }
    let conv_jobs_per_s = conv_jobs as f64 / t0.elapsed().as_secs_f64();
    let cplan = planner::conv_plan_for(CONV_N, &planner::synthetic_kernel(CONV_TAPS as usize));
    println!(
        "large_n: conv {conv_jobs_per_s:.0} jobs/s (n={CONV_N}, taps={CONV_TAPS}, block \
         {}, {} passes/block)",
        cplan.block_len(),
        cplan.passes_per_block()
    );
    println!("{}", engine.fleet_report());
    let backend = engine.backend().clone();
    engine.shutdown();

    // 5. Power telemetry: uncapped (boost) vs capped serving of one
    // seeded trace on a fresh 2-card fleet. All power-section numbers are
    // *simulated* quantities (deterministic across host machines), so the
    // CI gate can hold them to tight internal invariants: the capped draw
    // must sit under the budget and capped energy/job under uncapped.
    let power_jobs = if quick { 256 } else { 1024 };
    let specs = vec![tesla_v100(), tesla_v100()];
    let uncapped = telemetry_analysis::serve_trace(
        backend.clone(),
        &specs,
        &GovernorKind::FixedBoost,
        power_jobs,
        &[N as u64],
        0xBEEF,
        None,
    )
    .expect("uncapped power trace");
    let budget_w = 0.7 * uncapped.fleet_draw_1s_w;
    let capped = telemetry_analysis::serve_trace(
        backend,
        &specs,
        &GovernorKind::FixedBoost,
        power_jobs,
        &[N as u64],
        0xBEEF,
        Some(budget_w),
    )
    .expect("capped power trace");
    println!(
        "power: budget {budget_w:.1} W — uncapped {:.1} W / {:.3e} J/job / p99 {:.4} sim ms, \
         capped {:.1} W / {:.3e} J/job / p99 {:.4} sim ms ({} transitions)",
        uncapped.fleet_draw_1s_w,
        uncapped.energy_per_job_j,
        uncapped.p99_sim_ms,
        capped.fleet_draw_1s_w,
        capped.energy_per_job_j,
        capped.p99_sim_ms,
        capped.clock_transitions,
    );

    // 6. Robustness: the same serving pipeline with one of three cards
    // fail-stopped a few batches into the run, offered twice the
    // fault-free leg's job count, vs an identical fault-free control.
    // Both legs run on a fresh runtime (cold module cache) so they are
    // comparable; the fault schedule is batch-sequence keyed, hence
    // deterministic. The invariant the gate pins: zero lost jobs — every
    // submit resolves to a result or a typed error — and the fail-stopped
    // card lands in quarantine.
    struct RobustLeg {
        wall_s: f64,
        ok: u64,
        lost: u64,
        shed: u64,
        retried: u64,
        quarantines: u64,
        p99_sim_ms: f64,
    }
    let robust_leg = |jobs: usize, chaos: Option<&str>, rng: &mut Rng| -> RobustLeg {
        let backend = default_backend(Path::new("/nonexistent-artifacts")).expect("sim backend");
        let fleet = (0..3)
            .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedBoost))
            .collect();
        let cfg = EngineConfig {
            fault_plan: chaos
                .map(|s| FaultPlan::parse(s).expect("chaos spec"))
                .unwrap_or_default(),
            health: HealthPolicy {
                degraded_load_penalty: 2,
                probe_cooldown: Duration::from_millis(10),
                ..HealthPolicy::default()
            },
            retry: RetryPolicy {
                max_retries: 4,
                backoff_base: Duration::from_millis(1),
                ..RetryPolicy::default()
            },
            ..EngineConfig::default()
        };
        let engine = Engine::start(backend, fleet, cfg).expect("engine");
        let payloads: Vec<(Vec<f32>, Vec<f32>)> =
            (0..jobs).map(|_| rand_planes(N, rng)).collect();
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(jobs);
        for (re, im) in payloads {
            rxs.push(engine.submit(re, im).expect("robustness submit"));
        }
        assert!(engine.drain(Duration::from_secs(600)).complete, "robustness drain timed out");
        let wall_s = t0.elapsed().as_secs_f64();
        let mut ok = 0u64;
        let mut resolved = 0u64;
        let sim_ms = LogHistogram::new();
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(Ok(res)) => {
                    ok += 1;
                    resolved += 1;
                    sim_ms.record(res.sim_batch_s * 1e3);
                }
                Ok(Err(_)) => resolved += 1,
                Err(_) => {}
            }
        }
        let snap = engine.snapshot();
        let quarantines = engine
            .health_transitions()
            .iter()
            .filter(|t| t.to == HealthState::Quarantined)
            .count() as u64;
        engine.shutdown();
        RobustLeg {
            wall_s,
            ok,
            lost: jobs as u64 - resolved,
            shed: snap.fleet.jobs_shed,
            retried: snap.fleet.jobs_retried,
            quarantines,
            p99_sim_ms: sim_ms.snapshot().percentile(99.0),
        }
    };
    let robust_jobs = if quick { 384 } else { 1536 };
    let fault_free = robust_leg(robust_jobs, None, &mut rng);
    let faulted = robust_leg(2 * robust_jobs, Some("1:failstop,after=4"), &mut rng);
    assert_eq!(fault_free.lost, 0, "fault-free leg lost jobs");
    assert_eq!(faulted.lost, 0, "faulted leg lost accepted jobs");
    assert!(faulted.quarantines >= 1, "fail-stopped card never quarantined");
    let fault_free_jobs_per_s = fault_free.ok as f64 / fault_free.wall_s;
    let faulted_goodput_jobs_per_s = faulted.ok as f64 / faulted.wall_s;
    let goodput_frac = faulted_goodput_jobs_per_s / fault_free_jobs_per_s;
    let shed_rate = faulted.shed as f64 / (2 * robust_jobs) as f64;
    println!(
        "robustness: fault-free {fault_free_jobs_per_s:.0} jobs/s vs 1-of-3 failed at 2x load \
         {faulted_goodput_jobs_per_s:.0} goodput jobs/s ({goodput_frac:.2}x), {} lost, {} shed \
         (rate {shed_rate:.4}), {} retried, {} quarantine(s), p99 sim {:.4} ms vs {:.4} ms",
        faulted.lost,
        faulted.shed,
        faulted.retried,
        faulted.quarantines,
        faulted.p99_sim_ms,
        fault_free.p99_sim_ms,
    );

    // 7. Observability: the identical open-loop serve on a fresh 2-card
    // fleet, measured twice — request tracing disabled, then enabled
    // (span recording + histogram updates + ring writes on every job).
    // The gate pins traced >= untraced * 0.95: per-job tracing must stay
    // inside a 5% throughput budget. The readout number prices one full
    // trace summary (per-card + per-artifact histogram snapshots) plus
    // the four fleet e2e percentile reads — the cost a scrape pays.
    let obs_jobs = if quick { 512 } else { 2048 };
    let obs_leg = |traced: bool, rng: &mut Rng| -> (f64, u64, f64) {
        let backend = default_backend(Path::new("/nonexistent-artifacts")).expect("sim backend");
        let fleet = (0..CARDS)
            .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedClock(945.0)))
            .collect();
        let cfg = EngineConfig {
            trace: TraceConfig {
                enabled: traced,
                ..TraceConfig::default()
            },
            ..EngineConfig::default()
        };
        let engine = Engine::start(backend, fleet, cfg).expect("engine");
        let payloads: Vec<(Vec<f32>, Vec<f32>)> =
            (0..obs_jobs).map(|_| rand_planes(N, rng)).collect();
        for _ in 0..2 * DEVICE_BATCH {
            let (re, im) = payloads[0].clone();
            engine.submit(re, im).expect("obs warmup submit");
        }
        assert!(engine.drain(Duration::from_secs(120)).complete, "obs warmup drain");
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(obs_jobs);
        for (re, im) in payloads {
            rxs.push(engine.submit(re, im).expect("obs submit"));
        }
        assert!(engine.drain(Duration::from_secs(600)).complete, "obs drain timed out");
        for rx in rxs {
            black_box(rx.recv().expect("obs recv").expect("obs job ok"));
        }
        let jobs_per_s = obs_jobs as f64 / t0.elapsed().as_secs_f64();
        let spans = engine.tracer().ok_spans();
        let reads = if quick { 50 } else { 200 };
        let t0 = Instant::now();
        for _ in 0..reads {
            let summary = engine.tracer().summary();
            let e2e = summary.fleet().e2e_s;
            black_box((
                e2e.percentile(50.0),
                e2e.percentile(95.0),
                e2e.percentile(99.0),
                e2e.percentile(99.9),
            ));
        }
        let readout_us = t0.elapsed().as_secs_f64() * 1e6 / reads as f64;
        engine.shutdown();
        (jobs_per_s, spans, readout_us)
    };
    let (untraced_jobs_per_s, untraced_spans, _) = obs_leg(false, &mut rng);
    let (traced_jobs_per_s, spans_recorded, hist_readout_us) = obs_leg(true, &mut rng);
    assert_eq!(untraced_spans, 0, "disabled tracer recorded spans");
    assert_eq!(
        spans_recorded,
        (obs_jobs + 2 * DEVICE_BATCH) as u64,
        "traced leg lost spans (warmup included)"
    );
    let trace_overhead_frac = 1.0 - traced_jobs_per_s / untraced_jobs_per_s;
    println!(
        "observability: untraced {untraced_jobs_per_s:.0} jobs/s vs traced \
         {traced_jobs_per_s:.0} jobs/s (overhead {:.1}%), {spans_recorded} spans, summary \
         readout {hist_readout_us:.1} us",
        trace_overhead_frac * 100.0
    );

    // 8. Overload: bursty mixed-class arrivals (25% realtime / 50% batch
    // / 25% scavenger, the serve CLI's `mixed` mapping) offered at
    // 1x/2x/4x/8x the fleet's measured capacity (section 3's open-loop
    // jobs/s) through a fresh bounded 2-card fleet per leg. Goodput is
    // completions over the offered-load window (first to last submit;
    // backlog completions drained after the window are credited to it —
    // the same convention at every multiplier, so legs are comparable).
    // Realtime latency comes from the leg's own trace journal, which
    // also exercises the class/reason span plumbing end to end.
    struct OverloadLeg {
        offered: u64,
        ok: u64,
        shed: u64,
        untyped: u64,
        goodput_jobs_per_s: f64,
        realtime_goodput_jobs_per_s: f64,
        realtime_p99_ms: f64,
        shed_rate: f64,
        brownout_max_level: u8,
    }
    let overload_jobs = if quick { 256 } else { 1024 };
    let is_typed_shed = |e: &anyhow::Error| {
        matches!(
            e.downcast_ref::<CoordError>(),
            Some(
                CoordError::QueueFull { .. }
                    | CoordError::DeadlineInfeasible { .. }
                    | CoordError::BrownoutShed { .. }
                    | CoordError::RateLimited { .. }
            )
        )
    };
    let overload_leg = |mult: f64, rng: &mut Rng| -> OverloadLeg {
        let backend = default_backend(Path::new("/nonexistent-artifacts")).expect("sim backend");
        let fleet = (0..CARDS)
            .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedClock(945.0)))
            .collect();
        let journal = std::env::temp_dir().join(format!(
            "fftsweep_bench_overload_{mult}x_{}.jsonl",
            std::process::id()
        ));
        let cfg = EngineConfig {
            queue_bound: Some(32),
            trace: TraceConfig {
                jsonl_out: Some(journal.clone()),
                ..TraceConfig::default()
            },
            ..EngineConfig::default()
        };
        let engine = Engine::start(backend, fleet, cfg).expect("engine");
        // Warm the plan/module caches so the first volley is not billed
        // plan-build latency (warmup spans are batch-class, so they do
        // not contaminate the realtime percentiles).
        for _ in 0..2 * DEVICE_BATCH {
            let (re, im) = rand_planes(N, rng);
            engine.submit(re, im).expect("overload warmup submit");
        }
        assert!(engine.drain(Duration::from_secs(120)).complete, "overload warmup drain");
        let arrivals = ArrivalPlan {
            kind: ArrivalKind::Burst { size: 32, quiet_x: 1.0 },
            seed: 0xA11,
        }
        .schedule(mult * jobs_per_s, overload_jobs as u64, 1);
        let payloads: Vec<(Vec<f32>, Vec<f32>)> =
            (0..overload_jobs).map(|_| rand_planes(N, rng)).collect();
        let mut rxs = Vec::with_capacity(overload_jobs);
        let mut shed = 0u64;
        let t0 = Instant::now();
        for (j, (re, im)) in payloads.into_iter().enumerate() {
            if arrivals[j].gap_us > 0 {
                std::thread::sleep(Duration::from_micros(arrivals[j].gap_us));
            }
            let class = match j % 4 {
                0 => TenantClass::Realtime,
                3 => TenantClass::Scavenger,
                _ => TenantClass::Batch,
            };
            match engine.submit_qos(re, im, class, None) {
                Ok(rx) => rxs.push((class, rx)),
                Err(e) if is_typed_shed(&e) => shed += 1,
                Err(e) => panic!("untyped submit refusal at {mult}x: {e:#}"),
            }
        }
        let window_s = t0.elapsed().as_secs_f64();
        assert!(engine.drain(Duration::from_secs(600)).complete, "overload drain timed out");
        let mut ok = 0u64;
        let mut rt_ok = 0u64;
        let mut untyped = 0u64;
        for (class, rx) in rxs {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(Ok(_)) => {
                    ok += 1;
                    if class == TenantClass::Realtime {
                        rt_ok += 1;
                    }
                }
                // Admitted then evicted for a higher class: still typed.
                Ok(Err(e)) if is_typed_shed(&e) => shed += 1,
                _ => untyped += 1,
            }
        }
        let snap = engine.snapshot();
        let brownout_max_level = snap.overload.as_ref().map_or(0, |o| o.brownout_max_level);
        engine.shutdown();
        let spans = load_spans(&journal).expect("overload journal");
        let _ = std::fs::remove_file(&journal);
        let rt_ms = LogHistogram::new();
        for s in &spans {
            if s.outcome == SpanOutcome::Ok && s.class == "realtime" {
                rt_ms.record(s.e2e_s() * 1e3);
            }
        }
        OverloadLeg {
            offered: overload_jobs as u64,
            ok,
            shed,
            untyped,
            goodput_jobs_per_s: ok as f64 / window_s,
            realtime_goodput_jobs_per_s: rt_ok as f64 / window_s,
            realtime_p99_ms: rt_ms.snapshot().percentile(99.0),
            shed_rate: shed as f64 / overload_jobs as f64,
            brownout_max_level,
        }
    };
    let legs: Vec<(f64, OverloadLeg)> = [1.0, 2.0, 4.0, 8.0]
        .iter()
        .map(|&m| (m, overload_leg(m, &mut rng)))
        .collect();
    let leg_at = |m: f64| &legs.iter().find(|(lm, _)| *lm == m).expect("leg ran").1;
    let untyped_drops: u64 = legs.iter().map(|(_, l)| l.untyped).sum();
    for (m, l) in &legs {
        println!(
            "overload {m}x: goodput {:.0} jobs/s (realtime {:.0} jobs/s, p99 {:.2} ms), \
             {} ok / {} shed of {} (rate {:.3}), brownout peak L{}",
            l.goodput_jobs_per_s,
            l.realtime_goodput_jobs_per_s,
            l.realtime_p99_ms,
            l.ok,
            l.shed,
            l.offered,
            l.shed_rate,
            l.brownout_max_level
        );
    }
    assert_eq!(untyped_drops, 0, "a refused job was not a typed shed");
    assert!(
        leg_at(4.0).realtime_goodput_jobs_per_s >= 0.95 * leg_at(1.0).goodput_jobs_per_s,
        "realtime goodput collapsed under 4x overload: {:.0} jobs/s vs 1x-load {:.0} jobs/s",
        leg_at(4.0).realtime_goodput_jobs_per_s,
        leg_at(1.0).goodput_jobs_per_s
    );

    let mut root = Json::obj();
    root.set("bench", "serving".into());
    root.set("schema", 8.0.into());
    root.set("quick", quick.into());
    root.set("n", (N as u64).into());
    root.set("device_batch", (DEVICE_BATCH as u64).into());
    root.set("cards", (CARDS as u64).into());
    root.set("jobs", (fleet_jobs as u64).into());
    root.set("naive_rows_per_s", naive_rows_per_s.into());
    root.set("planned_serial_rows_per_s", planned_serial_rows_per_s.into());
    root.set("planned_serial_speedup", serial_speedup.into());
    root.set("planned_rows_per_s", planned_rows_per_s.into());
    root.set("planned_speedup", speedup.into());
    let mut nonpow2_json = Json::obj();
    nonpow2_json.set("n", (N_NONPOW2 as u64).into());
    nonpow2_json.set("rows_per_s", nonpow2_rows_per_s.into());
    root.set("nonpow2", nonpow2_json);
    let mut bluestein_json = Json::obj();
    bluestein_json.set("n", (N_BLUESTEIN as u64).into());
    bluestein_json.set("rows_per_s", bluestein_rows_per_s.into());
    root.set("bluestein", bluestein_json);
    let mut rfft_json = Json::obj();
    rfft_json.set("n", (N_RFFT as u64).into());
    rfft_json.set("rows_per_s", rfft_rows_per_s.into());
    rfft_json.set("vs_complex", rfft_vs_complex.into());
    root.set("rfft", rfft_json);
    let mut native_json = Json::obj();
    native_json.set("f32_rows_per_s", f32_native_rows_per_s.into());
    native_json.set("f64_convert_rows_per_s", f64_convert_rows_per_s.into());
    native_json.set("f32_vs_f64_convert", f32_vs_f64_convert.into());
    native_json.set("f32_f64_plane_bytes", (f32_f64_plane_bytes as u64).into());
    native_json.set("pool_batches_per_s", pool_batches_per_s.into());
    native_json.set("spawn_batches_per_s", spawn_batches_per_s.into());
    native_json.set("pool_vs_spawn", pool_vs_spawn.into());
    native_json.set("pool_workers", (pool.workers as u64).into());
    native_json.set("pool_threads_spawned", pool.spawned_total.into());
    root.set("native", native_json);
    let mut fleet_json = Json::obj();
    fleet_json.set("jobs_per_s", jobs_per_s.into());
    fleet_json.set("p50_ms", p50.into());
    fleet_json.set("p99_ms", p99.into());
    fleet_json.set("allocs_per_job", allocs_per_job.into());
    root.set("fleet", fleet_json);
    let mut power_json = Json::obj();
    power_json.set("jobs", (power_jobs as u64).into());
    power_json.set("budget_w", budget_w.into());
    power_json.set("uncapped_draw_1s_w", uncapped.fleet_draw_1s_w.into());
    power_json.set("capped_draw_1s_w", capped.fleet_draw_1s_w.into());
    power_json.set("uncapped_energy_per_job_j", uncapped.energy_per_job_j.into());
    power_json.set("capped_energy_per_job_j", capped.energy_per_job_j.into());
    power_json.set("uncapped_p99_sim_ms", uncapped.p99_sim_ms.into());
    power_json.set("capped_p99_sim_ms", capped.p99_sim_ms.into());
    power_json.set("capped_clock_transitions", capped.clock_transitions.into());
    root.set("power", power_json);
    let mut large_json = Json::obj();
    large_json.set("n", (N_LARGE as u64).into());
    large_json.set("four_step_rows_per_s", four_step_rows_per_s.into());
    large_json.set("monolithic_rows_per_s", monolithic_rows_per_s.into());
    large_json.set("four_step_vs_monolithic", four_step_vs_monolithic.into());
    large_json.set("four_step_passes", (four.pass_count() as u64).into());
    large_json.set("monolithic_passes", (mono.pass_count() as u64).into());
    large_json.set("four_step_twiddle_bytes", (four.twiddle_bytes() as u64).into());
    large_json.set("monolithic_twiddle_bytes", (mono.twiddle_bytes() as u64).into());
    large_json.set("conv_n", (CONV_N as u64).into());
    large_json.set("conv_taps", CONV_TAPS.into());
    large_json.set("conv_jobs_per_s", conv_jobs_per_s.into());
    large_json.set("conv_block_len", (cplan.block_len() as u64).into());
    large_json.set("conv_passes_per_block", (cplan.passes_per_block() as u64).into());
    root.set("large_n", large_json);
    let mut robust_json = Json::obj();
    robust_json.set("jobs", (robust_jobs as u64).into());
    robust_json.set("faulted_jobs", (2 * robust_jobs as u64).into());
    robust_json.set("fault_free_jobs_per_s", fault_free_jobs_per_s.into());
    robust_json.set("faulted_goodput_jobs_per_s", faulted_goodput_jobs_per_s.into());
    robust_json.set("goodput_frac", goodput_frac.into());
    robust_json.set("jobs_lost", faulted.lost.into());
    robust_json.set("shed_rate", shed_rate.into());
    robust_json.set("jobs_retried", faulted.retried.into());
    robust_json.set("quarantines", faulted.quarantines.into());
    robust_json.set("fault_free_p99_sim_ms", fault_free.p99_sim_ms.into());
    robust_json.set("faulted_p99_sim_ms", faulted.p99_sim_ms.into());
    root.set("robustness", robust_json);
    let mut obs_json = Json::obj();
    obs_json.set("jobs", (obs_jobs as u64).into());
    obs_json.set("untraced_jobs_per_s", untraced_jobs_per_s.into());
    obs_json.set("traced_jobs_per_s", traced_jobs_per_s.into());
    obs_json.set("trace_overhead_frac", trace_overhead_frac.into());
    obs_json.set("hist_readout_us", hist_readout_us.into());
    obs_json.set("spans_recorded", spans_recorded.into());
    root.set("observability", obs_json);
    let mut overload_json = Json::obj();
    overload_json.set("jobs_per_leg", (overload_jobs as u64).into());
    overload_json.set("arrival", "burst,size=32".into());
    overload_json.set("capacity_jobs_per_s", jobs_per_s.into());
    let mut legs_json = Json::obj();
    for (m, l) in &legs {
        let mut leg_json = Json::obj();
        leg_json.set("offered", l.offered.into());
        leg_json.set("ok", l.ok.into());
        leg_json.set("shed", l.shed.into());
        leg_json.set("goodput_jobs_per_s", l.goodput_jobs_per_s.into());
        leg_json.set("realtime_goodput_jobs_per_s", l.realtime_goodput_jobs_per_s.into());
        leg_json.set("realtime_p99_ms", l.realtime_p99_ms.into());
        leg_json.set("shed_rate", l.shed_rate.into());
        leg_json.set("brownout_max_level", (l.brownout_max_level as u64).into());
        legs_json.set(&format!("{m}x"), leg_json);
    }
    overload_json.set("legs", legs_json);
    overload_json.set("goodput_1x_jobs_per_s", leg_at(1.0).goodput_jobs_per_s.into());
    overload_json.set("goodput_4x_jobs_per_s", leg_at(4.0).goodput_jobs_per_s.into());
    overload_json.set(
        "realtime_goodput_4x_jobs_per_s",
        leg_at(4.0).realtime_goodput_jobs_per_s.into(),
    );
    overload_json.set("realtime_p99_ms_4x", leg_at(4.0).realtime_p99_ms.into());
    overload_json.set("shed_rate_4x", leg_at(4.0).shed_rate.into());
    overload_json.set("untyped_drops", untyped_drops.into());
    root.set("overload", overload_json);
    std::fs::write(&out_path, root.render() + "\n").expect("write BENCH_serving.json");
    println!("wrote {out_path}");
}
