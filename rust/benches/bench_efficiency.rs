//! Bench: Figs 10-16 — GFLOPS/W, execution-time cost, GFLOPS, and the
//! efficiency-increase series at the per-length optimal and mean-optimal
//! clocks vs both boost and base reference clocks.

mod common;

use fftsweep::analysis::figures;
use fftsweep::analysis::report::{headline, headline_table};
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::gpu::{all_gpus, jetson_nano, tesla_v100};
use fftsweep::types::Precision;
use fftsweep::util::bench::Bench;

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig10_16").with_iters(0, 1);

    let cfg = common::bench_cfg();
    for gpu in [tesla_v100(), jetson_nano()] {
        let tag = gpu.name.to_lowercase().replace(' ', "_");
        b.run(&format!("figs9_16_{tag}"), || {
            let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
            figures::figure9_to_14(&gpu, &sweep)
                .write_csv(&out.join(format!("fig10_14_{tag}.csv")))
                .unwrap();
            let (mean_opt, t) = figures::figure15_16(&gpu, &sweep);
            t.write_csv(&out.join(format!("fig15_16_{tag}.csv"))).unwrap();
            println!("  {} mean optimal: {mean_opt:.0} MHz", gpu.name);
        });
    }

    // Headline summary across every (gpu, precision): the abstract's
    // "60% / 50% with <10% time" claims.
    let mut headlines = Vec::new();
    b.run("headlines_all_gpus", || {
        headlines.clear();
        for gpu in all_gpus() {
            for p in Precision::ALL {
                if gpu.supports(p) {
                    headlines.push(headline(&gpu, p, &common::quick_cfg()));
                }
            }
        }
    });
    let t = headline_table(&headlines);
    t.write_csv(&out.join("headlines.csv")).unwrap();
    println!("\n{}", t.to_ascii());
    println!("{}", b.summary());
}
