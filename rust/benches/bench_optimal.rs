//! Bench: Fig 9 + Table 3 — per-length optimal clocks and the mean-optimal
//! frequency per (GPU, precision), compared against the paper's values.

mod common;

use fftsweep::analysis::tables::table3_paper_mhz;
use fftsweep::analysis::{mean_optimal_mhz, optima};
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::gpu::all_gpus;
use fftsweep::types::Precision;
use fftsweep::util::bench::Bench;
use fftsweep::util::table::{fnum, Table};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("table3_fig9").with_iters(0, 1);

    let cfg = common::bench_cfg();
    let mut t3 = Table::new(
        "Table 3: mean optimal clocks, measured vs paper [MHz]",
        &["gpu", "precision", "measured", "paper", "dev_pct"],
    );
    let mut fig9 = Table::new(
        "Fig 9: optimal clock as % of boost",
        &["gpu", "precision", "n", "pct_of_boost"],
    );
    for gpu in all_gpus() {
        for p in Precision::ALL {
            if !gpu.supports(p) {
                continue;
            }
            let label = format!("{}_{}", gpu.name.replace(' ', "_"), p.label());
            b.run(&label, || {
                let sweep = sweep_gpu(&gpu, p, &cfg);
                let pts = optima(&gpu, &sweep);
                let mean = mean_optimal_mhz(&gpu, &pts);
                let paper = table3_paper_mhz(gpu.name, p);
                t3.push_row(vec![
                    gpu.name.to_string(),
                    p.to_string(),
                    fnum(mean, 0),
                    paper.map(|x| fnum(x, 0)).unwrap_or_else(|| "-".into()),
                    paper
                        .map(|x| fnum((mean / x - 1.0) * 100.0, 1))
                        .unwrap_or_else(|| "-".into()),
                ]);
                for pt in &pts {
                    fig9.push_row(vec![
                        gpu.name.to_string(),
                        p.to_string(),
                        pt.n.to_string(),
                        fnum(pt.frac_of_boost * 100.0, 1),
                    ]);
                }
            });
        }
    }
    t3.write_csv(&out.join("table3.csv")).unwrap();
    fig9.write_csv(&out.join("fig9.csv")).unwrap();
    println!("\n{}", t3.to_ascii());
    println!("{}", b.summary());
}
