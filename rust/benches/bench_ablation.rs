//! Bench: ablations over the simulator's design choices (DESIGN.md §Perf)
//! — which mechanisms are load-bearing for the paper's phenomenon.

mod common;

use fftsweep::analysis::ablation::{ablation_table, run_ablation, Ablation};
use fftsweep::sim::gpu::{jetson_nano, tesla_v100};
use fftsweep::util::bench::{black_box, Bench};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("ablation").with_iters(1, 10);

    for gpu in [tesla_v100(), jetson_nano()] {
        let tag = gpu.name.to_lowercase().replace(' ', "_");
        let mut t = None;
        b.run(&format!("ablation_table_{tag}"), || {
            t = Some(ablation_table(&gpu, 16384));
        });
        let t = t.unwrap();
        t.write_csv(&out.join(format!("ablation_{tag}.csv"))).unwrap();
        println!("\n{}", t.to_ascii());
    }

    b.run("single_ablation_point", || {
        black_box(run_ablation(&tesla_v100(), 16384, Ablation::NoVoltageScaling));
    });
    println!("{}", b.summary());
}
