//! Bench: Fig 20 — NVVP-style kernel utilization profiles for the three
//! representative lengths (8192 / 16k / 2M) across the clock range.

mod common;

use fftsweep::analysis::figures;
use fftsweep::cufft::plan::plan;
use fftsweep::cufft::profile::{fig20_lengths, profile_plan};
use fftsweep::sim::freq_table::freq_table;
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::bench::{black_box, Bench};
use fftsweep::util::table::{fnum, Table};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig20").with_iters(2, 20);
    let gpu = tesla_v100();

    let mut t = None;
    b.run("fig20_boost_profiles", || {
        t = Some(figures::figure20(&gpu, gpu.boost_clock_mhz));
    });
    let t = t.unwrap();
    t.write_csv(&out.join("fig20.csv")).unwrap();
    println!("\n{}", t.to_ascii());

    // Profile across the clock range: issue-slot saturation at low clocks.
    let mut sweep_table = Table::new(
        "Fig 20 (extended): utilization vs clock, N=8192",
        &["f_mhz", "compute_pct", "issue_pct", "mbu_pct"],
    );
    let w = FftWorkload::new(8192, Precision::Fp32, gpu.working_set_bytes);
    let p = plan(w.n, w.precision);
    for f in freq_table(&gpu).stride(16) {
        let prof = profile_plan(&gpu, &w, &p, f);
        let k = &prof.kernels[0];
        sweep_table.push_row(vec![
            fnum(f, 0),
            fnum(k.compute_util * 100.0, 1),
            fnum(k.issue_slot_util * 100.0, 1),
            fnum(k.device_mbu * 100.0, 1),
        ]);
    }
    sweep_table.write_csv(&out.join("fig20_vs_clock.csv")).unwrap();

    b.run_with_elements("profile_plan_2M", Some(1), &mut || {
        let w = FftWorkload::new(fig20_lengths()[2], Precision::Fp32, gpu.working_set_bytes);
        let p = plan(w.n, w.precision);
        black_box(profile_plan(&gpu, &w, &p, 945.0));
    });
    println!("{}", b.summary());
}
