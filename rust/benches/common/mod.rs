//! Shared helpers for the bench binaries (criterion substitute — see
//! DESIGN.md §1): a standard output directory and the sweep configs the
//! figure regenerations use.

use std::path::PathBuf;

use fftsweep::harness::sweep::SweepConfig;
use fftsweep::harness::Protocol;

pub fn out_dir() -> PathBuf {
    let p = PathBuf::from("results/bench");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Sweep config used by the bench regenerations: the full frequency grid
/// subsampled 8x, a representative length set, the default protocol.
pub fn bench_cfg() -> SweepConfig {
    SweepConfig {
        lengths: vec![256, 1024, 8192, 16384, 262144, 1 << 21, 19321],
        freq_stride: 8,
        protocol: Protocol::default(),
    }
}

/// Faster config for per-iteration timing loops.
pub fn quick_cfg() -> SweepConfig {
    SweepConfig {
        lengths: vec![1024, 16384],
        freq_stride: 24,
        protocol: Protocol {
            reps_per_run: 4,
            runs: 3,
            seed: 0xbe,
        },
    }
}
