//! Bench: Figs 4/5/6 — the t_fix staircase per GPU/precision and the
//! t_f/t_d frequency-ratio curves, plus timing of the underlying exec
//! model (the analytic hot path of every sweep).

mod common;

use fftsweep::analysis::figures;
use fftsweep::cufft::plan::plan;
use fftsweep::harness::sweep::sweep_gpu;
use fftsweep::sim::exec_model::time_plan;
use fftsweep::sim::gpu::{all_gpus, jetson_nano, tesla_v100};
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::bench::{black_box, Bench};

fn main() {
    let out = common::out_dir();
    let mut b = Bench::new("fig4_5_6").with_iters(2, 15);

    // Regenerate Fig 4 (FP32 staircase, all GPUs).
    let lengths: Vec<u64> = (5..=21).map(|k| 1u64 << k).collect();
    let gpus = all_gpus();
    let mut fig4 = None;
    b.run("fig4_tfix_fp32_all_gpus", || {
        fig4 = Some(figures::figure4_5(&gpus, Precision::Fp32, &lengths));
    });
    fig4.unwrap().write_csv(&out.join("fig4.csv")).unwrap();

    // Fig 5: FP64 + FP16.
    let mut fig5a = None;
    b.run("fig5_tfix_fp64_fp16", || {
        let a = figures::figure4_5(&gpus, Precision::Fp64, &lengths);
        let c = figures::figure4_5(&gpus, Precision::Fp16, &lengths);
        fig5a = Some((a, c));
    });
    let (a, c) = fig5a.unwrap();
    a.write_csv(&out.join("fig5_fp64.csv")).unwrap();
    c.write_csv(&out.join("fig5_fp16.csv")).unwrap();

    // Fig 6: t_f/t_d for V100 + Jetson.
    let cfg = common::bench_cfg();
    for gpu in [tesla_v100(), jetson_nano()] {
        let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
        let t = figures::figure6(&gpu, &sweep);
        let tag = gpu.name.to_lowercase().replace(' ', "_");
        t.write_csv(&out.join(format!("fig6_{tag}.csv"))).unwrap();
    }

    // Micro: the exec-model evaluation itself (called ~10^4 times per report).
    let g = tesla_v100();
    let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
    let p = plan(w.n, w.precision);
    b.run_with_elements("exec_model_time_plan", Some(1), &mut || {
        black_box(time_plan(&g, &w, &p, 945.0));
    });

    println!("\n{}", b.summary());
}
