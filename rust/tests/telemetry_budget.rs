//! Power telemetry & budget-enforcement integration tests — the issue's
//! acceptance criterion, end to end on the sim backend:
//!
//! `serve --power-budget-w W` on a 2-card heterogeneous fleet keeps the
//! rolling 1 s fleet draw at or below W, while the uncapped run of the
//! same trace draws more and has equal-or-better simulated p99; the NVML
//! clock-transition count under the arbiter stays bounded (no per-batch
//! thrash).

#![cfg(not(feature = "xla"))]

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use fftsweep::coordinator::{CardConfig, Engine, EngineConfig};
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::Runtime;
use fftsweep::sim::gpu::{tesla_p4, tesla_v100};
use fftsweep::telemetry::FleetSnapshot;
use fftsweep::util::rng::Rng;
use fftsweep::util::stats::percentile;

fn sim_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).expect("sim runtime"))
}

/// Serve `jobs` seeded n=1024 transforms on a V100+P4 fleet, optionally
/// capped; returns (snapshot, per-job simulated batch ms).
fn serve_hetero(budget_w: Option<f64>, jobs: usize, seed: u64) -> (FleetSnapshot, Vec<f64>) {
    let fleet = vec![
        CardConfig::new(tesla_v100(), GovernorKind::FixedBoost),
        CardConfig::new(tesla_p4(), GovernorKind::FixedBoost),
    ];
    let cfg = EngineConfig {
        power_budget_w: budget_w,
        ..EngineConfig::default()
    };
    let engine = Engine::start(sim_runtime(), fleet, cfg).expect("engine");
    let mut rng = Rng::new(seed);
    let mut rxs = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let re: Vec<f32> = (0..1024).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..1024).map(|_| rng.gauss() as f32).collect();
        rxs.push(engine.submit(re, im).expect("submit"));
    }
    assert!(engine.drain(Duration::from_secs(120)).complete, "drain timed out");
    let mut sim_ms = Vec::with_capacity(jobs);
    for rx in rxs {
        let res = rx.recv().expect("recv").expect("job ok");
        sim_ms.push(res.sim_batch_s * 1e3);
    }
    let snapshot = engine.snapshot();
    engine.shutdown();
    (snapshot, sim_ms)
}

#[test]
fn power_budget_caps_fleet_draw_without_thrash() {
    let jobs = 1024;
    // Baseline: the same trace uncapped (boost everywhere, no DVFS).
    let (open, open_ms) = serve_hetero(None, jobs, 42);
    assert_eq!(open.fleet.jobs_completed, jobs as u64);
    let open_draw = open.fleet.draw_1s_w;
    assert!(open_draw > 0.0);
    // no governor ever asked for a lock: zero transitions uncapped
    assert_eq!(open.fleet.clock_transitions, 0, "uncapped boost must not lock clocks");

    // Capped at 60% of the measured uncapped draw.
    let budget_w = 0.6 * open_draw;
    let (capped, capped_ms) = serve_hetero(Some(budget_w), jobs, 42);
    assert_eq!(capped.fleet.jobs_completed, jobs as u64);
    assert_eq!(capped.power_budget_w, Some(budget_w));

    // 1. The rolling 1 s fleet draw sits at or below the cap…
    assert!(
        capped.fleet.draw_1s_w <= budget_w + 1e-6,
        "capped fleet draw {} W over the {budget_w} W budget",
        capped.fleet.draw_1s_w
    );
    // …every card within its own share, and the shares within the cap.
    let mut share_sum = 0.0;
    for c in &capped.cards {
        let share = c.power_share_w.expect("capped fleet publishes shares");
        assert!(
            c.avg_1s_w <= share + 1e-6,
            "card{} draw {} W over its {share} W share",
            c.index,
            c.avg_1s_w
        );
        share_sum += share;
    }
    assert!(share_sum <= budget_w + 1e-6, "shares {share_sum} W exceed the cap");

    // 2. The uncapped run draws strictly more on the same trace.
    assert!(
        open_draw > capped.fleet.draw_1s_w,
        "uncapped draw {open_draw} W not above capped {} W",
        capped.fleet.draw_1s_w
    );

    // 3. Uncapped p99 (simulated batch latency) is equal or better.
    let open_p99 = percentile(&open_ms, 99.0);
    let capped_p99 = percentile(&capped_ms, 99.0);
    assert!(
        open_p99 <= capped_p99 + 1e-9,
        "uncapped p99 {open_p99} ms worse than capped {capped_p99} ms"
    );

    // 4. Bounded transitions: the arbiter's hysteresis + the quantized
    // watt→clock cap mean each card locks once and holds — nothing
    // remotely like one transition per batch.
    assert!(capped.fleet.batches >= 12, "trace too small to judge thrash");
    for c in &capped.cards {
        assert!(
            c.clock_transitions <= 4,
            "card{} made {} transitions over {} batches — clock thrash",
            c.index,
            c.clock_transitions,
            c.batches
        );
    }
    assert!(
        capped.fleet.clock_transitions * 2 < capped.fleet.batches,
        "{} transitions over {} batches is per-batch churn",
        capped.fleet.clock_transitions,
        capped.fleet.batches
    );
    // At least one card had to actually lock below boost to meet the cap.
    assert!(
        capped.cards.iter().any(|c| c.clock_transitions >= 1),
        "no card ever locked: the budget did not bite"
    );

    // 5. Telemetry coherence: cumulative energy matches the metrics' view
    // (both are full-precision now) and per-job attribution is populated.
    for c in &capped.cards {
        assert!(c.energy_j > 0.0);
        assert!(c.busy_s > 0.0);
        assert!(c.energy_per_job_j > 0.0);
    }
    let recorder_total: f64 = capped.cards.iter().map(|c| c.energy_per_job_j * c.jobs_completed as f64).sum();
    assert!(
        (recorder_total - capped.fleet.energy_j).abs() <= 1e-6 * capped.fleet.energy_j.max(1.0),
        "per-job attribution {recorder_total} J diverges from fleet energy {} J",
        capped.fleet.energy_j
    );
}

#[test]
fn capped_snapshot_exports_and_renders() {
    let (open, _) = serve_hetero(None, 128, 7);
    let budget_w = 0.7 * open.fleet.draw_1s_w;
    let (snap, _) = serve_hetero(Some(budget_w), 128, 7);

    // Typed data drives all three renderings.
    let report = snap.render();
    assert_eq!(report.lines().count(), 3, "2 card lines + fleet trailer");
    assert!(report.contains("Tesla V100") && report.contains("Tesla P4"));
    assert!(report.contains("share"), "capped report shows watt shares");
    assert!(report.lines().last().unwrap().contains("budget"));

    let json = fftsweep::telemetry::snapshot_json(&snap).render();
    assert!(json.contains("\"power_budget_w\""));
    assert!(json.contains("\"avg_1s_w\""));
    assert!(json.contains("Tesla P4"));

    let prom = fftsweep::telemetry::prometheus_text(&snap);
    assert!(prom.contains("fftsweep_fleet_power_budget_watts"));
    assert!(prom.contains("gpu=\"Tesla P4\""));
}

#[test]
fn uncapped_engine_reports_no_budget_state() {
    let (snap, _) = serve_hetero(None, 64, 3);
    assert_eq!(snap.power_budget_w, None);
    for c in &snap.cards {
        assert_eq!(c.power_share_w, None);
    }
    assert!(!snap.fleet_summary().contains("budget"));
    // deadline misses: boost meets the tolerance deadline by construction
    assert_eq!(snap.fleet.deadline_misses, 0);
}
