//! Overload acceptance tests: QoS admission control under 4x offered
//! load (issue 10's end-to-end invariant).
//!
//! The contract under test: at 4x the fleet's measured capacity in
//! bursty mixed-class arrivals through a bounded queue, **the realtime
//! class rides through** (>= 95% of its offered jobs complete), every
//! refused job carries one of the four typed shed variants (`QueueFull`
//! / `DeadlineInfeasible` / `BrownoutShed` / `RateLimited` — never a
//! silent drop, never a panic), queue depth stays at or under the
//! bound, and the brownout ladder is witnessed escalating. Arrival
//! schedules are seeded, so the overload replays identically run to
//! run; only the wall-clock capacity measurement varies by machine.

#![cfg(not(feature = "xla"))]

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fftsweep::coordinator::admission::{AdmissionPolicy, BrownoutPolicy, TenantClass};
use fftsweep::coordinator::{CardConfig, CoordError, Engine, EngineConfig};
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::Runtime;
use fftsweep::sim::fault::{ArrivalKind, ArrivalPlan};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::rng::Rng;

fn sim_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).expect("sim runtime"))
}

fn rand_planes(n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    (
        (0..n).map(|_| rng.gauss() as f32).collect(),
        (0..n).map(|_| rng.gauss() as f32).collect(),
    )
}

fn typed_shed(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<CoordError>(),
        Some(
            CoordError::QueueFull { .. }
                | CoordError::DeadlineInfeasible { .. }
                | CoordError::BrownoutShed { .. }
                | CoordError::RateLimited { .. }
        )
    )
}

/// Mixed-class rotation, same shape as `serve --tenant-class mixed`:
/// 25% realtime / 50% batch / 25% scavenger.
fn class_of(j: usize) -> TenantClass {
    match j % 4 {
        0 => TenantClass::Realtime,
        3 => TenantClass::Scavenger,
        _ => TenantClass::Batch,
    }
}

/// The headline overload test. Capacity is *measured* (a closed-loop
/// warm-up leg on this machine and build profile), not taken from the
/// backend estimator — pacing against an optimistic estimate would turn
/// "4x" into an arbitrary multiple on a slow builder.
#[test]
fn four_x_burst_overload_protects_realtime_and_sheds_typed() {
    const BOUND: u64 = 16;
    let fleet = (0..2)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedBoost))
        .collect();
    let cfg = EngineConfig {
        queue_bound: Some(BOUND),
        admission: AdmissionPolicy {
            // Escalate after a short streak so the ladder is reliably
            // witnessed inside a fast test; keep de-escalation far out
            // so the final snapshot's max level is deterministic.
            brownout: Some(BrownoutPolicy {
                escalate_ticks: 3,
                deescalate_ticks: 100_000,
                ..BrownoutPolicy::default()
            }),
            ..AdmissionPolicy::default()
        },
        ..EngineConfig::default()
    };
    let engine = Engine::start(sim_runtime(), fleet, cfg).expect("engine");
    let mut rng = Rng::new(11);

    // Closed-loop capacity leg on a separate UNBOUNDED twin fleet: the
    // bounded engine would refuse flat-out submits and skew the
    // measurement. Including this engine's plan-compile cost slightly
    // under-reports capacity — conservative for the 4x multiplier.
    let cap_fleet = (0..2)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedBoost))
        .collect();
    let cap_engine =
        Engine::start(sim_runtime(), cap_fleet, EngineConfig::default()).expect("engine");
    let warm = 256usize;
    let t0 = Instant::now();
    let mut warm_rxs = Vec::with_capacity(warm);
    for _ in 0..warm {
        let (re, im) = rand_planes(1024, &mut rng);
        warm_rxs.push(cap_engine.submit(re, im).expect("unbounded submit"));
    }
    assert!(cap_engine.drain(Duration::from_secs(120)).complete, "warm-up drain");
    let capacity = warm as f64 / t0.elapsed().as_secs_f64().max(1e-6);
    for rx in warm_rxs {
        assert!(rx.recv_timeout(Duration::from_secs(10)).expect("warm reply").is_ok());
    }
    cap_engine.shutdown();

    // 4x offered load, bursty, seeded — the arrival gaps replay exactly.
    let jobs = 512usize;
    let arrivals = ArrivalPlan {
        kind: ArrivalKind::Burst { size: 32, quiet_x: 1.0 },
        seed: 0xBEEF,
    }
    .schedule(4.0 * capacity, jobs as u64, 1);
    assert_eq!(arrivals.len(), jobs);

    let mut rxs = Vec::new();
    let mut offered = [0u64; 3];
    let mut shed_submit = 0u64;
    for (j, a) in arrivals.iter().enumerate() {
        if a.gap_us > 0 {
            std::thread::sleep(Duration::from_micros(a.gap_us));
        }
        let class = class_of(j);
        offered[class.index()] += 1;
        let (re, im) = rand_planes(1024, &mut rng);
        match engine.submit_qos(re, im, class, None) {
            Ok(rx) => rxs.push((class, rx)),
            Err(e) => {
                assert!(typed_shed(&e), "refusal must be a typed shed: {e:#}");
                shed_submit += 1;
            }
        }
        // Bounded queues are the no-collapse half of the contract: the
        // admission layer must hold every card at or under the bound.
        if j % 64 == 0 {
            for card in engine.snapshot().cards {
                assert!(
                    card.inflight <= BOUND,
                    "card {} over its queue bound: {} > {BOUND}",
                    card.index,
                    card.inflight
                );
            }
        }
    }
    assert!(engine.drain(Duration::from_secs(120)).complete, "overload drain");

    // Every accepted job resolves; the only failures are eviction
    // victims, and those carry a typed shed too.
    let mut ok = [0u64; 3];
    let mut evicted = 0u64;
    for (class, rx) in rxs {
        match rx.recv_timeout(Duration::from_secs(10)).expect("job reply must arrive") {
            Ok(res) => {
                assert_eq!(res.out_re.len(), 1024);
                ok[class.index()] += 1;
            }
            Err(e) => {
                assert!(typed_shed(&e), "failed job must carry a typed shed: {e:#}");
                assert_ne!(
                    class,
                    TenantClass::Realtime,
                    "realtime is never evicted for another class"
                );
                evicted += 1;
            }
        }
    }
    let ok_total: u64 = ok.iter().sum();
    assert_eq!(
        ok_total + evicted + shed_submit,
        jobs as u64,
        "accounting: every offered job terminated exactly once"
    );
    assert!(shed_submit + evicted > 0, "4x offered load must shed something");

    // The acceptance bar: realtime rides through the overload.
    assert!(
        ok[0] as f64 >= 0.95 * offered[0] as f64,
        "realtime must complete >= 95% under 4x overload: {}/{} \
         (batch {}/{}, scavenger {}/{})",
        ok[0],
        offered[0],
        ok[1],
        offered[1],
        ok[2],
        offered[2]
    );

    // Overload observability: the shed counters account the refusals and
    // the ladder was witnessed escalating under sustained pressure.
    let snap = engine.snapshot();
    let over = snap.overload.expect("Engine::snapshot fills overload");
    assert_eq!(over.evictions, evicted, "eviction victims must be counted");
    assert_eq!(
        snap.fleet.jobs_submitted,
        ok_total + evicted,
        "refusals happen before accounting"
    );
    assert!(
        over.brownout_max_level >= 1,
        "sustained 4x pressure must escalate the brownout ladder"
    );
    engine.shutdown();
}

/// A deadline the predicted queue-wait + exec time cannot meet is
/// refused at enqueue — typed, before accounting — not discovered late.
#[test]
fn infeasible_deadline_is_refused_typed_at_enqueue() {
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    let err = engine
        .submit_qos(
            vec![0.0; 1024],
            vec![0.0; 1024],
            TenantClass::Realtime,
            Some(Duration::from_nanos(1)),
        )
        .expect_err("a 1ns deadline is infeasible for any batch");
    match err.downcast_ref::<CoordError>() {
        Some(CoordError::DeadlineInfeasible { n, class, deadline_ms, predicted_ms, .. }) => {
            assert_eq!(*n, 1024);
            assert_eq!(*class, "realtime");
            assert!(predicted_ms > deadline_ms, "the refusal must show its arithmetic");
        }
        other => panic!("expected DeadlineInfeasible, got {other:?}"),
    }
    let snap = engine.snapshot();
    assert_eq!(snap.fleet.jobs_submitted, 0, "refused before accounting");
    assert_eq!(snap.overload.expect("overload").deadline_sheds, 1);

    // A generous deadline sails through the same check.
    let rx = engine
        .submit_qos(
            vec![0.0; 1024],
            vec![0.0; 1024],
            TenantClass::Realtime,
            Some(Duration::from_secs(60)),
        )
        .expect("a 60s deadline is feasible");
    engine.flush();
    assert!(rx.recv_timeout(Duration::from_secs(30)).expect("reply").is_ok());
    engine.shutdown();
}

/// Per-class token buckets: a class over its sustained rate + burst is
/// refused with `RateLimited`; other classes are untouched.
#[test]
fn scavenger_rate_limit_is_enforced_per_class() {
    let cfg = EngineConfig {
        admission: AdmissionPolicy {
            // Scavenger: 1 token banked, refilling at a glacial rate —
            // the second submit inside the same test run must be refused.
            rate_per_s: [None, None, Some(1e-6)],
            ..AdmissionPolicy::default()
        },
        ..EngineConfig::default()
    };
    let engine =
        Engine::start_single(sim_runtime(), tesla_v100(), GovernorKind::FixedBoost, cfg)
            .expect("engine");

    let first = engine
        .submit_qos(vec![0.0; 1024], vec![0.0; 1024], TenantClass::Scavenger, None)
        .expect("burst token admits the first scavenger job");
    let err = engine
        .submit_qos(vec![0.0; 1024], vec![0.0; 1024], TenantClass::Scavenger, None)
        .expect_err("the bucket is empty");
    match err.downcast_ref::<CoordError>() {
        Some(CoordError::RateLimited { class, .. }) => assert_eq!(*class, "scavenger"),
        other => panic!("expected RateLimited, got {other:?}"),
    }
    // Batch is not rate limited: its bucket is a different class's.
    let second = engine
        .submit_qos(vec![0.0; 1024], vec![0.0; 1024], TenantClass::Batch, None)
        .expect("batch rides free of the scavenger limit");

    assert!(engine.drain(Duration::from_secs(30)).complete);
    assert!(first.recv_timeout(Duration::from_secs(10)).expect("reply").is_ok());
    assert!(second.recv_timeout(Duration::from_secs(10)).expect("reply").is_ok());
    let over = engine.snapshot().overload.expect("overload");
    assert_eq!(over.rate_limited, 1);
    assert_eq!(over.admitted, [0, 1, 1]);
    engine.shutdown();
}
