//! Integration tests over the PJRT runtime + coordinator: load real AOT
//! artifacts, execute them, and validate numerics against the pure-rust
//! DSP oracle. Requires `make artifacts` to have run (skips otherwise so
//! `cargo test` works in a fresh checkout).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fftsweep::coordinator::{Engine, EngineConfig};
use fftsweep::dsp;
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::{Manifest, Runtime};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::rng::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn runtime() -> Option<Runtime> {
    artifact_dir().map(|d| Runtime::new(&d).expect("runtime"))
}

fn rand_planes(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        (0..n).map(|_| rng.gauss() as f32).collect(),
        (0..n).map(|_| rng.gauss() as f32).collect(),
    )
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    assert!(m.of_kind("fft").len() >= 4);
    assert_eq!(m.of_kind("pipeline").len(), 5);
    for a in m.entries.values() {
        assert!(a.file.exists(), "{:?} missing", a.file);
    }
}

#[test]
fn fft_artifact_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    for (n, name) in [(256u64, "fft_f32_n256_b256"), (1024, "fft_f32_n1024_b64")] {
        let module = rt.load(name).expect("load");
        let total = (module.meta.batch * n) as usize;
        let (re, im) = rand_planes(total, n);
        let out = module.run_f32(&[&re, &im]).expect("run");
        assert_eq!(out.len(), 2);
        // check a few batch rows against the oracle
        for b in [0usize, module.meta.batch as usize - 1] {
            let off = b * n as usize;
            let x: Vec<dsp::C64> = (0..n as usize)
                .map(|i| dsp::C64::new(re[off + i] as f64, im[off + i] as f64))
                .collect();
            let want = dsp::fft(&x);
            for i in 0..n as usize {
                assert!(
                    (out[0][off + i] as f64 - want[i].re).abs() < 1e-2
                        && (out[1][off + i] as f64 - want[i].im).abs() < 1e-2,
                    "{name} row {b} bin {i}"
                );
            }
        }
    }
}

#[test]
fn four_step_artifact_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let module = rt.load("fft_f32_n16384_b4").expect("load");
    let n = 16384usize;
    let (re, im) = rand_planes(module.meta.batch as usize * n, 99);
    let out = module.run_f32(&[&re, &im]).expect("run");
    let x: Vec<dsp::C64> = (0..n)
        .map(|i| dsp::C64::new(re[i] as f64, im[i] as f64))
        .collect();
    let want = dsp::fft(&x);
    let scale = want.iter().map(|c| c.abs2().sqrt()).fold(0.0, f64::max);
    for i in 0..n {
        let err = ((out[0][i] as f64 - want[i].re).powi(2)
            + (out[1][i] as f64 - want[i].im).powi(2))
        .sqrt();
        assert!(err / scale < 1e-4, "bin {i}: err {err}");
    }
}

#[test]
fn fp64_artifact_runs() {
    let Some(rt) = runtime() else { return };
    let module = rt.load("fft_f64_n1024_b64").expect("load");
    let total = (module.meta.batch * module.meta.n) as usize;
    let mut rng = Rng::new(3);
    let re: Vec<f64> = (0..total).map(|_| rng.gauss()).collect();
    let im: Vec<f64> = (0..total).map(|_| rng.gauss()).collect();
    let out = module.run_f64(&[&re, &im]).expect("run");
    let x: Vec<dsp::C64> = (0..1024).map(|i| dsp::C64::new(re[i], im[i])).collect();
    let want = dsp::fft(&x);
    for i in 0..1024 {
        assert!((out[0][i] - want[i].re).abs() < 1e-8, "bin {i}");
    }
}

#[test]
fn pipeline_artifact_detects_pulsar() {
    let Some(rt) = runtime() else { return };
    let module = rt.load("pipeline_n16384_h8").expect("load");
    let n = 16384usize;
    let batch = module.meta.batch as usize;
    let params = dsp::PulsarParams {
        fundamental_bin: 321,
        harmonics: 8,
        amplitude: 0.25,
    };
    let mut rng = Rng::new(42);
    let mut re = Vec::with_capacity(batch * n);
    let mut im = Vec::with_capacity(batch * n);
    for _ in 0..batch {
        let x = dsp::pulsar_time_series(n, &params, &mut rng);
        for c in &x {
            re.push(c.re as f32);
            im.push(c.im as f32);
        }
    }
    let out = module.run_f32(&[&re, &im]).expect("run");
    assert_eq!(out.len(), 3); // harmonic sums, mean, std
    let n_out = n / 8;
    for b in 0..batch {
        let hs = &out[0][b * n_out..(b + 1) * n_out];
        let det = dsp::detect_peak(hs, 8).expect("detection");
        assert_eq!(det.bin, 321, "batch {b}: snr {}", det.snr);
        assert!(det.snr > 8.0, "batch {b}: snr {}", det.snr);
    }
    // mean/std outputs are per-row scalars
    assert_eq!(out[1].len(), batch);
    assert_eq!(out[2].len(), batch);
    assert!(out[2].iter().all(|&s| s > 0.0));
}

#[test]
fn engine_serves_batched_jobs_correctly() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    let engine = Engine::start_single(
        rt,
        tesla_v100(),
        GovernorKind::FixedClock(945.0),
        EngineConfig::default(),
    )
    .expect("engine");

    // Pre-build payloads and oracles so the submit loop is tight — the
    // flusher must not see artificial gaps between submissions.
    let n = 1024usize;
    let mut rng = Rng::new(11);
    let mut payloads = Vec::new();
    let mut want = Vec::new();
    for _ in 0..70 {
        let re: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let x: Vec<dsp::C64> = re
            .iter()
            .zip(&im)
            .map(|(&r, &i)| dsp::C64::new(r as f64, i as f64))
            .collect();
        want.push(dsp::fft(&x));
        payloads.push((re, im));
    }
    let mut jobs = Vec::new();
    for (re, im) in payloads {
        jobs.push(engine.submit(re, im).expect("submit"));
    }
    assert!(engine.drain(Duration::from_secs(120)).complete, "drain timed out");
    for (rx, want) in jobs.into_iter().zip(want) {
        let res = rx.recv().expect("recv").expect("job ok");
        assert_eq!(res.out_re.len(), n);
        for i in 0..n {
            assert!(
                (res.out_re[i] as f64 - want[i].re).abs() < 1e-2,
                "job {} bin {i}",
                res.id
            );
        }
    }
    // 70 jobs into device batches of 64: at least 2 batches, high occupancy
    let batches = engine
        .metrics
        .batches_executed
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches >= 2);
    assert!(engine.metrics.occupancy() > 0.5);
    // DVFS accounting shows a saving at 945 vs boost
    assert!(engine.metrics.energy_saving() > 0.15);
    engine.shutdown();
}

#[test]
fn engine_rejects_unroutable_length() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    let engine = Engine::start_single(
        rt,
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    assert!(engine.submit(vec![0.0; 123], vec![0.0; 123]).is_err());
    engine.shutdown();
}

#[test]
fn spectrum_artifact_is_fft_power() {
    let Some(rt) = runtime() else { return };
    let module = rt.load("spectrum_f32_n4096_b16").expect("load");
    let n = 4096usize;
    let (re, im) = rand_planes(module.meta.batch as usize * n, 5);
    let out = module.run_f32(&[&re, &im]).expect("run");
    let x: Vec<dsp::C64> = (0..n)
        .map(|i| dsp::C64::new(re[i] as f64, im[i] as f64))
        .collect();
    let want = dsp::fft(&x);
    for i in 0..n {
        let p = want[i].abs2();
        let got = out[0][i] as f64;
        assert!(
            (got - p).abs() <= 1e-3 * p.max(1.0),
            "bin {i}: {got} vs {p}"
        );
    }
}

#[test]
fn corrupted_artifact_fails_loud_not_silent() {
    // Failure injection: a tampered HLO file must produce an error at load
    // time (and `validate` must flag the digest), never silent bad numbers.
    let Some(dir) = artifact_dir() else { return };
    let tmp = std::env::temp_dir().join(format!("fftsweep_corrupt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        std::fs::copy(&p, tmp.join(p.file_name().unwrap())).unwrap();
    }
    // truncate one artifact mid-instruction
    let victim = tmp.join("fft_f32_n1024_b64.hlo.txt");
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();

    let findings = fftsweep::runtime::validation::validate(
        &Manifest::load(&tmp).unwrap(),
    );
    assert!(
        findings.iter().any(|f| f.artifact == "fft_f32_n1024_b64"),
        "validation must flag the tampered artifact"
    );

    let rt = Runtime::new(&tmp).expect("runtime");
    assert!(
        rt.load("fft_f32_n1024_b64").is_err(),
        "loading a truncated HLO must error"
    );
    // untouched artifacts still load
    assert!(rt.load("fft_f32_n256_b256").is_ok());
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn engine_survives_mixed_good_and_bad_submissions() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(Runtime::new(&dir).expect("runtime"));
    let engine = Engine::start_single(
        rt,
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    let mut rng = Rng::new(5);
    let mut good = Vec::new();
    for i in 0..20 {
        if i % 3 == 0 {
            // unroutable length — rejected synchronously, engine unharmed
            assert!(engine.submit(vec![0.0; 100], vec![0.0; 100]).is_err());
        } else {
            let re: Vec<f32> = (0..256).map(|_| rng.gauss() as f32).collect();
            let im: Vec<f32> = (0..256).map(|_| rng.gauss() as f32).collect();
            good.push(engine.submit(re, im).expect("good submit"));
        }
    }
    assert!(engine.drain(Duration::from_secs(60)).complete);
    for rx in good {
        assert!(rx.recv().expect("recv").is_ok());
    }
    engine.shutdown();
}
