//! Fault-tolerance acceptance tests: seeded chaos schedules against the
//! simulated fleet (issue 7's end-to-end invariant).
//!
//! The contract under test: **every accepted job terminates** — as a
//! `JobResult` or a typed `CoordError` — under any injected fault
//! schedule. Fail-stopped cards get quarantined and their jobs complete
//! on the survivors via the retry path; quarantined cards are probed
//! back in after a cooldown; drained cards quiesce without dropping
//! accepted work. Fault injection is keyed on per-card batch sequence
//! numbers (no wall clock, no RNG), so these schedules replay
//! identically run to run.

#![cfg(not(feature = "xla"))]

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fftsweep::coordinator::health::{HealthPolicy, HealthState};
use fftsweep::coordinator::{CardConfig, CoordError, Engine, EngineConfig, RetryPolicy};
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::Runtime;
use fftsweep::sim::fault::FaultPlan;
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::rng::Rng;

fn sim_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).expect("sim runtime"))
}

fn rand_planes(n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    (
        (0..n).map(|_| rng.gauss() as f32).collect(),
        (0..n).map(|_| rng.gauss() as f32).collect(),
    )
}

/// The headline chaos test: a 3-card fleet under sustained load with one
/// card fail-stopped mid-run and another flapping. Zero accepted jobs
/// may be lost, the survivors must absorb the failed card's work, and
/// the health plane must record both the quarantine and the later probe
/// re-admission.
#[test]
fn chaos_schedule_loses_no_accepted_jobs() {
    let fleet = (0..3)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedBoost))
        .collect();
    let cfg = EngineConfig {
        // card 1 dies for good after 3 batches; card 2 errors the first
        // batch of every cycle of 8 from the start.
        fault_plan: FaultPlan::parse("1:failstop,after=3;2:flap,after=0,period=8,down=1")
            .expect("chaos spec"),
        health: HealthPolicy {
            // keep degraded cards attractive enough to collect the
            // consecutive errors that prove the quarantine path, and
            // probe quickly so the re-admit shows up within the test.
            degraded_load_penalty: 2,
            probe_cooldown: Duration::from_millis(10),
            ..HealthPolicy::default()
        },
        retry: RetryPolicy {
            max_retries: 6,
            backoff_base: Duration::from_millis(1),
            ..RetryPolicy::default()
        },
        ..EngineConfig::default()
    };
    let engine = Engine::start(sim_runtime(), fleet, cfg).expect("engine");

    let jobs = 600usize;
    let mut rng = Rng::new(42);
    let mut rxs = Vec::with_capacity(jobs);
    for _ in 0..40 {
        for _ in 0..15 {
            let (re, im) = rand_planes(1024, &mut rng);
            rxs.push(engine.submit(re, im).expect("submit accepted"));
        }
        // pace the waves so every card sees many batches (and the
        // timeout flusher emits partials, multiplying the batch count).
        std::thread::sleep(Duration::from_millis(1));
    }

    assert!(
        engine.drain(Duration::from_secs(120)).complete,
        "drain must resolve every accepted job under chaos"
    );

    // Zero lost jobs: every reply channel resolves, and every failure is
    // a typed CoordError (never a dropped sender, never a bare string).
    let mut ok = 0u64;
    let mut failed = 0u64;
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(10)).expect("job reply must arrive") {
            Ok(res) => {
                assert_eq!(res.out_re.len(), 1024);
                ok += 1;
            }
            Err(e) => {
                assert!(
                    e.downcast_ref::<CoordError>().is_some(),
                    "failed job must carry a typed CoordError, got: {e:#}"
                );
                failed += 1;
            }
        }
    }
    assert_eq!(ok + failed, jobs as u64, "accounting: every submit resolved exactly once");
    assert!(
        ok >= (jobs as u64) * 9 / 10,
        "retries should complete the vast majority of jobs: ok={ok} failed={failed}"
    );

    let snap = engine.snapshot();
    assert_eq!(snap.fleet.jobs_submitted, jobs as u64);
    assert_eq!(snap.fleet.jobs_completed, ok);
    assert_eq!(snap.fleet.jobs_failed, failed);
    assert!(snap.fleet.batch_errors > 0, "injected faults must surface as batch errors");
    assert!(snap.fleet.jobs_retried > 0, "failed batches must re-dispatch through retry");
    assert!(snap.fleet.health_transitions >= 2, "quarantine + probe must be recorded");

    // The survivors absorbed the fail-stopped card's work.
    assert!(snap.cards[0].jobs_completed > 0, "card0 (healthy) must serve");
    assert!(snap.cards[2].jobs_completed > 0, "card2 (flapping) must still serve");
    assert!(
        snap.cards[0].jobs_completed + snap.cards[2].jobs_completed
            > snap.cards[1].jobs_completed,
        "survivors must out-serve the fail-stopped card: {:?}",
        snap.cards.iter().map(|c| c.jobs_completed).collect::<Vec<_>>()
    );
    assert!(snap.cards[1].health_transitions >= 1);

    // Health plane: card 1 was quarantined, and (after its cooldown,
    // via the supervisor's tick) probed back in as Degraded. The probe
    // is time-driven, so poll briefly for the re-admit transition.
    let log = engine.health_transitions();
    assert!(
        log.iter().any(|t| t.card == 1 && t.to == HealthState::Quarantined),
        "fail-stopped card must be quarantined: {log:?}"
    );
    let t0 = Instant::now();
    let readmitted = loop {
        if engine
            .health_transitions()
            .iter()
            .any(|t| t.card == 1 && t.reason == "probe re-admit")
        {
            break true;
        }
        if t0.elapsed() > Duration::from_secs(5) {
            break false;
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    assert!(readmitted, "quarantined card must be probed back in after its cooldown");

    engine.shutdown();
}

/// `drain_card` quiesces one card without dropping accepted work: its
/// pending slots flush, its in-flight count reaches zero, and it stays
/// out of the routing set until `readmit_card`.
#[test]
fn drain_card_quiesces_and_readmit_restores_routing() {
    let fleet = (0..2)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::FixedBoost))
        .collect();
    let engine = Engine::start(sim_runtime(), fleet, EngineConfig::default()).expect("engine");
    let mut rng = Rng::new(7);
    let mut rxs = Vec::new();
    for _ in 0..32 {
        let (re, im) = rand_planes(1024, &mut rng);
        rxs.push(engine.submit(re, im).expect("submit"));
    }

    let remaining = engine.drain_card(0, Duration::from_secs(30));
    assert_eq!(remaining, 0, "drained card must fully quiesce");
    let snap = engine.snapshot();
    assert!(!snap.cards[0].accepting, "drained card must report not-accepting");
    assert!(snap.cards[1].accepting);

    // While card 0 is draining, new submits route exclusively to card 1.
    let before = snap.cards[0].jobs_submitted;
    for _ in 0..8 {
        let (re, im) = rand_planes(1024, &mut rng);
        rxs.push(engine.submit(re, im).expect("submit during drain"));
    }
    assert_eq!(
        engine.snapshot().cards[0].jobs_submitted,
        before,
        "no new jobs may land on a draining card"
    );

    engine.readmit_card(0);
    assert!(engine.snapshot().cards[0].accepting, "readmit must restore the card");

    assert!(engine.drain(Duration::from_secs(60)).complete);
    for rx in rxs {
        assert!(rx.recv().expect("recv").is_ok(), "no accepted job may be lost by a drain");
    }
    engine.shutdown();
}

/// Submitting while every card is draining fails fast with a typed
/// `CardUnavailable` — no hang, no panic — and readmitting recovers.
#[test]
fn submit_during_full_drain_is_typed_and_prompt() {
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    assert_eq!(engine.drain_card(0, Duration::from_secs(1)), 0);

    let t0 = Instant::now();
    let err = engine
        .submit(vec![0.0; 1024], vec![0.0; 1024])
        .expect_err("the only card is draining");
    assert!(t0.elapsed() < Duration::from_secs(1), "rejection must be prompt");
    match err.downcast_ref::<CoordError>() {
        Some(CoordError::CardUnavailable { reason }) => {
            assert!(
                reason.contains("draining or quarantined"),
                "reason should name the cause: {reason}"
            );
        }
        other => panic!("expected CardUnavailable, got {other:?}"),
    }
    // Rejected at admission: nothing was accounted as accepted.
    assert_eq!(engine.snapshot().fleet.jobs_submitted, 0);

    engine.readmit_card(0);
    let res = engine.execute(vec![0.0; 1024], vec![0.0; 1024]).expect("serves after readmit");
    assert_eq!(res.out_re.len(), 1024);
    engine.shutdown();
}

/// The drain-timeout path (satellite b): with an injected stall holding
/// a job in flight, a too-short drain reports `complete == false` plus
/// the per-card remaining counts, and a patient drain then finishes.
#[test]
fn drain_timeout_reports_per_card_remaining() {
    let cfg = EngineConfig {
        fault_plan: FaultPlan::parse("0:stall,after=0,for=1000000,ms=300").expect("chaos spec"),
        ..EngineConfig::default()
    };
    let engine = Engine::start_single(sim_runtime(), tesla_v100(), GovernorKind::FixedBoost, cfg)
        .expect("engine");
    let rx = engine.submit(vec![0.0; 1024], vec![0.0; 1024]).expect("submit");

    let report = engine.drain(Duration::from_millis(30));
    assert!(!report.complete, "stalled card cannot drain in 30ms");
    assert_eq!(report.remaining.len(), 1);
    assert!(report.remaining_total() >= 1, "the stalled job must be reported in flight");

    let report = engine.drain(Duration::from_secs(30));
    assert!(report.complete, "patient drain outlasts the stall");
    assert_eq!(report.remaining_total(), 0);
    assert!(rx.recv().expect("recv").is_ok(), "stalled jobs complete, never drop");
    engine.shutdown();
}

/// A job that fails on every attempt the policy allows is shed with a
/// typed `RetriesExhausted` carrying the burned attempt count, the shed
/// is accounted, and the hard-failed card lands in quarantine.
#[test]
fn retries_exhausted_is_typed_and_accounted() {
    let cfg = EngineConfig {
        fault_plan: FaultPlan::parse("0:failstop,after=0").expect("chaos spec"),
        retry: RetryPolicy {
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
            ..RetryPolicy::default()
        },
        health: HealthPolicy {
            // keep the card quarantined for the duration of the test so
            // the snapshot assertion below is deterministic.
            probe_cooldown: Duration::from_secs(60),
            ..HealthPolicy::default()
        },
        ..EngineConfig::default()
    };
    let engine = Engine::start_single(sim_runtime(), tesla_v100(), GovernorKind::FixedBoost, cfg)
        .expect("engine");
    let rx = engine.submit(vec![0.0; 1024], vec![0.0; 1024]).expect("submit");
    engine.flush();

    let err = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("shed job must still resolve its reply channel")
        .expect_err("every attempt fail-stops");
    match err.downcast_ref::<CoordError>() {
        Some(CoordError::RetriesExhausted { n, attempts, .. }) => {
            assert_eq!(*n, 1024);
            assert_eq!(*attempts, 2, "both allowed retries were burned");
        }
        other => panic!("expected RetriesExhausted, got {other:?}"),
    }

    assert!(engine.drain(Duration::from_secs(10)).complete, "shed job is accounted");
    let snap = engine.snapshot();
    assert_eq!(snap.fleet.jobs_submitted, 1);
    assert_eq!(snap.fleet.jobs_completed, 0);
    assert_eq!(snap.fleet.jobs_failed, 1);
    assert_eq!(snap.fleet.jobs_shed, 1);
    assert_eq!(snap.fleet.jobs_retried, 2);
    assert_eq!(snap.fleet.batch_errors, 3, "original attempt + 2 retries all errored");
    assert_eq!(snap.cards[0].health, "quarantined");
    assert_eq!(snap.fleet.cards_quarantined, 1);
    assert_eq!(engine.health().state(0), HealthState::Quarantined);
    engine.shutdown();
}
