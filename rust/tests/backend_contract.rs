//! Backend conformance suite: every `ExecBackend` the build carries must
//! honor the same contract — honest capability discovery, typed refusals
//! for out-of-envelope requests, shape-correct typed batch entry points,
//! and a time estimator that is monotone in N across kernel-count
//! boundaries (the paper's execution-time staircase, Figs 4/5).

use std::path::Path;
use std::sync::Arc;

use fftsweep::runtime::{
    backend_by_name, compiled_backend_names, default_backend, BackendError, CufftProfileBackend,
    ExecBackend,
};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::rng::Rng;

fn dir() -> &'static Path {
    Path::new("/nonexistent-artifacts")
}

/// Every backend the build compiled in, by name, through the same
/// construction path the CLI uses.
fn all_backends() -> Vec<Arc<dyn ExecBackend>> {
    let mut backends = vec![default_backend(dir()).expect("default backend")];
    for name in compiled_backend_names() {
        backends.push(backend_by_name(name, dir()).expect(name));
    }
    backends
}

#[test]
fn capabilities_are_honest_for_every_backend() {
    for b in all_backends() {
        let caps = b.capabilities();
        assert_eq!(caps.backend, b.name(), "caps must name their backend");
        assert!(caps.summary().starts_with(&format!("backend {}", b.name())));
        // Every artifact the backend's own manifest advertises for an
        // executable kind sits inside the envelope and actually loads.
        for meta in b.manifest().entries.values() {
            if !caps.kinds.iter().any(|k| *k == meta.kind) {
                continue;
            }
            assert!(
                caps.supports_len(meta.n),
                "{}: manifest advertises {} (n={}) outside the claimed envelope",
                b.name(),
                meta.name,
                meta.n
            );
            let m = b.load(&meta.name).unwrap_or_else(|e| {
                panic!("{}: advertised artifact {} failed to load: {e:#}", b.name(), meta.name)
            });
            assert_eq!(m.meta.name, meta.name);
        }
        // Off-envelope lengths are refused by the same caps admission
        // check the Batcher consults.
        assert!(!caps.supports_len(0), "{}: n=0 must stay refused", b.name());
    }
}

#[test]
fn typed_entry_points_produce_correct_shapes() {
    for b in all_backends() {
        let caps = b.capabilities();
        let mut rng = Rng::new(11);
        for meta in b.manifest().entries.values() {
            // Keep the suite fast: exercise the numerics on the small and
            // mid lengths; the large-N tier is covered by planner tests.
            if meta.n > 16384 || !caps.kinds.iter().any(|k| *k == meta.kind) {
                continue;
            }
            let (n, batch) = (meta.n as usize, meta.batch as usize);
            let m = b.load(&meta.name).expect("load");
            match meta.kind.as_str() {
                "fft" => {
                    let re: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
                    let im: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
                    let (mut o_re, mut o_im) = (Vec::new(), Vec::new());
                    b.run_fft_into(&m, &re, &im, &mut o_re, &mut o_im)
                        .unwrap_or_else(|e| panic!("{}: {} run: {e:#}", b.name(), meta.name));
                    assert_eq!(o_re.len(), batch * n, "{}: {}", b.name(), meta.name);
                    assert_eq!(o_im.len(), batch * n, "{}: {}", b.name(), meta.name);
                    // Parseval on row 0: the transform is a real FFT, not
                    // a resize that happened to return.
                    let e_time: f64 = (0..n)
                        .map(|i| (re[i] as f64).powi(2) + (im[i] as f64).powi(2))
                        .sum();
                    let e_freq: f64 = (0..n)
                        .map(|i| (o_re[i] as f64).powi(2) + (o_im[i] as f64).powi(2))
                        .sum::<f64>()
                        / n as f64;
                    assert!(
                        (e_time - e_freq).abs() < 1e-3 * e_time.max(1.0),
                        "{}: {} violates Parseval: {e_time} vs {e_freq}",
                        b.name(),
                        meta.name
                    );
                }
                "rfft" => {
                    let x: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
                    let (mut o_re, mut o_im) = (Vec::new(), Vec::new());
                    b.run_rfft_into(&m, &x, &mut o_re, &mut o_im)
                        .unwrap_or_else(|e| panic!("{}: {} run: {e:#}", b.name(), meta.name));
                    let bins = n / 2 + 1;
                    assert_eq!(o_re.len(), batch * bins, "{}: {}", b.name(), meta.name);
                    assert_eq!(o_im.len(), batch * bins, "{}: {}", b.name(), meta.name);
                }
                "conv" => {
                    let x: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
                    let mut out = Vec::new();
                    b.run_conv_into(&m, &x, &mut out)
                        .unwrap_or_else(|e| panic!("{}: {} run: {e:#}", b.name(), meta.name));
                    assert_eq!(out.len(), batch * n, "{}: {}", b.name(), meta.name);
                }
                _ => {}
            }
        }
    }
}

#[test]
fn cufft_profile_refuses_unsupported_kinds_typed() {
    let b = CufftProfileBackend::new(dir()).expect("cufft-profile backend");
    let caps = b.capabilities();
    assert_eq!(caps.kinds, vec!["fft"], "replay backend prices C2C only");
    // The filtered manifest carries no rfft/conv entries at all.
    assert!(b.manifest().entries.values().all(|a| a.kind == "fft"));
    // And the typed entry points refuse those kinds with BackendError,
    // not a panic or a stringly error.
    let m = b.load("fft_f32_n1024_b64").expect("fft module");
    let x = vec![0.0f32; (m.meta.batch * m.meta.n) as usize];
    let (mut o_re, mut o_im) = (Vec::new(), Vec::new());
    for err in [
        b.run_rfft_into(&m, &x, &mut o_re, &mut o_im).unwrap_err(),
        b.run_conv_into(&m, &x, &mut o_re).unwrap_err(),
    ] {
        match err.downcast_ref::<BackendError>() {
            Some(BackendError::Unsupported { backend, n, .. }) => {
                assert_eq!(*backend, "cufft-profile");
                assert_eq!(*n, 1024);
            }
            other => panic!("expected BackendError::Unsupported, got {other:?} ({err:#})"),
        }
    }
}

#[test]
fn estimates_are_monotone_in_n_across_kernel_boundaries() {
    // 1024 / 2^14 / 2^21 straddle the plan model's kernel-count
    // boundaries (1, 2 and 3 kernels); estimates must rise strictly —
    // and never plateau — for every backend, so admission heuristics can
    // rely on "bigger transform, longer batch" regardless of target.
    let g = tesla_v100();
    for b in all_backends() {
        let t: Vec<f64> = [1024u64, 1 << 14, 1 << 21]
            .iter()
            .map(|&n| {
                let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
                b.estimate_time_s(&g, &w)
            })
            .collect();
        assert!(
            t.iter().all(|x| x.is_finite() && *x > 0.0),
            "{}: degenerate estimates {t:?}",
            b.name()
        );
        assert!(
            t[0] < t[1] && t[1] < t[2],
            "{}: estimate not monotone across kernel boundaries: {t:?}",
            b.name()
        );
    }
}

#[test]
fn backend_by_name_fails_loud_on_unknown_names() {
    let err = backend_by_name("warp-drive", dir()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown backend"), "got: {msg}");
    for name in compiled_backend_names() {
        assert!(msg.contains(name), "error must list compiled backend '{name}': {msg}");
    }
}

#[test]
fn engine_serves_end_to_end_through_an_erased_backend() {
    use fftsweep::coordinator::{Engine, EngineConfig};
    use fftsweep::governor::GovernorKind;
    // The coordinator's only runtime dependency is `dyn ExecBackend`: a
    // type-erased default backend drives a single-card fleet end to end.
    let backend = default_backend(dir()).expect("default backend");
    let engine = Engine::start_single(
        backend,
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine over dyn backend");
    let n = 1024usize;
    let mut rng = Rng::new(3);
    let re: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
    let res = engine.execute(re, im).expect("execute");
    assert_eq!(res.out_re.len(), n);
    assert_eq!(res.out_im.len(), n);
    assert_eq!(engine.backend().name(), engine.backend().capabilities().backend);
    engine.shutdown();
}
