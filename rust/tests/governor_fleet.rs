//! Fleet-engine integration tests over the simulated runtime backend.
//!
//! These run in a fresh checkout: the default (non-`xla`) runtime
//! synthesizes its manifest, so the whole serving stack — router,
//! least-loaded dispatch, per-card governors, NVML bracketing, metrics —
//! is exercised without any AOT artifacts on disk.

#![cfg(not(feature = "xla"))]

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use fftsweep::coordinator::{CardConfig, Engine, EngineConfig};
use fftsweep::dsp;
use fftsweep::governor::GovernorKind;
use fftsweep::runtime::Runtime;
use fftsweep::sim::gpu::{tesla_p4, tesla_v100};
use fftsweep::util::rng::Rng;

fn sim_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).expect("sim runtime"))
}

fn rand_planes(n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    (
        (0..n).map(|_| rng.gauss() as f32).collect(),
        (0..n).map(|_| rng.gauss() as f32).collect(),
    )
}

#[test]
fn single_card_serves_correct_ffts() {
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedClock(945.0),
        EngineConfig::default(),
    )
    .expect("engine");
    let n = 1024usize;
    let mut rng = Rng::new(3);
    let (re, im) = rand_planes(n, &mut rng);
    let x: Vec<dsp::C64> = re
        .iter()
        .zip(&im)
        .map(|(&r, &i)| dsp::C64::new(r as f64, i as f64))
        .collect();
    let want = dsp::fft(&x);
    let res = engine.execute(re, im).expect("job");
    for i in 0..n {
        assert!((res.out_re[i] as f64 - want[i].re).abs() < 1e-2, "bin {i}");
        assert!((res.out_im[i] as f64 - want[i].im).abs() < 1e-2, "bin {i}");
    }
    // locked below boost → the accounting must show a saving
    assert!(engine.metrics.energy_saving() > 0.15);
    engine.shutdown();
}

#[test]
fn fleet_spreads_load_and_aggregates_metrics() {
    let fleet = (0..4)
        .map(|_| CardConfig::new(tesla_v100(), GovernorKind::CommonClock))
        .collect();
    let engine = Engine::start(sim_runtime(), fleet, EngineConfig::default()).expect("engine");
    let mut rng = Rng::new(9);
    let n = 256usize;
    let jobs = 64usize;
    let mut rxs = Vec::new();
    for _ in 0..jobs {
        let (re, im) = rand_planes(n, &mut rng);
        rxs.push(engine.submit(re, im).expect("submit"));
    }
    assert!(engine.drain(Duration::from_secs(60)).complete, "drain timed out");
    for rx in rxs {
        assert!(rx.recv().expect("recv").is_ok());
    }

    // least-loaded dispatch spread jobs over every card. Exact 16/16/16/16
    // balance holds unless the submit loop is preempted past the 2 ms
    // flush timeout, so only a coarse floor is asserted.
    let per_card: Vec<u64> = engine
        .cards()
        .iter()
        .map(|c| c.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed))
        .collect();
    assert_eq!(per_card.iter().sum::<u64>(), jobs as u64);
    assert!(
        per_card.iter().all(|&c| c >= 4),
        "least-loaded must spread jobs over every card: {per_card:?}"
    );

    // per-card + fleet aggregate energy accounting: common clock < boost
    for c in engine.cards() {
        assert!(
            c.metrics.energy_saving() > 0.10,
            "card saving {}",
            c.metrics.energy_saving()
        );
        assert_eq!(c.inflight(), 0);
    }
    assert!(engine.metrics.energy_saving() > 0.10);
    let report = engine.fleet_report();
    assert_eq!(report.lines().count(), 5, "4 card lines + 1 fleet line");
    assert!(report.contains("card3"));
    assert!(report.contains("fleet:"));

    let last = engine.shutdown();
    assert!(last.starts_with("final"), "shutdown must emit a final summary: {last}");
    assert!(last.contains("jobs 64/64"));
}

#[test]
fn heterogeneous_fleet_reports_per_card_specs() {
    let fleet = vec![
        CardConfig::new(tesla_v100(), GovernorKind::CommonClock),
        CardConfig::new(tesla_p4(), GovernorKind::CommonClock),
    ];
    let engine = Engine::start(sim_runtime(), fleet, EngineConfig::default()).expect("engine");
    let mut rng = Rng::new(4);
    let mut rxs = Vec::new();
    for _ in 0..8 {
        let (re, im) = rand_planes(1024, &mut rng);
        rxs.push(engine.submit(re, im).expect("submit"));
    }
    assert!(engine.drain(Duration::from_secs(60)).complete);
    for rx in rxs {
        assert!(rx.recv().expect("recv").is_ok());
    }
    let report = engine.fleet_report();
    assert!(report.contains("Tesla V100"));
    assert!(report.contains("Tesla P4"));
    engine.shutdown();
}

#[test]
fn fleet_governors_are_per_card_instances() {
    // Two cards under the adaptive governor: each worker owns its own
    // instance, so both descend independently from boost.
    let fleet = vec![
        CardConfig::new(tesla_v100(), GovernorKind::Adaptive),
        CardConfig::new(tesla_v100(), GovernorKind::Adaptive),
    ];
    let engine = Engine::start(sim_runtime(), fleet, EngineConfig::default()).expect("engine");
    let mut rng = Rng::new(5);
    for _ in 0..6 {
        let mut rxs = Vec::new();
        for _ in 0..8 {
            let (re, im) = rand_planes(4096, &mut rng);
            rxs.push(engine.submit(re, im).expect("submit"));
        }
        assert!(engine.drain(Duration::from_secs(60)).complete);
        for rx in rxs {
            assert!(rx.recv().expect("recv").is_ok());
        }
    }
    // adaptive never does worse than boost, on either card
    for c in engine.cards() {
        assert!(c.metrics.energy_saving() >= -1e-9);
        assert!(c.metrics.batches_executed.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
    engine.shutdown();
}

#[test]
fn execute_flushes_only_its_own_slot() {
    // A pending partial batch on another artifact must NOT be force-flushed
    // by an unrelated execute(): it keeps packing toward full occupancy.
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig {
            // Disable the timeout flusher for the duration of the test so
            // only explicit flushes can release the partial batch.
            max_batch_wait: Duration::from_secs(3600),
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(17);

    // One n=256 job: batch 256 on that artifact, so it stays pending.
    let (re, im) = rand_planes(256, &mut rng);
    let pending_rx = engine.submit(re, im).expect("submit");

    // An unrelated n=1024 execute() completes without disturbing it.
    let (re, im) = rand_planes(1024, &mut rng);
    let res = engine.execute(re, im).expect("execute");
    assert_eq!(res.out_re.len(), 1024);
    assert_eq!(res.batch_occupancy, 1);
    assert!(
        matches!(pending_rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Empty)),
        "partial n=256 batch must still be packing after an unrelated execute()"
    );

    // A fleet-wide flush (the drain/shutdown primitive) releases it.
    engine.flush();
    assert!(engine.drain(Duration::from_secs(60)).complete);
    assert!(pending_rx.recv().expect("recv").is_ok());
    engine.shutdown();
}

#[test]
fn engine_serves_off_grid_lengths_end_to_end() {
    // The tentpole's serving acceptance: non-power-of-two lengths flow
    // through router → batcher → worker → planner (mixed radix) and come
    // back numerically correct vs the naive DFT.
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::PerLengthOptimal,
        EngineConfig::default(),
    )
    .expect("engine");
    let mut rng = Rng::new(23);
    for n in [1000usize, 1536] {
        let (re, im) = rand_planes(n, &mut rng);
        let x: Vec<dsp::C64> = re
            .iter()
            .zip(&im)
            .map(|(&r, &i)| dsp::C64::new(r as f64, i as f64))
            .collect();
        let want = dsp::fft::dft_naive(&x);
        let res = engine.execute(re, im).expect("off-grid job");
        assert_eq!(res.out_re.len(), n);
        for i in 0..n {
            assert!(
                (res.out_re[i] as f64 - want[i].re).abs() < 1e-2
                    && (res.out_im[i] as f64 - want[i].im).abs() < 1e-2,
                "n={n} bin {i}"
            );
        }
    }
    engine.shutdown();
}

#[test]
fn unroutable_length_is_a_typed_rejection() {
    use fftsweep::coordinator::CoordError;
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    let err = engine
        .submit(vec![0.0; 123], vec![0.0; 123])
        .expect_err("n=123 has no artifact");
    match err.downcast_ref::<CoordError>() {
        Some(CoordError::UnsupportedLength { n, dtype, supported }) => {
            assert_eq!(*n, 123);
            assert_eq!(dtype, "f32");
            for want in [1000u64, 1024, 1536] {
                assert!(supported.contains(&want), "{want} missing from {supported:?}");
            }
        }
        other => panic!("expected UnsupportedLength, got {other:?}"),
    }
    // The rejection is accounted as a failure, not a lost job.
    assert!(engine.drain(std::time::Duration::from_secs(10)).complete);
    engine.shutdown();
}

#[test]
fn shutdown_is_deterministic_and_idempotent_per_engine() {
    // No jobs at all: shutdown must still join cleanly and report zeros.
    let engine = Engine::start_single(
        sim_runtime(),
        tesla_v100(),
        GovernorKind::FixedBoost,
        EngineConfig::default(),
    )
    .expect("engine");
    let summary = engine.shutdown();
    assert!(summary.contains("jobs 0/0"), "{summary}");
}
