//! `fftsweep` — the L3 leader binary.
//!
//! Subcommands:
//!   report            run the sweep grid, write every table/figure CSV
//!   table <1|2|3|4>   print one paper table
//!   figure <2..20>    print one paper figure's series
//!   sweep             sweep one GPU/precision, print optima
//!   pipeline          run the section-5.3 pipeline comparison (Table 4)
//!   selftest          load AOT artifacts, run them, verify vs rust oracle
//!   serve             fleet demo: batch-serve FFT jobs across N governed cards
//!   govern            replay one traffic trace under every clock governor
//!
//! `fftsweep --help` prints usage.

use fftsweep::util::cliargs::Args;

mod cli;

fn main() {
    let args = Args::from_env();
    let code = match cli::dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}
