//! The two measurement log files the paper's protocol produces, and their
//! timestamp merge (the job the authors' R script does — section 4):
//!
//!   * an nvidia-smi/tegrastats style log: timestamp, power, core clock,
//!     memory clock, sampled every ~10-14 ms,
//!   * an nvprof style log: begin/end timestamps of every GPU kernel.
//!
//! `merge` localizes the FFT kernels inside the smi log (the red dots of
//! Fig 2) and verifies the requested clock was actually applied.

use crate::sim::sensor::PowerSample;

/// One nvprof-style kernel event.
#[derive(Debug, Clone)]
pub struct KernelEvent {
    pub name: String,
    pub begin_s: f64,
    pub end_s: f64,
}

/// The merged view of one measurement run.
#[derive(Debug, Clone)]
pub struct MergedLog {
    /// Samples falling inside any kernel interval (compute samples).
    pub compute: Vec<PowerSample>,
    /// Samples outside every kernel interval (grey dots of Fig 2).
    pub noncompute: Vec<PowerSample>,
    /// nvprof total kernel time (the paper's execution-time source).
    pub kernel_time_s: f64,
    /// Whether every compute sample reports the requested clock
    /// (the Titan V capping check of section 4).
    pub clock_honoured: bool,
    /// Maximum clock observed while computing.
    pub observed_clock_mhz: f64,
}

/// Render samples in the nvidia-smi CSV dialect (timestamp-ms, W, MHz, MHz).
pub fn render_smi_log(samples: &[PowerSample]) -> String {
    let mut out = String::from("timestamp_ms,power_w,core_clock_mhz,mem_clock_mhz\n");
    for s in samples {
        out.push_str(&format!(
            "{:.1},{:.2},{:.0},{:.0}\n",
            s.timestamp_s * 1e3,
            s.power_w,
            s.core_clock_mhz,
            s.mem_clock_mhz
        ));
    }
    out
}

/// Render kernel events in an nvprof-like CSV dialect.
pub fn render_nvprof_log(events: &[KernelEvent]) -> String {
    let mut out = String::from("kernel,begin_ms,end_ms\n");
    for e in events {
        out.push_str(&format!(
            "{},{:.3},{:.3}\n",
            e.name,
            e.begin_s * 1e3,
            e.end_s * 1e3
        ));
    }
    out
}

/// Parse the smi CSV back (round-trip used by tests and the `figure 2` CLI).
pub fn parse_smi_log(text: &str) -> Vec<PowerSample> {
    text.lines()
        .skip(1)
        .filter_map(|l| {
            let mut it = l.split(',');
            Some(PowerSample {
                timestamp_s: it.next()?.parse::<f64>().ok()? / 1e3,
                power_w: it.next()?.parse().ok()?,
                core_clock_mhz: it.next()?.parse().ok()?,
                mem_clock_mhz: it.next()?.parse().ok()?,
            })
        })
        .collect()
}

/// Merge the two logs by timestamp (the R-script step).
///
/// Both logs are chronologically ordered (they are append-only recordings),
/// so the kernel localization is a two-pointer scan: O(samples + events)
/// rather than O(samples × events) — the harness merges timelines with
/// thousands of repeated-batch kernel events per measurement (§Perf).
pub fn merge(
    samples: &[PowerSample],
    events: &[KernelEvent],
    requested_clock_mhz: f64,
) -> MergedLog {
    debug_assert!(samples.windows(2).all(|w| w[0].timestamp_s <= w[1].timestamp_s));
    debug_assert!(events.windows(2).all(|w| w[0].begin_s <= w[1].begin_s));
    let mut compute = Vec::new();
    let mut noncompute = Vec::new();
    let mut ei = 0usize;
    for s in samples {
        let t = s.timestamp_s;
        while ei < events.len() && events[ei].end_s <= t {
            ei += 1;
        }
        if ei < events.len() && t >= events[ei].begin_s && t < events[ei].end_s {
            compute.push(*s);
        } else {
            noncompute.push(*s);
        }
    }
    let kernel_time_s = events.iter().map(|e| e.end_s - e.begin_s).sum();
    let observed_clock_mhz = compute
        .iter()
        .map(|s| s.core_clock_mhz)
        .fold(0.0_f64, f64::max);
    let clock_honoured = compute
        .iter()
        .all(|s| (s.core_clock_mhz - requested_clock_mhz).abs() < 1.0);
    MergedLog {
        compute,
        noncompute,
        kernel_time_s,
        clock_honoured,
        observed_clock_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, p: f64, clk: f64) -> PowerSample {
        PowerSample {
            timestamp_s: t,
            power_w: p,
            core_clock_mhz: clk,
            mem_clock_mhz: 877.0,
        }
    }

    #[test]
    fn merge_splits_compute_from_noncompute() {
        let samples: Vec<PowerSample> =
            (0..10).map(|i| sample(i as f64 * 0.1, 100.0, 1000.0)).collect();
        let events = vec![KernelEvent {
            name: "fft".into(),
            begin_s: 0.25,
            end_s: 0.65,
        }];
        let m = merge(&samples, &events, 1000.0);
        assert_eq!(m.compute.len(), 4); // t = .3, .4, .5, .6
        assert_eq!(m.noncompute.len(), 6);
        assert!((m.kernel_time_s - 0.4).abs() < 1e-12);
        assert!(m.clock_honoured);
    }

    #[test]
    fn capped_clock_detected() {
        // Titan V case: requested 1912 but computes at 1335.
        let samples = vec![sample(0.1, 150.0, 1335.0), sample(0.2, 150.0, 1335.0)];
        let events = vec![KernelEvent {
            name: "fft".into(),
            begin_s: 0.0,
            end_s: 0.3,
        }];
        let m = merge(&samples, &events, 1912.0);
        assert!(!m.clock_honoured);
        assert_eq!(m.observed_clock_mhz, 1335.0);
    }

    #[test]
    fn smi_log_roundtrip() {
        let samples = vec![sample(0.0142, 213.55, 945.0), sample(0.0289, 210.0, 945.0)];
        let text = render_smi_log(&samples);
        let back = parse_smi_log(&text);
        assert_eq!(back.len(), 2);
        assert!((back[0].timestamp_s - 0.0142).abs() < 1e-4);
        assert!((back[0].power_w - 213.55).abs() < 1e-9);
        assert_eq!(back[1].core_clock_mhz, 945.0);
    }

    #[test]
    fn nvprof_log_rendering() {
        let ev = vec![KernelEvent {
            name: "vector_fft_radix8".into(),
            begin_s: 0.001,
            end_s: 0.004,
        }];
        let text = render_nvprof_log(&ev);
        assert!(text.contains("vector_fft_radix8,1.000,4.000"));
    }

    #[test]
    fn merge_with_multi_kernel_events() {
        let samples: Vec<PowerSample> =
            (0..20).map(|i| sample(i as f64 * 0.05, 100.0, 900.0)).collect();
        let events = vec![
            KernelEvent { name: "pass0".into(), begin_s: 0.10, end_s: 0.30 },
            KernelEvent { name: "pass1".into(), begin_s: 0.50, end_s: 0.70 },
        ];
        let m = merge(&samples, &events, 900.0);
        assert!((m.kernel_time_s - 0.4).abs() < 1e-12);
        assert!(m.compute.len() >= 6);
    }
}
