//! Frequency sweep orchestrator: the paper's full measurement grid —
//! every supported clock × every FFT length × every precision × every GPU.

use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};

use super::measure::{measure_point, Measurement, Protocol};

/// FFT lengths in the paper's test set: powers of two 2^5..2^22 (the top
/// octave covers the planner's four-step tier), a few smooth
/// non-powers-of-two, and Bluestein lengths (139², a large prime
/// multiple).
pub fn paper_lengths() -> Vec<u64> {
    let mut v: Vec<u64> = (5..=22).map(|k| 1u64 << k).collect();
    v.extend([96, 768, 1536, 3 * 4096, 5 * 4096, 3 << 20, 1000000]); // smooth non-pow2
    v.extend([19321, 32771 * 2]); // Bluestein (139², 2·prime)
    v.sort_unstable();
    v
}

/// A reduced length set for quick sweeps and tests (2^22 keeps the
/// four-step tier represented).
pub fn quick_lengths() -> Vec<u64> {
    vec![256, 1024, 8192, 16384, 1 << 18, 1 << 22, 19321]
}

/// Only power-of-two lengths (the FP16 constraint).
pub fn pow2_only(lengths: &[u64]) -> Vec<u64> {
    lengths
        .iter()
        .copied()
        .filter(|n| n & (n - 1) == 0)
        .collect()
}

/// The sweep result for one FFT length: one Measurement per clock.
#[derive(Debug, Clone)]
pub struct LengthSweep {
    pub n: u64,
    pub precision: Precision,
    pub points: Vec<Measurement>,
}

impl LengthSweep {
    pub fn frequencies(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.f_mhz).collect()
    }

    /// Measurement at (or nearest to) a given clock.
    pub fn at(&self, f_mhz: f64) -> &Measurement {
        self.points
            .iter()
            .min_by(|a, b| {
                (a.f_mhz - f_mhz)
                    .abs()
                    .partial_cmp(&(b.f_mhz - f_mhz).abs())
                    .unwrap()
            })
            .expect("empty sweep")
    }

    /// The default (boost-clock) point.
    pub fn default_point(&self, gpu: &GpuSpec) -> &Measurement {
        self.at(gpu.boost_clock_mhz)
    }
}

/// Full sweep for one (gpu, precision): every length × every clock.
#[derive(Debug, Clone)]
pub struct GpuSweep {
    pub gpu_name: String,
    pub precision: Precision,
    pub lengths: Vec<LengthSweep>,
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub lengths: Vec<u64>,
    /// Take every k-th table frequency (1 = the paper's full grid).
    pub freq_stride: usize,
    pub protocol: Protocol,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            lengths: paper_lengths(),
            freq_stride: 4,
            protocol: Protocol::default(),
        }
    }
}

impl SweepConfig {
    pub fn quick() -> Self {
        Self {
            lengths: quick_lengths(),
            freq_stride: 12,
            protocol: Protocol::quick(),
        }
    }
}

/// Run the sweep for one gpu/precision pair. Lengths unsupported by the
/// precision (FP16 non-pow2) are skipped, mirroring cuFFT's support matrix.
pub fn sweep_gpu(gpu: &GpuSpec, precision: Precision, cfg: &SweepConfig) -> GpuSweep {
    assert!(
        gpu.supports(precision),
        "{} does not support {}",
        gpu.name,
        precision
    );
    let lengths: Vec<u64> = if precision == Precision::Fp16 {
        pow2_only(&cfg.lengths)
    } else {
        cfg.lengths.clone()
    };
    let freqs = freq_table(gpu).stride(cfg.freq_stride);
    let sweeps = lengths
        .iter()
        .map(|&n| {
            let w = FftWorkload::new(n, precision, gpu.working_set_bytes);
            let points = freqs
                .iter()
                .map(|&f| measure_point(gpu, &w, f, &cfg.protocol))
                .collect();
            LengthSweep { n, precision, points }
        })
        .collect();
    GpuSweep {
        gpu_name: gpu.name.to_string(),
        precision,
        lengths: sweeps,
    }
}

/// Every supported (gpu, precision) sweep for a set of GPUs.
pub fn sweep_all(gpus: &[GpuSpec], cfg: &SweepConfig) -> Vec<(GpuSpec, GpuSweep)> {
    let mut out = Vec::new();
    for gpu in gpus {
        for p in Precision::ALL {
            if gpu.supports(p) {
                out.push((gpu.clone(), sweep_gpu(gpu, p, cfg)));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{tesla_p4, tesla_v100};

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            lengths: vec![1024, 16384],
            freq_stride: 30,
            protocol: Protocol { reps_per_run: 4, runs: 3, seed: 11 },
        }
    }

    #[test]
    fn sweep_covers_grid() {
        let g = tesla_v100();
        let cfg = tiny_cfg();
        let s = sweep_gpu(&g, Precision::Fp32, &cfg);
        assert_eq!(s.lengths.len(), 2);
        let nf = freq_table(&g).stride(30).len();
        for l in &s.lengths {
            assert_eq!(l.points.len(), nf);
        }
    }

    #[test]
    fn fp16_drops_non_pow2() {
        let g = tesla_v100();
        let mut cfg = tiny_cfg();
        cfg.lengths = vec![1024, 19321, 4096];
        let s = sweep_gpu(&g, Precision::Fp16, &cfg);
        let ns: Vec<u64> = s.lengths.iter().map(|l| l.n).collect();
        assert_eq!(ns, vec![1024, 4096]);
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn p4_fp16_rejected() {
        sweep_gpu(&tesla_p4(), Precision::Fp16, &tiny_cfg());
    }

    #[test]
    fn paper_lengths_sorted_unique_and_has_bluestein() {
        let ls = paper_lengths();
        assert!(ls.windows(2).all(|w| w[0] < w[1]));
        assert!(ls.contains(&19321));
        assert!(ls.contains(&(1 << 21)));
        assert!(ls.contains(&32));
    }

    #[test]
    fn at_finds_nearest_clock() {
        let g = tesla_v100();
        let s = sweep_gpu(&g, Precision::Fp32, &tiny_cfg());
        let m = s.lengths[0].at(946.0);
        assert!((m.f_mhz - 946.0).abs() < 120.0);
        let d = s.lengths[0].default_point(&g);
        assert!((d.f_mhz - g.boost_clock_mhz).abs() < 120.0);
    }
}
