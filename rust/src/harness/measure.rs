//! One measured point: run a workload at one clock through the simulator's
//! sensor pipeline exactly the way the paper measures a physical card —
//! repeated runs, noisy sampled power, kernel localization by timestamp
//! merge, relative-std measurement error.

use crate::cufft::plan::plan;
use crate::harness::energy;
use crate::harness::logs::{merge, KernelEvent};
use crate::sim::sensor::{sample_timeline, SensorConfig};
use crate::sim::{batch_timeline, GpuSpec};
use crate::types::FftWorkload;
use crate::util::rng::Rng;
use crate::util::stats;

/// Measurement protocol parameters (paper section 4).
#[derive(Debug, Clone)]
pub struct Protocol {
    /// Back-to-back batch repetitions per run (enough to dwarf the 14 ms
    /// sampling interval).
    pub reps_per_run: usize,
    /// Independent runs used for the relative-std measurement error.
    pub runs: usize,
    /// Master seed; every (gpu, N, f) point derives its own stream.
    pub seed: u64,
}

impl Default for Protocol {
    fn default() -> Self {
        Self { reps_per_run: 20, runs: 8, seed: 0x5eed }
    }
}

impl Protocol {
    /// A cheaper protocol for wide sweeps.
    pub fn quick() -> Self {
        Self { reps_per_run: 8, runs: 4, seed: 0x5eed }
    }
}

/// Everything measured at one (gpu, workload, clock) point.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub f_mhz: f64,
    /// Mean measured energy per batch, J (eq. 3 over sensor samples).
    pub energy_j: f64,
    /// Relative std of the energy across runs (the paper's measurement
    /// error, Fig 3).
    pub energy_rel_err: f64,
    /// Execution time per batch from the nvprof-style log, s.
    pub time_s: f64,
    /// Mean power over the kernels, W.
    pub avg_power_w: f64,
    /// eq. 5 computational performance, FLOPS.
    pub perf_flops: f64,
    /// eq. 4 energy efficiency, FLOPS/W.
    pub efficiency: f64,
    /// Whether the driver honoured the requested clock (Titan V cap).
    pub clock_honoured: bool,
    /// Number of kernels in the plan (Bluestein detection etc.).
    pub kernel_count: usize,
}

/// Measure one point. Deterministic given `protocol.seed`.
pub fn measure_point(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    f_mhz: f64,
    protocol: &Protocol,
) -> Measurement {
    let sensor = SensorConfig::for_gpu(gpu);
    let fft_plan = plan(workload.n, workload.precision);
    let mut master = Rng::new(
        protocol
            .seed
            ^ (workload.n.wrapping_mul(0x9E3779B97F4A7C15))
            ^ ((f_mhz * 10.0) as u64).wrapping_mul(0xC2B2AE3D27D4EB4F),
    );

    // Repeat batches until the compute window dwarfs the ~14 ms sampling
    // interval (the paper runs the FFT "multiple times" for this reason).
    let probe = crate::sim::run_batch(gpu, workload, f_mhz);
    let min_window_s = 0.30;
    let reps = protocol
        .reps_per_run
        .max((min_window_s / probe.timing.total_s.max(1e-6)).ceil() as usize)
        .min(4000);
    let (timeline, run) = batch_timeline(gpu, workload, f_mhz, reps);

    // nvprof events: kernel begin/end inside the timeline.
    let mut events = Vec::new();
    let mut t = 0.0;
    for (i, &(d, _, is_compute)) in timeline.segments.iter().enumerate() {
        if is_compute {
            events.push(KernelEvent {
                name: format!("fft_pass_{}", i % fft_plan.kernel_count().max(1)),
                begin_s: t,
                end_s: t + d,
            });
        }
        t += d;
    }

    let effective_clock = gpu.effective_clock(f_mhz);
    let mut energies = Vec::with_capacity(protocol.runs);
    let mut clock_ok = true;
    // Run-to-run variability: the instrumentation amplifiers drift between
    // runs (thermal/calibration state), on top of per-sample noise. Multi-
    // kernel plans (Bluestein) load the GPU unevenly, widening the spread,
    // and the spread grows at low clocks (paper Fig 3 / section 4).
    let kernel_spread = 1.0 + 0.04 * (fft_plan.kernel_count() as f64 - 1.0);
    let low_clock_spread = 1.0 + 0.5 * (1.0 - f_mhz / gpu.boost_clock_mhz).max(0.0);
    let drift_sd = 0.8 * gpu.sensor_noise_sd * kernel_spread * low_clock_spread;
    for r in 0..protocol.runs {
        let mut rng = master.fork(r as u64);
        let run_gain = (1.0 + drift_sd * rng.gauss()).max(0.2);
        let samples = sample_timeline(
            &timeline,
            &sensor,
            effective_clock,
            gpu.mem_clock_mhz,
            &mut rng,
        );
        let merged = merge(&samples, &events, f_mhz);
        clock_ok &= merged.clock_honoured;
        // energy over the compute samples only, scaled to one batch
        let e_run = energy::energy_from_samples(&merged.compute) * run_gain;
        energies.push(e_run / reps as f64);
    }

    let time_s = run.timing.total_s;
    let energy_j = stats::mean(&energies);
    let energy_rel_err = stats::rel_std(&energies);
    let perf_flops = energy::performance_flops(workload, 1, time_s);
    let efficiency = energy::energy_efficiency(perf_flops, time_s, energy_j.max(1e-12));

    Measurement {
        f_mhz,
        energy_j,
        energy_rel_err,
        time_s,
        avg_power_w: run.avg_power_w,
        perf_flops,
        efficiency,
        clock_honoured: clock_ok,
        kernel_count: fft_plan.kernel_count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{jetson_nano, tesla_v100, titan_v};
    use crate::types::Precision;

    fn quick() -> Protocol {
        Protocol { reps_per_run: 6, runs: 4, seed: 1 }
    }

    #[test]
    fn measured_energy_tracks_ground_truth() {
        let g = tesla_v100();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let m = measure_point(&g, &w, 1000.0, &quick());
        let truth = crate::sim::run_batch(&g, &w, 1000.0).energy_j;
        assert!(
            (m.energy_j - truth).abs() / truth < 0.10,
            "measured {} vs truth {}",
            m.energy_j,
            truth
        );
    }

    #[test]
    fn measurement_error_in_paper_band() {
        // ~5% for discrete cards (Fig 3)
        let g = tesla_v100();
        let w = FftWorkload::new(4096, Precision::Fp32, g.working_set_bytes);
        let m = measure_point(&g, &w, 945.0, &quick());
        assert!(m.energy_rel_err < 0.10, "rel err {}", m.energy_rel_err);
    }

    #[test]
    fn jetson_noisier_than_v100() {
        let v = tesla_v100();
        let j = jetson_nano();
        let wv = FftWorkload::new(1024, Precision::Fp32, v.working_set_bytes);
        let wj = FftWorkload::new(1024, Precision::Fp32, j.working_set_bytes);
        let p = Protocol { reps_per_run: 6, runs: 8, seed: 3 };
        let mv = measure_point(&v, &wv, 945.0, &p);
        let mj = measure_point(&j, &wj, 460.8, &p);
        assert!(
            mj.energy_rel_err > mv.energy_rel_err,
            "jetson {} !> v100 {}",
            mj.energy_rel_err,
            mv.energy_rel_err
        );
    }

    #[test]
    fn titan_v_clock_not_honoured_above_cap() {
        let g = titan_v();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let m_hi = measure_point(&g, &w, 1912.0, &quick());
        let m_lo = measure_point(&g, &w, 1000.0, &quick());
        assert!(!m_hi.clock_honoured);
        assert!(m_lo.clock_honoured);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = tesla_v100();
        let w = FftWorkload::new(1024, Precision::Fp32, g.working_set_bytes);
        let a = measure_point(&g, &w, 900.0, &quick());
        let b = measure_point(&g, &w, 900.0, &quick());
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.energy_rel_err, b.energy_rel_err);
    }

    #[test]
    fn bluestein_reports_many_kernels() {
        let g = tesla_v100();
        let w = FftWorkload::new(19321, Precision::Fp32, g.working_set_bytes);
        let m = measure_point(&g, &w, 945.0, &quick());
        assert!(m.kernel_count >= 10);
    }
}
