//! Measurement harness: the paper's experimental protocol (section 4) —
//! sweep orchestration, smi/nvprof log emulation + merge, and the energy
//! metric definitions (eqs. 3-8).

pub mod campaign;
pub mod energy;
pub mod logs;
pub mod measure;
pub mod sweep;

pub use measure::{measure_point, Measurement, Protocol};
pub use sweep::{sweep_all, sweep_gpu, GpuSweep, LengthSweep, SweepConfig};
