//! Parallel measurement campaign: fan the full (gpu × precision × length ×
//! clock) grid across worker threads. The simulator is deterministic per
//! point, so parallel execution reproduces the serial results exactly —
//! property-tested below.

use std::sync::mpsc;
use std::sync::Arc;

use crate::harness::measure::{measure_point, Measurement, Protocol};
use crate::harness::sweep::{GpuSweep, LengthSweep, SweepConfig};
use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};

/// One grid point job.
#[derive(Debug, Clone)]
struct Point {
    length_idx: usize,
    freq_idx: usize,
    n: u64,
    f_mhz: f64,
}

/// Run a sweep with `threads` workers. Equivalent to
/// `harness::sweep::sweep_gpu` but wall-clock ~threads× faster on the full
/// paper grid.
pub fn sweep_gpu_parallel(
    gpu: &GpuSpec,
    precision: Precision,
    cfg: &SweepConfig,
    threads: usize,
) -> GpuSweep {
    assert!(gpu.supports(precision));
    let lengths: Vec<u64> = if precision == Precision::Fp16 {
        crate::harness::sweep::pow2_only(&cfg.lengths)
    } else {
        cfg.lengths.clone()
    };
    let freqs = freq_table(gpu).stride(cfg.freq_stride);

    let mut points = Vec::new();
    for (li, &n) in lengths.iter().enumerate() {
        for (fi, &f) in freqs.iter().enumerate() {
            points.push(Point { length_idx: li, freq_idx: fi, n, f_mhz: f });
        }
    }

    let gpu = Arc::new(gpu.clone());
    let protocol = Arc::new(cfg.protocol.clone());
    let queue = Arc::new(std::sync::Mutex::new(points.into_iter()));
    let (tx, rx) = mpsc::channel::<(usize, usize, Measurement)>();

    let threads = threads.max(1);
    let mut handles = Vec::new();
    for _ in 0..threads {
        let queue = queue.clone();
        let tx = tx.clone();
        let gpu = gpu.clone();
        let protocol: Arc<Protocol> = protocol.clone();
        handles.push(std::thread::spawn(move || loop {
            let point = { queue.lock().unwrap().next() };
            let Some(p) = point else { return };
            let w = FftWorkload::new(p.n, precision, gpu.working_set_bytes);
            let m = measure_point(&gpu, &w, p.f_mhz, &protocol);
            if tx.send((p.length_idx, p.freq_idx, m)).is_err() {
                return;
            }
        }));
    }
    drop(tx);

    // Collect into the (length, freq) grid, preserving order.
    let mut grid: Vec<Vec<Option<Measurement>>> =
        lengths.iter().map(|_| vec![None; freqs.len()]).collect();
    for (li, fi, m) in rx {
        grid[li][fi] = Some(m);
    }
    for h in handles {
        h.join().expect("campaign worker panicked");
    }

    GpuSweep {
        gpu_name: gpu.name.to_string(),
        precision,
        lengths: lengths
            .iter()
            .zip(grid)
            .map(|(&n, row)| LengthSweep {
                n,
                precision,
                points: row.into_iter().map(|m| m.expect("missing point")).collect(),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::sweep::sweep_gpu;
    use crate::sim::gpu::tesla_v100;

    fn cfg() -> SweepConfig {
        SweepConfig {
            lengths: vec![1024, 16384],
            freq_stride: 24,
            protocol: Protocol { reps_per_run: 3, runs: 3, seed: 77 },
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let g = tesla_v100();
        let serial = sweep_gpu(&g, Precision::Fp32, &cfg());
        let parallel = sweep_gpu_parallel(&g, Precision::Fp32, &cfg(), 4);
        assert_eq!(serial.lengths.len(), parallel.lengths.len());
        for (s, p) in serial.lengths.iter().zip(&parallel.lengths) {
            assert_eq!(s.n, p.n);
            assert_eq!(s.points.len(), p.points.len());
            for (a, b) in s.points.iter().zip(&p.points) {
                assert_eq!(a.f_mhz, b.f_mhz);
                assert_eq!(a.energy_j, b.energy_j, "determinism broken at N={} f={}", s.n, a.f_mhz);
                assert_eq!(a.time_s, b.time_s);
            }
        }
    }

    #[test]
    fn single_thread_works() {
        let g = tesla_v100();
        let s = sweep_gpu_parallel(&g, Precision::Fp32, &cfg(), 1);
        assert_eq!(s.lengths.len(), 2);
    }

    #[test]
    fn fp16_filtering_preserved() {
        let g = tesla_v100();
        let mut c = cfg();
        c.lengths = vec![1024, 19321];
        let s = sweep_gpu_parallel(&g, Precision::Fp16, &c, 2);
        assert_eq!(s.lengths.len(), 1);
        assert_eq!(s.lengths[0].n, 1024);
    }
}
