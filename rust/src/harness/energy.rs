//! Energy/efficiency metric definitions — the paper's equations 3-8.

use crate::types::FftWorkload;

/// eq. (3): E_f = Σ P_i · t_i  — implemented over sensor samples in
/// `sim::sensor::integrate_energy`; this wrapper documents the pairing.
pub use crate::sim::sensor::integrate_energy as energy_from_samples;

/// eq. (5): computational performance in FLOPS.
/// C_p = 5 N log2(N) · N_b · N_FFT / t
pub fn performance_flops(workload: &FftWorkload, n_runs: u64, total_time_s: f64) -> f64 {
    if total_time_s <= 0.0 {
        return 0.0;
    }
    workload.flops() * n_runs as f64 / total_time_s
}

/// eq. (4): energy efficiency E_ef = C_p · t / E_f  (FLOPS per watt).
pub fn energy_efficiency(c_p_flops: f64, total_time_s: f64, energy_j: f64) -> f64 {
    if energy_j <= 0.0 {
        return 0.0;
    }
    c_p_flops * total_time_s / energy_j
}

/// eq. (7): increase in energy efficiency I_ef = E_ef,o / E_ef,d.
pub fn efficiency_increase(e_ef_optimal: f64, e_ef_default: f64) -> f64 {
    if e_ef_default <= 0.0 {
        return 0.0;
    }
    e_ef_optimal / e_ef_default
}

/// GFLOPS/W convenience used by Fig 10.
pub fn gflops_per_watt(workload: &FftWorkload, n_runs: u64, time_s: f64, energy_j: f64) -> f64 {
    let cp = performance_flops(workload, n_runs, time_s);
    energy_efficiency(cp, time_s, energy_j) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Precision};

    fn wl() -> FftWorkload {
        // 4 FFTs of N=1024 fp32
        FftWorkload::new(1024, Precision::Fp32, 1024 * 8 * 4)
    }

    #[test]
    fn eq5_performance() {
        let w = wl();
        // 5·1024·10·4 flops in 1 ms → 204.8 MFLOP / 1e-3 s
        let f = performance_flops(&w, 1, 1e-3);
        assert!((f - 5.0 * 1024.0 * 10.0 * 4.0 / 1e-3).abs() < 1.0);
        assert_eq!(performance_flops(&w, 1, 0.0), 0.0);
    }

    #[test]
    fn eq4_is_flops_per_watt() {
        // C_p·t/E = (flops/s)·s/J = flops/J = flops per watt-second per second
        let w = wl();
        let cp = performance_flops(&w, 1, 2.0);
        let eef = energy_efficiency(cp, 2.0, 100.0);
        // total flops / energy
        assert!((eef - w.flops() / 100.0).abs() < 1e-9);
    }

    #[test]
    fn eq7_ratio() {
        assert_eq!(efficiency_increase(3.0, 2.0), 1.5);
        assert_eq!(efficiency_increase(1.0, 0.0), 0.0);
    }

    #[test]
    fn gflops_per_watt_scales() {
        let w = wl();
        let a = gflops_per_watt(&w, 10, 1.0, 50.0);
        let b = gflops_per_watt(&w, 10, 1.0, 100.0);
        assert!((a / b - 2.0).abs() < 1e-9);
    }
}
