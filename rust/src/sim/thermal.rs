//! Thermal extension: sustained-operation temperature, leakage feedback
//! and thermal throttling — behaviour the paper's single-batch protocol
//! does not capture but a 24/7 SKA deployment hits. Running at the
//! mean-optimal clock keeps the die far below the throttle point, which
//! is an *additional* argument for DVFS the paper leaves implicit.
//!
//! Model: first-order thermal RC — T' = T_amb + P·R_th, approached with
//! time constant tau; leakage grows with temperature (≈ +1%/°C around the
//! operating point); above T_throttle the driver caps the clock, which on
//! a boost-clock card costs throughput.

use crate::sim::power::kernel_power_w;
use crate::sim::{run_batch, GpuSpec};
use crate::types::FftWorkload;

#[derive(Debug, Clone)]
pub struct ThermalParams {
    pub t_ambient_c: f64,
    /// Junction-to-ambient thermal resistance, °C per W.
    pub r_th_c_per_w: f64,
    /// Thermal time constant, seconds.
    pub tau_s: f64,
    /// Leakage growth per °C above 45 °C (fraction).
    pub leak_per_c: f64,
    /// Throttle temperature, °C.
    pub t_throttle_c: f64,
    /// Clock multiplier applied while throttled.
    pub throttle_frac: f64,
}

impl Default for ThermalParams {
    fn default() -> Self {
        Self {
            t_ambient_c: 30.0,
            r_th_c_per_w: 0.22,
            tau_s: 40.0,
            leak_per_c: 0.01,
            t_throttle_c: 83.0,
            throttle_frac: 0.88,
        }
    }
}

/// Steady-state operating point at a fixed clock under continuous load.
#[derive(Debug, Clone)]
pub struct SteadyState {
    pub clock_mhz: f64,
    pub temperature_c: f64,
    pub power_w: f64,
    pub throttled: bool,
    /// Sustained throughput relative to the cold-card single batch.
    pub sustained_throughput: f64,
}

/// Iterate the coupled power/temperature fixed point: P depends on leakage
/// (temperature), T depends on P.
pub fn steady_state(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    clock_mhz: f64,
    params: &ThermalParams,
) -> SteadyState {
    let mut clock = clock_mhz;
    let mut throttled = false;
    for _round in 0..2 {
        let base = run_batch(gpu, workload, clock);
        let timing = &base.timing.per_kernel[0];
        let p_cold = kernel_power_w(gpu, timing, clock);
        // fixed point: T = T_amb + R*(P_cold * (1 + leak_growth(T)))
        let mut t = params.t_ambient_c + params.r_th_c_per_w * p_cold;
        let mut p = p_cold;
        for _ in 0..50 {
            let leak_scale = 1.0 + params.leak_per_c * (t - 45.0).max(0.0)
                * (gpu.leak_w / (gpu.leak_w + gpu.core_w + gpu.mem_w + gpu.idle_w));
            p = p_cold * leak_scale;
            let t_new = params.t_ambient_c + params.r_th_c_per_w * p;
            if (t_new - t).abs() < 1e-6 {
                t = t_new;
                break;
            }
            t = t_new;
        }
        if t > params.t_throttle_c && !throttled {
            // throttle and re-solve once at the reduced clock
            clock = clock_mhz * params.throttle_frac;
            throttled = true;
            continue;
        }
        let cold = run_batch(gpu, workload, clock_mhz).timing.total_s;
        let hot = run_batch(gpu, workload, clock).timing.total_s;
        return SteadyState {
            clock_mhz: clock,
            temperature_c: t,
            power_w: p,
            throttled,
            sustained_throughput: cold / hot,
        };
    }
    unreachable!("throttle loop resolves in two rounds");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    fn setup() -> (GpuSpec, FftWorkload) {
        let g = tesla_v100();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        (g, w)
    }

    #[test]
    fn tuned_clock_runs_cooler() {
        let (g, w) = setup();
        let p = ThermalParams::default();
        let hot = steady_state(&g, &w, g.boost_clock_mhz, &p);
        let cool = steady_state(&g, &w, 945.0, &p);
        assert!(
            cool.temperature_c + 8.0 < hot.temperature_c,
            "boost {:.1}°C vs tuned {:.1}°C",
            hot.temperature_c,
            cool.temperature_c
        );
    }

    #[test]
    fn boost_can_throttle_in_warm_ambient() {
        let (g, w) = setup();
        let mut p = ThermalParams::default();
        p.t_ambient_c = 38.0; // a warm container at the telescope site
        let hot = steady_state(&g, &w, g.boost_clock_mhz, &p);
        let cool = steady_state(&g, &w, 945.0, &p);
        assert!(hot.throttled, "boost at 38°C ambient should throttle ({:.1}°C)", hot.temperature_c);
        assert!(!cool.throttled, "tuned clock must not throttle ({:.1}°C)", cool.temperature_c);
        // once boost throttles, the tuned card's *sustained* throughput gap shrinks
        assert!(cool.sustained_throughput > hot.sustained_throughput * 0.92);
    }

    #[test]
    fn leakage_feedback_raises_power() {
        let (g, w) = setup();
        let mut p = ThermalParams::default();
        p.t_throttle_c = 200.0; // isolate the leakage effect from throttling
        let s = steady_state(&g, &w, g.boost_clock_mhz, &p);
        let timing = run_batch(&g, &w, g.boost_clock_mhz).timing.per_kernel[0].clone();
        let cold = kernel_power_w(&g, &timing, g.boost_clock_mhz);
        assert!(s.power_w > cold, "hot {} !> cold {}", s.power_w, cold);
    }

    #[test]
    fn fixed_point_converges() {
        let (g, w) = setup();
        let p = ThermalParams::default();
        for f in [1530.0, 1200.0, 945.0, 700.0] {
            let s = steady_state(&g, &w, f, &p);
            assert!(s.temperature_c > p.t_ambient_c);
            assert!(s.temperature_c < 120.0);
        }
    }
}
