//! GPU power model under voltage/frequency scaling.
//!
//! P(f) = P_idle + P_leak·(V/Vmax)² + P_mem·u_mem + P_core·(f/f_boost)·(V/Vmax)²·u_core
//!
//! The voltage curve V(f) is flat at the DVFS floor below the knee clock and
//! ramps linearly to Vmax at f_max. The knee creates the non-linear power
//! drop the paper measures (Fig 8) and puts the energy minimum for
//! memory-bound kernels at/near the knee (Fig 7 / Table 3).

use crate::sim::exec_model::KernelTiming;
use crate::sim::freq_table::freq_table;
use crate::sim::gpu::GpuSpec;

/// Normalized core voltage V(f)/Vmax for a requested clock.
pub fn voltage_frac(gpu: &GpuSpec, f_mhz: f64) -> f64 {
    let f = gpu.effective_clock(f_mhz);
    let f_max = freq_table(gpu).f_max_mhz;
    if f <= gpu.f_knee_mhz {
        gpu.v_min_frac
    } else {
        let ramp = (f - gpu.f_knee_mhz) / (f_max - gpu.f_knee_mhz);
        gpu.v_min_frac + (1.0 - gpu.v_min_frac) * ramp.min(1.0)
    }
}

/// Average board power while a kernel with the given timing runs at `f_mhz`.
pub fn kernel_power_w(gpu: &GpuSpec, timing: &KernelTiming, f_mhz: f64) -> f64 {
    let f = gpu.effective_clock(f_mhz);
    let v = voltage_frac(gpu, f);
    let f_frac = f / gpu.boost_clock_mhz;
    // Core activity: issue slots busy, plus a floor for fetch/decode/wait
    // cycles that still toggle while the SM stalls on memory.
    let u_core = 0.30 + 0.70 * timing.issue_util.max(timing.compute_util);
    gpu.idle_w
        + gpu.leak_w * v * v
        + gpu.mem_w * timing.mem_util
        + gpu.core_w * f_frac * v * v * u_core
}

/// Board power when the GPU is loaded but not computing FFTs (host<->device
/// copies, the grey regions of the paper's Fig 2 logs).
pub fn noncompute_power_w(gpu: &GpuSpec, f_mhz: f64) -> f64 {
    let v = voltage_frac(gpu, f_mhz);
    let f_frac = gpu.effective_clock(f_mhz) / gpu.boost_clock_mhz;
    gpu.idle_w + gpu.leak_w * v * v + 0.35 * gpu.mem_w + 0.15 * gpu.core_w * f_frac * v * v
}

/// Idle power at the bottom P-state (between runs).
pub fn idle_power_w(gpu: &GpuSpec) -> f64 {
    gpu.idle_w + gpu.leak_w * gpu.v_min_frac * gpu.v_min_frac * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cufft::plan::plan;
    use crate::sim::exec_model::time_plan;
    use crate::sim::gpu::{all_gpus, jetson_nano, tesla_v100};
    use crate::types::{FftWorkload, Precision};

    fn timing_at(gpu: &GpuSpec, f: f64) -> KernelTiming {
        let w = FftWorkload::new(4096, Precision::Fp32, gpu.working_set_bytes);
        let p = plan(w.n, w.precision);
        time_plan(gpu, &w, &p, f).per_kernel[0].clone()
    }

    #[test]
    fn voltage_flat_below_knee() {
        let g = tesla_v100();
        assert_eq!(voltage_frac(&g, 300.0), g.v_min_frac);
        assert_eq!(voltage_frac(&g, g.f_knee_mhz), g.v_min_frac);
        assert!(voltage_frac(&g, 1200.0) > g.v_min_frac);
        assert!((voltage_frac(&g, 1530.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn power_monotone_in_frequency() {
        let g = tesla_v100();
        let mut last = f64::MAX;
        for f in [1530.0, 1300.0, 1100.0, 945.0, 700.0, 500.0] {
            let t = timing_at(&g, f);
            let p = kernel_power_w(&g, &t, f);
            assert!(p < last, "power should fall with clock: {p} !< {last} at {f}");
            last = p;
        }
    }

    #[test]
    fn boost_power_fraction_of_tdp() {
        // An FFT keeps a GPU busy but not at TDP: expect 55-90% of TDP at
        // boost for the discrete cards (Fig 8 territory).
        for g in all_gpus() {
            let t = timing_at(&g, g.boost_clock_mhz);
            let p = kernel_power_w(&g, &t, g.boost_clock_mhz);
            let frac = p / g.tdp_w;
            assert!(
                (0.45..=0.95).contains(&frac),
                "{}: boost FFT power {p:.1} W = {:.2} of TDP",
                g.name,
                frac
            );
        }
    }

    #[test]
    fn avg_batch_power_monotone_across_full_table() {
        // The telemetry watt→clock inversion
        // (`telemetry::clock_cap_for_budget`) walks the frequency table
        // from the top and stops at the first clock whose mean batch draw
        // fits the budget — which is only the *fastest* feasible clock if
        // mean draw never rises as the clock falls. Pin that invariant
        // over every in-envelope table entry of every card.
        for g in all_gpus() {
            let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
            let mut last = f64::MAX;
            for f in freq_table(&g)
                .stride(2)
                .into_iter()
                .filter(|&f| f <= g.boost_clock_mhz + 1e-9)
            {
                let p = crate::sim::run_batch(&g, &w, f).avg_power_w;
                // Sub-watt model wiggle is tolerable (the cap search
                // re-checks the budget per clock); a real rise is not.
                assert!(
                    p <= last + 0.5,
                    "{}: avg power rose {last} → {p} W at {f} MHz",
                    g.name
                );
                last = p.min(last);
            }
        }
    }

    #[test]
    fn nonlinear_drop_around_knee() {
        // Fig 8: the power-vs-clock curve is non-linear — per MHz it falls
        // faster on the voltage ramp (above the knee) than on the voltage
        // floor, where only the f-linear dynamic term and the utilization
        // shift remain.
        let g = tesla_v100();
        let p = |f: f64| kernel_power_w(&g, &timing_at(&g, f), f);
        let above = p(1200.0) - p(960.0); // 240 MHz spanning the ramp
        let below = p(900.0) - p(660.0); // 240 MHz on the floor
        assert!(above > below, "ramp {above:.1} vs floor {below:.1}");
        // and near the floor, at flat execution time, the drop is weak
        let shallow = p(950.0) - p(870.0);
        assert!(above / 3.0 > shallow, "ramp/80MHz {above} vs floor/80MHz {shallow}");
    }

    #[test]
    fn noncompute_power_below_kernel_power() {
        let g = tesla_v100();
        let t = timing_at(&g, g.boost_clock_mhz);
        assert!(noncompute_power_w(&g, g.boost_clock_mhz) < kernel_power_w(&g, &t, g.boost_clock_mhz));
        assert!(idle_power_w(&g) < noncompute_power_w(&g, g.boost_clock_mhz));
    }

    #[test]
    fn jetson_power_band() {
        // Nano runs in a 5/10 W envelope.
        let g = jetson_nano();
        let t = timing_at(&g, 921.6);
        let p = kernel_power_w(&g, &t, 921.6);
        assert!((3.0..=10.0).contains(&p), "Nano FFT power {p:.2} W");
    }

    #[test]
    fn titan_v_power_capped_with_clock() {
        let g = crate::sim::gpu::titan_v();
        let t_hi = timing_at(&g, 1912.0);
        let t_cap = timing_at(&g, 1335.0);
        let p_hi = kernel_power_w(&g, &t_hi, 1912.0);
        let p_cap = kernel_power_w(&g, &t_cap, 1335.0);
        // compute clock capped → same power during the kernel (Fig 7 note:
        // energy per batch flat above 1335 MHz)
        assert!((p_hi - p_cap).abs() < 1e-9);
    }
}
