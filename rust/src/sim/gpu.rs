//! GPU card specifications (paper Table 2) plus the DVFS model parameters
//! each card needs for the power/performance simulation.
//!
//! The spec columns are transcribed from the paper; the model parameters
//! (voltage curve, power split, issue cost) are calibrated so that the
//! derived optimal frequencies land on the paper's Table 3 and the
//! qualitative behaviours of Figs 6-8 emerge.

use crate::types::{gib, Precision};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryKind {
    Gddr5,
    Hbm2,
    Lpddr4,
}

impl MemoryKind {
    pub fn label(self) -> &'static str {
        match self {
            MemoryKind::Gddr5 => "GDDR5",
            MemoryKind::Hbm2 => "HBM2",
            MemoryKind::Lpddr4 => "LPDDR4",
        }
    }

    /// HBM2 cards expose no memory-clock control (paper section 2.2).
    pub fn memory_clock_adjustable(self) -> bool {
        !matches!(self, MemoryKind::Hbm2)
    }
}

/// Table 2 hardware spec + DVFS model calibration for one card.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: &'static str,
    pub cuda_cores: u32,
    pub sms: u32,
    pub base_clock_mhz: f64,
    pub boost_clock_mhz: f64,
    pub mem_clock_mhz: f64,
    /// Device-memory bandwidth, GB/s.
    pub dev_bw_gbs: f64,
    /// Shared-memory bandwidth at boost clock, GB/s (Table 2 formula).
    pub shared_bw_gbs: f64,
    pub mem_kind: MemoryKind,
    pub mem_bytes: u64,
    pub tdp_w: f64,
    /// Fixed working set the harness processes per batch (paper: 2 GiB,
    /// Jetson ¼ of that due to its 4 GB of memory).
    pub working_set_bytes: u64,

    // ---- DVFS / power model calibration ----
    /// Always-on board power (fans, VRM losses, idle SMs), W.
    pub idle_w: f64,
    /// Leakage at V = Vmax, W; scales with (V/Vmax)^2.
    pub leak_w: f64,
    /// Memory subsystem power at 100% device-BW utilization, W.
    pub mem_w: f64,
    /// Core dynamic power at boost clock, Vmax, 100% active, W.
    pub core_w: f64,
    /// Minimum core voltage as a fraction of Vmax (the DVFS voltage floor).
    pub v_min_frac: f64,
    /// Clock at/below which voltage sits at the floor, MHz. The energy
    /// optimum gravitates here for memory-bound kernels (Table 3).
    pub f_knee_mhz: f64,
    /// Below this clock the driver drops to an idle-class P-state with
    /// severely reduced resources (sharp time cliff, paper section 6).
    pub pstate_floor_mhz: f64,
    /// Extra slowdown multiplier inside the idle P-state.
    pub pstate_penalty: f64,
    /// Driver-enforced compute clock cap (Titan V: 1335 MHz, section 4).
    pub driver_cap_mhz: Option<f64>,
    /// Issue cost: pipeline cycles per complex element per butterfly stage.
    pub cycles_per_stage: f64,
    /// Issue cost: fixed addressing/load-store cycles per complex element
    /// per kernel pass.
    pub cycles_base: f64,
    /// FP throughput relative to FP32 (t_issue divides by this).
    pub fp64_ratio: f64,
    pub fp16_ratio: Option<f64>,
    /// Relative std-dev of the power sensor (paper: ~3-5%, Jetson ≤15%).
    pub sensor_noise_sd: f64,
    /// Relative BW relief from reduced cache contention at lower clocks
    /// (case (a)/(b) of Fig 6).
    pub contention_relief: f64,
    /// Clock fraction (of boost) below which the address-generation rate
    /// can no longer keep the memory system saturated. A per-architecture
    /// constant — warps issue one request every k cycles, so the request
    /// rate is ∝ f and independent of FFT length, which is why the paper
    /// finds near-identical optimal clocks across lengths (Fig 9).
    pub mem_sat_frac: f64,
}

impl GpuSpec {
    pub fn supports(&self, p: Precision) -> bool {
        match p {
            Precision::Fp16 => self.fp16_ratio.is_some(),
            _ => true,
        }
    }

    /// "Crippled" FP64 (1/32 rate consumer parts) behaves compute-bound.
    pub fn fp_ratio(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp32 => 1.0,
            Precision::Fp64 => self.fp64_ratio,
            Precision::Fp16 => self.fp16_ratio.unwrap_or(1.0),
        }
    }

    /// The default (boost) clock — what the card runs without DVFS tuning.
    pub fn default_clock_mhz(&self) -> f64 {
        self.boost_clock_mhz
    }

    /// Effective compute clock after driver capping (Titan V, section 4).
    pub fn effective_clock(&self, requested_mhz: f64) -> f64 {
        match self.driver_cap_mhz {
            Some(cap) => requested_mhz.min(cap),
            None => requested_mhz,
        }
    }

    pub fn has_base_clock(&self) -> bool {
        // The Jetson Nano has no separate base clock (paper Fig 16 note).
        self.base_clock_mhz != self.boost_clock_mhz
    }
}

pub fn tesla_v100() -> GpuSpec {
    GpuSpec {
        name: "Tesla V100",
        arch: "Volta",
        cuda_cores: 5120,
        sms: 80,
        base_clock_mhz: 1200.0,
        boost_clock_mhz: 1530.0,
        mem_clock_mhz: 877.0,
        dev_bw_gbs: 900.0,
        shared_bw_gbs: 14550.0,
        mem_kind: MemoryKind::Hbm2,
        mem_bytes: gib(16),
        tdp_w: 300.0,
        working_set_bytes: gib(2),
        idle_w: 38.0,
        leak_w: 44.0,
        mem_w: 72.0,
        core_w: 150.0,
        v_min_frac: 0.70,
        f_knee_mhz: 960.0,
        pstate_floor_mhz: 300.0,
        pstate_penalty: 2.8,
        driver_cap_mhz: None,
        cycles_per_stage: 5.3,
        cycles_base: 6.0,
        fp64_ratio: 0.5,
        fp16_ratio: Some(2.0),
        sensor_noise_sd: 0.040,
        contention_relief: 0.035,
        mem_sat_frac: 0.58,
    }
}

pub fn tesla_p4() -> GpuSpec {
    GpuSpec {
        name: "Tesla P4",
        arch: "Pascal",
        cuda_cores: 2560,
        sms: 20,
        base_clock_mhz: 810.0,
        boost_clock_mhz: 1063.0,
        mem_clock_mhz: 3003.0,
        dev_bw_gbs: 192.0,
        shared_bw_gbs: 2657.0,
        mem_kind: MemoryKind::Gddr5,
        mem_bytes: gib(8),
        tdp_w: 75.0,
        working_set_bytes: gib(2),
        idle_w: 11.0,
        leak_w: 13.0,
        mem_w: 20.0,
        core_w: 36.0,
        v_min_frac: 0.76,
        f_knee_mhz: 755.0,
        pstate_floor_mhz: 500.0,
        pstate_penalty: 2.2,
        driver_cap_mhz: None,
        cycles_per_stage: 5.8,
        cycles_base: 6.0,
        fp64_ratio: 1.0 / 32.0,
        fp16_ratio: None,
        sensor_noise_sd: 0.045,
        contention_relief: 0.025,
        mem_sat_frac: 0.66,
    }
}

pub fn titan_xp() -> GpuSpec {
    GpuSpec {
        name: "Titan XP",
        arch: "Pascal",
        cuda_cores: 3840,
        sms: 30,
        base_clock_mhz: 1405.0,
        boost_clock_mhz: 1480.0,
        mem_clock_mhz: 5005.0,
        dev_bw_gbs: 547.0,
        shared_bw_gbs: 5395.0,
        mem_kind: MemoryKind::Gddr5,
        mem_bytes: gib(12),
        tdp_w: 250.0,
        working_set_bytes: gib(2),
        idle_w: 24.0,
        leak_w: 36.0,
        mem_w: 58.0,
        core_w: 120.0,
        v_min_frac: 0.74,
        f_knee_mhz: 1160.0,
        pstate_floor_mhz: 500.0,
        pstate_penalty: 2.4,
        driver_cap_mhz: None,
        cycles_per_stage: 5.8,
        cycles_base: 6.0,
        fp64_ratio: 1.0 / 32.0,
        fp16_ratio: None,
        sensor_noise_sd: 0.045,
        contention_relief: 0.030,
        mem_sat_frac: 0.74,
    }
}

pub fn titan_v() -> GpuSpec {
    GpuSpec {
        name: "Titan V",
        arch: "Volta",
        cuda_cores: 5120,
        sms: 80,
        base_clock_mhz: 1220.0,
        boost_clock_mhz: 1455.0,
        mem_clock_mhz: 850.0,
        dev_bw_gbs: 652.0,
        shared_bw_gbs: 14550.0,
        mem_kind: MemoryKind::Hbm2,
        mem_bytes: gib(12),
        tdp_w: 250.0,
        working_set_bytes: gib(2),
        idle_w: 30.0,
        leak_w: 40.0,
        mem_w: 60.0,
        core_w: 138.0,
        v_min_frac: 0.72,
        f_knee_mhz: 965.0,
        pstate_floor_mhz: 300.0,
        pstate_penalty: 2.8,
        // The driver caps compute kernels at 1335 MHz even when a higher
        // clock is requested (paper section 4, driver 450.36.06).
        driver_cap_mhz: Some(1335.0),
        cycles_per_stage: 5.3,
        cycles_base: 6.0,
        fp64_ratio: 0.5,
        fp16_ratio: Some(2.0),
        sensor_noise_sd: 0.045,
        contention_relief: 0.030,
        mem_sat_frac: 0.62,
    }
}

pub fn jetson_nano() -> GpuSpec {
    GpuSpec {
        name: "Jetson Nano",
        arch: "Maxwell",
        cuda_cores: 128,
        sms: 2,
        // No distinct base clock on the Nano.
        base_clock_mhz: 921.6,
        boost_clock_mhz: 921.6,
        mem_clock_mhz: 1600.0,
        dev_bw_gbs: 25.6,
        shared_bw_gbs: 230.0,
        mem_kind: MemoryKind::Lpddr4,
        mem_bytes: gib(4),
        tdp_w: 10.0,
        // ¼ of the 2 GiB working set (paper: limited card memory).
        working_set_bytes: gib(2) / 4,
        idle_w: 1.6,
        leak_w: 1.1,
        mem_w: 1.9,
        core_w: 4.3,
        v_min_frac: 0.56,
        f_knee_mhz: 470.0,
        pstate_floor_mhz: 100.0,
        pstate_penalty: 2.0,
        driver_cap_mhz: None,
        cycles_per_stage: 4.4,
        cycles_base: 3.2,
        fp64_ratio: 1.0 / 32.0,
        fp16_ratio: Some(2.0),
        sensor_noise_sd: 0.10,
        contention_relief: 0.015,
        mem_sat_frac: 0.50,
    }
}

/// All five cards in the paper's order of presentation.
pub fn all_gpus() -> Vec<GpuSpec> {
    vec![titan_xp(), tesla_p4(), titan_v(), tesla_v100(), jetson_nano()]
}

/// Lookup by loose name ("v100", "Tesla V100", "jetson", ...).
pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    let lower = name.to_ascii_lowercase().replace([' ', '-', '_'], "");
    all_gpus().into_iter().find(|g| {
        let gname = g.name.to_ascii_lowercase().replace(' ', "");
        gname == lower
            || gname.contains(&lower)
            || (lower == "nano" && g.name == "Jetson Nano")
            || (lower == "xp" && g.name == "Titan XP")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_core_counts() {
        assert_eq!(tesla_v100().cuda_cores, 5120);
        assert_eq!(tesla_v100().sms, 80);
        assert_eq!(tesla_p4().cuda_cores, 2560);
        assert_eq!(titan_xp().sms, 30);
        assert_eq!(jetson_nano().cuda_cores, 128);
    }

    #[test]
    fn table2_bandwidths() {
        assert_eq!(tesla_v100().dev_bw_gbs, 900.0);
        assert_eq!(titan_v().dev_bw_gbs, 652.0);
        assert_eq!(jetson_nano().dev_bw_gbs, 25.6);
        assert_eq!(tesla_v100().shared_bw_gbs, 14550.0);
    }

    #[test]
    fn hbm2_memory_clock_fixed() {
        assert!(!tesla_v100().mem_kind.memory_clock_adjustable());
        assert!(!titan_v().mem_kind.memory_clock_adjustable());
        assert!(tesla_p4().mem_kind.memory_clock_adjustable());
        assert!(jetson_nano().mem_kind.memory_clock_adjustable());
    }

    #[test]
    fn precision_support_matrix() {
        // P4 and Titan XP do not support FP16 (paper section 5).
        assert!(!tesla_p4().supports(Precision::Fp16));
        assert!(!titan_xp().supports(Precision::Fp16));
        assert!(tesla_v100().supports(Precision::Fp16));
        assert!(titan_v().supports(Precision::Fp16));
        assert!(jetson_nano().supports(Precision::Fp16));
        for g in all_gpus() {
            assert!(g.supports(Precision::Fp32));
            assert!(g.supports(Precision::Fp64));
        }
    }

    #[test]
    fn crippled_fp64_on_consumer_parts() {
        assert!(tesla_p4().fp_ratio(Precision::Fp64) < 0.1);
        assert!(titan_xp().fp_ratio(Precision::Fp64) < 0.1);
        assert_eq!(tesla_v100().fp_ratio(Precision::Fp64), 0.5);
    }

    #[test]
    fn titan_v_driver_cap() {
        let tv = titan_v();
        assert_eq!(tv.effective_clock(1912.0), 1335.0);
        assert_eq!(tv.effective_clock(1000.0), 1000.0);
        assert_eq!(tesla_v100().effective_clock(1530.0), 1530.0);
    }

    #[test]
    fn jetson_quarter_working_set() {
        assert_eq!(jetson_nano().working_set_bytes * 4, tesla_v100().working_set_bytes);
        assert!(!jetson_nano().has_base_clock());
        assert!(tesla_v100().has_base_clock());
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(gpu_by_name("v100").unwrap().name, "Tesla V100");
        assert_eq!(gpu_by_name("Jetson Nano").unwrap().name, "Jetson Nano");
        assert_eq!(gpu_by_name("titanv").unwrap().name, "Titan V");
        assert_eq!(gpu_by_name("xp").unwrap().name, "Titan XP");
        assert_eq!(gpu_by_name("p4").unwrap().name, "Tesla P4");
        assert!(gpu_by_name("a100").is_none());
    }

    #[test]
    fn knee_matches_table3_neighbourhood() {
        // The calibrated knee must sit near the paper's mean optimal
        // frequency for the memory-bound FP32 case.
        assert!((tesla_v100().f_knee_mhz - 945.0).abs() < 40.0);
        assert!((tesla_p4().f_knee_mhz - 746.0).abs() < 40.0);
        assert!((titan_v().f_knee_mhz - 952.0).abs() < 40.0);
        assert!((titan_xp().f_knee_mhz - 1151.0).abs() < 40.0);
        assert!((jetson_nano().f_knee_mhz - 460.8).abs() < 40.0);
    }
}
