//! Deterministic fault injection for the simulated fleet.
//!
//! A [`FaultPlan`] describes, per card, *when* and *how* that card
//! misbehaves, keyed on the card's own batch sequence number — no wall
//! clock, no RNG — so a chaos schedule replays identically run to run.
//! The coordinator's workers consult [`FaultState::next_batch`] once per
//! dequeued batch and act on the returned [`BatchFault`]:
//!
//! * **fail-stop** — every batch from `after` onwards errors (the card
//!   never computes again until the process restarts);
//! * **stall** — batches in `[after, after+for)` sleep `ms` milliseconds
//!   before executing (latency inflation; jobs still complete);
//! * **flap** — starting at `after`, the card cycles `period` batches at
//!   a time, erroring the first `down` of each cycle;
//! * **clock-lock** — batches in `[after, after+for)` arm the injected
//!   NVML lock fault, so `set_gpu_locked_clocks` returns an error and the
//!   card runs un-derated at boost.
//!
//! Specs parse from the CLI `--chaos` grammar: semicolon-separated
//! `card:kind[,key=val...]` clauses, e.g.
//! `"1:failstop,after=32;2:flap,period=8,down=2"`.

use anyhow::{bail, Context, Result};

/// One way a single card misbehaves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultKind {
    /// Every batch from `after` (0-based sequence number) onwards fails.
    FailStop { after: u64 },
    /// Batches in `[after, after + batches)` sleep `ms` before executing.
    Stall { after: u64, batches: u64, ms: u64 },
    /// From `after`, repeat: `down` failing batches then `period - down`
    /// healthy ones.
    Flap { after: u64, period: u64, down: u64 },
    /// Batches in `[after, after + batches)` make `set_gpu_locked_clocks`
    /// fail (the card keeps computing, unlocked at boost).
    ClockLock { after: u64, batches: u64 },
}

/// A fault bound to one card index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CardFault {
    pub card: usize,
    pub kind: FaultKind,
}

/// The full injected-fault schedule for a fleet. Empty by default.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<CardFault>,
}

/// What the worker must do for one dequeued batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchFault {
    /// The batch errors instead of executing.
    pub fail: bool,
    /// Sleep this long before executing (0 = no stall).
    pub stall_ms: u64,
    /// Arm the injected NVML clock-lock error for this batch.
    pub clock_lock: bool,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a `--chaos` spec: `card:kind[,key=val...]` clauses joined by
    /// `;`. Kinds and their keys (all optional, with defaults):
    ///
    /// * `failstop` — `after` (default 0)
    /// * `stall` — `after` (0), `for` (u64::MAX), `ms` (50)
    /// * `flap` — `after` (0), `period` (8), `down` (2)
    /// * `clocklock` — `after` (0), `for` (u64::MAX)
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            faults.push(parse_clause(clause).with_context(|| format!("chaos clause '{clause}'"))?);
        }
        Ok(FaultPlan { faults })
    }
}

fn parse_clause(clause: &str) -> Result<CardFault> {
    let (card_s, rest) = clause
        .split_once(':')
        .context("expected 'card:kind[,key=val...]'")?;
    let card: usize = card_s.trim().parse().context("card index")?;
    let mut parts = rest.split(',').map(str::trim);
    let kind_s = parts.next().unwrap_or("");
    let mut after = 0u64;
    let mut batches = u64::MAX;
    let mut ms = 50u64;
    let mut period = 8u64;
    let mut down = 2u64;
    for kv in parts {
        let (k, v) = kv.split_once('=').with_context(|| format!("'{kv}': expected key=val"))?;
        let v: u64 = v.trim().parse().with_context(|| format!("value of '{k}'"))?;
        match k.trim() {
            "after" => after = v,
            "for" => batches = v,
            "ms" => ms = v,
            "period" => period = v,
            "down" => down = v,
            other => bail!("unknown key '{other}'"),
        }
    }
    let kind = match kind_s {
        "failstop" => FaultKind::FailStop { after },
        "stall" => FaultKind::Stall { after, batches, ms },
        "flap" => {
            anyhow::ensure!(period > 0 && down <= period, "flap wants 0 < down <= period");
            FaultKind::Flap { after, period, down }
        }
        "clocklock" => FaultKind::ClockLock { after, batches },
        other => bail!("unknown fault kind '{other}' (failstop|stall|flap|clocklock)"),
    };
    Ok(CardFault { card, kind })
}

/// Per-card runtime state: the card's faults plus its batch counter.
/// Owned by the card's worker thread; purely sequence-driven.
#[derive(Debug, Default)]
pub struct FaultState {
    kinds: Vec<FaultKind>,
    seq: u64,
}

impl FaultState {
    /// Extract the faults targeting `card` from the plan.
    pub fn for_card(plan: &FaultPlan, card: usize) -> FaultState {
        FaultState {
            kinds: plan
                .faults
                .iter()
                .filter(|f| f.card == card)
                .map(|f| f.kind.clone())
                .collect(),
            seq: 0,
        }
    }

    /// Evaluate the schedule for the next batch and advance the counter.
    pub fn next_batch(&mut self) -> BatchFault {
        let s = self.seq;
        self.seq += 1;
        let mut out = BatchFault::default();
        for k in &self.kinds {
            match *k {
                FaultKind::FailStop { after } => {
                    if s >= after {
                        out.fail = true;
                    }
                }
                FaultKind::Stall { after, batches, ms } => {
                    if s >= after && s - after < batches {
                        out.stall_ms = out.stall_ms.max(ms);
                    }
                }
                FaultKind::Flap { after, period, down } => {
                    if s >= after && (s - after) % period < down {
                        out.fail = true;
                    }
                }
                FaultKind::ClockLock { after, batches } => {
                    if s >= after && s - after < batches {
                        out.clock_lock = true;
                    }
                }
            }
        }
        out
    }

    /// Batches evaluated so far.
    pub fn batches_seen(&self) -> u64 {
        self.seq
    }
}

/// How submit-side arrivals are shaped under `serve --chaos-arrivals` —
/// the *load* half of chaos, next to the card faults above. Schedules
/// are fully materialised up front from a seeded [`Rng`], so a chaos
/// arrival trace replays identically run to run (same property the
/// batch-sequence faults have).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Back-to-back volleys of `size` jobs separated by quiet gaps; the
    /// mean offered rate is preserved (`quiet_x` scales the gaps).
    Burst { size: u64, quiet_x: f64 },
    /// Sinusoidal rate swing with `period` jobs per cycle: offered rate
    /// oscillates in `[mean·(1−swing), mean·(1+swing)]`.
    Diurnal { period: u64, swing: f64 },
    /// Bursts plus a scrambled per-job FFT-length pick, the worst case
    /// for the batcher's per-(n, artifact) slots.
    Adversarial { size: u64 },
}

/// A parsed `--chaos-arrivals` spec: the shape plus the RNG seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPlan {
    pub kind: ArrivalKind,
    pub seed: u64,
}

/// One scheduled arrival: sleep `gap_us` after the previous submit,
/// then submit (optionally overriding the FFT length index for
/// adversarial mixes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub gap_us: u64,
    /// `Some(i)` = submit the i-th configured length (adversarial only).
    pub length_idx: Option<usize>,
}

impl ArrivalPlan {
    /// Parse a `--chaos-arrivals` spec: `kind[,key=val...]`. Kinds and
    /// keys (all optional, with defaults):
    ///
    /// * `burst` — `size` (32), `quiet` (gap multiplier ×100, default
    ///   100 = mean-rate-preserving), `seed` (7)
    /// * `diurnal` — `period` (256), `swing` (amplitude ×100, default
    ///   80), `seed` (7)
    /// * `adversarial` — `size` (32), `seed` (7)
    pub fn parse(spec: &str) -> Result<ArrivalPlan> {
        let mut parts = spec.split(',').map(str::trim);
        let kind_s = parts.next().unwrap_or("");
        let (mut size, mut quiet, mut period, mut swing, mut seed) = (32u64, 100u64, 256u64, 80u64, 7u64);
        for kv in parts {
            let (k, v) = kv.split_once('=').with_context(|| format!("'{kv}': expected key=val"))?;
            let v: u64 = v.trim().parse().with_context(|| format!("value of '{k}'"))?;
            match k.trim() {
                "size" => size = v,
                "quiet" => quiet = v,
                "period" => period = v,
                "swing" => swing = v,
                "seed" => seed = v,
                other => bail!("unknown key '{other}'"),
            }
        }
        anyhow::ensure!(size > 0, "burst size must be > 0");
        anyhow::ensure!(period > 0, "diurnal period must be > 0");
        anyhow::ensure!(swing < 100, "diurnal swing must be < 100 (percent)");
        let kind = match kind_s {
            "burst" => ArrivalKind::Burst { size, quiet_x: quiet as f64 / 100.0 },
            "diurnal" => ArrivalKind::Diurnal { period, swing: swing as f64 / 100.0 },
            "adversarial" => ArrivalKind::Adversarial { size },
            other => bail!("unknown arrival kind '{other}' (burst|diurnal|adversarial)"),
        };
        Ok(ArrivalPlan { kind, seed })
    }

    /// Materialise the whole deterministic schedule: `jobs` arrivals at
    /// a mean offered rate of `rate_jobs_per_s`, shaped by the kind.
    /// `n_lengths` is how many FFT lengths the submitter is configured
    /// with (adversarial mixes pick among them; others leave the
    /// submitter's default).
    pub fn schedule(&self, rate_jobs_per_s: f64, jobs: u64, n_lengths: usize) -> Vec<Arrival> {
        let mean_gap_us = if rate_jobs_per_s > 0.0 { 1e6 / rate_jobs_per_s } else { 0.0 };
        let mut rng = crate::util::rng::Rng::new(self.seed);
        let burst_gaps = |size: u64, quiet_x: f64, rng: &mut crate::util::rng::Rng| {
            (0..jobs)
                .map(|i| {
                    if i > 0 && i % size == 0 {
                        // The whole volley's budget lands in one quiet
                        // gap, jittered ±50% so volleys don't phase-lock
                        // across runs with different seeds.
                        (size as f64 * mean_gap_us * quiet_x * rng.range_f64(0.5, 1.5)) as u64
                    } else {
                        0
                    }
                })
                .collect::<Vec<u64>>()
        };
        match self.kind {
            ArrivalKind::Burst { size, quiet_x } => burst_gaps(size, quiet_x, &mut rng)
                .into_iter()
                .map(|gap_us| Arrival { gap_us, length_idx: None })
                .collect(),
            ArrivalKind::Diurnal { period, swing } => (0..jobs)
                .map(|i| {
                    let phase = (i % period) as f64 / period as f64 * std::f64::consts::TAU;
                    let rate_x = 1.0 + swing * phase.sin();
                    Arrival {
                        gap_us: (mean_gap_us / rate_x.max(1e-3)) as u64,
                        length_idx: None,
                    }
                })
                .collect(),
            ArrivalKind::Adversarial { size } => {
                let gaps = burst_gaps(size, 1.0, &mut rng);
                gaps.into_iter()
                    .map(|gap_us| Arrival {
                        gap_us,
                        length_idx: (n_lengths > 1).then(|| rng.below(n_lengths as u64) as usize),
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(state: &mut FaultState, n: usize) -> Vec<bool> {
        (0..n).map(|_| state.next_batch().fail).collect()
    }

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("0:failstop,after=32; 1:stall,after=8,for=16,ms=20;2:flap,period=6,down=2 ; 0:clocklock,for=4").unwrap();
        assert_eq!(p.faults.len(), 4);
        assert_eq!(p.faults[0], CardFault { card: 0, kind: FaultKind::FailStop { after: 32 } });
        assert_eq!(
            p.faults[1],
            CardFault { card: 1, kind: FaultKind::Stall { after: 8, batches: 16, ms: 20 } }
        );
        assert_eq!(
            p.faults[2],
            CardFault { card: 2, kind: FaultKind::Flap { after: 0, period: 6, down: 2 } }
        );
        assert_eq!(
            p.faults[3],
            CardFault { card: 0, kind: FaultKind::ClockLock { after: 0, batches: 4 } }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("nocolon").is_err());
        assert!(FaultPlan::parse("x:failstop").is_err(), "bad card index");
        assert!(FaultPlan::parse("0:meltdown").is_err(), "unknown kind");
        assert!(FaultPlan::parse("0:failstop,when=3").is_err(), "unknown key");
        assert!(FaultPlan::parse("0:flap,down=9,period=4").is_err(), "down > period");
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn failstop_is_permanent() {
        let p = FaultPlan::parse("0:failstop,after=2").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        assert_eq!(fails(&mut s, 5), vec![false, false, true, true, true]);
        // other cards are untouched
        let mut other = FaultState::for_card(&p, 1);
        assert_eq!(fails(&mut other, 3), vec![false, false, false]);
    }

    #[test]
    fn flap_cycles_down_then_up() {
        let p = FaultPlan::parse("0:flap,after=1,period=3,down=1").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        // seq 0 healthy (before `after`), then D U U D U U ...
        assert_eq!(
            fails(&mut s, 7),
            vec![false, true, false, false, true, false, false]
        );
    }

    #[test]
    fn stall_and_clocklock_windows() {
        let p = FaultPlan::parse("0:stall,after=1,for=2,ms=30;0:clocklock,after=2,for=1").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        let b: Vec<BatchFault> = (0..4).map(|_| s.next_batch()).collect();
        assert_eq!(b[0].stall_ms, 0);
        assert_eq!(b[1].stall_ms, 30);
        assert_eq!(b[2].stall_ms, 30);
        assert_eq!(b[3].stall_ms, 0);
        assert!(!b[1].clock_lock && b[2].clock_lock && !b[3].clock_lock);
        assert!(b.iter().all(|f| !f.fail));
        assert_eq!(s.batches_seen(), 4);
    }

    #[test]
    fn schedule_is_deterministic() {
        let p = FaultPlan::parse("0:flap,period=5,down=2;0:stall,after=3,for=4,ms=10").unwrap();
        let run = |mut s: FaultState| -> Vec<BatchFault> { (0..20).map(|_| s.next_batch()).collect() };
        let a = run(FaultState::for_card(&p, 0));
        let b = run(FaultState::for_card(&p, 0));
        assert_eq!(a, b, "same plan, same card, same trace");
    }

    #[test]
    fn arrival_parse_full_grammar() {
        let p = ArrivalPlan::parse("burst").unwrap();
        assert_eq!(p.kind, ArrivalKind::Burst { size: 32, quiet_x: 1.0 });
        assert_eq!(p.seed, 7);
        let p = ArrivalPlan::parse("burst,size=8,quiet=150,seed=42").unwrap();
        assert_eq!(p.kind, ArrivalKind::Burst { size: 8, quiet_x: 1.5 });
        assert_eq!(p.seed, 42);
        let p = ArrivalPlan::parse("diurnal,period=64,swing=50").unwrap();
        assert_eq!(p.kind, ArrivalKind::Diurnal { period: 64, swing: 0.5 });
        let p = ArrivalPlan::parse("adversarial,size=16").unwrap();
        assert_eq!(p.kind, ArrivalKind::Adversarial { size: 16 });
        assert!(ArrivalPlan::parse("tsunami").is_err(), "unknown kind");
        assert!(ArrivalPlan::parse("burst,when=3").is_err(), "unknown key");
        assert!(ArrivalPlan::parse("burst,size=0").is_err(), "zero burst");
        assert!(ArrivalPlan::parse("diurnal,swing=100").is_err(), "swing ≥ 100%");
    }

    #[test]
    fn burst_arrivals_preserve_mean_rate_and_replay() {
        let p = ArrivalPlan::parse("burst,size=16,seed=3").unwrap();
        let a = p.schedule(1000.0, 512, 1);
        assert_eq!(a.len(), 512);
        assert_eq!(a, p.schedule(1000.0, 512, 1), "same seed, same trace");
        // within a volley the gap is zero; only volley boundaries wait
        assert!(a[1].gap_us == 0 && a[15].gap_us == 0);
        assert!(a[16].gap_us > 0, "volley boundary waits");
        // the total offered time stays near jobs/rate (jitter is ±50%
        // per gap, so the sum stays well inside ±30% over 31 gaps)
        let total_us: u64 = a.iter().map(|x| x.gap_us).sum();
        let expect_us = 512.0 * 1e3;
        assert!(
            (total_us as f64 / expect_us - 1.0).abs() < 0.3,
            "total {total_us} vs {expect_us}"
        );
        assert!(a.iter().all(|x| x.length_idx.is_none()));
        // a different seed reshuffles the quiet gaps
        let b = ArrivalPlan::parse("burst,size=16,seed=4").unwrap().schedule(1000.0, 512, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn diurnal_arrivals_swing_around_the_mean() {
        let p = ArrivalPlan::parse("diurnal,period=64,swing=80").unwrap();
        let a = p.schedule(1000.0, 128, 1);
        let gaps: Vec<u64> = a.iter().map(|x| x.gap_us).collect();
        let (lo, hi) = (*gaps.iter().min().unwrap(), *gaps.iter().max().unwrap());
        // rate swings ×1.8 / ×0.2 around the 1000 µs mean gap
        assert!(lo < 600, "peak-rate gap compresses: {lo}");
        assert!(hi > 3000, "trough-rate gap stretches: {hi}");
        assert_eq!(&gaps[..64], &gaps[64..], "cycles repeat exactly");
    }

    #[test]
    fn adversarial_arrivals_scramble_the_length_mix() {
        let p = ArrivalPlan::parse("adversarial,size=8,seed=11").unwrap();
        let a = p.schedule(2000.0, 256, 4);
        assert_eq!(a, p.schedule(2000.0, 256, 4), "deterministic");
        let mut seen = [false; 4];
        for x in &a {
            seen[x.length_idx.expect("adversarial picks lengths")] = true;
        }
        assert!(seen.iter().all(|&s| s), "all configured lengths hit");
        // with a single configured length there is nothing to scramble
        assert!(p.schedule(2000.0, 16, 1).iter().all(|x| x.length_idx.is_none()));
    }
}
