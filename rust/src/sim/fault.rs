//! Deterministic fault injection for the simulated fleet.
//!
//! A [`FaultPlan`] describes, per card, *when* and *how* that card
//! misbehaves, keyed on the card's own batch sequence number — no wall
//! clock, no RNG — so a chaos schedule replays identically run to run.
//! The coordinator's workers consult [`FaultState::next_batch`] once per
//! dequeued batch and act on the returned [`BatchFault`]:
//!
//! * **fail-stop** — every batch from `after` onwards errors (the card
//!   never computes again until the process restarts);
//! * **stall** — batches in `[after, after+for)` sleep `ms` milliseconds
//!   before executing (latency inflation; jobs still complete);
//! * **flap** — starting at `after`, the card cycles `period` batches at
//!   a time, erroring the first `down` of each cycle;
//! * **clock-lock** — batches in `[after, after+for)` arm the injected
//!   NVML lock fault, so `set_gpu_locked_clocks` returns an error and the
//!   card runs un-derated at boost.
//!
//! Specs parse from the CLI `--chaos` grammar: semicolon-separated
//! `card:kind[,key=val...]` clauses, e.g.
//! `"1:failstop,after=32;2:flap,period=8,down=2"`.

use anyhow::{bail, Context, Result};

/// One way a single card misbehaves.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultKind {
    /// Every batch from `after` (0-based sequence number) onwards fails.
    FailStop { after: u64 },
    /// Batches in `[after, after + batches)` sleep `ms` before executing.
    Stall { after: u64, batches: u64, ms: u64 },
    /// From `after`, repeat: `down` failing batches then `period - down`
    /// healthy ones.
    Flap { after: u64, period: u64, down: u64 },
    /// Batches in `[after, after + batches)` make `set_gpu_locked_clocks`
    /// fail (the card keeps computing, unlocked at boost).
    ClockLock { after: u64, batches: u64 },
}

/// A fault bound to one card index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CardFault {
    pub card: usize,
    pub kind: FaultKind,
}

/// The full injected-fault schedule for a fleet. Empty by default.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<CardFault>,
}

/// What the worker must do for one dequeued batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchFault {
    /// The batch errors instead of executing.
    pub fail: bool,
    /// Sleep this long before executing (0 = no stall).
    pub stall_ms: u64,
    /// Arm the injected NVML clock-lock error for this batch.
    pub clock_lock: bool,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a `--chaos` spec: `card:kind[,key=val...]` clauses joined by
    /// `;`. Kinds and their keys (all optional, with defaults):
    ///
    /// * `failstop` — `after` (default 0)
    /// * `stall` — `after` (0), `for` (u64::MAX), `ms` (50)
    /// * `flap` — `after` (0), `period` (8), `down` (2)
    /// * `clocklock` — `after` (0), `for` (u64::MAX)
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            faults.push(parse_clause(clause).with_context(|| format!("chaos clause '{clause}'"))?);
        }
        Ok(FaultPlan { faults })
    }
}

fn parse_clause(clause: &str) -> Result<CardFault> {
    let (card_s, rest) = clause
        .split_once(':')
        .context("expected 'card:kind[,key=val...]'")?;
    let card: usize = card_s.trim().parse().context("card index")?;
    let mut parts = rest.split(',').map(str::trim);
    let kind_s = parts.next().unwrap_or("");
    let mut after = 0u64;
    let mut batches = u64::MAX;
    let mut ms = 50u64;
    let mut period = 8u64;
    let mut down = 2u64;
    for kv in parts {
        let (k, v) = kv.split_once('=').with_context(|| format!("'{kv}': expected key=val"))?;
        let v: u64 = v.trim().parse().with_context(|| format!("value of '{k}'"))?;
        match k.trim() {
            "after" => after = v,
            "for" => batches = v,
            "ms" => ms = v,
            "period" => period = v,
            "down" => down = v,
            other => bail!("unknown key '{other}'"),
        }
    }
    let kind = match kind_s {
        "failstop" => FaultKind::FailStop { after },
        "stall" => FaultKind::Stall { after, batches, ms },
        "flap" => {
            anyhow::ensure!(period > 0 && down <= period, "flap wants 0 < down <= period");
            FaultKind::Flap { after, period, down }
        }
        "clocklock" => FaultKind::ClockLock { after, batches },
        other => bail!("unknown fault kind '{other}' (failstop|stall|flap|clocklock)"),
    };
    Ok(CardFault { card, kind })
}

/// Per-card runtime state: the card's faults plus its batch counter.
/// Owned by the card's worker thread; purely sequence-driven.
#[derive(Debug, Default)]
pub struct FaultState {
    kinds: Vec<FaultKind>,
    seq: u64,
}

impl FaultState {
    /// Extract the faults targeting `card` from the plan.
    pub fn for_card(plan: &FaultPlan, card: usize) -> FaultState {
        FaultState {
            kinds: plan
                .faults
                .iter()
                .filter(|f| f.card == card)
                .map(|f| f.kind.clone())
                .collect(),
            seq: 0,
        }
    }

    /// Evaluate the schedule for the next batch and advance the counter.
    pub fn next_batch(&mut self) -> BatchFault {
        let s = self.seq;
        self.seq += 1;
        let mut out = BatchFault::default();
        for k in &self.kinds {
            match *k {
                FaultKind::FailStop { after } => {
                    if s >= after {
                        out.fail = true;
                    }
                }
                FaultKind::Stall { after, batches, ms } => {
                    if s >= after && s - after < batches {
                        out.stall_ms = out.stall_ms.max(ms);
                    }
                }
                FaultKind::Flap { after, period, down } => {
                    if s >= after && (s - after) % period < down {
                        out.fail = true;
                    }
                }
                FaultKind::ClockLock { after, batches } => {
                    if s >= after && s - after < batches {
                        out.clock_lock = true;
                    }
                }
            }
        }
        out
    }

    /// Batches evaluated so far.
    pub fn batches_seen(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(state: &mut FaultState, n: usize) -> Vec<bool> {
        (0..n).map(|_| state.next_batch().fail).collect()
    }

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("0:failstop,after=32; 1:stall,after=8,for=16,ms=20;2:flap,period=6,down=2 ; 0:clocklock,for=4").unwrap();
        assert_eq!(p.faults.len(), 4);
        assert_eq!(p.faults[0], CardFault { card: 0, kind: FaultKind::FailStop { after: 32 } });
        assert_eq!(
            p.faults[1],
            CardFault { card: 1, kind: FaultKind::Stall { after: 8, batches: 16, ms: 20 } }
        );
        assert_eq!(
            p.faults[2],
            CardFault { card: 2, kind: FaultKind::Flap { after: 0, period: 6, down: 2 } }
        );
        assert_eq!(
            p.faults[3],
            CardFault { card: 0, kind: FaultKind::ClockLock { after: 0, batches: 4 } }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("nocolon").is_err());
        assert!(FaultPlan::parse("x:failstop").is_err(), "bad card index");
        assert!(FaultPlan::parse("0:meltdown").is_err(), "unknown kind");
        assert!(FaultPlan::parse("0:failstop,when=3").is_err(), "unknown key");
        assert!(FaultPlan::parse("0:flap,down=9,period=4").is_err(), "down > period");
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn failstop_is_permanent() {
        let p = FaultPlan::parse("0:failstop,after=2").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        assert_eq!(fails(&mut s, 5), vec![false, false, true, true, true]);
        // other cards are untouched
        let mut other = FaultState::for_card(&p, 1);
        assert_eq!(fails(&mut other, 3), vec![false, false, false]);
    }

    #[test]
    fn flap_cycles_down_then_up() {
        let p = FaultPlan::parse("0:flap,after=1,period=3,down=1").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        // seq 0 healthy (before `after`), then D U U D U U ...
        assert_eq!(
            fails(&mut s, 7),
            vec![false, true, false, false, true, false, false]
        );
    }

    #[test]
    fn stall_and_clocklock_windows() {
        let p = FaultPlan::parse("0:stall,after=1,for=2,ms=30;0:clocklock,after=2,for=1").unwrap();
        let mut s = FaultState::for_card(&p, 0);
        let b: Vec<BatchFault> = (0..4).map(|_| s.next_batch()).collect();
        assert_eq!(b[0].stall_ms, 0);
        assert_eq!(b[1].stall_ms, 30);
        assert_eq!(b[2].stall_ms, 30);
        assert_eq!(b[3].stall_ms, 0);
        assert!(!b[1].clock_lock && b[2].clock_lock && !b[3].clock_lock);
        assert!(b.iter().all(|f| !f.fail));
        assert_eq!(s.batches_seen(), 4);
    }

    #[test]
    fn schedule_is_deterministic() {
        let p = FaultPlan::parse("0:flap,period=5,down=2;0:stall,after=3,for=4,ms=10").unwrap();
        let run = |mut s: FaultState| -> Vec<BatchFault> { (0..20).map(|_| s.next_batch()).collect() };
        let a = run(FaultState::for_card(&p, 0));
        let b = run(FaultState::for_card(&p, 0));
        assert_eq!(a, b, "same plan, same card, same trace");
    }
}
