//! On-board power sensor emulation (nvidia-smi / tegrastats).
//!
//! The paper samples the driver's power query at a requested 10 ms interval
//! but observes a mean achieved interval of 14.2 ms with jitter, and the
//! on-board instrumentation amplifiers carry a 3-5% error (≤15% on the
//! Nano).  The harness integrates energy from these noisy samples (eq. 3),
//! so the sensor model is what produces the measurement-error surface of
//! Fig 3 and the run-to-run spread of every measured quantity.

use crate::sim::gpu::GpuSpec;
use crate::util::rng::Rng;

/// A ground-truth power timeline: consecutive segments of constant power.
#[derive(Debug, Clone, Default)]
pub struct PowerTimeline {
    /// (duration_s, power_w, is_compute) segments in execution order.
    pub segments: Vec<(f64, f64, bool)>,
}

impl PowerTimeline {
    pub fn push(&mut self, duration_s: f64, power_w: f64, is_compute: bool) {
        if duration_s > 0.0 {
            self.segments.push((duration_s, power_w, is_compute));
        }
    }

    pub fn total_duration(&self) -> f64 {
        self.segments.iter().map(|s| s.0).sum()
    }

    /// Analytic ∫P·dt over the *compute* segments (ground truth energy, J).
    pub fn true_compute_energy(&self) -> f64 {
        self.segments
            .iter()
            .filter(|s| s.2)
            .map(|s| s.0 * s.1)
            .sum()
    }

    pub fn compute_duration(&self) -> f64 {
        self.segments.iter().filter(|s| s.2).map(|s| s.0).sum()
    }

    /// Power at absolute time t (None before t=0 or past the end).
    /// Segment edges belong to the *following* segment: a boundary
    /// timestamp reads the segment that starts there, and the final
    /// end-time reads None — the same half-open `[start, end)` convention
    /// as [`TimelineIndex::power_at`], which the telemetry sampler leans
    /// on (boundary-tested below).
    pub fn power_at(&self, t: f64) -> Option<(f64, bool)> {
        if t < 0.0 {
            return None;
        }
        let mut acc = 0.0;
        for &(d, p, c) in &self.segments {
            if t < acc + d {
                return Some((p, c));
            }
            acc += d;
        }
        None
    }

    /// Precompute segment end-times for O(log n) lookups during sampling
    /// (the harness samples a timeline with thousands of repeated-batch
    /// segments — the linear scan in `power_at` is O(n) per sample).
    pub fn index(&self) -> TimelineIndex<'_> {
        let mut ends = Vec::with_capacity(self.segments.len());
        let mut acc = 0.0;
        for &(d, _, _) in &self.segments {
            acc += d;
            ends.push(acc);
        }
        TimelineIndex { timeline: self, ends }
    }
}

/// Binary-search index over a timeline (see [`PowerTimeline::index`]).
pub struct TimelineIndex<'a> {
    timeline: &'a PowerTimeline,
    ends: Vec<f64>,
}

impl TimelineIndex<'_> {
    pub fn power_at(&self, t: f64) -> Option<(f64, bool)> {
        if t < 0.0 {
            return None;
        }
        let i = self.ends.partition_point(|&e| e <= t);
        self.timeline
            .segments
            .get(i)
            .map(|&(_, p, c)| (p, c))
    }
}

/// One driver sample as the harness logs it (paper Fig 2 rows).
#[derive(Debug, Clone, Copy)]
pub struct PowerSample {
    pub timestamp_s: f64,
    pub power_w: f64,
    /// Clock the driver reports at this instant.
    pub core_clock_mhz: f64,
    pub mem_clock_mhz: f64,
}

#[derive(Debug, Clone)]
pub struct SensorConfig {
    /// Requested sampling interval (paper: 10 ms).
    pub requested_interval_s: f64,
    /// Mean achieved interval (paper: 14.2 ms).
    pub achieved_interval_s: f64,
    /// Multiplicative gaussian noise σ on each power reading.
    pub noise_sd: f64,
}

impl SensorConfig {
    pub fn for_gpu(gpu: &GpuSpec) -> Self {
        Self {
            requested_interval_s: 0.010,
            achieved_interval_s: 0.0142,
            noise_sd: gpu.sensor_noise_sd,
        }
    }
}

/// Sample a timeline the way nvidia-smi would: jittered intervals,
/// noisy amplifier readings, the currently reported clocks attached.
pub fn sample_timeline(
    timeline: &PowerTimeline,
    cfg: &SensorConfig,
    core_clock_mhz: f64,
    mem_clock_mhz: f64,
    rng: &mut Rng,
) -> Vec<PowerSample> {
    let total = timeline.total_duration();
    let index = timeline.index();
    let mut out = Vec::new();
    // random phase: the sampler is not aligned with kernel starts
    let mut t = rng.f64() * cfg.requested_interval_s;
    let jitter_span = 2.0 * (cfg.achieved_interval_s - cfg.requested_interval_s);
    while t < total {
        if let Some((p, _)) = index.power_at(t) {
            let noisy = p * (1.0 + cfg.noise_sd * rng.gauss());
            out.push(PowerSample {
                timestamp_s: t,
                power_w: noisy.max(0.0),
                core_clock_mhz,
                mem_clock_mhz,
            });
        }
        // achieved interval: requested + uniform driver-side delay
        t += cfg.requested_interval_s + jitter_span * rng.f64();
    }
    out
}

/// Energy from samples by rectangle integration: E = Σ P_i · t_i (eq. 3),
/// with t_i the gap to the previous sample.
pub fn integrate_energy(samples: &[PowerSample]) -> f64 {
    let mut e = 0.0;
    for i in 1..samples.len() {
        let dt = samples[i].timestamp_s - samples[i - 1].timestamp_s;
        e += samples[i].power_w * dt;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;

    fn flat_timeline(duration: f64, power: f64) -> PowerTimeline {
        let mut t = PowerTimeline::default();
        t.push(duration, power, true);
        t
    }

    #[test]
    fn true_energy_analytic() {
        let mut t = PowerTimeline::default();
        t.push(1.0, 100.0, true);
        t.push(0.5, 40.0, false);
        t.push(2.0, 50.0, true);
        assert_eq!(t.true_compute_energy(), 200.0);
        assert_eq!(t.total_duration(), 3.5);
        assert_eq!(t.compute_duration(), 3.0);
    }

    #[test]
    fn power_at_segment_lookup() {
        let mut t = PowerTimeline::default();
        t.push(1.0, 10.0, true);
        t.push(1.0, 20.0, false);
        assert_eq!(t.power_at(0.5), Some((10.0, true)));
        assert_eq!(t.power_at(1.5), Some((20.0, false)));
        assert_eq!(t.power_at(2.5), None);
    }

    #[test]
    fn power_at_exact_segment_edges_and_past_the_end() {
        // Boundary contract the telemetry sampler leans on: edges belong
        // to the following segment ([start, end) per segment), the total
        // duration itself is past-the-end, and negative time is None —
        // identically for the linear scan and the binary-search index.
        let mut t = PowerTimeline::default();
        t.push(1.0, 10.0, true);
        t.push(1.0, 20.0, false);
        t.push(0.5, 30.0, true);
        let idx = t.index();
        // t = 0 reads the first segment
        assert_eq!(t.power_at(0.0), Some((10.0, true)));
        assert_eq!(idx.power_at(0.0), Some((10.0, true)));
        // exact interior edges read the segment that starts there
        assert_eq!(t.power_at(1.0), Some((20.0, false)));
        assert_eq!(idx.power_at(1.0), Some((20.0, false)));
        assert_eq!(t.power_at(2.0), Some((30.0, true)));
        assert_eq!(idx.power_at(2.0), Some((30.0, true)));
        // the final end-time and beyond are None
        assert_eq!(t.power_at(2.5), None);
        assert_eq!(idx.power_at(2.5), None);
        assert_eq!(t.power_at(1e9), None);
        assert_eq!(idx.power_at(1e9), None);
        // negative time is None on both paths (the scan used to return
        // the first segment here, diverging from the index)
        assert_eq!(t.power_at(-0.25), None);
        assert_eq!(idx.power_at(-0.25), None);
    }

    #[test]
    fn index_matches_scan_on_a_dense_grid() {
        let mut t = PowerTimeline::default();
        t.push(0.3, 100.0, true);
        t.push(0.7, 40.0, false);
        t.push(0.2, 150.0, true);
        t.push(0.8, 60.0, true);
        let idx = t.index();
        let mut x = -0.1;
        while x < 2.2 {
            assert_eq!(t.power_at(x), idx.power_at(x), "diverged at t={x}");
            x += 0.01;
        }
    }

    #[test]
    fn empty_and_zero_length_segments_lookup_none() {
        let empty = PowerTimeline::default();
        assert_eq!(empty.power_at(0.0), None);
        assert_eq!(empty.index().power_at(0.0), None);
        // zero/negative-duration pushes are dropped entirely
        let mut t = PowerTimeline::default();
        t.push(0.0, 99.0, true);
        t.push(-1.0, 99.0, true);
        assert!(t.segments.is_empty());
        t.push(1.0, 50.0, true);
        assert_eq!(t.power_at(0.5), Some((50.0, true)));
        assert_eq!(t.index().power_at(1.0), None, "end of the only segment");
    }

    #[test]
    fn achieved_interval_near_paper_value() {
        let cfg = SensorConfig::for_gpu(&tesla_v100());
        let tl = flat_timeline(10.0, 100.0);
        let mut rng = Rng::new(1);
        let s = sample_timeline(&tl, &cfg, 1530.0, 877.0, &mut rng);
        let mut gaps = Vec::new();
        for w in s.windows(2) {
            gaps.push(w[1].timestamp_s - w[0].timestamp_s);
        }
        let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(
            (mean_gap - 0.0142).abs() < 0.001,
            "mean gap {mean_gap} != 14.2 ms"
        );
    }

    #[test]
    fn integrated_energy_close_to_truth() {
        let cfg = SensorConfig::for_gpu(&tesla_v100());
        let tl = flat_timeline(5.0, 200.0);
        let mut rng = Rng::new(7);
        let s = sample_timeline(&tl, &cfg, 1530.0, 877.0, &mut rng);
        let e = integrate_energy(&s);
        let truth = tl.true_compute_energy();
        assert!(
            (e - truth).abs() / truth < 0.05,
            "measured {e} vs true {truth}"
        );
    }

    #[test]
    fn noise_produces_run_to_run_spread() {
        let cfg = SensorConfig::for_gpu(&tesla_v100());
        let tl = flat_timeline(0.5, 150.0);
        let mut master = Rng::new(42);
        let energies: Vec<f64> = (0..20)
            .map(|i| {
                let mut r = master.fork(i);
                integrate_energy(&sample_timeline(&tl, &cfg, 1530.0, 877.0, &mut r))
            })
            .collect();
        let rel = crate::util::stats::rel_std(&energies);
        assert!(rel > 0.001 && rel < 0.12, "rel spread {rel}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SensorConfig::for_gpu(&tesla_v100());
        let tl = flat_timeline(1.0, 99.0);
        let a = sample_timeline(&tl, &cfg, 1000.0, 877.0, &mut Rng::new(3));
        let b = sample_timeline(&tl, &cfg, 1000.0, 877.0, &mut Rng::new(3));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.power_w, y.power_w);
            assert_eq!(x.timestamp_s, y.timestamp_s);
        }
    }
}
