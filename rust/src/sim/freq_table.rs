//! Allowed core-clock frequency tables (paper Table 1).
//!
//! Clocks can only be set to hardware-defined values: from f_max down to
//! f_min with an alternating step pattern (7/8 MHz on Volta, 12/13 MHz on
//! Pascal, a fixed 76.8 MHz on the Jetson Nano).

use crate::sim::gpu::GpuSpec;

/// Table 1 row: the DVFS-settable clock domain of one card.
#[derive(Debug, Clone)]
pub struct FreqTable {
    pub f_max_mhz: f64,
    pub f_min_mhz: f64,
    /// Alternating decrement pattern, applied cyclically from f_max.
    pub steps_mhz: Vec<f64>,
}

impl FreqTable {
    /// Enumerate every supported frequency, descending from f_max to f_min.
    pub fn frequencies(&self) -> Vec<f64> {
        let mut out = vec![self.f_max_mhz];
        let mut f = self.f_max_mhz;
        let mut i = 0usize;
        while f > self.f_min_mhz {
            f -= self.steps_mhz[i % self.steps_mhz.len()];
            i += 1;
            if f < self.f_min_mhz - 1e-9 {
                break;
            }
            out.push((f * 10.0).round() / 10.0);
        }
        if (out.last().copied().unwrap_or(f64::MAX) - self.f_min_mhz).abs() > 1e-9 {
            out.push(self.f_min_mhz);
        }
        out
    }

    /// Snap an arbitrary request to the nearest supported clock
    /// (what the driver does with a requested locked clock).
    pub fn snap(&self, requested_mhz: f64) -> f64 {
        self.frequencies()
            .into_iter()
            .min_by(|a, b| {
                (a - requested_mhz)
                    .abs()
                    .partial_cmp(&(b - requested_mhz).abs())
                    .unwrap()
            })
            .unwrap_or(self.f_max_mhz)
    }

    /// Nearest supported clock that does not exceed `cap_mhz` — the
    /// "snap, but never past the default/boost clock" variant. A plain
    /// nearest-snap can land *above* the cap when the cap sits between
    /// table entries (the P4's boost does), which would price "boost" at
    /// an unreachable clock; governors use this to stay within both the
    /// table and the card's default envelope.
    ///
    /// Edge case: if `cap_mhz` lies below the table floor there is no
    /// clock satisfying the cap, and the floor (`f_min_mhz`, the lowest
    /// supported clock) is returned as the closest achievable — callers
    /// that must treat that as an error should check `cap_mhz >=
    /// f_min_mhz` themselves. Every shipped card has boost >= f_min, so
    /// the governor paths never hit this.
    pub fn snap_at_most(&self, requested_mhz: f64, cap_mhz: f64) -> f64 {
        self.frequencies()
            .into_iter()
            .filter(|f| *f <= cap_mhz + 1e-9)
            .min_by(|a, b| {
                (a - requested_mhz)
                    .abs()
                    .partial_cmp(&(b - requested_mhz).abs())
                    .unwrap()
            })
            .unwrap_or(self.f_min_mhz)
    }

    pub fn contains(&self, f_mhz: f64) -> bool {
        self.frequencies().iter().any(|f| (f - f_mhz).abs() < 1e-6)
    }

    /// Every k-th frequency (the sweep harness subsamples dense tables).
    /// The stride is clamped so short tables (Jetson: 12 entries) always
    /// keep at least ~8 points.
    pub fn stride(&self, k: usize) -> Vec<f64> {
        let all = self.frequencies();
        let k = k.max(1).min((all.len() / 8).max(1));
        let mut out: Vec<f64> = all.iter().copied().step_by(k).collect();
        if let (Some(&last_all), Some(&last_out)) = (all.last(), out.last()) {
            if (last_all - last_out).abs() > 1e-9 {
                out.push(last_all); // always include f_min
            }
        }
        out
    }
}

/// Table 1 for a given card.
pub fn freq_table(gpu: &GpuSpec) -> FreqTable {
    match gpu.name {
        "Tesla V100" => FreqTable {
            f_max_mhz: 1530.0,
            f_min_mhz: 135.0,
            steps_mhz: vec![7.0, 8.0],
        },
        "Tesla P4" => FreqTable {
            f_max_mhz: 1531.0,
            f_min_mhz: 455.0,
            steps_mhz: vec![12.0, 13.0],
        },
        "Titan XP" => FreqTable {
            f_max_mhz: 1911.0,
            f_min_mhz: 379.0,
            steps_mhz: vec![12.0, 13.0],
        },
        "Titan V" => FreqTable {
            f_max_mhz: 1912.0,
            f_min_mhz: 135.0,
            steps_mhz: vec![7.0, 8.0],
        },
        "Jetson Nano" => FreqTable {
            f_max_mhz: 921.6,
            f_min_mhz: 76.8,
            steps_mhz: vec![76.8],
        },
        other => panic!("no frequency table for {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::*;

    #[test]
    fn v100_table_bounds_and_steps() {
        let t = freq_table(&tesla_v100());
        let f = t.frequencies();
        assert_eq!(f[0], 1530.0);
        assert_eq!(*f.last().unwrap(), 135.0);
        // alternating 7/8 → pairs of 15 MHz
        assert_eq!(f[0] - f[1], 7.0);
        assert_eq!(f[1] - f[2], 8.0);
        // 1530 - 135 = 1395 = 93 * 15 → exact landing on f_min
        assert_eq!(f.len(), 187);
    }

    #[test]
    fn jetson_table_is_uniform() {
        let t = freq_table(&jetson_nano());
        let f = t.frequencies();
        assert_eq!(f.len(), 12);
        assert!((f[0] - 921.6).abs() < 1e-9);
        assert!((f[11] - 76.8).abs() < 1e-9);
        for w in f.windows(2) {
            assert!((w[0] - w[1] - 76.8).abs() < 1e-6);
        }
    }

    #[test]
    fn all_tables_descend_to_fmin() {
        for g in all_gpus() {
            let t = freq_table(&g);
            let f = t.frequencies();
            assert!(f.windows(2).all(|w| w[0] > w[1]), "{} not descending", g.name);
            assert!((f[0] - t.f_max_mhz).abs() < 1e-9);
            assert!((*f.last().unwrap() - t.f_min_mhz).abs() < 1e-9);
        }
    }

    #[test]
    fn paper_table1_values() {
        let cases = [
            ("Tesla V100", 1530.0, 135.0),
            ("Tesla P4", 1531.0, 455.0),
            ("Titan XP", 1911.0, 379.0),
            ("Titan V", 1912.0, 135.0),
            ("Jetson Nano", 921.6, 76.8),
        ];
        for (name, fmax, fmin) in cases {
            let g = gpu_by_name(name).unwrap();
            let t = freq_table(&g);
            assert_eq!(t.f_max_mhz, fmax, "{name}");
            assert_eq!(t.f_min_mhz, fmin, "{name}");
        }
    }

    #[test]
    fn snap_picks_nearest() {
        let t = freq_table(&tesla_v100());
        let snapped = t.snap(946.0);
        assert!(t.contains(snapped));
        assert!((snapped - 946.0).abs() <= 8.0);
    }

    #[test]
    fn snap_at_most_never_exceeds_cap() {
        for g in all_gpus() {
            let t = freq_table(&g);
            // Request well above boost: plain snap may overshoot the cap
            // (P4: boost 1063 sits between 12/13 MHz steps), snap_at_most
            // must not.
            let f = t.snap_at_most(t.f_max_mhz + 100.0, g.boost_clock_mhz);
            assert!(t.contains(f), "{}: {f} not a table clock", g.name);
            assert!(
                f <= g.boost_clock_mhz + 1e-9,
                "{}: {f} above boost {}",
                g.name,
                g.boost_clock_mhz
            );
            // At-or-below requests behave like plain snap.
            let lo = t.snap_at_most(t.f_min_mhz - 50.0, g.boost_clock_mhz);
            assert!((lo - t.f_min_mhz).abs() < 1e-9);
        }
    }

    #[test]
    fn stride_keeps_endpoints() {
        let t = freq_table(&tesla_v100());
        let s = t.stride(10);
        assert_eq!(s[0], 1530.0);
        assert!((s.last().unwrap() - 135.0).abs() < 1e-9);
        assert!(s.len() < t.frequencies().len());
    }

    #[test]
    fn boost_clock_is_in_table_neighbourhood() {
        for g in all_gpus() {
            let t = freq_table(&g);
            let snapped = t.snap(g.boost_clock_mhz);
            assert!(
                (snapped - g.boost_clock_mhz).abs() <= 13.0,
                "{}: boost {} snapped {}",
                g.name,
                g.boost_clock_mhz,
                snapped
            );
        }
    }
}
