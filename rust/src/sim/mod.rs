//! GPU DVFS simulator: the substitution for the paper's five physical
//! NVIDIA cards (see DESIGN.md §1).
//!
//! `gpu` holds Table 2 specs + model calibration, `freq_table` holds
//! Table 1, `exec_model` prices a cuFFT plan at a clock, `power` prices
//! the board power, and `sensor` turns ground-truth timelines into the
//! noisy driver samples the harness integrates.

pub mod exec_model;
pub mod fault;
pub mod freq_table;
pub mod gpu;
pub mod power;
pub mod sensor;
pub mod thermal;

use crate::cufft::plan::{plan, FftPlan};
use crate::sim::exec_model::{time_plan, PlanTiming};
use crate::sim::power::kernel_power_w;
use crate::sim::sensor::PowerTimeline;
use crate::types::FftWorkload;

pub use gpu::GpuSpec;

/// The full simulated outcome of running one FFT batch at one clock.
#[derive(Debug, Clone)]
pub struct BatchRun {
    pub f_mhz: f64,
    pub timing: PlanTiming,
    /// Mean power over the batch's compute time, W.
    pub avg_power_w: f64,
    /// Ground-truth energy over the compute time, J.
    pub energy_j: f64,
}

/// Simulate one batch of `workload` on `gpu` at requested clock `f_mhz`.
pub fn run_batch(gpu: &GpuSpec, workload: &FftWorkload, f_mhz: f64) -> BatchRun {
    let p = plan(workload.n, workload.precision);
    run_batch_with_plan(gpu, workload, &p, f_mhz)
}

pub fn run_batch_with_plan(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    plan: &FftPlan,
    f_mhz: f64,
) -> BatchRun {
    let timing = time_plan(gpu, workload, plan, f_mhz);
    let mut energy = 0.0;
    for k in &timing.per_kernel {
        energy += kernel_power_w(gpu, k, f_mhz) * k.t_total;
    }
    let avg_power_w = if timing.total_s > 0.0 {
        energy / timing.total_s
    } else {
        0.0
    };
    BatchRun {
        f_mhz,
        timing,
        avg_power_w,
        energy_j: energy,
    }
}

/// Build the power timeline of `reps` back-to-back batches bracketed by
/// host-transfer segments, ready for the sensor (the paper's measurement
/// protocol: transfer in, run the FFT repeatedly, transfer out — Fig 2).
pub fn batch_timeline(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    f_mhz: f64,
    reps: usize,
) -> (PowerTimeline, BatchRun) {
    let run = run_batch(gpu, workload, f_mhz);
    let mut tl = PowerTimeline::default();
    // host->device copy of the working set over PCIe (~12 GB/s effective) or
    // the Nano's unified memory path.
    let copy_bw = if gpu.name == "Jetson Nano" { 8e9 } else { 12e9 };
    let copy_s = workload.data_bytes as f64 / copy_bw;
    let p_copy = power::noncompute_power_w(gpu, f_mhz);
    tl.push(copy_s, p_copy, false);
    for _ in 0..reps {
        for k in &run.timing.per_kernel {
            tl.push(k.t_total, kernel_power_w(gpu, k, f_mhz), true);
        }
    }
    tl.push(copy_s, p_copy, false);
    (tl, run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    #[test]
    fn energy_minimum_below_boost_v100() {
        // The defining result: sweeping clocks, the energy per batch has a
        // minimum well below boost (Fig 7).
        let g = tesla_v100();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let fs = crate::sim::freq_table::freq_table(&g).frequencies();
        let runs: Vec<BatchRun> = fs.iter().map(|&f| run_batch(&g, &w, f)).collect();
        let energies: Vec<f64> = runs.iter().map(|r| r.energy_j).collect();
        let imin = crate::util::stats::argmin(&energies).unwrap();
        let f_opt = fs[imin];
        assert!(
            f_opt < 0.8 * g.boost_clock_mhz,
            "optimal {f_opt} MHz not below boost"
        );
        assert!(
            f_opt > 0.4 * g.boost_clock_mhz,
            "optimal {f_opt} MHz implausibly low"
        );
    }

    #[test]
    fn timeline_contains_compute_and_copies() {
        let g = tesla_v100();
        let w = FftWorkload::new(1024, Precision::Fp32, g.working_set_bytes);
        let (tl, run) = batch_timeline(&g, &w, 1000.0, 3);
        assert!(tl.segments.first().unwrap().2 == false);
        assert!(tl.segments.last().unwrap().2 == false);
        let compute: f64 = tl.compute_duration();
        assert!((compute - 3.0 * run.timing.total_s).abs() < 1e-12);
        // compute power above copy power
        let p_copy = tl.segments[0].1;
        let p_kernel = tl.segments[1].1;
        assert!(p_kernel > p_copy);
    }

    #[test]
    fn avg_power_consistent_with_energy() {
        let g = tesla_v100();
        let w = FftWorkload::new(4096, Precision::Fp32, g.working_set_bytes);
        let r = run_batch(&g, &w, 1200.0);
        assert!((r.avg_power_w * r.timing.total_s - r.energy_j).abs() < 1e-9);
    }
}
