//! Kernel execution-time model under core-clock scaling.
//!
//! Every cuFFT kernel is device-memory-bandwidth bound at the default clock
//! (paper section 2). Lowering the core clock affects it through three
//! coupled rooflines:
//!
//!   t_mem    — device traffic / effective bandwidth. Effective bandwidth
//!              *slightly improves* at lower clocks (reduced cache
//!              contention — the paper's case (a)/(b)), but collapses once
//!              the issue rate can no longer keep enough memory requests in
//!              flight (latency-hiding loss, section 6).
//!   t_issue  — instruction issue: elements × cycles-per-element / (cores·f).
//!              Dominates on compute-weak parts (Jetson; crippled-FP64
//!              consumer cards) → the paper's case (c).
//!   t_shared — shared-memory/L1 traffic at a bandwidth proportional to f.
//!              Dominates for the largest single-kernel N (the paper's
//!              N = 8192 case (c) on the V100).
//!
//! Below the P-state floor the driver drops to an idle-class state with
//! severely reduced resources — the sharp cliff all cards show.

use crate::cufft::plan::{FftPlan, KernelKind};
use crate::sim::gpu::GpuSpec;
use crate::types::FftWorkload;

/// Timing decomposition of one kernel at one clock (all seconds).
#[derive(Debug, Clone)]
pub struct KernelTiming {
    pub t_mem: f64,
    pub t_issue: f64,
    pub t_shared: f64,
    /// Smooth-max of the three rooflines, including the P-state penalty.
    pub t_total: f64,
    /// Device-memory bandwidth utilization (for Fig 20).
    pub mem_util: f64,
    /// Issue-slot utilization (for Fig 20).
    pub issue_util: f64,
    /// Compute (FP pipe) utilization estimate (for Fig 20).
    pub compute_util: f64,
}

/// Timing of a full plan at one clock.
#[derive(Debug, Clone)]
pub struct PlanTiming {
    pub per_kernel: Vec<KernelTiming>,
    pub total_s: f64,
}

/// Smooth maximum (p-norm): differentiable crossovers between rooflines,
/// matching the gradual onset the paper measures rather than a hard kink.
fn smooth_max3(a: f64, b: f64, c: f64) -> f64 {
    const P: f64 = 6.0;
    let m = a.max(b).max(c);
    if m <= 0.0 {
        return 0.0;
    }
    let s = (a / m).powf(P) + (b / m).powf(P) + (c / m).powf(P);
    m * s.powf(1.0 / P)
}

/// Time one kernel of `plan` over `workload` at core clock `f_mhz`.
pub fn time_kernel(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    kernel_stages: f64,
    traffic_factor: f64,
    kind: KernelKind,
    shared_resident: bool,
    f_mhz: f64,
) -> KernelTiming {
    let f = gpu.effective_clock(f_mhz);
    let f_frac = f / gpu.boost_clock_mhz;
    let data_bytes = workload.data_bytes as f64;
    let elements = workload.elements() as f64;

    // --- device-memory roofline ---
    let traffic = traffic_factor * data_bytes;
    // case (a)/(b): a few % of bandwidth comes back at lower clock as L2
    // contention eases...
    let relief = 1.0 + gpu.contention_relief * (1.0 - f_frac).max(0.0);
    // ...until the issue rate can no longer cover memory latency: below
    // f_sat the outstanding-request count drops linearly with f.
    let issue_cycles_per_elem = match kind {
        KernelKind::FftPass => {
            gpu.cycles_base
                + gpu.cycles_per_stage * kernel_stages / gpu.fp_ratio(workload.precision)
        }
        KernelKind::Pointwise => gpu.cycles_base + 2.0 / gpu.fp_ratio(workload.precision),
    };
    // Latency hiding: warps generate one memory request every k cycles, so
    // the request rate is ∝ f and independent of transform length. Below
    // the per-architecture saturation fraction the effective bandwidth
    // scales with the clock (section 6: "not enough threads with data").
    let hiding = (f_frac / gpu.mem_sat_frac).min(1.0);
    let bw_eff = gpu.dev_bw_gbs * 1e9 * relief * hiding.max(1e-3);
    let t_mem = traffic / bw_eff;
    let t_mem_ideal = traffic / (gpu.dev_bw_gbs * 1e9);

    // --- instruction-issue roofline ---
    let t_issue = elements * issue_cycles_per_elem / (gpu.cuda_cores as f64 * f * 1e6);

    // --- shared-memory roofline (single-kernel resident passes) ---
    let t_shared = if shared_resident && kernel_stages > 0.0 {
        // Radix-8 butterflies: one shared-memory round trip (read+write)
        // per three radix-2-equivalent stages.
        let shared_round_trips = (kernel_stages / 3.0).ceil();
        let shared_traffic = 2.0 * shared_round_trips * data_bytes;
        shared_traffic / (gpu.shared_bw_gbs * 1e9 * f_frac.max(1e-3))
    } else {
        0.0
    };

    let mut t_total = smooth_max3(t_mem, t_issue, t_shared);

    // --- idle P-state cliff ---
    if f < gpu.pstate_floor_mhz {
        t_total *= gpu.pstate_penalty;
    }

    let mem_util = (t_mem_ideal / t_total).min(1.0);
    let issue_util = (t_issue / t_total).min(1.0);
    // FP pipes are busy for the butterfly's FLOP share of issue cycles.
    let fp_share = if issue_cycles_per_elem > 0.0 {
        (gpu.cycles_per_stage * kernel_stages / gpu.fp_ratio(workload.precision)
            / issue_cycles_per_elem)
            .min(1.0)
    } else {
        0.0
    };
    let compute_util = issue_util * fp_share;

    KernelTiming {
        t_mem,
        t_issue,
        t_shared,
        t_total,
        mem_util,
        issue_util,
        compute_util,
    }
}

/// Interpolated time/power point for lengths off the power-of-two
/// measurement grid (see [`interp_time_power`]).
#[derive(Debug, Clone, Copy)]
pub struct InterpPoint {
    pub time_s: f64,
    pub avg_power_w: f64,
    pub energy_j: f64,
}

fn exact_time_power(gpu: &GpuSpec, workload: &FftWorkload, f_mhz: f64) -> InterpPoint {
    let plan = crate::cufft::plan::plan(workload.n, workload.precision);
    let timing = time_plan(gpu, workload, &plan, f_mhz);
    let mut energy = 0.0;
    for k in &timing.per_kernel {
        energy += crate::sim::power::kernel_power_w(gpu, k, f_mhz) * k.t_total;
    }
    InterpPoint {
        time_s: timing.total_s,
        avg_power_w: if timing.total_s > 0.0 { energy / timing.total_s } else { 0.0 },
        energy_j: energy,
    }
}

/// Time/power for `workload` at clock `f_mhz`, interpolated in log₂N for
/// lengths off the power-of-two grid: price both bracketing pow2 anchors
/// exactly (same data volume) and blend geometrically. Power-of-two
/// lengths return the exact model point, so the curve is continuous at
/// the anchors. This is what lets the per-length-optimal and common-clock
/// governors produce sane requests for off-grid lengths (n=1000, n=1536)
/// without running a fresh measurement sweep per unseen length — and
/// without the single-kernel-capacity staircase of the exact plan model
/// landing between two serving lengths that differ by a few samples.
pub fn interp_time_power(gpu: &GpuSpec, workload: &FftWorkload, f_mhz: f64) -> InterpPoint {
    let n = workload.n;
    if n.is_power_of_two() || n < 4 {
        return exact_time_power(gpu, workload, f_mhz);
    }
    let hi = n.next_power_of_two();
    let lo = hi / 2;
    let w = ((n as f64).log2() - (lo as f64).log2()) / ((hi as f64).log2() - (lo as f64).log2());
    let lo_w = FftWorkload::new(lo, workload.precision, workload.data_bytes);
    let hi_w = FftWorkload::new(hi, workload.precision, workload.data_bytes);
    let lo_pt = exact_time_power(gpu, &lo_w, f_mhz);
    let hi_pt = exact_time_power(gpu, &hi_w, f_mhz);
    // Geometric blend: times and powers are positive and roughly
    // log-linear in N between anchors, and the blend is exact at both.
    let time_s = lo_pt.time_s.powf(1.0 - w) * hi_pt.time_s.powf(w);
    let avg_power_w = lo_pt.avg_power_w.powf(1.0 - w) * hi_pt.avg_power_w.powf(w);
    InterpPoint {
        time_s,
        avg_power_w,
        energy_j: time_s * avg_power_w,
    }
}

/// Time a whole plan at one clock.
pub fn time_plan(gpu: &GpuSpec, workload: &FftWorkload, plan: &FftPlan, f_mhz: f64) -> PlanTiming {
    let per_kernel: Vec<KernelTiming> = plan
        .kernels
        .iter()
        .map(|k| {
            time_kernel(
                gpu,
                workload,
                k.stages,
                k.traffic_factor,
                k.kind,
                k.shared_resident,
                f_mhz,
            )
        })
        .collect();
    let total_s = per_kernel.iter().map(|k| k.t_total).sum();
    PlanTiming { per_kernel, total_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cufft::plan::plan;
    use crate::sim::gpu::{jetson_nano, tesla_v100};
    use crate::types::{gib, FftWorkload, Precision};

    fn v100_w(n: u64) -> (GpuSpec, FftWorkload) {
        let g = tesla_v100();
        let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
        (g, w)
    }

    #[test]
    fn memory_bound_at_boost() {
        let (g, w) = v100_w(1024);
        let p = plan(w.n, w.precision);
        let t = time_plan(&g, &w, &p, g.boost_clock_mhz);
        let k = &t.per_kernel[0];
        assert!(k.t_mem > k.t_issue, "cuFFT must be memory-bound at boost");
        assert!(k.t_mem > k.t_shared);
        // 2 GiB read+write at 900 GB/s ≈ 4.8 ms
        assert!((t.total_s - 2.0 * gib(2) as f64 / 900e9).abs() / t.total_s < 0.15);
    }

    #[test]
    fn fig20_issue_utilization_midrange_at_boost() {
        // NVVP reports roughly half-utilized issue slots for mid-size N.
        let (g, w) = v100_w(4096);
        let p = plan(w.n, w.precision);
        let t = time_plan(&g, &w, &p, g.boost_clock_mhz);
        let k = &t.per_kernel[0];
        assert!(
            k.issue_util > 0.25 && k.issue_util < 0.85,
            "issue_util={}",
            k.issue_util
        );
        assert!(k.mem_util > 0.8, "mem_util={}", k.mem_util);
    }

    #[test]
    fn case_b_small_slowdown_at_optimal_v100() {
        // Paper: V100 exec-time increase at the optimal clock is below ~5%
        // for most N (Fig 11).
        for n in [256u64, 1024, 4096, 65536] {
            let (g, w) = v100_w(n);
            let p = plan(w.n, w.precision);
            let t_boost = time_plan(&g, &w, &p, g.boost_clock_mhz).total_s;
            let t_opt = time_plan(&g, &w, &p, 945.0).total_s;
            let inc = t_opt / t_boost - 1.0;
            assert!(
                inc < 0.10,
                "N={n}: {:.1}% increase at 945 MHz",
                inc * 100.0
            );
        }
    }

    #[test]
    fn case_c_shared_bound_n8192() {
        // N=8192 is the largest single-kernel fp32 plan: highest shared-
        // memory pressure → markedly worse slowdown than its neighbours
        // (paper Fig 6 case (c)).
        let (g, w8) = v100_w(8192);
        let p8 = plan(8192, Precision::Fp32);
        let (_, w1) = v100_w(1024);
        let p1 = plan(1024, Precision::Fp32);
        let f = 700.0;
        let slow8 = time_plan(&g, &w8, &p8, f).total_s
            / time_plan(&g, &w8, &p8, g.boost_clock_mhz).total_s;
        let slow1 = time_plan(&g, &w1, &p1, f).total_s
            / time_plan(&g, &w1, &p1, g.boost_clock_mhz).total_s;
        assert!(
            slow8 > slow1 + 0.02,
            "8192 should degrade faster: {slow8:.3} vs {slow1:.3}"
        );
    }

    #[test]
    fn jetson_is_compute_bound_case_c() {
        // Paper: the Nano shows case (c) almost everywhere — time rises
        // with every frequency decrement.
        let g = jetson_nano();
        let w = FftWorkload::new(1024, Precision::Fp32, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let fs = [921.6, 768.0, 614.4, 460.8, 307.2];
        let times: Vec<f64> = fs.iter().map(|&f| time_plan(&g, &w, &p, f).total_s).collect();
        for win in times.windows(2) {
            assert!(win[1] > win[0] * 1.02, "Jetson time must rise per step: {times:?}");
        }
        // slowdown at the knee is substantial (paper: ≥ 40%)
        assert!(times[3] / times[0] > 1.3, "{:?}", times);
    }

    #[test]
    fn pstate_cliff() {
        let (g, w) = v100_w(1024);
        let p = plan(w.n, w.precision);
        let just_above = time_plan(&g, &w, &p, g.pstate_floor_mhz + 5.0).total_s;
        let below = time_plan(&g, &w, &p, g.pstate_floor_mhz - 30.0).total_s;
        assert!(below > just_above * 1.8, "{below} vs {just_above}");
    }

    #[test]
    fn titan_v_cap_freezes_times_above_1335() {
        let g = crate::sim::gpu::titan_v();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let a = time_plan(&g, &w, &p, 1912.0).total_s;
        let b = time_plan(&g, &w, &p, 1400.0).total_s;
        let c = time_plan(&g, &w, &p, 1335.0).total_s;
        assert_eq!(a, c);
        assert_eq!(b, c);
        let lower = time_plan(&g, &w, &p, 1000.0).total_s;
        assert!(lower != c);
    }

    #[test]
    fn crippled_fp64_dominated_by_issue() {
        let g = crate::sim::gpu::tesla_p4();
        let w = FftWorkload::new(4096, Precision::Fp64, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let t = time_plan(&g, &w, &p, g.boost_clock_mhz);
        let k = &t.per_kernel[0];
        assert!(
            k.t_issue > k.t_mem,
            "P4 FP64 must be compute-bound: issue {} vs mem {}",
            k.t_issue,
            k.t_mem
        );
    }

    #[test]
    fn staircase_total_time_vs_n() {
        // t_fix roughly flat across the single-kernel plateau, then jumps
        // (Fig 4).
        let (g, _) = v100_w(0x1000);
        let t = |n: u64| {
            let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
            time_plan(&g, &w, &plan(n, Precision::Fp32), g.boost_clock_mhz).total_s
        };
        let t32 = t(32);
        let t8192 = t(8192);
        let t16384 = t(16384);
        assert!((t8192 / t32 - 1.0).abs() < 0.25, "plateau: {t32} vs {t8192}");
        assert!(t16384 > 1.6 * t8192, "staircase jump missing");
    }

    #[test]
    fn interp_is_exact_at_pow2_anchors() {
        let (g, w) = v100_w(4096);
        let p = plan(w.n, w.precision);
        let f = 945.0;
        let exact = time_plan(&g, &w, &p, f).total_s;
        let it = interp_time_power(&g, &w, f);
        assert!((it.time_s - exact).abs() < 1e-15 * exact.max(1.0));
        assert!(it.avg_power_w > 0.0 && it.energy_j > 0.0);
    }

    #[test]
    fn interp_off_grid_lands_between_anchors() {
        let g = tesla_v100();
        for n in [1000u64, 1536, 3000] {
            let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
            let lo = FftWorkload::new(n.next_power_of_two() / 2, w.precision, w.data_bytes);
            let hi = FftWorkload::new(n.next_power_of_two(), w.precision, w.data_bytes);
            for f in [g.boost_clock_mhz, 945.0, 600.0] {
                let it = interp_time_power(&g, &w, f);
                let t_lo = interp_time_power(&g, &lo, f).time_s;
                let t_hi = interp_time_power(&g, &hi, f).time_s;
                let (t_min, t_max) = (t_lo.min(t_hi), t_lo.max(t_hi));
                assert!(
                    it.time_s >= t_min * (1.0 - 1e-12) && it.time_s <= t_max * (1.0 + 1e-12),
                    "n={n} f={f}: {} outside [{t_min}, {t_max}]",
                    it.time_s
                );
                assert!(it.avg_power_w > 0.0);
                assert!((it.energy_j - it.time_s * it.avg_power_w).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn interp_anchor_grid_extends_to_2_22() {
        // Large-N tier regression: the pow2 anchors price exactly up to
        // 2^22 and an off-grid length between the top anchors (3·2^20 ∈
        // (2^21, 2^22)) still lands between its brackets — so the
        // per-length-optimal and common-clock governors stay meaningful
        // in the planner's four-step tier.
        let g = tesla_v100();
        for f in [g.boost_clock_mhz, 945.0] {
            let top = interp_time_power(
                &g,
                &FftWorkload::new(1 << 22, Precision::Fp32, g.working_set_bytes),
                f,
            );
            assert!(top.time_s > 0.0 && top.avg_power_w > 0.0 && top.energy_j > 0.0);
            let w = FftWorkload::new(3 << 20, Precision::Fp32, g.working_set_bytes);
            let it = interp_time_power(&g, &w, f);
            let t_lo = interp_time_power(
                &g,
                &FftWorkload::new(1 << 21, w.precision, w.data_bytes),
                f,
            )
            .time_s;
            let t_hi = top.time_s;
            let (t_min, t_max) = (t_lo.min(t_hi), t_lo.max(t_hi));
            assert!(
                it.time_s >= t_min * (1.0 - 1e-12) && it.time_s <= t_max * (1.0 + 1e-12),
                "f={f}: {} outside [{t_min}, {t_max}]",
                it.time_s
            );
        }
    }

    #[test]
    fn interp_energy_curve_has_minimum_below_boost_off_grid() {
        // The property the governors rely on: the interpolated energy
        // curve at an off-grid length still has its optimum well below
        // boost (the paper's headline shape).
        let g = tesla_v100();
        let w = FftWorkload::new(1000, Precision::Fp32, g.working_set_bytes);
        let freqs = crate::sim::freq_table::freq_table(&g).stride(4);
        let energies: Vec<f64> = freqs
            .iter()
            .map(|&f| interp_time_power(&g, &w, f).energy_j)
            .collect();
        let imin = crate::util::stats::argmin(&energies).unwrap();
        let f_opt = freqs[imin];
        assert!(f_opt < 0.85 * g.boost_clock_mhz, "optimum {f_opt} not below boost");
        assert!(f_opt > 0.4 * g.boost_clock_mhz, "optimum {f_opt} implausibly low");
    }

    #[test]
    fn smooth_max_bounds() {
        assert!(smooth_max3(1.0, 0.0, 0.0) >= 1.0);
        assert!(smooth_max3(1.0, 1.0, 1.0) <= 3.0f64.powf(1.0 / 6.0) * 1.0 + 1e-12);
        assert_eq!(smooth_max3(0.0, 0.0, 0.0), 0.0);
    }
}
