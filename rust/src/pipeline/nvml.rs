//! Simulated NVIDIA Management Library (NVML) clock control.
//!
//! The paper's pipeline demo (section 5.3) brackets the cuFFT call with
//! `nvmlDeviceSetGpuLockedClocks` / `nvmlDeviceResetGpuLockedClocks`.
//! This module reproduces that call surface against the simulator,
//! including the two real-world constraints the paper notes:
//!   * locked clocks are fully supported only on Tesla-class boards,
//!   * requests snap to the card's supported frequency table, and the
//!     driver may cap the effective compute clock (Titan V).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::sim::freq_table::{freq_table, FreqTable};
use crate::sim::GpuSpec;

#[derive(Debug, thiserror::Error)]
pub enum NvmlError {
    #[error("locked clocks not supported on {0} (non-Tesla board)")]
    NotSupported(String),
    #[error("requested clock range [{0}, {1}] MHz invalid")]
    BadRange(f64, f64),
    /// An armed fault-injection window (`sim::fault`): the driver call
    /// fails the way a flaky board's does, while the card keeps running
    /// at its default clocks.
    #[error("injected clock-lock fault on {0}")]
    FaultInjected(String),
}

/// Clock-lock state of one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClockState {
    Default,
    Locked { min_mhz: f64, max_mhz: f64 },
}

/// The simulated NVML handle for one GPU.
pub struct SimNvml {
    gpu_name: String,
    boost_mhz: f64,
    table: FreqTable,
    tesla_class: bool,
    state: Mutex<ClockState>,
    /// Every state transition, for the Fig 19 clock trace.
    transitions: Mutex<Vec<(ClockState, f64)>>,
    /// While set, `set_gpu_locked_clocks` fails with
    /// [`NvmlError::FaultInjected`] (chaos testing).
    lock_fault: AtomicBool,
}

impl SimNvml {
    pub fn new(gpu: &GpuSpec) -> Self {
        Self {
            gpu_name: gpu.name.to_string(),
            boost_mhz: gpu.boost_clock_mhz,
            table: freq_table(gpu),
            tesla_class: gpu.name.starts_with("Tesla"),
            state: Mutex::new(ClockState::Default),
            transitions: Mutex::new(Vec::new()),
            lock_fault: AtomicBool::new(false),
        }
    }

    /// Arm (or disarm) the injected clock-lock fault: while armed, every
    /// `set_gpu_locked_clocks` call errors and the card stays at its
    /// default clocks — the `FaultKind::ClockLock` failure mode.
    pub fn set_lock_fault(&self, armed: bool) {
        self.lock_fault.store(armed, Ordering::Relaxed);
    }

    /// nvmlDeviceSetGpuLockedClocks(min, max).
    pub fn set_gpu_locked_clocks(&self, min_mhz: f64, max_mhz: f64) -> Result<(), NvmlError> {
        if self.lock_fault.load(Ordering::Relaxed) {
            return Err(NvmlError::FaultInjected(self.gpu_name.clone()));
        }
        if !self.tesla_class {
            return Err(NvmlError::NotSupported(self.gpu_name.clone()));
        }
        if !(min_mhz <= max_mhz) || min_mhz <= 0.0 {
            return Err(NvmlError::BadRange(min_mhz, max_mhz));
        }
        let snapped = ClockState::Locked {
            min_mhz: self.table.snap(min_mhz),
            max_mhz: self.table.snap(max_mhz),
        };
        *self.state.lock().unwrap() = snapped;
        self.transitions
            .lock()
            .unwrap()
            .push((snapped, self.current_clock_mhz()));
        Ok(())
    }

    /// nvmlDeviceResetGpuLockedClocks().
    pub fn reset_gpu_locked_clocks(&self) {
        *self.state.lock().unwrap() = ClockState::Default;
        self.transitions
            .lock()
            .unwrap()
            .push((ClockState::Default, self.current_clock_mhz()));
    }

    pub fn state(&self) -> ClockState {
        *self.state.lock().unwrap()
    }

    /// The clock the card would run a kernel at right now.
    pub fn current_clock_mhz(&self) -> f64 {
        match *self.state.lock().unwrap() {
            ClockState::Default => self.boost_mhz,
            ClockState::Locked { max_mhz, .. } => max_mhz,
        }
    }

    pub fn transition_count(&self) -> usize {
        self.transitions.lock().unwrap().len()
    }

    /// The full transition trace: every lock/reset with the effective
    /// clock after it (the Fig 19 series; telemetry renders it so an
    /// operator can see that a budget arbiter settles instead of
    /// thrashing).
    pub fn transition_trace(&self) -> Vec<(ClockState, f64)> {
        self.transitions.lock().unwrap().clone()
    }

    /// Whether this board accepts `set_gpu_locked_clocks` (Tesla-class).
    /// Single source of truth for the check — consumers should ask the
    /// handle instead of re-matching on the GPU name.
    pub fn supports_locked_clocks(&self) -> bool {
        self.tesla_class
    }
}

/// RAII clock-lock guard: lock on creation, reset on drop (exception-safe
/// pipeline integration with "minimal changes to the codebase").
pub struct ClockGuard<'a> {
    nvml: &'a SimNvml,
}

impl<'a> ClockGuard<'a> {
    pub fn lock(nvml: &'a SimNvml, mhz: f64) -> Result<Self, NvmlError> {
        nvml.set_gpu_locked_clocks(mhz, mhz)?;
        Ok(Self { nvml })
    }
}

impl Drop for ClockGuard<'_> {
    fn drop(&mut self) {
        self.nvml.reset_gpu_locked_clocks();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{jetson_nano, tesla_v100, titan_xp};

    #[test]
    fn lock_and_reset() {
        let nv = SimNvml::new(&tesla_v100());
        assert_eq!(nv.current_clock_mhz(), 1530.0);
        nv.set_gpu_locked_clocks(945.0, 945.0).unwrap();
        let f = nv.current_clock_mhz();
        assert!((f - 945.0).abs() <= 8.0, "snapped to {f}");
        nv.reset_gpu_locked_clocks();
        assert_eq!(nv.current_clock_mhz(), 1530.0);
        assert_eq!(nv.transition_count(), 2);
    }

    #[test]
    fn transition_trace_records_states_and_clocks() {
        let nv = SimNvml::new(&tesla_v100());
        nv.set_gpu_locked_clocks(945.0, 945.0).unwrap();
        nv.reset_gpu_locked_clocks();
        let trace = nv.transition_trace();
        assert_eq!(trace.len(), nv.transition_count());
        assert!(matches!(trace[0].0, ClockState::Locked { .. }));
        assert!((trace[0].1 - 945.0).abs() <= 8.0);
        assert_eq!(trace[1].0, ClockState::Default);
        assert_eq!(trace[1].1, 1530.0);
    }

    #[test]
    fn non_tesla_rejected() {
        for g in [titan_xp(), jetson_nano()] {
            let nv = SimNvml::new(&g);
            assert!(matches!(
                nv.set_gpu_locked_clocks(900.0, 900.0),
                Err(NvmlError::NotSupported(_))
            ));
        }
    }

    #[test]
    fn bad_range_rejected() {
        let nv = SimNvml::new(&tesla_v100());
        assert!(matches!(
            nv.set_gpu_locked_clocks(1000.0, 900.0),
            Err(NvmlError::BadRange(..))
        ));
        assert!(nv.set_gpu_locked_clocks(-5.0, 900.0).is_err());
    }

    #[test]
    fn guard_resets_on_drop() {
        let nv = SimNvml::new(&tesla_v100());
        {
            let _g = ClockGuard::lock(&nv, 945.0).unwrap();
            assert!(matches!(nv.state(), ClockState::Locked { .. }));
        }
        assert_eq!(nv.state(), ClockState::Default);
    }

    #[test]
    fn injected_lock_fault_fails_then_recovers() {
        let nv = SimNvml::new(&tesla_v100());
        nv.set_lock_fault(true);
        assert!(matches!(
            nv.set_gpu_locked_clocks(945.0, 945.0),
            Err(NvmlError::FaultInjected(_))
        ));
        assert_eq!(nv.state(), ClockState::Default, "failed lock leaves default clocks");
        assert_eq!(nv.transition_count(), 0, "failed lock records no transition");
        nv.set_lock_fault(false);
        assert!(nv.set_gpu_locked_clocks(945.0, 945.0).is_ok(), "disarmed hook recovers");
    }

    #[test]
    fn requests_snap_to_table() {
        let nv = SimNvml::new(&tesla_v100());
        nv.set_gpu_locked_clocks(946.3, 946.3).unwrap();
        if let ClockState::Locked { max_mhz, .. } = nv.state() {
            let table = freq_table(&tesla_v100());
            assert!(table.contains(max_mhz));
        } else {
            panic!("not locked");
        }
    }
}
