//! The section-5.3 pulsar-search pipeline: stage model, simulated NVML
//! clock control, governed pipeline runner (Table 4 / Fig 19) and the
//! real-time provisioning model (section 2.3).
//!
//! The deadline clock policy that used to live here moved to
//! [`crate::governor::deadline`] when clock policies became a first-class
//! subsystem.

pub mod nvml;
pub mod realtime;
pub mod runner;
pub mod stages;

pub use nvml::{ClockGuard, SimNvml};
pub use runner::{run_pipeline, run_pipeline_at, table4, PipelineRun, Table4Row};
