//! The section-5.3 pulsar-search pipeline: stage model, simulated NVML
//! clock control, pipeline runner (Table 4 / Fig 19) and the real-time
//! provisioning model (section 2.3).

pub mod nvml;
pub mod realtime;
pub mod scheduler;
pub mod runner;
pub mod stages;

pub use nvml::{ClockGuard, SimNvml};
pub use runner::{run_pipeline, table4, PipelineRun, Table4Row};
