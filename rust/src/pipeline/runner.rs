//! Pipeline execution on the simulated GPU with NVML clock control —
//! regenerates Table 4 and the Fig 19 power/clock trace.
//!
//! The FFT stage's clock is decided by a pluggable
//! [`crate::governor::ClockGovernor`]; non-FFT stages always run at boost,
//! exactly as the paper brackets only the cuFFT call with
//! SetGpuLockedClocks / ResetGpuLockedClocks. The paper's two policies are
//! the `FixedBoost` (default) and `FixedClock(mean-optimal)` (DVFS)
//! governors; `table4` compares any governor against boost.

use crate::governor::{BatchFeedback, ClockGovernor, GovernorContext, GovernorKind};
use crate::pipeline::nvml::{ClockGuard, SimNvml};
use crate::pipeline::stages::{pipeline_stages, Stage};
use crate::sim::exec_model::time_kernel;
use crate::sim::power::kernel_power_w;
use crate::sim::sensor::PowerTimeline;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};

/// Timing/energy of one stage at one clock.
#[derive(Debug, Clone)]
pub struct StageRun {
    pub name: &'static str,
    pub is_fft: bool,
    pub clock_mhz: f64,
    pub time_s: f64,
    pub energy_j: f64,
}

/// One full pipeline execution.
#[derive(Debug, Clone)]
pub struct PipelineRun {
    pub stages: Vec<StageRun>,
    pub timeline: PowerTimeline,
    /// Clock trace: (t_start_s, clock_mhz) per stage (Fig 19 bottom panel).
    pub clock_trace: Vec<(f64, f64)>,
}

impl PipelineRun {
    pub fn total_time_s(&self) -> f64 {
        self.stages.iter().map(|s| s.time_s).sum()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.stages.iter().map(|s| s.energy_j).sum()
    }

    /// Execution-time share of the FFT (the paper's "FFT execution
    /// footprint", Table 4 column 2).
    pub fn fft_time_fraction(&self) -> f64 {
        let fft: f64 = self.stages.iter().filter(|s| s.is_fft).map(|s| s.time_s).sum();
        fft / self.total_time_s()
    }
}

fn run_stage(gpu: &GpuSpec, workload: &FftWorkload, stage: &Stage, f_mhz: f64) -> StageRun {
    let mut time_s = 0.0;
    let mut energy_j = 0.0;
    for k in &stage.kernels {
        let t = time_kernel(
            gpu,
            workload,
            k.stages,
            k.traffic_factor,
            k.kind,
            k.shared_resident,
            f_mhz,
        );
        time_s += t.t_total;
        energy_j += kernel_power_w(gpu, &t, f_mhz) * t.t_total;
    }
    StageRun {
        name: stage.name,
        is_fft: stage.is_fft,
        clock_mhz: gpu.effective_clock(f_mhz),
        time_s,
        energy_j,
    }
}

/// Run the pipeline once with `governor` deciding the FFT-stage clock.
/// The governor sees the FFT workload, decides, and gets the stage outcome
/// fed back (so adaptive policies work across repeated pipeline runs).
pub fn run_pipeline(
    gpu: &GpuSpec,
    n: u64,
    harmonics: u64,
    governor: &mut dyn ClockGovernor,
) -> PipelineRun {
    let ctx = GovernorContext::default();
    let nvml = SimNvml::new(gpu);
    let workload = FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes);
    let stages = pipeline_stages(n, Precision::Fp32, harmonics);
    let mut runs = Vec::new();
    let mut timeline = PowerTimeline::default();
    let mut clock_trace = Vec::new();
    let mut t = 0.0;
    for stage in &stages {
        let clock = if stage.is_fft {
            let requested = governor
                .choose(gpu, &workload, &ctx)
                .unwrap_or(gpu.boost_clock_mhz);
            if nvml_supported(gpu) {
                // the paper's bracketing: lock, run, reset (via guard)
                let _guard = ClockGuard::lock(&nvml, requested).expect("tesla-class lock");
                nvml.current_clock_mhz()
            } else {
                requested // non-Tesla: the harness sets clocks offline
            }
        } else {
            gpu.boost_clock_mhz
        };
        let run = run_stage(gpu, &workload, stage, clock);
        if stage.is_fft {
            let boost_probe = run_stage(gpu, &workload, stage, gpu.boost_clock_mhz);
            let deadline = ctx.effective_deadline_s(boost_probe.time_s);
            governor.observe(&BatchFeedback {
                n,
                f_mhz: clock,
                time_s: run.time_s,
                deadline_s: deadline,
                slack: 1.0 - run.time_s / deadline,
                energy_j: run.energy_j,
            });
        }
        clock_trace.push((t, run.clock_mhz));
        timeline.push(run.time_s, run.energy_j / run.time_s.max(1e-12), true);
        t += run.time_s;
        runs.push(run);
    }
    PipelineRun {
        stages: runs,
        timeline,
        clock_trace,
    }
}

/// Fixed-clock convenience, the pre-governor call shape:
/// `None` → boost everywhere; `Some(f)` → NVML-locked FFT clock.
pub fn run_pipeline_at(
    gpu: &GpuSpec,
    n: u64,
    harmonics: u64,
    fft_clock_mhz: Option<f64>,
) -> PipelineRun {
    let kind = match fft_clock_mhz {
        Some(f) => GovernorKind::FixedClock(f),
        None => GovernorKind::FixedBoost,
    };
    let mut gov = kind.make();
    run_pipeline(gpu, n, harmonics, &mut *gov)
}

fn nvml_supported(gpu: &GpuSpec) -> bool {
    gpu.name.starts_with("Tesla")
}

/// One Table 4 row.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub harmonics: u64,
    pub fft_time_pct: f64,
    pub eff_increase: f64,
}

/// Regenerate Table 4: pipeline energy-efficiency increase vs #harmonics,
/// for any clock governor compared against the all-boost default. One
/// governor instance spans all rows, so sweep-derived policies
/// (CommonClock, PerLengthOptimal) measure once and reuse their cache.
pub fn table4(gpu: &GpuSpec, n: u64, governor: &GovernorKind) -> Vec<Table4Row> {
    let mut gov = governor.make();
    [2u64, 4, 8, 16, 32]
        .iter()
        .map(|&h| {
            let default = run_pipeline_at(gpu, n, h, None);
            let dvfs = run_pipeline(gpu, n, h, &mut *gov);
            // Same work both ways → efficiency increase = energy ratio
            // corrected by the time ratio (eq. 4 with equal C_p·t... the
            // paper reports E_ef ratios; with fixed work this reduces to
            // E_default / E_dvfs).
            Table4Row {
                harmonics: h,
                fft_time_pct: default.fft_time_fraction() * 100.0,
                eff_increase: default.total_energy_j() / dvfs.total_energy_j(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;

    const N: u64 = 500_000; // the paper's pipeline FFT length (5·10^5)

    #[test]
    fn dvfs_saves_pipeline_energy() {
        let g = tesla_v100();
        let default = run_pipeline_at(&g, N, 8, None);
        let dvfs = run_pipeline_at(&g, N, 8, Some(945.0));
        assert!(dvfs.total_energy_j() < default.total_energy_j());
        // and costs little time
        let slowdown = dvfs.total_time_s() / default.total_time_s();
        assert!(slowdown < 1.10, "slowdown {slowdown}");
    }

    #[test]
    fn governed_pipeline_beats_boost_for_energy_policies() {
        // The governor plumbing end to end: every energy-oriented policy
        // must save pipeline energy vs the all-boost default.
        let g = tesla_v100();
        let default = run_pipeline_at(&g, N, 8, None);
        for kind in [GovernorKind::CommonClock, GovernorKind::DeadlineAware] {
            let mut gov = kind.make();
            let run = run_pipeline(&g, N, 8, &mut *gov);
            assert!(
                run.total_energy_j() < default.total_energy_j(),
                "{:?} failed to save energy",
                kind
            );
        }
    }

    #[test]
    fn fft_fraction_decreases_with_harmonics() {
        // Table 4 column 2: 60.85% at h=2 → 51.34% at h=32.
        let g = tesla_v100();
        let f2 = run_pipeline_at(&g, N, 2, None).fft_time_fraction();
        let f32_ = run_pipeline_at(&g, N, 32, None).fft_time_fraction();
        assert!(f2 > f32_, "{f2} !> {f32_}");
        assert!((0.45..0.75).contains(&f2), "h=2 fraction {f2}");
        assert!((0.35..0.65).contains(&f32_), "h=32 fraction {f32_}");
    }

    #[test]
    fn table4_shape_matches_paper() {
        // Efficiency increase ~1.24-1.29, monotonically decreasing with h.
        let g = tesla_v100();
        let rows = table4(&g, N, &GovernorKind::FixedClock(945.0));
        assert_eq!(rows.len(), 5);
        for w in rows.windows(2) {
            assert!(
                w[0].eff_increase >= w[1].eff_increase - 1e-9,
                "eff increase must fall with h: {:?}",
                rows
            );
            assert!(w[0].fft_time_pct > w[1].fft_time_pct);
        }
        for r in &rows {
            assert!(
                (1.10..1.60).contains(&r.eff_increase),
                "h={}: {}",
                r.harmonics,
                r.eff_increase
            );
        }
    }

    #[test]
    fn clock_trace_shows_fft_dip() {
        let g = tesla_v100();
        let run = run_pipeline_at(&g, N, 8, Some(945.0));
        // first stage (fft) at the locked clock, later stages at boost
        assert!(run.clock_trace[0].1 < 1000.0);
        assert_eq!(run.clock_trace[1].1, g.boost_clock_mhz);
        assert_eq!(run.clock_trace.len(), 4);
    }

    #[test]
    fn consistency_with_expected_composition() {
        // Paper section 6.2: expected pipeline gain ≈ FFT-only gain scaled
        // by the FFT's time share. Check within a loose band.
        let g = tesla_v100();
        let h = 2;
        let default = run_pipeline_at(&g, N, h, None);
        let dvfs = run_pipeline_at(&g, N, h, Some(945.0));
        let frac = default.fft_time_fraction();
        let fft_only_default: f64 = default.stages[0].energy_j;
        let fft_only_dvfs: f64 = dvfs.stages[0].energy_j;
        let fft_gain = fft_only_default / fft_only_dvfs;
        let expected = 1.0 / (1.0 - frac * (1.0 - 1.0 / fft_gain));
        let actual = default.total_energy_j() / dvfs.total_energy_j();
        assert!(
            (actual / expected - 1.0).abs() < 0.15,
            "actual {actual} vs composed {expected}"
        );
    }
}
