//! The pulsar-search pipeline stage model (paper section 5.3).
//!
//! Stages: FFT → power spectrum → mean & std → harmonic sum.  The non-FFT
//! stages are simple pointwise/reduction kernels; the harmonic sum is the
//! standard doubling implementation (log2(H) passes over the spectrum).
//! Stage traffic is expressed in units of the complex input size, the same
//! convention as `cufft::plan`.

use crate::cufft::plan::{plan, KernelDesc, KernelKind};
use crate::types::Precision;

/// One pipeline stage: a name plus the kernels it launches.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: &'static str,
    pub kernels: Vec<KernelDesc>,
    pub is_fft: bool,
}

fn pointwise(traffic_factor: f64) -> KernelDesc {
    KernelDesc {
        kind: KernelKind::Pointwise,
        stages: 0.0,
        traffic_factor,
        shared_resident: false,
    }
}

/// Build the stage list for FFT length `n` and `harmonics` summed.
pub fn pipeline_stages(n: u64, precision: Precision, harmonics: u64) -> Vec<Stage> {
    assert!(harmonics >= 1 && harmonics.is_power_of_two(), "harmonics must be a power of two");
    let fft_plan = plan(n, precision);
    let mut stages = vec![Stage {
        name: "fft",
        kernels: fft_plan.kernels.clone(),
        is_fft: true,
    }];
    // power spectrum: read complex (1.0 of data), write real (0.5)
    stages.push(Stage {
        name: "power_spectrum",
        kernels: vec![pointwise(1.5)],
        is_fft: false,
    });
    // mean & std: read the real spectrum (0.5), tiny write
    stages.push(Stage {
        name: "mean_std",
        kernels: vec![pointwise(0.55)],
        is_fft: false,
    });
    // harmonic sum: doubling algorithm, log2(H) passes, each read+write the
    // real spectrum (0.5 + 0.5), with a fixed normalization pass.
    let hs_passes = (harmonics as f64).log2().max(0.0) as u64;
    let mut hs = vec![pointwise(0.5)];
    for _ in 0..hs_passes {
        hs.push(pointwise(1.0));
    }
    stages.push(Stage {
        name: "harmonic_sum",
        kernels: hs,
        is_fft: false,
    });
    stages
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_list_structure() {
        let s = pipeline_stages(500_000, Precision::Fp32, 8);
        let names: Vec<&str> = s.iter().map(|st| st.name).collect();
        assert_eq!(names, vec!["fft", "power_spectrum", "mean_std", "harmonic_sum"]);
        assert!(s[0].is_fft && !s[1].is_fft);
    }

    #[test]
    fn n_5e5_is_smooth_multikernel() {
        // 5·10^5 = 2^5 · 5^6: Cooley-Tukey, multiple passes.
        let s = pipeline_stages(500_000, Precision::Fp32, 2);
        assert!(s[0].kernels.len() >= 2, "{}", s[0].kernels.len());
    }

    #[test]
    fn harmonic_sum_grows_with_h() {
        let h2 = pipeline_stages(500_000, Precision::Fp32, 2);
        let h32 = pipeline_stages(500_000, Precision::Fp32, 32);
        let t = |s: &Stage| s.kernels.iter().map(|k| k.traffic_factor).sum::<f64>();
        assert!(t(&h32[3]) > t(&h2[3]));
        assert_eq!(h2[3].kernels.len(), 2);
        assert_eq!(h32[3].kernels.len(), 6);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn odd_harmonics_rejected() {
        pipeline_stages(1024, Precision::Fp32, 3);
    }
}
