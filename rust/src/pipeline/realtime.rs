//! Real-time processing model (paper section 2.3 / 6.1).
//!
//! The real-time speed-up S = t_a / t_p: acquisition time over processing
//! time. S >= 1 → the pipeline keeps up. Lowering the clock trades S for
//! energy; when S drops below 1 more cards are needed, with the capital vs
//! operational cost trade-off the paper discusses.

/// Real-time characteristics of one configuration.
#[derive(Debug, Clone)]
pub struct RealtimeAssessment {
    /// S = t_a / t_p.
    pub speedup: f64,
    pub realtime: bool,
    /// Cards needed to restore S >= 1 at this clock (paper's "60% more
    /// hardware" style statements).
    pub cards_needed: u64,
    /// Fractional extra hardware vs a single boost-clock card that just
    /// meets real time.
    pub extra_hardware_frac: f64,
}

/// Assess a configuration: data acquired over `t_acquire_s` must be
/// processed in `t_process_s` per card; FFT batches split freely across
/// cards (the paper's assumption for transforms that fit in card memory).
pub fn assess(t_acquire_s: f64, t_process_s: f64) -> RealtimeAssessment {
    assert!(t_acquire_s > 0.0 && t_process_s > 0.0);
    let speedup = t_acquire_s / t_process_s;
    let cards_needed = (t_process_s / t_acquire_s).ceil().max(1.0) as u64;
    RealtimeAssessment {
        speedup,
        realtime: speedup >= 1.0,
        cards_needed,
        extra_hardware_frac: (t_process_s / t_acquire_s - 1.0).max(0.0),
    }
}

/// The energy/hardware trade-off of running at a lower clock: given the
/// boost-clock processing time (S=1 reference: t_a == t_p_boost) and the
/// slowdown factor at the tuned clock, how much more hardware for how much
/// less energy?
#[derive(Debug, Clone)]
pub struct TradeOff {
    pub slowdown: f64,
    pub cards_needed: u64,
    pub energy_ratio: f64,
    /// Net energy change across the (larger) fleet.
    pub fleet_energy_ratio: f64,
}

pub fn tradeoff(slowdown: f64, energy_ratio: f64) -> TradeOff {
    assert!(slowdown > 0.0 && energy_ratio > 0.0);
    let cards = slowdown.ceil().max(1.0) as u64;
    TradeOff {
        slowdown,
        cards_needed: cards,
        energy_ratio,
        // Each card now processes 1/cards of the data in the same wall
        // time; total energy scales with the per-unit-work energy only.
        fleet_energy_ratio: energy_ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_above_one_is_realtime() {
        let a = assess(10.0, 8.0);
        assert!(a.realtime);
        assert!((a.speedup - 1.25).abs() < 1e-12);
        assert_eq!(a.cards_needed, 1);
        assert_eq!(a.extra_hardware_frac, 0.0);
    }

    #[test]
    fn jetson_case_sixty_percent_more_hardware() {
        // Paper: Nano needs ~60% more time at optimal → 60% more hardware.
        let a = assess(1.0, 1.6);
        assert!(!a.realtime);
        assert_eq!(a.cards_needed, 2);
        assert!((a.extra_hardware_frac - 0.6).abs() < 1e-12);
    }

    #[test]
    fn v100_case_stays_realtime_with_slack() {
        // <5% slowdown fits inside a real pipeline's performance buffer.
        let a = assess(1.0, 1.04);
        assert_eq!(a.cards_needed, 2); // strictly S<1 without buffer...
        assert!(!a.realtime);
        let with_buffer = assess(1.10, 1.04);
        assert!(with_buffer.realtime);
    }

    #[test]
    fn tradeoff_fleet_energy() {
        let t = tradeoff(1.6, 0.6);
        assert_eq!(t.cards_needed, 2);
        assert!((t.fleet_energy_ratio - 0.6).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_times_rejected() {
        assess(0.0, 1.0);
    }
}
