//! Per-card power telemetry recorder.
//!
//! Every governed batch the worker executes is priced by the simulator
//! (`sim::run_batch` → average draw + energy at the governed clock, the
//! same numbers `SimNvml`/`sim::power` produce for the paper's figures).
//! The recorder turns that stream into operator-facing time series:
//!
//!   * instant draw (last executed batch, W),
//!   * rolling averages over the trailing 1 s / 10 s of *simulated busy
//!     time* (the card's time axis is the sum of simulated batch
//!     durations — wall-clock on the host says nothing about what the
//!     simulated card dissipates),
//!   * cumulative energy in full-precision joules (an `f64` behind the
//!     lock — never the truncating µJ counters of `Metrics`),
//!   * per-length energy attribution (energy/job by transform length),
//!   * deadline misses and observed clock changes.
//!
//! Storage is one fixed-capacity [`Ring`] of batch samples behind a single
//! short-held mutex ("lock-light": one lock per batch on the worker side,
//! one per read on the exporter side; the hot counters that dashboards
//! poll are atomics outside the lock). The retained window can be
//! materialized as a [`PowerTimeline`] so everything built for the paper's
//! sensor model — `power_at`, `TimelineIndex`, `sample_timeline` — works
//! unchanged on live serving telemetry (that is what the `fftsweep
//! telemetry` replay renders).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sim::sensor::{sample_timeline, PowerSample, PowerTimeline, SensorConfig};
use crate::telemetry::ring::Ring;
use crate::util::rng::Rng;

/// Recorder sizing knobs.
#[derive(Debug, Clone)]
pub struct RecorderConfig {
    /// Retained batch samples per card (ring capacity).
    pub capacity: usize,
    /// Short rolling window, seconds of simulated busy time.
    pub short_window_s: f64,
    /// Long rolling window, seconds of simulated busy time.
    pub long_window_s: f64,
}

impl Default for RecorderConfig {
    fn default() -> Self {
        Self {
            capacity: 1024,
            short_window_s: 1.0,
            long_window_s: 10.0,
        }
    }
}

/// One executed batch as the recorder retains it.
#[derive(Debug, Clone)]
pub struct BatchSample {
    /// Start of the batch on the card's simulated busy-time axis, s.
    pub t_start_s: f64,
    pub duration_s: f64,
    /// Mean simulated board draw over the batch, W.
    pub power_w: f64,
    pub energy_j: f64,
    pub clock_mhz: f64,
    pub n: u64,
    /// Jobs packed into the batch (occupancy).
    pub jobs: u64,
    pub deadline_missed: bool,
}

#[derive(Debug, Default, Clone, Copy)]
struct LengthEnergy {
    jobs: u64,
    energy_j: f64,
}

struct Inner {
    ring: Ring<BatchSample>,
    /// Cumulative simulated busy time, s (the time axis).
    now_s: f64,
    /// Cumulative energy, full-precision joules.
    energy_j: f64,
    jobs: u64,
    per_length: BTreeMap<u64, LengthEnergy>,
    clock_changes: u64,
    last_clock_mhz: f64,
}

impl Inner {
    /// Retained samples as a ground-truth timeline (all segments compute),
    /// re-based so t=0 is the oldest retained sample — the single
    /// materialization both the exact-lookup and noisy-sampler paths use.
    fn timeline(&self) -> PowerTimeline {
        let mut tl = PowerTimeline::default();
        for s in self.ring.iter() {
            tl.push(s.duration_s, s.power_w, true);
        }
        tl
    }
}

/// Per-card power telemetry (see module docs).
pub struct PowerRecorder {
    cfg: RecorderConfig,
    /// Draw reported when no batch ran yet (the card's idle floor, W).
    idle_w: f64,
    inner: Mutex<Inner>,
    batches: AtomicU64,
    deadline_misses: AtomicU64,
}

impl PowerRecorder {
    pub fn new(idle_w: f64, cfg: RecorderConfig) -> Self {
        Self {
            idle_w,
            inner: Mutex::new(Inner {
                ring: Ring::new(cfg.capacity),
                now_s: 0.0,
                energy_j: 0.0,
                jobs: 0,
                per_length: BTreeMap::new(),
                clock_changes: 0,
                last_clock_mhz: f64::NAN,
            }),
            cfg,
            batches: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
        }
    }

    /// Record one executed batch (worker hot path: one short lock).
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        clock_mhz: f64,
        duration_s: f64,
        power_w: f64,
        energy_j: f64,
        n: u64,
        jobs: u64,
        deadline_missed: bool,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        if deadline_missed {
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.last_clock_mhz != clock_mhz {
            if !inner.last_clock_mhz.is_nan() {
                inner.clock_changes += 1;
            }
            inner.last_clock_mhz = clock_mhz;
        }
        let sample = BatchSample {
            t_start_s: inner.now_s,
            duration_s,
            power_w,
            energy_j,
            clock_mhz,
            n,
            jobs,
            deadline_missed,
        };
        inner.now_s += duration_s;
        inner.energy_j += energy_j;
        inner.jobs += jobs;
        let slot = inner.per_length.entry(n).or_default();
        slot.jobs += jobs;
        slot.energy_j += energy_j;
        inner.ring.push(sample);
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::Relaxed)
    }

    /// Observed clock *changes* across recorded batches (a proxy for DVFS
    /// churn; the authoritative NVML transition trace lives on `SimNvml`).
    pub fn clock_changes(&self) -> u64 {
        self.inner.lock().unwrap().clock_changes
    }

    /// Cumulative simulated busy time, s.
    pub fn busy_s(&self) -> f64 {
        self.inner.lock().unwrap().now_s
    }

    /// Cumulative energy, J (full precision — no µJ truncation).
    pub fn cumulative_energy_j(&self) -> f64 {
        self.inner.lock().unwrap().energy_j
    }

    pub fn jobs(&self) -> u64 {
        self.inner.lock().unwrap().jobs
    }

    /// Mean attributed energy per job over everything recorded, J
    /// (batch energy split evenly across the jobs packed into it; padding
    /// rows bill to the jobs that caused the batch).
    pub fn energy_per_job_j(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.jobs == 0 {
            return 0.0;
        }
        inner.energy_j / inner.jobs as f64
    }

    /// Per-transform-length attribution: (n, jobs, energy J), ascending n.
    pub fn per_length_energy(&self) -> Vec<(u64, u64, f64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .per_length
            .iter()
            .map(|(&n, e)| (n, e.jobs, e.energy_j))
            .collect()
    }

    /// Draw of the most recently executed batch, W (idle floor before any).
    pub fn instant_w(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        inner.ring.newest().map(|s| s.power_w).unwrap_or(self.idle_w)
    }

    /// Energy-weighted mean draw over the trailing `window_s` of simulated
    /// busy time (partial windows average what is covered; the idle floor
    /// before anything ran).
    pub fn rolling_avg_w(&self, window_s: f64) -> f64 {
        let inner = self.inner.lock().unwrap();
        let mut energy = 0.0;
        let mut covered = 0.0;
        for s in inner.ring.iter().rev() {
            if covered >= window_s {
                break;
            }
            // Clip the oldest contributing sample at the window edge.
            let take = s.duration_s.min(window_s - covered);
            let frac = if s.duration_s > 0.0 { take / s.duration_s } else { 0.0 };
            energy += s.energy_j * frac;
            covered += take;
        }
        if covered <= 0.0 {
            return self.idle_w;
        }
        energy / covered
    }

    /// The short (1 s) rolling average, W.
    pub fn avg_short_w(&self) -> f64 {
        self.rolling_avg_w(self.cfg.short_window_s)
    }

    /// The long (10 s) rolling average, W.
    pub fn avg_long_w(&self) -> f64 {
        self.rolling_avg_w(self.cfg.long_window_s)
    }

    /// Materialize the retained window as a ground-truth [`PowerTimeline`].
    /// Everything written for the paper's sensor path — exact `power_at`
    /// lookups, `TimelineIndex`, noisy `sample_timeline` — runs unchanged
    /// on it.
    pub fn window_timeline(&self) -> PowerTimeline {
        self.inner.lock().unwrap().timeline()
    }

    /// Replay the retained window through the noisy driver-sampling model
    /// (nvidia-smi emulation) — what `fftsweep telemetry` renders.
    pub fn sample_window(
        &self,
        sensor: &SensorConfig,
        mem_clock_mhz: f64,
        rng: &mut Rng,
    ) -> Vec<PowerSample> {
        let (tl, clock) = {
            let inner = self.inner.lock().unwrap();
            let clock = inner.ring.newest().map(|s| s.clock_mhz).unwrap_or(0.0);
            (inner.timeline(), clock)
        };
        sample_timeline(&tl, sensor, clock, mem_clock_mhz, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recorder() -> PowerRecorder {
        PowerRecorder::new(
            38.0,
            RecorderConfig {
                capacity: 8,
                short_window_s: 1.0,
                long_window_s: 10.0,
            },
        )
    }

    #[test]
    fn empty_recorder_reports_idle_floor() {
        let r = recorder();
        assert_eq!(r.instant_w(), 38.0);
        assert_eq!(r.avg_short_w(), 38.0);
        assert_eq!(r.cumulative_energy_j(), 0.0);
        assert_eq!(r.energy_per_job_j(), 0.0);
        assert_eq!(r.batches(), 0);
        assert!(r.window_timeline().segments.is_empty());
    }

    #[test]
    fn cumulative_energy_keeps_sub_microjoule_batches() {
        // The `Metrics` truncation bug this subsystem must not share:
        // 10_000 batches of 0.3 µJ must sum to 3 mJ, not zero.
        let r = recorder();
        for _ in 0..10_000 {
            r.record_batch(945.0, 1e-6, 0.3, 0.3e-6, 1024, 1, false);
        }
        assert!((r.cumulative_energy_j() - 3.0e-3).abs() < 1e-12);
        assert_eq!(r.jobs(), 10_000);
        assert!((r.energy_per_job_j() - 0.3e-6).abs() < 1e-18);
    }

    #[test]
    fn rolling_average_windows_over_busy_time() {
        let r = recorder();
        // 0.6 s at 100 W, then 0.6 s at 200 W of simulated busy time.
        r.record_batch(945.0, 0.6, 100.0, 60.0, 1024, 4, false);
        r.record_batch(945.0, 0.6, 200.0, 120.0, 1024, 4, false);
        assert_eq!(r.instant_w(), 200.0);
        // 1 s window: all of the newest batch + 0.4 s of the older one.
        let want = (120.0 + 60.0 * (0.4 / 0.6)) / 1.0;
        assert!((r.rolling_avg_w(1.0) - want).abs() < 1e-9, "{}", r.rolling_avg_w(1.0));
        // 10 s window covers everything: plain mean power.
        assert!((r.rolling_avg_w(10.0) - 150.0).abs() < 1e-9);
        // Tiny window: just the newest batch.
        assert!((r.rolling_avg_w(0.1) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn ring_eviction_bounds_the_window_not_the_totals() {
        let r = recorder();
        for i in 0..20 {
            r.record_batch(945.0, 0.1, 100.0 + i as f64, 1.0, 1024, 2, false);
        }
        // capacity 8: the timeline window holds only the newest 8 …
        let tl = r.window_timeline();
        assert_eq!(tl.segments.len(), 8);
        assert!((tl.total_duration() - 0.8).abs() < 1e-12);
        // … but cumulative accounting saw everything.
        assert_eq!(r.batches(), 20);
        assert_eq!(r.jobs(), 40);
        assert!((r.cumulative_energy_j() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn per_length_attribution_splits_energy_by_n() {
        let r = recorder();
        r.record_batch(945.0, 0.1, 100.0, 10.0, 1024, 2, false);
        r.record_batch(945.0, 0.1, 100.0, 10.0, 1024, 2, false);
        r.record_batch(945.0, 0.2, 120.0, 24.0, 4096, 3, false);
        let by_len = r.per_length_energy();
        assert_eq!(by_len.len(), 2);
        assert_eq!(by_len[0], (1024, 4, 20.0));
        assert_eq!(by_len[1], (4096, 3, 24.0));
        // fleet-level mean energy/job
        assert!((r.energy_per_job_j() - 44.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_misses_and_clock_changes_counted() {
        let r = recorder();
        r.record_batch(1530.0, 0.1, 200.0, 20.0, 1024, 1, false);
        r.record_batch(1530.0, 0.1, 200.0, 20.0, 1024, 1, true);
        r.record_batch(945.0, 0.1, 120.0, 12.0, 1024, 1, false);
        r.record_batch(945.0, 0.1, 120.0, 12.0, 1024, 1, false);
        assert_eq!(r.deadline_misses(), 1);
        // one observed change (1530 → 945); the first batch sets the
        // baseline and counts no transition
        assert_eq!(r.clock_changes(), 1);
    }

    #[test]
    fn window_timeline_supports_sensor_sampling() {
        // The retained window flows through the paper's sensor model
        // unchanged: integrate the noisy samples and land near truth.
        let r = recorder();
        for _ in 0..4 {
            r.record_batch(945.0, 0.5, 150.0, 75.0, 1024, 2, false);
        }
        let tl = r.window_timeline();
        assert_eq!(tl.true_compute_energy(), 300.0);
        // exact lookups at an interior point and past the end
        assert_eq!(tl.power_at(0.25), Some((150.0, true)));
        assert_eq!(tl.power_at(2.0), None);
        let cfg = SensorConfig {
            requested_interval_s: 0.010,
            achieved_interval_s: 0.0142,
            noise_sd: 0.02,
        };
        let samples = r.sample_window(&cfg, 877.0, &mut Rng::new(11));
        assert!(samples.len() > 100);
        let e = crate::sim::sensor::integrate_energy(&samples);
        assert!((e - 300.0).abs() / 300.0 < 0.05, "sampled {e} vs 300");
    }
}
