//! Fleet power-budget arbitration.
//!
//! An operator cap (`serve --power-budget-w W`) is a *fleet* quantity; the
//! DVFS knob is *per card*. The [`PowerBudget`] arbiter closes that gap:
//! it periodically splits the global watt ceiling into per-card shares
//! proportional to each card's offered load (inflight + queued jobs),
//! clamped to what the card can physically do (its idle floor and TDP),
//! with a deadband so small load wobbles do not move shares — and
//! therefore do not move clocks (no per-batch NVML thrash; asserted
//! against `SimNvml::transition_count` in the integration tests).
//!
//! Shares reach the workers through a lock-free [`ShareCell`] each, and
//! reach the governors as the [`crate::governor::GovernorContext`]
//! `power_budget_w` hint. [`clock_cap_for_budget`] is the shared
//! watt→clock inversion: the fastest supported clock whose predicted
//! batch draw fits the share (board power is monotone in clock — tested
//! in `sim::power`).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::freq_table::freq_table;
use crate::sim::{run_batch, GpuSpec};
use crate::types::FftWorkload;

/// Lock-free per-card watt share: an `f64` in atomic bits, with
/// `+inf` meaning "uncapped". Writers (the arbiter) and readers (the
/// card worker, once per batch) never block each other.
#[derive(Debug)]
pub struct ShareCell(AtomicU64);

impl ShareCell {
    pub fn unlimited() -> Self {
        Self(AtomicU64::new(f64::INFINITY.to_bits()))
    }

    pub fn with_share(w: f64) -> Self {
        Self(AtomicU64::new(w.to_bits()))
    }

    /// The current share; `None` when uncapped.
    pub fn get(&self) -> Option<f64> {
        let w = f64::from_bits(self.0.load(Ordering::Relaxed));
        if w.is_finite() {
            Some(w)
        } else {
            None
        }
    }

    pub fn set(&self, share: Option<f64>) {
        let w = share.unwrap_or(f64::INFINITY);
        self.0.store(w.to_bits(), Ordering::Relaxed);
    }
}

/// Quantize a watt share to quarter-watt resolution — the memoization key
/// workers use for their watt→clock cap cache, so sub-deadband share
/// wiggle can never grow the cache or re-derive a cap.
pub fn budget_key(share_w: f64) -> u64 {
    (share_w.max(0.0) * 4.0).round() as u64
}

/// Fastest supported clock (at or below boost) whose predicted mean batch
/// draw fits `budget_w` for this workload. Board power falls monotonically
/// with clock, so the first feasible entry of the descending table is the
/// answer; if even the table floor exceeds the budget the floor is
/// returned (best effort — the share was below the card's physical
/// minimum). The returned clock is always a frequency-table entry.
pub fn clock_cap_for_budget(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    budget_w: f64,
    freq_stride: usize,
) -> f64 {
    let table = freq_table(gpu);
    for f in table.stride(freq_stride.max(1)) {
        if f > gpu.boost_clock_mhz + 1e-9 {
            continue;
        }
        if run_batch(gpu, workload, f).avg_power_w <= budget_w {
            return f;
        }
    }
    table.f_min_mhz
}

/// Per-card physical share bounds: no share below the idle floor makes
/// sense (the board draws it regardless), none above TDP is spendable.
pub fn share_bounds_w(gpu: &GpuSpec) -> (f64, f64) {
    (crate::sim::power::idle_power_w(gpu), gpu.tdp_w)
}

/// The fleet watt-ceiling arbiter (pure policy; the engine owns the
/// thread that drives it).
#[derive(Debug, Clone)]
pub struct PowerBudget {
    /// Global cap, W.
    pub total_w: f64,
    /// Relative deadband: a card's share only moves when the newly
    /// computed share differs from the current one by more than this
    /// fraction (hysteresis against clock thrash).
    pub deadband_frac: f64,
}

impl PowerBudget {
    pub fn new(total_w: f64) -> Self {
        Self {
            total_w,
            deadband_frac: 0.10,
        }
    }

    /// Split `total_w` into per-card shares proportional to `loads`
    /// (offered jobs per card; all-idle falls back to an even split),
    /// clamped to `bounds` (floor, ceiling) per card, then filtered
    /// through the deadband against `prev`.
    ///
    /// Invariants (tested): every share is within its card's bounds; the
    /// sum never exceeds `total_w` when the floors permit it (infeasible
    /// budgets degrade to the floor vector — best effort); a load vector
    /// whose proportional shares sit inside the deadband reproduces
    /// `prev` exactly (share stability ⇒ clock stability).
    pub fn redistribute(
        &self,
        loads: &[f64],
        bounds: &[(f64, f64)],
        prev: &[Option<f64>],
    ) -> Vec<f64> {
        assert_eq!(loads.len(), bounds.len());
        assert_eq!(loads.len(), prev.len());
        let n = loads.len();
        if n == 0 {
            return Vec::new();
        }
        let floors: f64 = bounds.iter().map(|b| b.0).sum();
        let spend = (self.total_w - floors).max(0.0);

        let total_load: f64 = loads.iter().map(|l| l.max(0.0)).sum();
        let weight = |i: usize| {
            if total_load > 0.0 {
                loads[i].max(0.0) / total_load
            } else {
                1.0 / n as f64
            }
        };

        // Proportional split of the spend above the floors, capped at each
        // card's ceiling; one redistribution round hands capped overflow
        // to the cards that still have headroom.
        let mut shares: Vec<f64> = (0..n).map(|i| bounds[i].0 + spend * weight(i)).collect();
        let mut overflow = 0.0;
        let mut headroom_weight = 0.0;
        for i in 0..n {
            if shares[i] > bounds[i].1 {
                overflow += shares[i] - bounds[i].1;
                shares[i] = bounds[i].1;
            } else {
                headroom_weight += weight(i);
            }
        }
        if overflow > 0.0 && headroom_weight > 0.0 {
            for i in 0..n {
                if shares[i] < bounds[i].1 {
                    let extra = overflow * weight(i) / headroom_weight;
                    shares[i] = (shares[i] + extra).min(bounds[i].1);
                }
            }
        }

        // Hysteresis: keep the previous share when the move is inside the
        // deadband (a kept share is still clamped to the card's bounds).
        let targets = shares.clone();
        for i in 0..n {
            if let Some(p) = prev[i] {
                if (shares[i] - p).abs() <= self.deadband_frac * p {
                    shares[i] = p.clamp(bounds[i].0, bounds[i].1);
                }
            }
        }

        // The cap outranks the deadband: if holding old shares while
        // others rose pushed the sum over the total, walk the held-high
        // shares back toward their freshly computed targets until the
        // fleet fits again (the targets themselves sum within the total
        // whenever the floors permit, so this always converges).
        let mut sum: f64 = shares.iter().sum();
        if sum > self.total_w + 1e-9 {
            for i in 0..n {
                if sum <= self.total_w + 1e-9 {
                    break;
                }
                if shares[i] > targets[i] {
                    let give = (shares[i] - targets[i]).min(sum - self.total_w);
                    shares[i] -= give;
                    sum -= give;
                }
            }
        }
        shares
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{tesla_p4, tesla_v100};
    use crate::types::Precision;

    fn wl(gpu: &GpuSpec, n: u64) -> FftWorkload {
        FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes)
    }

    #[test]
    fn share_cell_roundtrips() {
        let c = ShareCell::unlimited();
        assert_eq!(c.get(), None);
        c.set(Some(123.5));
        assert_eq!(c.get(), Some(123.5));
        c.set(None);
        assert_eq!(c.get(), None);
        let c2 = ShareCell::with_share(60.25);
        assert_eq!(c2.get(), Some(60.25));
        assert_eq!(budget_key(60.25), 241);
        assert_eq!(budget_key(60.30), 241, "quarter-watt quantization");
    }

    #[test]
    fn cap_is_monotone_in_budget_and_in_table() {
        let g = tesla_v100();
        let w = wl(&g, 16384);
        let table = freq_table(&g);
        let mut last = 0.0;
        for budget in [80.0, 120.0, 160.0, 200.0, 260.0] {
            let f = clock_cap_for_budget(&g, &w, budget, 2);
            assert!(table.contains(f), "{f} not a table clock");
            assert!(f >= last, "cap must rise with budget: {f} < {last}");
            assert!(f <= g.boost_clock_mhz + 1e-9);
            last = f;
        }
    }

    #[test]
    fn cap_respects_the_budget_it_was_derived_for() {
        let g = tesla_v100();
        let w = wl(&g, 16384);
        for budget in [100.0, 150.0, 220.0] {
            let f = clock_cap_for_budget(&g, &w, budget, 2);
            let p = run_batch(&g, &w, f).avg_power_w;
            assert!(p <= budget + 1e-9, "cap {f} MHz draws {p} W > {budget} W");
        }
    }

    #[test]
    fn generous_budget_caps_at_boost_tiny_budget_at_floor() {
        let g = tesla_v100();
        let w = wl(&g, 16384);
        let rich = clock_cap_for_budget(&g, &w, 10_000.0, 2);
        assert!(rich >= g.boost_clock_mhz - 13.0, "rich cap {rich}");
        let poor = clock_cap_for_budget(&g, &w, 1.0, 2);
        assert!(
            (poor - freq_table(&g).f_min_mhz).abs() < 1e-9,
            "infeasible budget degrades to the table floor, got {poor}"
        );
    }

    #[test]
    fn shares_proportional_to_load_within_bounds() {
        let b = PowerBudget::new(300.0);
        let bounds = vec![(40.0, 300.0), (12.0, 75.0)];
        let shares = b.redistribute(&[3.0, 1.0], &bounds, &[None, None]);
        // floors 52, spend 248: 40 + 186 = 226, 12 + 62 = 74
        assert!((shares[0] - 226.0).abs() < 1e-9, "{shares:?}");
        assert!((shares[1] - 74.0).abs() < 1e-9, "{shares:?}");
        assert!(shares.iter().sum::<f64>() <= 300.0 + 1e-9);
    }

    #[test]
    fn idle_fleet_splits_evenly_and_ceilings_redistribute() {
        let b = PowerBudget::new(200.0);
        // card 1's TDP ceiling (75 W) caps its even share; card 0 absorbs
        // the overflow.
        let bounds = vec![(40.0, 300.0), (12.0, 75.0)];
        let shares = b.redistribute(&[0.0, 0.0], &bounds, &[None, None]);
        assert!(shares[1] <= 75.0 + 1e-9);
        assert!(shares[0] > 100.0, "{shares:?}");
        assert!(shares.iter().sum::<f64>() <= 200.0 + 1e-9);
    }

    #[test]
    fn infeasible_budget_degrades_to_floors() {
        let b = PowerBudget::new(10.0);
        let bounds = vec![(40.0, 300.0), (12.0, 75.0)];
        let shares = b.redistribute(&[1.0, 1.0], &bounds, &[None, None]);
        assert_eq!(shares, vec![40.0, 12.0]);
    }

    #[test]
    fn deadband_keeps_previous_shares_stable() {
        let b = PowerBudget::new(300.0);
        let bounds = vec![(40.0, 300.0), (12.0, 75.0)];
        let first = b.redistribute(&[2.0, 2.0], &bounds, &[None, None]);
        // A small load wobble (< deadband worth of share movement) must
        // reproduce the previous shares bit-for-bit.
        let prev: Vec<Option<f64>> = first.iter().map(|&s| Some(s)).collect();
        let second = b.redistribute(&[2.1, 2.0], &bounds, &prev);
        assert_eq!(first, second, "deadband must suppress share wiggle");
        // A big swing does move them.
        let third = b.redistribute(&[8.0, 1.0], &bounds, &prev);
        assert!(third[0] > first[0], "{third:?} vs {first:?}");
    }

    #[test]
    fn cap_outranks_deadband_when_shares_rise_elsewhere() {
        // Card 0's share rises past the deadband while card 1's stays
        // within it: keeping card 1's old (higher) share would breach the
        // total, so it is walked back to its fresh target.
        let b = PowerBudget::new(162.0);
        let bounds = vec![(38.6, 300.0), (11.55, 75.0)];
        let prev = vec![Some(81.0), Some(73.0)];
        let shares = b.redistribute(&[1.0, 1.0], &bounds, &prev);
        assert!(
            shares.iter().sum::<f64>() <= 162.0 + 1e-9,
            "hysteresis breached the cap: {shares:?}"
        );
        for (s, (floor, ceil)) in shares.iter().zip(&bounds) {
            assert!(*s >= *floor - 1e-9 && *s <= *ceil + 1e-9, "{shares:?}");
        }
    }

    #[test]
    fn real_card_bounds_are_sane() {
        for g in [tesla_v100(), tesla_p4()] {
            let (floor, ceil) = share_bounds_w(&g);
            assert!(floor > 0.0 && floor < ceil, "{}: {floor}..{ceil}", g.name);
            assert_eq!(ceil, g.tdp_w);
        }
    }
}
