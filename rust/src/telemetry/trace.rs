//! Per-job request tracing through the serving stack.
//!
//! Every job submitted to the `Engine` carries a [`Stamps`] record on its
//! envelope; the coordinator fills the stage timestamps as the job moves
//! enqueue → admit → batch-seal → dispatch → exec → complete, and the
//! worker folds the finished [`Span`] — stage times plus the governor's
//! clock decision, batch occupancy, retry count and the job's attributed
//! joules — into the card's [`Tracer`] state:
//!
//!   * a fixed-capacity [`Ring`] of completed spans (overwrite-oldest,
//!     behind a short-hold mutex — the "lock-light" part: the only lock
//!     on the hot path, held for one push),
//!   * lock-free [`LogHistogram`]s of queue wait / exec / end-to-end
//!     latency and energy per job, per card and per artifact kind,
//!   * optionally a JSONL journal (`serve --trace-out`), one span per
//!     line, replayable by `fftsweep trace`.
//!
//! Stage timestamps are recorded as microseconds since the engine epoch,
//! captured from monotonic `Instant`s, so within a span they are
//! guaranteed monotone and the six stage segments sum exactly to the
//! end-to-end latency.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{Context, Result};

use super::histogram::{HistogramSnapshot, LogHistogram};
use super::ring::Ring;
use crate::coordinator::admission::TenantClass;
use crate::util::json::Json;

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Tracing knobs on `EngineConfig`. Enabled by default: the overhead
/// budget (gated in the bench `observability` section) is <5% of
/// closed-loop throughput, cheap enough to be always-on.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Completed spans retained in memory (overwrite-oldest).
    pub ring_capacity: usize,
    /// Stream completed spans to this file as JSONL, one span per line.
    pub jsonl_out: Option<PathBuf>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            ring_capacity: 4096,
            jsonl_out: None,
        }
    }
}

/// In-flight stage timestamps, carried on the job envelope. All four
/// start equal at submit time; the coordinator overwrites `admit` when
/// the router accepts the job, the batcher overwrites `seal` when the
/// batch closes, and the dispatcher overwrites `dispatch` when the
/// batch is handed to a worker channel.
#[derive(Debug, Clone, Copy)]
pub struct Stamps {
    pub enqueue: Instant,
    pub admit: Instant,
    pub seal: Instant,
    pub dispatch: Instant,
}

impl Stamps {
    pub fn now() -> Self {
        let t = Instant::now();
        Self {
            enqueue: t,
            admit: t,
            seal: t,
            dispatch: t,
        }
    }
}

impl Default for Stamps {
    fn default() -> Self {
        Self::now()
    }
}

/// How the job left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Completed with a result.
    Ok,
    /// Dropped with a typed error (retries exhausted, no eligible card,
    /// or shutdown).
    Shed,
}

impl SpanOutcome {
    pub fn label(self) -> &'static str {
        match self {
            SpanOutcome::Ok => "ok",
            SpanOutcome::Shed => "shed",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "ok" => Some(SpanOutcome::Ok),
            "shed" => Some(SpanOutcome::Shed),
            _ => None,
        }
    }
}

/// One completed request, stage-stamped in µs since the engine epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub job_id: u64,
    /// Artifact cache key of the plan the job executed under.
    pub artifact: String,
    pub n: u64,
    pub card: usize,
    pub enqueue_us: u64,
    pub admit_us: u64,
    pub seal_us: u64,
    pub dispatch_us: u64,
    pub exec_start_us: u64,
    pub exec_end_us: u64,
    pub complete_us: u64,
    /// The governor's pre-cap clock choice for the batch, MHz.
    pub requested_mhz: f64,
    /// The clock actually granted after budget/health caps and menu
    /// snapping, MHz. `granted < requested` marks the span as capped.
    pub granted_mhz: f64,
    pub batch_occupancy: u64,
    /// Submit attempts (1 = first try; >1 = retried after a fault).
    pub attempts: u32,
    /// Joules attributed to this job: batch energy / occupancy, the same
    /// accounting `PowerRecorder` totals are built from.
    pub energy_j: f64,
    /// Simulated on-card batch time, s (moves with DVFS).
    pub sim_batch_s: f64,
    pub outcome: SpanOutcome,
    /// QoS class label the job ran under (`realtime`/`batch`/`scavenger`).
    /// Empty on journals written before admission control existed.
    pub class: String,
    /// Why a shed span was dropped (admission/brownout/backpressure
    /// reason, or the coordinator error's shed reason). Empty for `ok`
    /// spans — check_trace.py enforces nonempty-iff-shed.
    pub reason: String,
}

impl Span {
    /// Was the granted clock below the governor's request (power budget
    /// or health derate bit)?
    pub fn capped(&self) -> bool {
        self.granted_mhz < self.requested_mhz - 1e-9
    }

    /// enqueue → admit: router/admission time, s.
    pub fn admit_s(&self) -> f64 {
        us_delta(self.enqueue_us, self.admit_us)
    }

    /// admit → seal: time waiting for the batch to fill, s.
    pub fn batch_wait_s(&self) -> f64 {
        us_delta(self.admit_us, self.seal_us)
    }

    /// seal → exec-start: dispatch channel plus worker queueing, s.
    pub fn dispatch_s(&self) -> f64 {
        us_delta(self.seal_us, self.exec_start_us)
    }

    /// Everything before execution began, s.
    pub fn queue_wait_s(&self) -> f64 {
        us_delta(self.enqueue_us, self.exec_start_us)
    }

    /// exec-start → exec-end: host wall-clock execution time, s.
    pub fn exec_s(&self) -> f64 {
        us_delta(self.exec_start_us, self.exec_end_us)
    }

    /// exec-end → complete: result fan-out and reply delivery, s.
    pub fn reply_s(&self) -> f64 {
        us_delta(self.exec_end_us, self.complete_us)
    }

    /// Submit → reply, s.
    pub fn e2e_s(&self) -> f64 {
        us_delta(self.enqueue_us, self.complete_us)
    }

    /// Stage stamps in submission order, for monotonicity checks.
    pub fn stamps_us(&self) -> [u64; 7] {
        [
            self.enqueue_us,
            self.admit_us,
            self.seal_us,
            self.dispatch_us,
            self.exec_start_us,
            self.exec_end_us,
            self.complete_us,
        ]
    }

    pub fn monotone(&self) -> bool {
        self.stamps_us().windows(2).all(|w| w[0] <= w[1])
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("job_id", self.job_id.into());
        j.set("artifact", self.artifact.as_str().into());
        j.set("n", self.n.into());
        j.set("card", (self.card as u64).into());
        j.set("enqueue_us", self.enqueue_us.into());
        j.set("admit_us", self.admit_us.into());
        j.set("seal_us", self.seal_us.into());
        j.set("dispatch_us", self.dispatch_us.into());
        j.set("exec_start_us", self.exec_start_us.into());
        j.set("exec_end_us", self.exec_end_us.into());
        j.set("complete_us", self.complete_us.into());
        j.set("requested_mhz", self.requested_mhz.into());
        j.set("granted_mhz", self.granted_mhz.into());
        j.set("batch_occupancy", self.batch_occupancy.into());
        j.set("attempts", (self.attempts as u64).into());
        j.set("energy_j", self.energy_j.into());
        j.set("sim_batch_s", self.sim_batch_s.into());
        j.set("outcome", self.outcome.label().into());
        j.set("class", self.class.as_str().into());
        j.set("reason", self.reason.as_str().into());
        j
    }

    /// One JSONL line (no trailing newline).
    pub fn to_jsonl_line(&self) -> String {
        self.to_json().render_compact()
    }

    pub fn from_json(j: &Json) -> Result<Span> {
        fn num(j: &Json, key: &str) -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("span field `{key}` missing or not a number"))
        }
        fn uint(j: &Json, key: &str) -> Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .with_context(|| format!("span field `{key}` missing or not a u64"))
        }
        let outcome_label = j
            .get("outcome")
            .and_then(Json::as_str)
            .context("span field `outcome` missing or not a string")?;
        Ok(Span {
            job_id: uint(j, "job_id")?,
            artifact: j
                .get("artifact")
                .and_then(Json::as_str)
                .context("span field `artifact` missing or not a string")?
                .to_string(),
            n: uint(j, "n")?,
            card: uint(j, "card")? as usize,
            enqueue_us: uint(j, "enqueue_us")?,
            admit_us: uint(j, "admit_us")?,
            seal_us: uint(j, "seal_us")?,
            dispatch_us: uint(j, "dispatch_us")?,
            exec_start_us: uint(j, "exec_start_us")?,
            exec_end_us: uint(j, "exec_end_us")?,
            complete_us: uint(j, "complete_us")?,
            requested_mhz: num(j, "requested_mhz")?,
            granted_mhz: num(j, "granted_mhz")?,
            batch_occupancy: uint(j, "batch_occupancy")?,
            attempts: uint(j, "attempts")? as u32,
            energy_j: num(j, "energy_j")?,
            sim_batch_s: num(j, "sim_batch_s")?,
            outcome: SpanOutcome::from_label(outcome_label)
                .with_context(|| format!("unknown span outcome `{outcome_label}`"))?,
            // Both default empty so journals written before admission
            // control (schema ≤7) stay replayable by `fftsweep trace`.
            class: j
                .get("class")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            reason: j
                .get("reason")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        })
    }
}

fn us_delta(from_us: u64, to_us: u64) -> f64 {
    to_us.saturating_sub(from_us) as f64 * 1e-6
}

/// The four distributions the tentpole tracks, as live histograms.
#[derive(Debug, Default)]
pub struct HistSet {
    pub queue_wait_s: LogHistogram,
    pub exec_s: LogHistogram,
    pub e2e_s: LogHistogram,
    pub energy_j: LogHistogram,
}

impl HistSet {
    fn observe(&self, span: &Span) {
        self.queue_wait_s.record(span.queue_wait_s());
        self.exec_s.record(span.exec_s());
        self.e2e_s.record(span.e2e_s());
        self.energy_j.record(span.energy_j);
    }

    pub fn snapshot(&self) -> HistSetSnapshot {
        HistSetSnapshot {
            queue_wait_s: self.queue_wait_s.snapshot(),
            exec_s: self.exec_s.snapshot(),
            e2e_s: self.e2e_s.snapshot(),
            energy_j: self.energy_j.snapshot(),
        }
    }
}

/// Point-in-time copy of a [`HistSet`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSetSnapshot {
    pub queue_wait_s: HistogramSnapshot,
    pub exec_s: HistogramSnapshot,
    pub e2e_s: HistogramSnapshot,
    pub energy_j: HistogramSnapshot,
}

/// Per-QoS-class span counters, one row per [`TenantClass`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassSpans {
    pub class: &'static str,
    pub ok_spans: u64,
    pub shed_spans: u64,
}

/// What the exporters see: counters plus per-card / per-artifact
/// histogram snapshots, attached to `FleetSnapshot.trace`.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub enabled: bool,
    /// Spans completed with a result.
    pub ok_spans: u64,
    /// Spans dropped with a typed error.
    pub shed_spans: u64,
    /// Ok/shed split per QoS class, one row per `admission::CLASSES`
    /// entry. Spans with an unknown/empty class label (pre-QoS journals)
    /// count only in the totals above.
    pub per_class: Vec<ClassSpans>,
    /// Spans currently held in the ring.
    pub ring_len: usize,
    /// Spans the ring has overwritten.
    pub ring_dropped: u64,
    /// JSONL write failures (the journal is best-effort; serving never
    /// blocks on a full disk).
    pub sink_errors: u64,
    /// Index = card id.
    pub per_card: Vec<HistSetSnapshot>,
    /// Sorted by artifact key.
    pub per_artifact: Vec<(String, HistSetSnapshot)>,
}

impl TraceSummary {
    /// Fleet-wide rollup across cards.
    pub fn fleet(&self) -> HistSetSnapshot {
        let mut out = HistSetSnapshot::default();
        for set in &self.per_card {
            out.queue_wait_s.merge(&set.queue_wait_s);
            out.exec_s.merge(&set.exec_s);
            out.e2e_s.merge(&set.e2e_s);
            out.energy_j.merge(&set.energy_j);
        }
        out
    }
}

/// Fleet-shared tracing state. `record` touches one short-hold mutex
/// (the span ring) plus lock-free histogram counters; everything else is
/// read-side.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    epoch: Instant,
    spans: Mutex<Ring<Span>>,
    ok_spans: AtomicU64,
    shed_spans: AtomicU64,
    /// [class][outcome] counters: outcome 0 = ok, 1 = shed.
    class_spans: [[AtomicU64; 2]; 3],
    sink: Option<Mutex<BufWriter<File>>>,
    sink_errors: AtomicU64,
    per_card: Vec<HistSet>,
    per_artifact: Mutex<BTreeMap<String, Arc<HistSet>>>,
}

impl Tracer {
    pub fn new(cfg: &TraceConfig, n_cards: usize, epoch: Instant) -> Result<Self> {
        let sink = match (&cfg.jsonl_out, cfg.enabled) {
            (Some(path), true) => {
                let f = File::create(path)
                    .with_context(|| format!("creating trace journal {}", path.display()))?;
                Some(Mutex::new(BufWriter::new(f)))
            }
            _ => None,
        };
        Ok(Self {
            enabled: cfg.enabled,
            epoch,
            spans: Mutex::new(Ring::new(cfg.ring_capacity.max(1))),
            ok_spans: AtomicU64::new(0),
            shed_spans: AtomicU64::new(0),
            class_spans: Default::default(),
            sink,
            sink_errors: AtomicU64::new(0),
            per_card: (0..n_cards).map(|_| HistSet::default()).collect(),
            per_artifact: Mutex::new(BTreeMap::new()),
        })
    }

    /// A tracer that records nothing (used when `trace.enabled = false`).
    pub fn disabled(n_cards: usize, epoch: Instant) -> Self {
        Self::new(
            &TraceConfig {
                enabled: false,
                ring_capacity: 1,
                jsonl_out: None,
            },
            n_cards,
            epoch,
        )
        .expect("disabled tracer has no sink to fail")
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since the engine epoch for a monotonic instant.
    pub fn micros(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    pub fn record(&self, span: Span) {
        if !self.enabled {
            return;
        }
        if let Some(class) = TenantClass::from_label(&span.class) {
            let slot = usize::from(span.outcome == SpanOutcome::Shed);
            self.class_spans[class.index()][slot].fetch_add(1, Ordering::Relaxed);
        }
        match span.outcome {
            SpanOutcome::Ok => {
                self.ok_spans.fetch_add(1, Ordering::Relaxed);
                if let Some(set) = self.per_card.get(span.card) {
                    set.observe(&span);
                }
                let set = {
                    let mut map = relock(&self.per_artifact);
                    Arc::clone(
                        map.entry(span.artifact.clone())
                            .or_insert_with(|| Arc::new(HistSet::default())),
                    )
                };
                set.observe(&span);
            }
            SpanOutcome::Shed => {
                self.shed_spans.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(sink) = &self.sink {
            let line = span.to_jsonl_line();
            let mut w = relock(sink);
            if writeln!(w, "{line}").is_err() {
                self.sink_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        relock(&self.spans).push(span);
    }

    pub fn ok_spans(&self) -> u64 {
        self.ok_spans.load(Ordering::Relaxed)
    }

    pub fn shed_spans(&self) -> u64 {
        self.shed_spans.load(Ordering::Relaxed)
    }

    /// The most recent completed spans, oldest first (up to `limit`).
    pub fn recent(&self, limit: usize) -> Vec<Span> {
        let ring = relock(&self.spans);
        let skip = ring.len().saturating_sub(limit);
        ring.iter().skip(skip).cloned().collect()
    }

    /// Flush the JSONL journal (called on engine shutdown).
    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            if relock(sink).flush().is_err() {
                self.sink_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn summary(&self) -> TraceSummary {
        let (ring_len, ring_dropped) = {
            let ring = relock(&self.spans);
            (ring.len(), ring.dropped())
        };
        TraceSummary {
            enabled: self.enabled,
            ok_spans: self.ok_spans.load(Ordering::Relaxed),
            shed_spans: self.shed_spans.load(Ordering::Relaxed),
            per_class: crate::coordinator::admission::CLASSES
                .iter()
                .map(|c| ClassSpans {
                    class: c.label(),
                    ok_spans: self.class_spans[c.index()][0].load(Ordering::Relaxed),
                    shed_spans: self.class_spans[c.index()][1].load(Ordering::Relaxed),
                })
                .collect(),
            ring_len,
            ring_dropped,
            sink_errors: self.sink_errors.load(Ordering::Relaxed),
            per_card: self.per_card.iter().map(HistSet::snapshot).collect(),
            per_artifact: relock(&self.per_artifact)
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(job_id: u64, card: usize, base_us: u64) -> Span {
        Span {
            job_id,
            artifact: "fft_f32_n1024_b64".into(),
            n: 1024,
            card,
            enqueue_us: base_us,
            admit_us: base_us + 10,
            seal_us: base_us + 210,
            dispatch_us: base_us + 215,
            exec_start_us: base_us + 240,
            exec_end_us: base_us + 1240,
            complete_us: base_us + 1250,
            requested_mhz: 945.0,
            granted_mhz: 772.5,
            batch_occupancy: 64,
            attempts: 1,
            energy_j: 2.5e-4,
            sim_batch_s: 8.0e-4,
            outcome: SpanOutcome::Ok,
            class: "batch".into(),
            reason: String::new(),
        }
    }

    #[test]
    fn segments_sum_to_end_to_end() {
        let s = span(1, 0, 1000);
        assert!(s.monotone());
        let total = s.admit_s() + s.batch_wait_s() + s.dispatch_s() + s.exec_s() + s.reply_s();
        assert!((total - s.e2e_s()).abs() < 1e-12);
        assert!((s.queue_wait_s() - (s.admit_s() + s.batch_wait_s() + s.dispatch_s())).abs() < 1e-12);
        assert!(s.capped(), "granted 772.5 < requested 945");
    }

    #[test]
    fn jsonl_roundtrip_is_lossless() {
        let s = span(7, 1, 123_456);
        let line = s.to_jsonl_line();
        assert!(!line.contains('\n'));
        let back = Span::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, s);
        let mut shed = span(8, 0, 200_000);
        shed.outcome = SpanOutcome::Shed;
        shed.class = "scavenger".into();
        shed.reason = "brownout shed".into();
        let back = Span::from_json(&Json::parse(&shed.to_jsonl_line()).unwrap()).unwrap();
        assert_eq!(back.outcome, SpanOutcome::Shed);
        assert_eq!(back.class, "scavenger");
        assert_eq!(back.reason, "brownout shed");
    }

    #[test]
    fn pre_qos_journals_default_class_and_reason_empty() {
        // Journals written before schema 8 carry no class/reason keys;
        // replay must not reject them. Exercise the missing-key path by
        // parsing a line with the keys absent, and the null path via set.
        let line = span(3, 0, 500).to_jsonl_line();
        let stripped: String = line
            .replace(",\"class\":\"batch\"", "")
            .replace(",\"reason\":\"\"", "");
        let back = Span::from_json(&Json::parse(&stripped).unwrap()).unwrap();
        assert_eq!(back.class, "");
        assert_eq!(back.reason, "");
        let mut j = span(3, 0, 500).to_json();
        j.set("class", Json::Null);
        j.set("reason", Json::Null);
        let back = Span::from_json(&j).unwrap();
        assert_eq!(back.class, "");
        assert_eq!(back.reason, "");
    }

    #[test]
    fn from_json_rejects_missing_or_malformed_fields() {
        let mut j = span(1, 0, 0).to_json();
        j.set("exec_end_us", Json::Null);
        assert!(Span::from_json(&j).is_err());
        let mut j = span(1, 0, 0).to_json();
        j.set("outcome", "exploded".into());
        assert!(Span::from_json(&j).is_err());
        assert!(Span::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn tracer_aggregates_per_card_and_artifact() {
        let t = Tracer::new(&TraceConfig::default(), 2, Instant::now()).unwrap();
        assert!(t.enabled());
        for i in 0..10 {
            t.record(span(i, (i % 2) as usize, 1000 * i));
        }
        let mut other = span(99, 0, 50_000);
        other.artifact = "fft_f32_n2048_b64".into();
        t.record(other);
        let mut shed = span(100, 0, 60_000);
        shed.outcome = SpanOutcome::Shed;
        shed.class = "scavenger".into();
        shed.reason = "queue full".into();
        t.record(shed);

        let s = t.summary();
        assert_eq!(s.ok_spans, 11);
        assert_eq!(s.shed_spans, 1);
        assert_eq!(s.per_class.len(), 3);
        assert_eq!(s.per_class[0].class, "realtime");
        assert_eq!((s.per_class[0].ok_spans, s.per_class[0].shed_spans), (0, 0));
        assert_eq!(s.per_class[1].class, "batch");
        assert_eq!((s.per_class[1].ok_spans, s.per_class[1].shed_spans), (11, 0));
        assert_eq!(s.per_class[2].class, "scavenger");
        assert_eq!((s.per_class[2].ok_spans, s.per_class[2].shed_spans), (0, 1));
        assert_eq!(s.per_card.len(), 2);
        assert_eq!(s.per_card[0].e2e_s.count, 6, "cards 0,2,4,6,8 + the odd artifact");
        assert_eq!(s.per_card[1].e2e_s.count, 5);
        assert_eq!(s.per_artifact.len(), 2);
        let fleet = s.fleet();
        assert_eq!(fleet.e2e_s.count, 11);
        // every recorded span had e2e = 1250 µs; the histogram read
        // stays within the bucket error bound
        let p99 = fleet.e2e_s.percentile(99.0);
        assert!((p99 / 1.25e-3 - 1.0).abs() < 0.025, "p99 {p99}");
        // energy attribution: histogram sum equals the recorded joules
        assert!((fleet.energy_j.sum - 11.0 * 2.5e-4).abs() < 1e-12);
        assert_eq!(s.ring_len, 12, "shed spans land in the ring too");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled(2, Instant::now());
        assert!(!t.enabled());
        t.record(span(1, 0, 0));
        let s = t.summary();
        assert_eq!(s.ok_spans, 0);
        assert_eq!(s.ring_len, 0);
        assert!(s.fleet().e2e_s.is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let cfg = TraceConfig {
            ring_capacity: 4,
            ..TraceConfig::default()
        };
        let t = Tracer::new(&cfg, 1, Instant::now()).unwrap();
        for i in 0..7 {
            t.record(span(i, 0, 1000 * i));
        }
        let s = t.summary();
        assert_eq!(s.ok_spans, 7, "counters see every span");
        assert_eq!(s.ring_len, 4);
        assert_eq!(s.ring_dropped, 3);
        let ids: Vec<u64> = t.recent(10).iter().map(|s| s.job_id).collect();
        assert_eq!(ids, vec![3, 4, 5, 6], "oldest overwritten first");
        assert_eq!(t.recent(2).len(), 2);
    }

    #[test]
    fn jsonl_sink_streams_one_line_per_span() {
        let path = std::env::temp_dir().join(format!(
            "fftsweep_trace_test_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        let cfg = TraceConfig {
            jsonl_out: Some(path.clone()),
            ..TraceConfig::default()
        };
        let t = Tracer::new(&cfg, 1, Instant::now()).unwrap();
        for i in 0..5 {
            t.record(span(i, 0, 1000 * i));
        }
        t.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let spans: Vec<Span> = text
            .lines()
            .map(|l| Span::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect();
        assert_eq!(spans.len(), 5);
        assert_eq!(spans[4].job_id, 4);
        assert_eq!(t.summary().sink_errors, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_record_and_summary_do_not_tear() {
        let t = Arc::new(Tracer::new(&TraceConfig::default(), 4, Instant::now()).unwrap());
        let writers: Vec<_> = (0..4)
            .map(|c| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    for i in 0..2_000 {
                        t.record(span(i, c, 100 * i));
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            let s = t.summary();
            assert!(s.ok_spans <= 8_000);
            assert!(s.fleet().e2e_s.count <= 8_000);
            assert!(s.ring_len <= 4096);
        }
        for w in writers {
            w.join().unwrap();
        }
        let s = t.summary();
        assert_eq!(s.ok_spans, 8_000);
        assert_eq!(s.fleet().e2e_s.count, 8_000);
    }
}
