//! Fleet power telemetry & power-budget enforcement — the operator layer
//! between the DVFS simulator and the serving coordinator.
//!
//! The paper proves the per-card knob (lock one clock, save 50-60% energy
//! for <10% slowdown); running that result as a *fleet* needs two things
//! the governors alone cannot provide: visibility (what is every card
//! drawing right now, what does a job cost in joules) and control (keep
//! the whole fleet under an operator watt ceiling). This subsystem adds
//! both:
//!
//!   * [`recorder::PowerRecorder`] — lock-light per-card time series of
//!     simulated draw (instant / rolling 1 s / rolling 10 s), cumulative
//!     full-precision joules, per-length energy attribution, deadline
//!     misses; the retained window replays through the paper's sensor
//!     model unchanged ([`crate::sim::sensor::PowerTimeline`]).
//!   * [`budget::PowerBudget`] — the fleet watt-ceiling arbiter:
//!     load-proportional per-card shares with deadband hysteresis,
//!     delivered to workers via lock-free [`budget::ShareCell`]s and to
//!     governors as the `GovernorContext::power_budget_w` hint;
//!     [`budget::clock_cap_for_budget`] inverts watts → fastest feasible
//!     clock.
//!   * [`snapshot::FleetSnapshot`] — the typed fleet state every consumer
//!     (CLI report, benches, tests) reads; the old human report string is
//!     now a renderer on top of it.
//!   * [`export`] — JSON (`serve --telemetry-out`) and Prometheus text
//!     exposition renderings of a snapshot.
//!   * [`trace`] + [`histogram`] — per-job request tracing: every job
//!     carries stage timestamps (enqueue → admit → seal → dispatch →
//!     exec → complete) plus the governor's clock decision, batch
//!     occupancy, retry count and attributed joules; completed
//!     [`trace::Span`]s land in a fixed-capacity ring, in lock-free
//!     log-bucketed [`histogram::LogHistogram`]s (queue wait / exec /
//!     end-to-end latency / energy per job, per card and per artifact)
//!     and optionally in a JSONL journal (`serve --trace-out`,
//!     replayable with `fftsweep trace`).
//!
//! Consumers: `coordinator::Engine` (per-card recorders + the arbiter
//! thread + the tracer), `analysis::telemetry` (capped-vs-uncapped
//! comparison table), `analysis::trace` (span-journal replay),
//! `fftsweep serve --power-budget-w/--telemetry-out/--trace-out`,
//! `fftsweep telemetry` and `fftsweep trace` in the CLI, and
//! `benches/bench_serving.rs` (the `power` and `observability` sections
//! of `BENCH_serving.json`).

pub mod budget;
pub mod export;
pub mod histogram;
pub mod recorder;
pub mod ring;
pub mod snapshot;
pub mod trace;

pub use budget::{budget_key, clock_cap_for_budget, share_bounds_w, PowerBudget, ShareCell};
pub use export::{prometheus_text, snapshot_json};
pub use histogram::{HistogramSnapshot, LogHistogram};
pub use recorder::{BatchSample, PowerRecorder, RecorderConfig};
pub use ring::Ring;
pub use snapshot::{CardSnapshot, FleetSnapshot, FleetTotals, OverloadSnapshot};
pub use trace::{
    ClassSpans, HistSetSnapshot, Span, SpanOutcome, Stamps, TraceConfig, TraceSummary, Tracer,
};
