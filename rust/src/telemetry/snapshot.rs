//! Typed fleet state — what used to be the human-only
//! `Engine::fleet_report()` string, as structured data.
//!
//! `FleetSnapshot` is the single source the JSON/Prometheus exporters,
//! the `fftsweep telemetry` tables, the benches and the tests all
//! consume; the old report string is now just [`FleetSnapshot::render`]
//! on top of it.

use crate::telemetry::trace::TraceSummary;
use crate::util::table::fnum;

/// One card's full serving + power state at snapshot time.
#[derive(Debug, Clone)]
pub struct CardSnapshot {
    pub index: usize,
    pub gpu: String,
    pub governor: String,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub batches: u64,
    /// Mean batch occupancy, 0..1.
    pub occupancy: f64,
    /// Wall-clock execution time spent in batches, s.
    pub exec_s: f64,
    /// Simulated energy at the governed clocks, J (full precision).
    pub energy_j: f64,
    /// Simulated energy had every batch run at boost, J.
    pub boost_energy_j: f64,
    /// 1 - energy/boost_energy.
    pub energy_saving: f64,
    /// NVML clock-lock state transitions (the Fig 19 trace length).
    pub clock_transitions: u64,
    /// The clock the card would run a kernel at right now, MHz.
    pub current_clock_mhz: f64,
    /// Draw of the last executed batch, W.
    pub instant_w: f64,
    /// Rolling mean draw over the trailing 1 s of simulated busy time, W.
    pub avg_1s_w: f64,
    /// Rolling mean draw over the trailing 10 s of simulated busy time, W.
    pub avg_10s_w: f64,
    /// Cumulative simulated busy time, s.
    pub busy_s: f64,
    /// Mean attributed energy per job, J.
    pub energy_per_job_j: f64,
    pub deadline_misses: u64,
    /// The arbiter's current watt share (None = uncapped).
    pub power_share_w: Option<f64>,
    pub inflight: u64,
    /// Health-state label: "healthy" | "degraded" | "quarantined".
    pub health: String,
    /// Health state-machine transitions so far (quarantines, probe
    /// re-admits, recoveries).
    pub health_transitions: u64,
    /// Jobs re-dispatched onto this card after failing elsewhere.
    pub jobs_retried: u64,
    /// Jobs shed with a typed error (subset of `jobs_failed`).
    pub jobs_shed: u64,
    /// Batches that errored on this card.
    pub batch_errors: u64,
    /// Whether the card is accepting new work (false while draining).
    pub accepting: bool,
}

/// Fleet-aggregate counters (sums/means over the cards).
#[derive(Debug, Clone, Default)]
pub struct FleetTotals {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub batches: u64,
    pub occupancy: f64,
    pub exec_s: f64,
    pub energy_j: f64,
    pub boost_energy_j: f64,
    pub energy_saving: f64,
    /// Σ over cards of the 1 s rolling draw, W — the quantity a
    /// `--power-budget-w` cap constrains.
    pub draw_1s_w: f64,
    pub energy_per_job_j: f64,
    pub deadline_misses: u64,
    pub clock_transitions: u64,
    pub jobs_retried: u64,
    pub jobs_shed: u64,
    pub batch_errors: u64,
    pub health_transitions: u64,
    /// Cards currently in the `quarantined` health state.
    pub cards_quarantined: u64,
}

/// Admission/overload state at snapshot time: the brownout ladder
/// position plus the typed shed counters the admission controller keeps
/// per class and per reason. All counters are monotone.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OverloadSnapshot {
    /// Current brownout rung (0 = off … 3 = realtime-only).
    pub brownout_level: u8,
    /// Highest rung reached since the engine started.
    pub brownout_max_level: u8,
    /// Ladder level-up transitions so far.
    pub brownout_escalations: u64,
    /// Jobs admitted per QoS class, priority order
    /// (realtime, batch, scavenger).
    pub admitted: [u64; 3],
    /// Sheds: deadline infeasible at admission.
    pub deadline_sheds: u64,
    /// Sheds: class refused by the brownout ladder.
    pub brownout_sheds: u64,
    /// Sheds: token-bucket rate limit.
    pub rate_limited: u64,
    /// Queued lower-class jobs evicted to admit higher-class work.
    pub evictions: u64,
}

impl OverloadSnapshot {
    /// Every admission-layer drop, across reasons.
    pub fn total_sheds(&self) -> u64 {
        self.deadline_sheds + self.brownout_sheds + self.rate_limited + self.evictions
    }
}

/// The whole fleet, typed.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub cards: Vec<CardSnapshot>,
    pub fleet: FleetTotals,
    /// The operator's global cap (None = uncapped serving).
    pub power_budget_w: Option<f64>,
    /// Request-trace rollup (span counters + latency/energy histograms).
    /// `Engine::snapshot` always fills it; `from_cards` leaves it `None`
    /// so card-only consumers (and tests) stay unchanged.
    pub trace: Option<TraceSummary>,
    /// Admission/brownout rollup. Filled by `Engine::snapshot`, `None`
    /// from `from_cards` (same contract as `trace`).
    pub overload: Option<OverloadSnapshot>,
}

impl FleetSnapshot {
    /// Derive the fleet aggregate from per-card snapshots.
    pub fn from_cards(cards: Vec<CardSnapshot>, power_budget_w: Option<f64>) -> Self {
        let mut t = FleetTotals::default();
        for c in &cards {
            t.jobs_submitted += c.jobs_submitted;
            t.jobs_completed += c.jobs_completed;
            t.jobs_failed += c.jobs_failed;
            t.batches += c.batches;
            t.exec_s += c.exec_s;
            t.energy_j += c.energy_j;
            t.boost_energy_j += c.boost_energy_j;
            t.draw_1s_w += c.avg_1s_w;
            t.deadline_misses += c.deadline_misses;
            t.clock_transitions += c.clock_transitions;
            t.jobs_retried += c.jobs_retried;
            t.jobs_shed += c.jobs_shed;
            t.batch_errors += c.batch_errors;
            t.health_transitions += c.health_transitions;
            if c.health == "quarantined" {
                t.cards_quarantined += 1;
            }
        }
        let occ_weight: f64 = cards.iter().map(|c| c.batches as f64).sum();
        if occ_weight > 0.0 {
            t.occupancy = cards
                .iter()
                .map(|c| c.occupancy * c.batches as f64)
                .sum::<f64>()
                / occ_weight;
        }
        if t.boost_energy_j > 0.0 {
            t.energy_saving = 1.0 - t.energy_j / t.boost_energy_j;
        }
        if t.jobs_completed > 0 {
            t.energy_per_job_j = t.energy_j / t.jobs_completed as f64;
        }
        Self {
            cards,
            fleet: t,
            power_budget_w,
            trace: None,
            overload: None,
        }
    }

    /// One-line fleet summary (the trailer of the rendered report and of
    /// `Engine::shutdown`).
    pub fn fleet_summary(&self) -> String {
        let t = &self.fleet;
        let budget = match self.power_budget_w {
            Some(w) => format!(", budget {} W (1s draw {} W)", fnum(w, 0), fnum(t.draw_1s_w, 1)),
            None => String::new(),
        };
        // Robustness counters only appear once something went wrong, so a
        // healthy fleet's summary is byte-identical to the pre-chaos one.
        let mut chaos = String::new();
        if t.jobs_retried > 0 || t.jobs_shed > 0 {
            chaos.push_str(&format!(", {} retried / {} shed", t.jobs_retried, t.jobs_shed));
        }
        if t.cards_quarantined > 0 {
            chaos.push_str(&format!(", {} card(s) quarantined", t.cards_quarantined));
        }
        // Overload markers follow the same quiet-when-healthy rule: an
        // idle ladder with zero admission sheds prints nothing.
        if let Some(o) = &self.overload {
            if o.brownout_max_level > 0 {
                chaos.push_str(&format!(
                    ", brownout L{} (peak L{}, {} escalations)",
                    o.brownout_level, o.brownout_max_level, o.brownout_escalations
                ));
            }
            if o.total_sheds() > 0 {
                chaos.push_str(&format!(
                    ", admission sheds {} (deadline {}, brownout {}, rate {}, evicted {})",
                    o.total_sheds(),
                    o.deadline_sheds,
                    o.brownout_sheds,
                    o.rate_limited,
                    o.evictions
                ));
            }
        }
        format!(
            "jobs {}/{} ok ({} failed), batches {}, occupancy {:.1}%, exec {:.3} s, energy saving {:.1}%{}{}",
            t.jobs_completed,
            t.jobs_submitted,
            t.jobs_failed,
            t.batches,
            t.occupancy * 100.0,
            t.exec_s,
            t.energy_saving * 100.0,
            budget,
            chaos,
        )
    }

    /// The human report the CLI prints: one line per card, one fleet
    /// trailer — the renderer sits *on top of* the typed data.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.cards {
            let share = match c.power_share_w {
                Some(w) => format!(", share {} W", fnum(w, 0)),
                None => String::new(),
            };
            // Shown only off the happy path: the healthy, accepting card's
            // line keeps its established shape (and line count).
            let mut health = String::new();
            if c.health != "healthy" {
                health.push_str(&format!(" <{}>", c.health));
            }
            if !c.accepting {
                health.push_str(" <draining>");
            }
            out.push_str(&format!(
                "card{}{health} {} [{}]: jobs {}/{} ok ({} failed), batches {}, occupancy {:.1}%, exec {:.3} s, energy saving {:.1}% (clock transitions {}, draw {}/{} W inst/1s{}, {} misses)\n",
                c.index,
                c.gpu,
                c.governor,
                c.jobs_completed,
                c.jobs_submitted,
                c.jobs_failed,
                c.batches,
                c.occupancy * 100.0,
                c.exec_s,
                c.energy_saving * 100.0,
                c.clock_transitions,
                fnum(c.instant_w, 1),
                fnum(c.avg_1s_w, 1),
                share,
                c.deadline_misses,
            ));
        }
        out.push_str(&format!("fleet: {}", self.fleet_summary()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn card(index: usize, completed: u64, energy: f64, boost: f64, draw: f64) -> CardSnapshot {
        CardSnapshot {
            index,
            gpu: "Tesla V100".into(),
            governor: "common".into(),
            jobs_submitted: completed,
            jobs_completed: completed,
            jobs_failed: 0,
            batches: completed / 2,
            occupancy: 0.5,
            exec_s: 0.1,
            energy_j: energy,
            boost_energy_j: boost,
            energy_saving: 1.0 - energy / boost,
            clock_transitions: 1,
            current_clock_mhz: 945.0,
            instant_w: draw,
            avg_1s_w: draw,
            avg_10s_w: draw,
            busy_s: 0.5,
            energy_per_job_j: energy / completed as f64,
            deadline_misses: 0,
            power_share_w: Some(150.0),
            inflight: 0,
            health: "healthy".into(),
            health_transitions: 0,
            jobs_retried: 0,
            jobs_shed: 0,
            batch_errors: 0,
            accepting: true,
        }
    }

    #[test]
    fn aggregates_sum_and_weight_correctly() {
        let s = FleetSnapshot::from_cards(
            vec![card(0, 10, 6.0, 10.0, 120.0), card(1, 30, 12.0, 30.0, 60.0)],
            Some(250.0),
        );
        assert_eq!(s.fleet.jobs_completed, 40);
        assert_eq!(s.fleet.batches, 20);
        assert!((s.fleet.energy_j - 18.0).abs() < 1e-12);
        assert!((s.fleet.energy_saving - (1.0 - 18.0 / 40.0)).abs() < 1e-12);
        assert!((s.fleet.draw_1s_w - 180.0).abs() < 1e-12);
        assert!((s.fleet.energy_per_job_j - 18.0 / 40.0).abs() < 1e-12);
        assert_eq!(s.fleet.clock_transitions, 2);
    }

    #[test]
    fn render_keeps_the_report_shape() {
        let s = FleetSnapshot::from_cards(
            vec![card(0, 4, 1.0, 2.0, 100.0), card(1, 4, 1.0, 2.0, 100.0)],
            None,
        );
        let r = s.render();
        assert_eq!(r.lines().count(), 3, "2 card lines + 1 fleet line");
        assert!(r.contains("card0 Tesla V100 [common]"));
        assert!(r.contains("card1"));
        assert!(r.lines().last().unwrap().starts_with("fleet: jobs 8/8 ok"));
    }

    #[test]
    fn budget_appears_in_fleet_summary_when_capped() {
        let capped =
            FleetSnapshot::from_cards(vec![card(0, 2, 1.0, 2.0, 90.0)], Some(120.0));
        assert!(capped.fleet_summary().contains("budget 120 W"));
        let open = FleetSnapshot::from_cards(vec![card(0, 2, 1.0, 2.0, 90.0)], None);
        assert!(!open.fleet_summary().contains("budget"));
    }

    #[test]
    fn health_aggregates_and_markers() {
        let mut sick = card(0, 10, 6.0, 10.0, 120.0);
        sick.health = "quarantined".into();
        sick.health_transitions = 3;
        sick.jobs_retried = 4;
        sick.jobs_shed = 1;
        sick.batch_errors = 5;
        sick.accepting = false;
        let ok = card(1, 30, 12.0, 30.0, 60.0);
        let s = FleetSnapshot::from_cards(vec![sick, ok], None);
        assert_eq!(s.fleet.cards_quarantined, 1);
        assert_eq!(s.fleet.health_transitions, 3);
        assert_eq!(s.fleet.jobs_retried, 4);
        assert_eq!(s.fleet.jobs_shed, 1);
        assert_eq!(s.fleet.batch_errors, 5);
        let r = s.render();
        assert_eq!(r.lines().count(), 3, "markers never add lines");
        assert!(r.contains("card0 <quarantined> <draining>"));
        assert!(!r.contains("card1 <"), "healthy card line unchanged");
        assert!(s.fleet_summary().contains("4 retried / 1 shed"));
        assert!(s.fleet_summary().contains("1 card(s) quarantined"));
    }

    #[test]
    fn healthy_fleet_summary_has_no_chaos_noise() {
        let mut s = FleetSnapshot::from_cards(vec![card(0, 4, 1.0, 2.0, 100.0)], None);
        s.overload = Some(OverloadSnapshot::default());
        assert!(!s.fleet_summary().contains("retried"));
        assert!(!s.fleet_summary().contains("quarantined"));
        assert!(!s.fleet_summary().contains("brownout"));
        assert!(!s.fleet_summary().contains("admission sheds"));
        assert!(!s.render().contains('<'));
    }

    #[test]
    fn overload_markers_appear_once_the_ladder_moves() {
        let mut s = FleetSnapshot::from_cards(vec![card(0, 4, 1.0, 2.0, 100.0)], None);
        s.overload = Some(OverloadSnapshot {
            brownout_level: 2,
            brownout_max_level: 3,
            brownout_escalations: 4,
            admitted: [10, 20, 5],
            deadline_sheds: 3,
            brownout_sheds: 7,
            rate_limited: 1,
            evictions: 2,
        });
        let summary = s.fleet_summary();
        assert!(summary.contains("brownout L2 (peak L3, 4 escalations)"), "{summary}");
        assert!(
            summary.contains("admission sheds 13 (deadline 3, brownout 7, rate 1, evicted 2)"),
            "{summary}"
        );
        assert_eq!(s.overload.unwrap().total_sheds(), 13);
        assert_eq!(s.render().lines().count(), 2, "markers never add lines");
    }

    #[test]
    fn empty_fleet_is_all_zero() {
        let s = FleetSnapshot::from_cards(Vec::new(), None);
        assert_eq!(s.fleet.jobs_completed, 0);
        assert_eq!(s.fleet.occupancy, 0.0);
        assert_eq!(s.fleet.energy_saving, 0.0);
        assert!(s.fleet_summary().contains("jobs 0/0"));
    }
}
