//! Log-bucketed (HDR-style) latency/energy histogram.
//!
//! One shared implementation replaces the ad-hoc sort-based percentile
//! paths in the serving analysis and `bench_serving`: recording is a
//! single atomic `fetch_add` into one of 2048 fixed buckets (lock-free,
//! wait-free on the hot path — the tracing overhead budget in DESIGN.md
//! §4h depends on this), and readout walks the bucket array once.
//!
//! Bucket scheme: 32 geometric sub-buckets per octave (factor
//! 2^(1/32) ≈ 1.0219 between edges) spanning 64 octaves from
//! [`MIN_VALUE`] = 1e-9, so values from a nanosecond/nanojoule to
//! ~1.8e10 land in a dedicated bucket. Reporting a bucket's geometric
//! midpoint bounds the relative quantile error at 2^(1/64) − 1 ≈ 1.1%
//! (≈ 2.2% worst-case against an arbitrary in-bucket distribution) —
//! tight enough that p50/p95/p99/p999 readouts are indistinguishable
//! from exact sorting at serving noise levels, verified against
//! `util::stats::percentile` in the tests below.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lower edge of bucket 0; everything at or below it (and any
/// non-finite or negative sample) is clamped into bucket 0.
pub const MIN_VALUE: f64 = 1e-9;
/// Geometric sub-buckets per octave (power of two).
pub const SUBS_PER_OCTAVE: usize = 32;
/// Octaves covered above `MIN_VALUE`.
pub const OCTAVES: usize = 64;
/// Total bucket count.
pub const BUCKETS: usize = SUBS_PER_OCTAVE * OCTAVES;

/// Map a sample to its bucket. Total (monotone) over all f64 inputs.
pub fn bucket_index(v: f64) -> usize {
    if !v.is_finite() || v <= MIN_VALUE {
        return 0;
    }
    let idx = ((v / MIN_VALUE).log2() * SUBS_PER_OCTAVE as f64).floor() as i64;
    idx.clamp(0, BUCKETS as i64 - 1) as usize
}

/// Geometric midpoint of bucket `i` — the value reported for any sample
/// that landed in it.
pub fn bucket_value(i: usize) -> f64 {
    MIN_VALUE * ((i as f64 + 0.5) / SUBS_PER_OCTAVE as f64).exp2()
}

/// Exclusive upper edge of bucket `i`.
pub fn bucket_upper(i: usize) -> f64 {
    MIN_VALUE * ((i as f64 + 1.0) / SUBS_PER_OCTAVE as f64).exp2()
}

/// Concurrent log-bucketed histogram. `record` is lock-free; `snapshot`
/// reads the buckets without stopping writers (each counter is read
/// atomically, so a concurrent snapshot is a consistent-enough view:
/// totals may trail in-flight records by a few samples but never tear).
#[derive(Debug)]
pub struct LogHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Σ samples, stored as f64 bits and updated by CAS — full precision
    /// without a mutex on the record path.
    sum_bits: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn record(&self, v: f64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let add = if v.is_finite() { v.max(0.0) } else { 0.0 };
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + add).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// A point-in-time copy of a [`LogHistogram`]: plain data, mergeable,
/// with rank-exact percentile readout over the bucket midpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The value at percentile `p` in [0, 100]: rank selection over the
    /// recorded samples (rank = ⌈p/100 · count⌉), reported as the
    /// containing bucket's geometric midpoint. 0.0 on an empty snapshot.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Cumulative counts at each bound (Prometheus `le` semantics): the
    /// number of samples whose bucket lies entirely at or below the
    /// bound. Off by at most one bucket width (≈ 2.2%) for bounds that
    /// fall inside a bucket; exact when bounds sit on bucket edges.
    pub fn cumulative_le(&self, bounds: &[f64]) -> Vec<u64> {
        let mut out = Vec::with_capacity(bounds.len());
        let mut i = 0usize;
        let mut cum = 0u64;
        for &bound in bounds {
            while i < BUCKETS && bucket_upper(i) <= bound {
                cum += self.counts[i];
                i += 1;
            }
            out.push(cum);
        }
        out
    }

    /// Fold another snapshot into this one (per-artifact → fleet rollup).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;
    use std::sync::Arc;

    #[test]
    fn empty_histogram_reads_zero() {
        let h = LogHistogram::new();
        let s = h.snapshot();
        assert!(s.is_empty());
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.cumulative_le(&[1.0]), vec![0]);
    }

    #[test]
    fn single_value_reads_back_within_bucket_error() {
        let h = LogHistogram::new();
        h.record(3.5e-3);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        for p in [0.0, 50.0, 99.0, 100.0] {
            let got = s.percentile(p);
            assert!(
                (got / 3.5e-3 - 1.0).abs() < 0.025,
                "p{p}: got {got}, want ~3.5e-3"
            );
        }
        assert!((s.mean() - 3.5e-3).abs() < 1e-12, "sum is exact");
    }

    #[test]
    fn pathological_inputs_clamp_into_bucket_zero() {
        let h = LogHistogram::new();
        for v in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 1e-12] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.counts[0], 6);
        assert!(s.sum.is_finite());
    }

    #[test]
    fn huge_values_clamp_into_the_top_bucket() {
        let h = LogHistogram::new();
        h.record(1e300);
        assert_eq!(h.snapshot().counts[BUCKETS - 1], 1);
    }

    #[test]
    fn bucket_index_is_monotone_over_edges() {
        let mut prev = 0usize;
        for i in 0..2000 {
            let v = MIN_VALUE * 1.01f64.powi(i);
            let b = bucket_index(v);
            assert!(b >= prev, "index decreased at {v}");
            prev = b;
        }
    }

    /// The satellite contract: against exact sort-based percentiles on
    /// random samples, the histogram readout stays within a bounded
    /// relative error (bucket width ≈ 2.2%; gate at 5%).
    #[test]
    fn bounded_relative_error_vs_exact_sort() {
        let mut rng = Rng::new(0x51DE);
        for (lo, hi) in [(-6.0, -2.0), (-4.0, 1.0), (-1.0, 3.0)] {
            let h = LogHistogram::new();
            let xs: Vec<f64> = (0..10_000)
                .map(|_| 10f64.powf(rng.range_f64(lo, hi)))
                .collect();
            for &x in &xs {
                h.record(x);
            }
            let s = h.snapshot();
            for p in [50.0, 95.0, 99.0, 99.9] {
                let exact = stats::percentile(&xs, p);
                let approx = s.percentile(p);
                assert!(
                    (approx / exact - 1.0).abs() < 0.05,
                    "p{p} over 10^[{lo},{hi}): approx {approx} vs exact {exact}"
                );
            }
            let exact_mean = xs.iter().sum::<f64>() / xs.len() as f64;
            assert!((s.mean() / exact_mean - 1.0).abs() < 1e-9, "mean is exact");
        }
    }

    #[test]
    fn cumulative_le_is_monotone_and_complete() {
        let mut rng = Rng::new(7);
        let h = LogHistogram::new();
        for _ in 0..5_000 {
            h.record(10f64.powf(rng.range_f64(-5.0, 0.0)));
        }
        let s = h.snapshot();
        let bounds = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, f64::INFINITY];
        let cum = s.cumulative_le(&bounds);
        for w in cum.windows(2) {
            assert!(w[0] <= w[1], "cumulative counts must not decrease");
        }
        assert_eq!(*cum.last().unwrap(), s.count, "+Inf covers every sample");
        // a bound inside the range splits the samples non-trivially
        assert!(cum[2] > 0 && cum[2] < s.count);
    }

    #[test]
    fn merge_is_exact_union() {
        let a = LogHistogram::new();
        let b = LogHistogram::new();
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            a.record(rng.range_f64(1e-4, 1e-2));
            b.record(rng.range_f64(1e-3, 1e-1));
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.count, 2000);
        assert!((merged.sum - (a.snapshot().sum + b.snapshot().sum)).abs() < 1e-12);
        let total: u64 = merged.counts.iter().sum();
        assert_eq!(total, 2000);
    }

    /// Concurrent recording loses nothing, and snapshots taken while
    /// writers are live never tear (count covers every finished record).
    #[test]
    fn concurrent_records_are_all_counted() {
        let h = Arc::new(LogHistogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..25_000 {
                        h.record(rng.range_f64(1e-6, 1e-1));
                    }
                })
            })
            .collect();
        // interleave snapshots with the writers
        for _ in 0..50 {
            let s = h.snapshot();
            assert!(s.count <= 100_000);
            assert!(s.counts.iter().sum::<u64>() <= 100_000);
        }
        for t in threads {
            t.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100_000);
        assert_eq!(s.counts.iter().sum::<u64>(), 100_000);
        assert!(s.sum > 0.0 && s.sum.is_finite());
    }
}
