//! Telemetry exporters: machine-readable JSON (via the crate's own
//! `util::json` writer — no serde in the offline crate set) and
//! Prometheus text exposition format, both rendered from the typed
//! [`FleetSnapshot`].
//!
//! Schema 2 adds the request-trace rollup: span counters plus the
//! latency/energy histograms, exported as percentile readouts in the
//! JSON document and as proper `# TYPE ... histogram` families (with
//! cumulative `le` buckets, `_sum` and `_count`) in the Prometheus text.
//!
//! Schema 3 adds the overload rollup — brownout ladder position and the
//! per-reason admission shed counters — plus the per-QoS-class span
//! split (`trace.per_class` in JSON,
//! `fftsweep_trace_class_spans_total{class,outcome}` in Prometheus).

use std::fmt::Write as _;

use crate::telemetry::histogram::HistogramSnapshot;
use crate::telemetry::snapshot::{CardSnapshot, FleetSnapshot};
use crate::telemetry::trace::HistSetSnapshot;
use crate::util::json::Json;

/// The JSON document `serve --telemetry-out` writes.
pub fn snapshot_json(s: &FleetSnapshot) -> Json {
    let mut root = Json::obj();
    root.set("schema", 3u64.into());
    root.set(
        "power_budget_w",
        s.power_budget_w.map(Json::Num).unwrap_or(Json::Null),
    );

    let mut cards = Json::Arr(Vec::new());
    for c in &s.cards {
        cards.push(card_json(c));
    }
    root.set("cards", cards);

    let t = &s.fleet;
    let mut fleet = Json::obj();
    fleet.set("jobs_submitted", t.jobs_submitted.into());
    fleet.set("jobs_completed", t.jobs_completed.into());
    fleet.set("jobs_failed", t.jobs_failed.into());
    fleet.set("batches", t.batches.into());
    fleet.set("occupancy", t.occupancy.into());
    fleet.set("exec_s", t.exec_s.into());
    fleet.set("energy_j", t.energy_j.into());
    fleet.set("boost_energy_j", t.boost_energy_j.into());
    fleet.set("energy_saving", t.energy_saving.into());
    fleet.set("draw_1s_w", t.draw_1s_w.into());
    fleet.set("energy_per_job_j", t.energy_per_job_j.into());
    fleet.set("deadline_misses", t.deadline_misses.into());
    fleet.set("clock_transitions", t.clock_transitions.into());
    fleet.set("jobs_retried", t.jobs_retried.into());
    fleet.set("jobs_shed", t.jobs_shed.into());
    fleet.set("batch_errors", t.batch_errors.into());
    fleet.set("health_transitions", t.health_transitions.into());
    fleet.set("cards_quarantined", t.cards_quarantined.into());
    root.set("fleet", fleet);

    if let Some(o) = &s.overload {
        let mut ov = Json::obj();
        ov.set("brownout_level", (o.brownout_level as u64).into());
        ov.set("brownout_max_level", (o.brownout_max_level as u64).into());
        ov.set("brownout_escalations", o.brownout_escalations.into());
        let mut admitted = Json::obj();
        for (c, &n) in crate::coordinator::admission::CLASSES.iter().zip(&o.admitted) {
            admitted.set(c.label(), n.into());
        }
        ov.set("admitted", admitted);
        ov.set("deadline_sheds", o.deadline_sheds.into());
        ov.set("brownout_sheds", o.brownout_sheds.into());
        ov.set("rate_limited", o.rate_limited.into());
        ov.set("evictions", o.evictions.into());
        ov.set("total_sheds", o.total_sheds().into());
        root.set("overload", ov);
    }

    if let Some(tr) = &s.trace {
        let mut trace = Json::obj();
        trace.set("enabled", tr.enabled.into());
        trace.set("ok_spans", tr.ok_spans.into());
        trace.set("shed_spans", tr.shed_spans.into());
        let mut per_class = Json::obj();
        for cs in &tr.per_class {
            let mut row = Json::obj();
            row.set("ok_spans", cs.ok_spans.into());
            row.set("shed_spans", cs.shed_spans.into());
            per_class.set(cs.class, row);
        }
        trace.set("per_class", per_class);
        trace.set("ring_len", (tr.ring_len as u64).into());
        trace.set("ring_dropped", tr.ring_dropped.into());
        trace.set("sink_errors", tr.sink_errors.into());
        trace.set("fleet", hist_set_json(&tr.fleet()));
        let mut per_card = Json::Arr(Vec::new());
        for set in &tr.per_card {
            per_card.push(hist_set_json(set));
        }
        trace.set("per_card", per_card);
        let mut per_artifact = Json::obj();
        for (artifact, set) in &tr.per_artifact {
            per_artifact.set(artifact, hist_set_json(set));
        }
        trace.set("per_artifact", per_artifact);
        root.set("trace", trace);
    }
    root
}

/// Percentile readout of one histogram — what dashboards that don't
/// ingest raw buckets consume.
fn hist_json(h: &HistogramSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("count", h.count.into());
    o.set("mean", h.mean().into());
    o.set("p50", h.percentile(50.0).into());
    o.set("p95", h.percentile(95.0).into());
    o.set("p99", h.percentile(99.0).into());
    o.set("p999", h.percentile(99.9).into());
    o
}

fn hist_set_json(s: &HistSetSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("queue_wait_s", hist_json(&s.queue_wait_s));
    o.set("exec_s", hist_json(&s.exec_s));
    o.set("e2e_s", hist_json(&s.e2e_s));
    o.set("energy_j", hist_json(&s.energy_j));
    o
}

/// Numeric health code for dashboards: healthy 0, degraded 1,
/// quarantined 2 (unknown labels clamp to quarantined — fail loud).
fn health_code(label: &str) -> f64 {
    match label {
        "healthy" => 0.0,
        "degraded" => 1.0,
        _ => 2.0,
    }
}

fn card_json(c: &CardSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("index", (c.index as u64).into());
    o.set("gpu", c.gpu.as_str().into());
    o.set("governor", c.governor.as_str().into());
    o.set("jobs_submitted", c.jobs_submitted.into());
    o.set("jobs_completed", c.jobs_completed.into());
    o.set("jobs_failed", c.jobs_failed.into());
    o.set("batches", c.batches.into());
    o.set("occupancy", c.occupancy.into());
    o.set("exec_s", c.exec_s.into());
    o.set("energy_j", c.energy_j.into());
    o.set("boost_energy_j", c.boost_energy_j.into());
    o.set("energy_saving", c.energy_saving.into());
    o.set("clock_transitions", c.clock_transitions.into());
    o.set("current_clock_mhz", c.current_clock_mhz.into());
    o.set("instant_w", c.instant_w.into());
    o.set("avg_1s_w", c.avg_1s_w.into());
    o.set("avg_10s_w", c.avg_10s_w.into());
    o.set("busy_s", c.busy_s.into());
    o.set("energy_per_job_j", c.energy_per_job_j.into());
    o.set("deadline_misses", c.deadline_misses.into());
    o.set(
        "power_share_w",
        c.power_share_w.map(Json::Num).unwrap_or(Json::Null),
    );
    o.set("inflight", c.inflight.into());
    o.set("health", c.health.as_str().into());
    o.set("health_transitions", c.health_transitions.into());
    o.set("jobs_retried", c.jobs_retried.into());
    o.set("jobs_shed", c.jobs_shed.into());
    o.set("batch_errors", c.batch_errors.into());
    o.set("accepting", c.accepting.into());
    o
}

/// Prometheus label values: escape backslash, quote and newline
/// (exposition-format string rules).
fn prom_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn prom_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "NaN".into()
    }
}

/// Render the snapshot in Prometheus text exposition format. Gauge names
/// are prefixed `fftsweep_`; per-card series carry `card`, `gpu` and
/// `governor` labels.
fn gauge(out: &mut String, name: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
}

fn counter(out: &mut String, name: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
}

/// `le` bounds for the latency histogram families, seconds. Sparse
/// decade/half-decade ladder: the live histograms keep ~2.2% resolution,
/// the exposition only needs scrape-friendly bucket counts.
const LATENCY_BOUNDS_S: [f64; 16] = [
    1e-6, 1e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5,
    1.0, 5.0,
];

/// `le` bounds for the energy-per-job family, joules.
const ENERGY_BOUNDS_J: [f64; 14] = [
    1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 100.0,
];

/// One Prometheus histogram family: HELP/TYPE header, then per series
/// the cumulative `le` buckets (closed by `+Inf` == `_count`), `_sum`
/// and `_count` — the exposition-format histogram contract.
fn histogram_family(
    out: &mut String,
    name: &str,
    help: &str,
    series: &[(String, &HistogramSnapshot)],
    bounds: &[f64],
) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (labels, h) in series {
        for (&bound, &cum) in bounds.iter().zip(h.cumulative_le(bounds).iter()) {
            let _ = writeln!(
                out,
                "{name}_bucket{{{labels},le=\"{}\"}} {cum}",
                prom_num(bound)
            );
        }
        let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(out, "{name}_sum{{{labels}}} {}", prom_num(h.sum));
        let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count);
    }
}

pub fn prometheus_text(s: &FleetSnapshot) -> String {
    let mut out = String::new();

    // Build (metric, per-card extractor) pairs once so every series of a
    // metric family sits under one HELP/TYPE header, as the format requires.
    type Get = fn(&CardSnapshot) -> f64;
    let families: &[(&str, &str, Get)] = &[
        ("fftsweep_card_power_watts", "Simulated draw of the last executed batch", |c| c.instant_w),
        ("fftsweep_card_power_1s_watts", "Rolling 1s mean simulated draw", |c| c.avg_1s_w),
        ("fftsweep_card_power_10s_watts", "Rolling 10s mean simulated draw", |c| c.avg_10s_w),
        ("fftsweep_card_energy_joules_total", "Cumulative simulated energy", |c| c.energy_j),
        ("fftsweep_card_energy_per_job_joules", "Mean attributed energy per job", |c| c.energy_per_job_j),
        ("fftsweep_card_jobs_completed_total", "Jobs completed", |c| c.jobs_completed as f64),
        ("fftsweep_card_jobs_failed_total", "Jobs failed", |c| c.jobs_failed as f64),
        ("fftsweep_card_deadline_misses_total", "Batches that missed their effective deadline", |c| {
            c.deadline_misses as f64
        }),
        ("fftsweep_card_clock_transitions_total", "NVML clock-lock state transitions", |c| {
            c.clock_transitions as f64
        }),
        ("fftsweep_card_clock_mhz", "Current effective core clock", |c| c.current_clock_mhz),
        ("fftsweep_card_power_share_watts", "Arbiter watt share (+Inf when uncapped)", |c| {
            c.power_share_w.unwrap_or(f64::INFINITY)
        }),
        ("fftsweep_card_health_state", "Health state: 0 healthy, 1 degraded, 2 quarantined", |c| {
            health_code(&c.health)
        }),
        ("fftsweep_card_health_transitions_total", "Health state-machine transitions", |c| {
            c.health_transitions as f64
        }),
        ("fftsweep_card_jobs_retried_total", "Jobs re-dispatched onto this card after failing elsewhere", |c| {
            c.jobs_retried as f64
        }),
        ("fftsweep_card_jobs_shed_total", "Jobs dropped with a typed error", |c| {
            c.jobs_shed as f64
        }),
        ("fftsweep_card_batch_errors_total", "Batches that errored on this card", |c| {
            c.batch_errors as f64
        }),
        ("fftsweep_card_accepting", "1 while the card accepts new work, 0 while draining", |c| {
            if c.accepting { 1.0 } else { 0.0 }
        }),
    ];
    for (name, help, get) in families {
        gauge(&mut out, name, help);
        for c in &s.cards {
            let _ = writeln!(
                out,
                "{name}{{card=\"{}\",gpu=\"{}\",governor=\"{}\"}} {}",
                c.index,
                prom_escape(&c.gpu),
                prom_escape(&c.governor),
                if name.contains("share") && c.power_share_w.is_none() {
                    "+Inf".to_string()
                } else {
                    prom_num(get(c))
                }
            );
        }
    }

    gauge(&mut out, "fftsweep_fleet_power_1s_watts", "Fleet rolling 1s simulated draw");
    let _ = writeln!(out, "fftsweep_fleet_power_1s_watts {}", prom_num(s.fleet.draw_1s_w));
    gauge(&mut out, "fftsweep_fleet_power_budget_watts", "Operator power budget (+Inf when uncapped)");
    let _ = writeln!(
        out,
        "fftsweep_fleet_power_budget_watts {}",
        s.power_budget_w.map(prom_num).unwrap_or_else(|| "+Inf".into())
    );
    gauge(&mut out, "fftsweep_fleet_energy_joules_total", "Fleet cumulative simulated energy");
    let _ = writeln!(out, "fftsweep_fleet_energy_joules_total {}", prom_num(s.fleet.energy_j));
    gauge(&mut out, "fftsweep_fleet_energy_saving_ratio", "1 - energy/boost_energy");
    let _ = writeln!(out, "fftsweep_fleet_energy_saving_ratio {}", prom_num(s.fleet.energy_saving));
    gauge(&mut out, "fftsweep_fleet_cards_quarantined", "Cards currently quarantined");
    let _ = writeln!(
        out,
        "fftsweep_fleet_cards_quarantined {}",
        prom_num(s.fleet.cards_quarantined as f64)
    );
    gauge(&mut out, "fftsweep_fleet_jobs_shed_total", "Jobs dropped fleet-wide with a typed error");
    let _ = writeln!(out, "fftsweep_fleet_jobs_shed_total {}", prom_num(s.fleet.jobs_shed as f64));

    if let Some(o) = &s.overload {
        gauge(&mut out, "fftsweep_brownout_level", "Brownout ladder rung (0 off, 3 realtime-only)");
        let _ = writeln!(out, "fftsweep_brownout_level {}", o.brownout_level);
        gauge(&mut out, "fftsweep_brownout_max_level", "Highest brownout rung reached");
        let _ = writeln!(out, "fftsweep_brownout_max_level {}", o.brownout_max_level);
        counter(&mut out, "fftsweep_brownout_escalations_total", "Brownout ladder level-up transitions");
        let _ = writeln!(out, "fftsweep_brownout_escalations_total {}", o.brownout_escalations);
        counter(&mut out, "fftsweep_admission_admitted_total", "Jobs admitted by QoS class");
        for (c, &n) in crate::coordinator::admission::CLASSES.iter().zip(&o.admitted) {
            let _ = writeln!(out, "fftsweep_admission_admitted_total{{class=\"{}\"}} {n}", c.label());
        }
        counter(&mut out, "fftsweep_admission_sheds_total", "Admission-layer drops by typed reason");
        let _ = writeln!(out, "fftsweep_admission_sheds_total{{reason=\"deadline_infeasible\"}} {}", o.deadline_sheds);
        let _ = writeln!(out, "fftsweep_admission_sheds_total{{reason=\"brownout\"}} {}", o.brownout_sheds);
        let _ = writeln!(out, "fftsweep_admission_sheds_total{{reason=\"rate_limited\"}} {}", o.rate_limited);
        let _ = writeln!(out, "fftsweep_admission_sheds_total{{reason=\"evicted\"}} {}", o.evictions);
    }

    if let Some(tr) = &s.trace {
        counter(&mut out, "fftsweep_trace_spans_total", "Completed request spans by outcome");
        let _ = writeln!(out, "fftsweep_trace_spans_total{{outcome=\"ok\"}} {}", tr.ok_spans);
        let _ = writeln!(out, "fftsweep_trace_spans_total{{outcome=\"shed\"}} {}", tr.shed_spans);
        counter(
            &mut out,
            "fftsweep_trace_class_spans_total",
            "Completed request spans by QoS class and outcome",
        );
        for cs in &tr.per_class {
            let _ = writeln!(
                out,
                "fftsweep_trace_class_spans_total{{class=\"{}\",outcome=\"ok\"}} {}",
                cs.class, cs.ok_spans
            );
            let _ = writeln!(
                out,
                "fftsweep_trace_class_spans_total{{class=\"{}\",outcome=\"shed\"}} {}",
                cs.class, cs.shed_spans
            );
        }
        counter(
            &mut out,
            "fftsweep_trace_sink_errors_total",
            "JSONL journal write failures (best-effort sink)",
        );
        let _ = writeln!(out, "fftsweep_trace_sink_errors_total {}", tr.sink_errors);
        gauge(&mut out, "fftsweep_trace_ring_spans", "Spans currently retained in the ring");
        let _ = writeln!(out, "fftsweep_trace_ring_spans {}", tr.ring_len);

        let card_series = |get: fn(&HistSetSnapshot) -> &HistogramSnapshot| -> Vec<(String, &HistogramSnapshot)> {
            tr.per_card
                .iter()
                .enumerate()
                .map(|(i, set)| (format!("card=\"{i}\""), get(set)))
                .collect()
        };
        histogram_family(
            &mut out,
            "fftsweep_trace_queue_wait_seconds",
            "Submit to exec-start wait per job",
            &card_series(|set| &set.queue_wait_s),
            &LATENCY_BOUNDS_S,
        );
        histogram_family(
            &mut out,
            "fftsweep_trace_exec_seconds",
            "Host wall-clock batch execution time per job",
            &card_series(|set| &set.exec_s),
            &LATENCY_BOUNDS_S,
        );
        histogram_family(
            &mut out,
            "fftsweep_trace_e2e_latency_seconds",
            "Submit to reply end-to-end latency per job",
            &card_series(|set| &set.e2e_s),
            &LATENCY_BOUNDS_S,
        );
        histogram_family(
            &mut out,
            "fftsweep_trace_energy_per_job_joules",
            "Simulated joules attributed per job",
            &card_series(|set| &set.energy_j),
            &ENERGY_BOUNDS_J,
        );
        let artifact_series: Vec<(String, &HistogramSnapshot)> = tr
            .per_artifact
            .iter()
            .map(|(artifact, set)| {
                (format!("artifact=\"{}\"", prom_escape(artifact)), &set.e2e_s)
            })
            .collect();
        histogram_family(
            &mut out,
            "fftsweep_trace_artifact_e2e_latency_seconds",
            "End-to-end latency per job by serving artifact",
            &artifact_series,
            &LATENCY_BOUNDS_S,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::snapshot::{FleetSnapshot, OverloadSnapshot};

    fn snap(budget: Option<f64>) -> FleetSnapshot {
        let card = CardSnapshot {
            index: 0,
            gpu: "Tesla \"V100\"".into(),
            governor: "common".into(),
            jobs_submitted: 8,
            jobs_completed: 8,
            jobs_failed: 0,
            batches: 2,
            occupancy: 1.0,
            exec_s: 0.01,
            energy_j: 0.5,
            boost_energy_j: 1.0,
            energy_saving: 0.5,
            clock_transitions: 1,
            current_clock_mhz: 945.0,
            instant_w: 120.0,
            avg_1s_w: 118.5,
            avg_10s_w: 110.0,
            busy_s: 0.004,
            energy_per_job_j: 0.0625,
            deadline_misses: 0,
            power_share_w: budget.map(|w| w / 2.0),
            inflight: 0,
            health: "degraded".into(),
            health_transitions: 2,
            jobs_retried: 3,
            jobs_shed: 1,
            batch_errors: 4,
            accepting: true,
        };
        FleetSnapshot::from_cards(vec![card], budget)
    }

    /// A snapshot whose trace summary holds real recorded spans: five per
    /// card, all with e2e 1250 µs and 0.25 mJ, one artifact name that
    /// needs label escaping.
    fn traced_snap() -> FleetSnapshot {
        use crate::telemetry::trace::{Span, SpanOutcome, TraceConfig, Tracer};
        use std::time::Instant;
        let t = Tracer::new(&TraceConfig::default(), 2, Instant::now()).unwrap();
        for i in 0..10u64 {
            let base = 1000 * i;
            t.record(Span {
                job_id: i,
                artifact: "fft \"odd\"\nname".into(),
                n: 1024,
                card: (i % 2) as usize,
                enqueue_us: base,
                admit_us: base + 10,
                seal_us: base + 210,
                dispatch_us: base + 215,
                exec_start_us: base + 240,
                exec_end_us: base + 1240,
                complete_us: base + 1250,
                requested_mhz: 945.0,
                granted_mhz: 945.0,
                batch_occupancy: 64,
                attempts: 1,
                energy_j: 2.5e-4,
                sim_batch_s: 8.0e-4,
                outcome: SpanOutcome::Ok,
                class: "realtime".into(),
                reason: String::new(),
            });
        }
        let mut s = snap(None);
        s.trace = Some(t.summary());
        s.overload = Some(OverloadSnapshot {
            brownout_level: 1,
            brownout_max_level: 2,
            brownout_escalations: 3,
            admitted: [10, 0, 0],
            deadline_sheds: 2,
            brownout_sheds: 1,
            rate_limited: 0,
            evictions: 1,
        });
        s
    }

    #[test]
    fn json_roundtrips_key_fields() {
        let j = snapshot_json(&snap(Some(240.0))).render();
        assert!(j.contains("\"schema\": 3"));
        assert!(j.contains("\"power_budget_w\": 240"));
        assert!(j.contains("\"avg_1s_w\": 118.5"));
        assert!(j.contains("\"power_share_w\": 120"));
        assert!(j.contains("\"energy_saving\": 0.5"));
        assert!(j.contains("\"gpu\": \"Tesla \\\"V100\\\"\""));
        // fleet aggregate present
        assert!(j.contains("\"draw_1s_w\": 118.5"));
        // robustness fields round-trip on card and fleet
        assert!(j.contains("\"health\": \"degraded\""));
        assert!(j.contains("\"jobs_retried\": 3"));
        assert!(j.contains("\"jobs_shed\": 1"));
        assert!(j.contains("\"batch_errors\": 4"));
        assert!(j.contains("\"accepting\": true"));
        assert!(j.contains("\"cards_quarantined\": 0"));
    }

    #[test]
    fn uncapped_budget_serializes_as_null() {
        let j = snapshot_json(&snap(None)).render();
        assert!(j.contains("\"power_budget_w\": null"));
        assert!(j.contains("\"power_share_w\": null"));
    }

    #[test]
    fn prometheus_format_is_well_formed() {
        for text in [prometheus_text(&snap(Some(240.0))), prometheus_text(&traced_snap())] {
            for line in text.lines() {
                assert!(
                    line.starts_with('#') || line.contains(' '),
                    "bad exposition line: {line}"
                );
            }
            // every family has HELP + TYPE, every TYPE is a known kind
            let helps = text.lines().filter(|l| l.starts_with("# HELP")).count();
            let types = text.lines().filter(|l| l.starts_with("# TYPE")).count();
            assert_eq!(helps, types);
            assert!(text
                .lines()
                .filter(|l| l.starts_with("# TYPE"))
                .all(|l| l.ends_with("gauge") || l.ends_with("counter") || l.ends_with("histogram")));
        }
        let text = prometheus_text(&snap(Some(240.0)));
        assert!(text.contains("fftsweep_fleet_power_budget_watts 240"));
        assert!(text.contains("fftsweep_card_power_1s_watts{card=\"0\",gpu=\"Tesla \\\"V100\\\"\",governor=\"common\"} 118.5"));
        assert!(!text.contains("fftsweep_trace_"), "no trace series without a summary");
    }

    #[test]
    fn trace_json_exports_counters_and_percentiles() {
        let j = snapshot_json(&traced_snap()).render();
        assert!(j.contains("\"ok_spans\": 10"));
        assert!(j.contains("\"shed_spans\": 0"));
        assert!(j.contains("\"per_artifact\""));
        assert!(j.contains("\"p999\""));
        // per-class split: the fixture records every span as realtime
        let parsed = Json::parse(&j).unwrap();
        let rt_ok = parsed
            .get("trace")
            .and_then(|t| t.get("per_class"))
            .and_then(|p| p.get("realtime"))
            .and_then(|r| r.get("ok_spans"))
            .and_then(Json::as_u64)
            .unwrap();
        assert_eq!(rt_ok, 10);
        // percentile readout of the constant 1.25e-3 s e2e stays within
        // the histogram's bucket error
        let parsed = Json::parse(&j).unwrap();
        let p99 = parsed
            .get("trace")
            .and_then(|t| t.get("fleet"))
            .and_then(|f| f.get("e2e_s"))
            .and_then(|h| h.get("p99"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((p99 / 1.25e-3 - 1.0).abs() < 0.025, "p99 {p99}");
        // the untraced snapshot carries no trace key at all
        assert!(!snapshot_json(&snap(None)).render().contains("\"trace\""));
    }

    #[test]
    fn trace_prometheus_histograms_are_cumulative_and_closed() {
        let text = prometheus_text(&traced_snap());
        assert!(text.contains("# TYPE fftsweep_trace_e2e_latency_seconds histogram"));
        assert!(text.contains("fftsweep_trace_spans_total{outcome=\"ok\"} 10"));

        // card 0's e2e buckets: nondecreasing, closed by +Inf == _count
        let buckets: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("fftsweep_trace_e2e_latency_seconds_bucket{card=\"0\""))
            .collect();
        assert!(buckets.len() > 2, "expected a bucket ladder, got {buckets:?}");
        let counts: Vec<u64> = buckets
            .iter()
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "buckets must be cumulative");
        let inf = buckets.iter().find(|l| l.contains("le=\"+Inf\"")).unwrap();
        assert!(inf.ends_with(" 5"), "+Inf bucket covers card 0's 5 spans: {inf}");
        assert!(text.contains("fftsweep_trace_e2e_latency_seconds_count{card=\"0\"} 5"));
        let sum = text
            .lines()
            .find(|l| l.starts_with("fftsweep_trace_e2e_latency_seconds_sum{card=\"0\"}"))
            .unwrap();
        let sum_v: f64 = sum.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum_v - 5.0 * 1.25e-3).abs() < 1e-12, "{sum}");

        // the artifact label is escaped per the exposition string rules
        assert!(text.contains(
            "fftsweep_trace_artifact_e2e_latency_seconds_count{artifact=\"fft \\\"odd\\\"\\nname\"} 10"
        ));
    }

    #[test]
    fn overload_section_exports_in_both_formats() {
        let s = traced_snap();
        let j = snapshot_json(&s).render();
        assert!(j.contains("\"overload\""));
        assert!(j.contains("\"brownout_level\": 1"));
        assert!(j.contains("\"brownout_max_level\": 2"));
        assert!(j.contains("\"deadline_sheds\": 2"));
        assert!(j.contains("\"total_sheds\": 4"));
        let parsed = Json::parse(&j).unwrap();
        let admitted_rt = parsed
            .get("overload")
            .and_then(|o| o.get("admitted"))
            .and_then(|a| a.get("realtime"))
            .and_then(Json::as_u64)
            .unwrap();
        assert_eq!(admitted_rt, 10);

        let text = prometheus_text(&s);
        assert!(text.contains("fftsweep_brownout_level 1"));
        assert!(text.contains("fftsweep_brownout_max_level 2"));
        assert!(text.contains("fftsweep_brownout_escalations_total 3"));
        assert!(text.contains("fftsweep_admission_admitted_total{class=\"realtime\"} 10"));
        assert!(text.contains("fftsweep_admission_admitted_total{class=\"scavenger\"} 0"));
        assert!(text.contains("fftsweep_admission_sheds_total{reason=\"deadline_infeasible\"} 2"));
        assert!(text.contains("fftsweep_admission_sheds_total{reason=\"evicted\"} 1"));
        assert!(text.contains("fftsweep_trace_class_spans_total{class=\"realtime\",outcome=\"ok\"} 10"));
        assert!(text.contains("fftsweep_trace_class_spans_total{class=\"batch\",outcome=\"shed\"} 0"));

        // a snapshot without the rollup exports neither family
        let bare = prometheus_text(&snap(None));
        assert!(!bare.contains("fftsweep_brownout_"));
        assert!(!bare.contains("fftsweep_admission_"));
        assert!(!snapshot_json(&snap(None)).render().contains("\"overload\""));
    }

    #[test]
    fn health_gauges_exported() {
        let text = prometheus_text(&snap(None));
        let state_line = text
            .lines()
            .find(|l| l.starts_with("fftsweep_card_health_state{"))
            .unwrap();
        assert!(state_line.ends_with(" 1"), "degraded maps to 1: {state_line}");
        let accepting_line = text
            .lines()
            .find(|l| l.starts_with("fftsweep_card_accepting{"))
            .unwrap();
        assert!(accepting_line.ends_with(" 1"), "{accepting_line}");
        assert!(text.contains("fftsweep_card_jobs_retried_total{"));
        assert!(text.contains("fftsweep_card_batch_errors_total{"));
        assert!(text.contains("fftsweep_fleet_cards_quarantined 0"));
        assert!(text.contains("fftsweep_fleet_jobs_shed_total 1"));
        assert_eq!(health_code("healthy"), 0.0);
        assert_eq!(health_code("quarantined"), 2.0);
        assert_eq!(health_code("???"), 2.0, "unknown labels clamp loud");
    }

    #[test]
    fn uncapped_prometheus_reports_inf() {
        let text = prometheus_text(&snap(None));
        assert!(text.contains("fftsweep_fleet_power_budget_watts +Inf"));
        assert!(text.contains("fftsweep_card_power_share_watts{card=\"0\"") );
        let share_line = text
            .lines()
            .find(|l| l.starts_with("fftsweep_card_power_share_watts{"))
            .unwrap();
        assert!(share_line.ends_with("+Inf"), "{share_line}");
    }
}
