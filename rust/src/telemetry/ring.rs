//! Fixed-capacity ring buffer — the storage primitive every telemetry
//! time series sits on.
//!
//! A `Ring<T>` never reallocates after construction: pushes past capacity
//! overwrite the oldest entry. That keeps the per-card recorder's memory
//! bounded no matter how long the fleet serves, and keeps `push` O(1) with
//! no amortized spikes (no `Vec` growth) on the worker hot path.

/// Fixed-capacity overwrite-oldest ring buffer.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: Vec<T>,
    cap: usize,
    /// Next write position (wraps at `cap` once full).
    head: usize,
    /// Total pushes ever — `total - len()` is the dropped count.
    total: u64,
}

impl<T> Ring<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "ring capacity must be >= 1");
        Self {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            total: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// How many entries were ever pushed (retained + overwritten).
    pub fn total_pushed(&self) -> u64 {
        self.total
    }

    /// How many entries fell off the back.
    pub fn dropped(&self) -> u64 {
        self.total - self.buf.len() as u64
    }

    /// Append, overwriting the oldest entry once at capacity.
    pub fn push(&mut self, value: T) {
        if self.buf.len() < self.cap {
            self.buf.push(value);
        } else {
            self.buf[self.head] = value;
        }
        self.head = (self.head + 1) % self.cap;
        self.total += 1;
    }

    /// The most recently pushed entry.
    pub fn newest(&self) -> Option<&T> {
        if self.buf.is_empty() {
            return None;
        }
        let idx = (self.head + self.cap - 1) % self.cap;
        // Before the first wrap `head` trails `len`, so clamp into the
        // initialized prefix.
        self.buf.get(if idx < self.buf.len() { idx } else { self.buf.len() - 1 })
    }

    /// Iterate oldest → newest. Double-ended, so `.rev()` walks newest →
    /// oldest (how the rolling-window scans run).
    pub fn iter(&self) -> impl DoubleEndedIterator<Item = &T> {
        let split = if self.buf.len() < self.cap { 0 } else { self.head };
        let (tail, head_part) = self.buf.split_at(split);
        head_part.iter().chain(tail.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_overwrites_oldest() {
        let mut r = Ring::new(3);
        assert!(r.is_empty());
        for v in 0..5 {
            r.push(v);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.total_pushed(), 5);
        assert_eq!(r.dropped(), 2);
        let got: Vec<i32> = r.iter().copied().collect();
        assert_eq!(got, vec![2, 3, 4], "oldest → newest after overwrite");
        assert_eq!(r.newest(), Some(&4));
    }

    #[test]
    fn partial_fill_iterates_in_order() {
        let mut r = Ring::new(8);
        r.push(10);
        r.push(20);
        let got: Vec<i32> = r.iter().copied().collect();
        assert_eq!(got, vec![10, 20]);
        assert_eq!(r.newest(), Some(&20));
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn rev_iteration_is_newest_first() {
        let mut r = Ring::new(4);
        for v in 0..9 {
            r.push(v);
        }
        let got: Vec<i32> = r.iter().rev().copied().collect();
        assert_eq!(got, vec![8, 7, 6, 5]);
    }

    #[test]
    fn capacity_one_keeps_only_newest() {
        let mut r = Ring::new(1);
        for v in 0..4 {
            r.push(v);
            assert_eq!(r.newest(), Some(&v));
            assert_eq!(r.len(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = Ring::<u8>::new(0);
    }

    #[test]
    fn never_reallocates_past_construction() {
        let mut r = Ring::new(16);
        r.push(0u64);
        let ptr = r.buf.as_ptr();
        for v in 1..100 {
            r.push(v);
        }
        assert_eq!(r.buf.as_ptr(), ptr, "ring storage must stay in place");
    }

    /// Wraparound holds its invariants across many full revolutions, not
    /// just the first: the retained window is always the last `cap`
    /// pushes in order, and the drop accounting matches.
    #[test]
    fn repeated_wraparound_keeps_window_and_accounting() {
        let cap = 7usize;
        let mut r = Ring::new(cap);
        for v in 0u64..200 {
            r.push(v);
            let expect_len = (v as usize + 1).min(cap);
            assert_eq!(r.len(), expect_len);
            assert_eq!(r.newest(), Some(&v));
            assert_eq!(r.total_pushed(), v + 1);
            assert_eq!(r.dropped(), (v + 1).saturating_sub(cap as u64));
            let got: Vec<u64> = r.iter().copied().collect();
            let lo = (v as usize + 1).saturating_sub(cap) as u64;
            let want: Vec<u64> = (lo..=v).collect();
            assert_eq!(got, want, "window after push {v}");
        }
    }

    /// The serving tracer's usage pattern: writers record through a
    /// mutex while another thread snapshots concurrently. Every snapshot
    /// must be internally consistent (a contiguous, ordered suffix of
    /// the pushes so far) — no torn or reordered windows.
    #[test]
    fn concurrent_snapshot_while_recording_sees_consistent_suffixes() {
        use std::sync::{Arc, Mutex};

        let ring = Arc::new(Mutex::new(Ring::new(32)));
        let writer = {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for v in 0u64..20_000 {
                    ring.lock().unwrap().push(v);
                }
            })
        };
        let mut last_total = 0u64;
        for _ in 0..500 {
            let (window, total): (Vec<u64>, u64) = {
                let r = ring.lock().unwrap();
                (r.iter().copied().collect(), r.total_pushed())
            };
            assert!(total >= last_total, "total_pushed is monotone");
            last_total = total;
            // the window is exactly the last min(total, cap) values
            let want: Vec<u64> = (total.saturating_sub(window.len() as u64)..total).collect();
            assert_eq!(window, want, "snapshot at total={total}");
        }
        writer.join().unwrap();
        let r = ring.lock().unwrap();
        assert_eq!(r.total_pushed(), 20_000);
        assert_eq!(r.newest(), Some(&19_999));
    }
}
