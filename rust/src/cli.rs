//! CLI command dispatch for the `fftsweep` binary.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use fftsweep::analysis::report::{full_report, headline_table};
use fftsweep::analysis::{figures, govern, optima, tables};
use fftsweep::coordinator::admission::TenantClass;
use fftsweep::coordinator::health::HealthPolicy;
use fftsweep::coordinator::{CardConfig, CoordError, Engine, EngineConfig, RetryPolicy};
use fftsweep::dsp;
use fftsweep::governor::{GovernorContext, GovernorKind};
use fftsweep::harness::sweep::{paper_lengths, quick_lengths, sweep_gpu, SweepConfig};
use fftsweep::harness::Protocol;
use fftsweep::pipeline::{run_pipeline_at, table4};
use fftsweep::runtime::{backend_by_name, compiled_backend_names, ExecBackend, Manifest, Runtime};
use fftsweep::sim::fault::{Arrival, ArrivalPlan, FaultPlan};
use fftsweep::sim::gpu::{all_gpus, gpu_by_name, GpuSpec};
use fftsweep::telemetry::TraceConfig;
use fftsweep::types::Precision;
use fftsweep::util::cliargs::Args;
use fftsweep::util::rng::Rng;
use fftsweep::util::table::fnum;

const USAGE: &str = "\
fftsweep — DVFS energy-efficiency study of FFTs (paper reproduction)

USAGE:
  fftsweep report   [--out results] [--quick]
  fftsweep table    <1|2|3|4> [--quick]
  fftsweep figure   <2|3|4|5|6|7|8|9|13|15|17|20> [--gpu v100] [--precision fp32] [--quick]
  fftsweep sweep    [--gpu v100] [--precision fp32] [--quick] [--lengths 1000,1536,4096]
  fftsweep pipeline [--gpu v100] [--n 500000] [--governor fixed --clock 945]
  fftsweep selftest [--artifacts artifacts]
  fftsweep serve    [--artifacts artifacts] [--backend default] [--jobs 256]
                    [--governor fixed --clock 945]
                    [--cards 1 | --gpus v100,p4,...] [--deadline-ms <ms>]
                    [--lengths 1000,1536,4096] [--conv-taps <t>]
                    [--power-budget-w <W>] [--telemetry-out <file.json>] [--prom]
                    [--trace-out <file.jsonl>] [--no-trace]
                    [--chaos <spec>] [--retries 3] [--retry-backoff-ms 1]
                    [--queue-bound <n>] [--quarantine-errors 3]
                    [--tenant-class realtime|batch|scavenger|mixed]
                    [--chaos-arrivals <spec>] [--offered-load <mult>]
  fftsweep trace    <journal.jsonl>
  fftsweep telemetry [--gpus v100,p4 | --gpu v100 --cards 2] [--jobs 256]
                    [--backend default] [--governor boost] [--power-budget-w <W>]
                    [--seed 7] [--lengths 1024,4096] [--telemetry-out <file.json>]
                    [--prom]
  fftsweep govern   [--gpu v100] [--backend default] [--batches 96] [--seed 7]
                    [--clock 945] [--quick] [--lengths 1000,1536,16384]
                    [--conv-taps <t>] [--budget-w <W>]
  fftsweep validate [--artifacts artifacts]
  fftsweep ablation [--gpu v100] [--n 16384]
  fftsweep schedule [--gpu v100] [--n 16384] [--deadline-mult 1.5]
  fftsweep roofline [--n 8192] [--precision fp32] [--gpu v100]
  fftsweep cost     [--gpu v100] [--n 16384] [--clock 945] [--gpus 500]
  fftsweep thermal  [--gpu v100] [--n 16384] [--ambient 30]

LENGTHS: transform lengths are arbitrary (>= 1) — powers of two, smooth
non-powers of two (mixed-radix 2/3/5/4/8 plans) and prime/Bluestein
lengths all plan and serve; `serve --lengths` is admission-checked
against the routable artifact set. Past the L2-resident tier the planner
switches to the cache-blocked four-step decomposition automatically
(override the threshold with env FFTSWEEP_FFT_FOURSTEP=<n>; 0 disables).

CONV: `serve --conv-taps t` mixes FFT-domain FIR jobs into the traffic —
every fourth job filters a random real row through a routable (n, taps)
conv artifact (batched overlap-save: forward FFT → pointwise kernel
spectrum → inverse, planned once per (N, kernel)); a taps value with no
conv artifact fails loud naming the routable (n, taps) pairs.
`govern --conv-taps t` prices that traffic instead: each menu length is
replaced by the overlap-save FFT block length the conv plan actually
runs, so governors pick clocks for the filterbank's real transforms.

POWER: `serve --power-budget-w W` keeps the fleet's rolling 1s simulated
draw at or below W — an arbiter splits the cap into per-card watt shares
(proportional to offered load, with hysteresis) and each worker's
governor is capped through its budget hint. `fftsweep telemetry` replays
one seeded trace uncapped vs capped and tabulates energy/job, simulated
p50/p99 and draw; `--telemetry-out` writes the typed fleet snapshot as
JSON and `--prom` prints Prometheus text exposition.

TRACE: every served job carries a request span (enqueue → admit →
batch-seal → dispatch → exec → complete stamps plus the governor's clock
decision, batch occupancy, retries and attributed joules); completed
spans feed per-card/per-artifact latency+energy histograms exported in
the telemetry JSON and as Prometheus histogram families. `serve
--trace-out f.jsonl` streams one span per line; `fftsweep trace
f.jsonl` replays a journal into the queue/batch-wait/exec percentile
breakdown, split capped vs uncapped. `--no-trace` disables tracing
(overhead is gated <5% in the bench, so on is the default).

CHAOS: `serve --chaos spec` injects deterministic faults into the
simulated fleet: semicolon-separated `card:kind[,key=val...]` clauses
with kinds failstop (`after`), stall (`after,for,ms`), flap
(`after,period,down`) and clocklock (`after,for`), e.g.
`--chaos \"1:failstop,after=32;2:flap,period=8,down=2\"`. Failed batches
retry on another card with capped exponential backoff (`--retries`,
`--retry-backoff-ms`); cards crossing `--quarantine-errors` consecutive
errors are quarantined and probed back in; `--queue-bound` caps per-card
in-flight jobs, refusing excess submits with a typed QueueFull error.
Every accepted job terminates in a result or a typed error.

QOS: `serve --tenant-class c` tags traffic with a priority class
(realtime > batch > scavenger; `mixed` = 25% realtime / 50% batch / 25%
scavenger round-robin). `--deadline-ms` doubles as each job's end-to-end
deadline: admission sheds jobs whose predicted queue-wait + exec time
already exceeds it (typed DeadlineInfeasible) instead of completing them
late. Backpressure is class-ordered: at the `--queue-bound` a new
higher-class job evicts a queued scavenger/batch job (typed QueueFull to
the victim) before being refused itself. Sustained queue pressure climbs
a brownout ladder — clocks float to boost for realtime batches, then
scavenger and then batch admissions are shed (typed BrownoutShed) —
with hysteresis on the way down. `--chaos-arrivals kind[,key=val...]`
shapes WHEN jobs arrive: deterministic seeded `burst` (`size,quiet,seed`),
`diurnal` (`period,swing,seed`) and `adversarial` (`size,seed` — bursts
plus a scrambled length mix) generators, offered at `--offered-load`
times the fleet's estimated capacity (default 1). Every shed is a typed
error, a traced span with the reason, and a per-class/per-reason counter
in the JSON/Prometheus exports.

BACKENDS (the --backend values): `default` is the build's native backend
(the bit-exact sim runtime, or PJRT-CPU when built with `--features
xla`); `sim` / `xla` name them explicitly; `cufft-profile` replays the
paper's cuFFT kernel-sequence traces (fft only — rfft/conv jobs are
refused with a typed capability error). `fftsweep telemetry` and
`fftsweep govern` print the active backend's capability summary header.

GOVERNORS (the --governor values):
  boost        no DVFS: everything at the boost clock
  fixed:<mhz>  one locked clock (bare `fixed` reads --clock, default 945)
  optimal      per-length measured energy optimum (paper Fig 9)
  common       the paper's single mean-optimal clock (Table 3)
  deadline     lowest-energy clock that meets each batch deadline (§6.2)
  adaptive     EWMA slack feedback, descends the energy curve under slack
";

pub fn dispatch(args: &Args) -> Result<()> {
    if args.has("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "report" => cmd_report(args),
        "table" => cmd_table(args),
        "figure" => cmd_figure(args),
        "sweep" => cmd_sweep(args),
        "pipeline" => cmd_pipeline(args),
        "selftest" => cmd_selftest(args),
        "serve" => cmd_serve(args),
        "telemetry" => cmd_telemetry(args),
        "trace" => cmd_trace(args),
        "govern" => cmd_govern(args),
        "validate" => cmd_validate(args),
        "ablation" => cmd_ablation(args),
        "schedule" => cmd_schedule(args),
        "roofline" => cmd_roofline(args),
        "cost" => cmd_cost(args),
        "thermal" => cmd_thermal(args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn sweep_cfg(args: &Args) -> SweepConfig {
    let mut cfg = if args.has("quick") {
        SweepConfig::quick()
    } else {
        SweepConfig {
            lengths: paper_lengths(),
            freq_stride: 4,
            protocol: Protocol::default(),
        }
    };
    if let Some(ls) = args.get("lengths") {
        cfg.lengths = ls
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
    }
    cfg.freq_stride = args.usize_or("freq-stride", cfg.freq_stride);
    cfg
}

fn gpu_arg(args: &Args) -> Result<GpuSpec> {
    let name = args.str_or("gpu", "v100");
    gpu_by_name(name).with_context(|| format!("unknown gpu '{name}'"))
}

fn precision_arg(args: &Args) -> Result<Precision> {
    let p = args.str_or("precision", "fp32");
    Precision::parse(p).with_context(|| format!("unknown precision '{p}'"))
}

/// `--backend <name>` resolved against the `--artifacts` dir; unknown
/// names fail loud listing what this build compiled in.
fn backend_arg(args: &Args) -> Result<std::sync::Arc<dyn ExecBackend>> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let name = args.str_or("backend", "default");
    backend_by_name(name, &dir).with_context(|| {
        format!(
            "resolving --backend '{name}' (compiled in: default, {})",
            compiled_backend_names().join(", ")
        )
    })
}

/// `--governor <name>` with `fixed` (the default) reading `--clock`.
fn governor_arg(args: &Args, default: &str) -> Result<GovernorKind> {
    let name = args.str_or("governor", default);
    if name == "fixed" {
        return Ok(GovernorKind::FixedClock(args.f64_or("clock", 945.0)));
    }
    GovernorKind::parse(name)
}

fn cmd_report(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str_or("out", "results"));
    let cfg = sweep_cfg(args);
    let headlines = full_report(&out, &cfg)?;
    println!("{}", headline_table(&headlines).to_ascii());
    println!("wrote CSVs under {out:?}");
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .context("table number required (1-4)")?;
    let cfg = sweep_cfg(args);
    match which.as_str() {
        "1" => println!("{}", tables::table1().to_ascii()),
        "2" => println!("{}", tables::table2().to_ascii()),
        "3" => println!("{}", tables::table3(&cfg).to_ascii()),
        "4" => {
            let gpu = gpu_arg(args)?;
            let kind = governor_arg(args, "fixed")?;
            let n = args.u64_or("n", 500_000);
            let rows = table4(&gpu, n, &kind);
            println!(
                "Table 4: pipeline energy-efficiency increase ({}, governor {})",
                gpu.name,
                kind.label()
            );
            println!("{:>9} | {:>12} | {:>12}", "harmonics", "FFT time [%]", "eff increase");
            for r in rows {
                println!(
                    "{:>9} | {:>12} | {:>12}",
                    r.harmonics,
                    fnum(r.fft_time_pct, 2),
                    fnum(r.eff_increase, 3)
                );
            }
        }
        other => bail!("unknown table '{other}' (1-4)"),
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which: u32 = args
        .positional
        .get(1)
        .context("figure number required")?
        .parse()
        .context("figure number must be an integer")?;
    let gpu = gpu_arg(args)?;
    let precision = precision_arg(args)?;
    let cfg = sweep_cfg(args);
    let table = match which {
        2 => figures::figure2(&gpu, args.u64_or("n", 16384), args.f64_or("clock", 1020.0), 0xF16).0,
        3 => figures::figure3(&gpu, &sweep_gpu(&gpu, precision, &cfg)),
        4 => figures::figure4_5(&all_gpus(), Precision::Fp32, &cfg.lengths),
        5 => figures::figure4_5(&all_gpus(), precision, &cfg.lengths),
        6 => figures::figure6(&gpu, &sweep_gpu(&gpu, precision, &cfg)),
        7 => figures::figure7(&all_gpus(), &cfg),
        8 => figures::figure8(&gpu, &sweep_gpu(&gpu, precision, &cfg)),
        9..=14 => figures::figure9_to_14(&gpu, &sweep_gpu(&gpu, precision, &cfg)),
        15 | 16 => figures::figure15_16(&gpu, &sweep_gpu(&gpu, precision, &cfg)).1,
        17 | 18 => figures::figure17_18(&gpu, &sweep_gpu(&gpu, precision, &cfg)),
        19 => {
            let run = run_pipeline_at(&gpu, args.u64_or("n", 500_000), 8, Some(args.f64_or("clock", 945.0)));
            println!("Fig 19: pipeline stage trace ({}):", gpu.name);
            let mut t = 0.0;
            for s in &run.stages {
                println!(
                    "  t={:>8} ms  {:<14} clock={:>7} MHz  P={:>7} W  E={:>8} J",
                    fnum(t * 1e3, 2),
                    s.name,
                    fnum(s.clock_mhz, 0),
                    fnum(s.energy_j / s.time_s.max(1e-12), 1),
                    fnum(s.energy_j, 2)
                );
                t += s.time_s;
            }
            return Ok(());
        }
        20 => figures::figure20(&gpu, args.f64_or("clock", gpu.boost_clock_mhz)),
        other => bail!("figure {other} not implemented (2-20)"),
    };
    println!("{}", table.to_ascii());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let gpu = gpu_arg(args)?;
    let precision = precision_arg(args)?;
    let mut cfg = sweep_cfg(args);
    if !args.has("quick") && !args.has("lengths") {
        cfg.lengths = quick_lengths();
    }
    let sweep = sweep_gpu(&gpu, precision, &cfg);
    let pts = optima(&gpu, &sweep);
    println!("{} {} sweep:", gpu.name, precision);
    println!(
        "{:>9} | {:>9} | {:>8} | {:>9} | {:>9} | {:>9}",
        "N", "f_opt MHz", "% boost", "dT %", "Ief boost", "Ief base"
    );
    for p in &pts {
        println!(
            "{:>9} | {:>9} | {:>8} | {:>9} | {:>9} | {:>9}",
            p.n,
            fnum(p.f_opt_mhz, 0),
            fnum(p.frac_of_boost * 100.0, 1),
            fnum(p.time_increase * 100.0, 2),
            fnum(p.eff_increase_vs_boost, 3),
            fnum(p.eff_increase_vs_base, 3)
        );
    }
    let mean = fftsweep::analysis::mean_optimal_mhz(&gpu, &pts);
    println!("mean optimal: {} MHz", fnum(mean, 1));
    if let Some(paper) = tables::table3_paper_mhz(gpu.name, precision) {
        println!("paper Table 3: {} MHz", fnum(paper, 1));
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let gpu = gpu_arg(args)?;
    let n = args.u64_or("n", 500_000);
    let kind = governor_arg(args, "fixed")?;
    println!(
        "pipeline comparison on {} (N={n}, FFT governor {}):",
        gpu.name,
        kind.label()
    );
    let rows = table4(&gpu, n, &kind);
    println!("{:>9} | {:>12} | {:>12}", "harmonics", "FFT time [%]", "eff increase");
    for r in &rows {
        println!(
            "{:>9} | {:>12} | {:>12}",
            r.harmonics,
            fnum(r.fft_time_pct, 2),
            fnum(r.eff_increase, 3)
        );
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    let manifest: Vec<String> = rt.manifest().entries.keys().cloned().collect();
    println!("artifacts: {}", manifest.join(", "));

    // Validate the fp32 1024 FFT against the rust oracle.
    let meta = rt.manifest().fft(1024, "f32")?.clone();
    let module = rt.load(&meta.name)?;
    let total = (meta.batch * meta.n) as usize;
    let mut rng = Rng::new(0xA0A0);
    let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
    let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
    let out = module.run_f32(&[&re, &im])?;
    let mut max_err = 0.0f64;
    for b in 0..meta.batch as usize {
        let off = b * meta.n as usize;
        let x: Vec<dsp::C64> = (0..meta.n as usize)
            .map(|i| dsp::C64::new(re[off + i] as f64, im[off + i] as f64))
            .collect();
        let want = dsp::fft(&x);
        for i in 0..meta.n as usize {
            max_err = max_err
                .max((out[0][off + i] as f64 - want[i].re).abs())
                .max((out[1][off + i] as f64 - want[i].im).abs());
        }
    }
    println!("fft_f32_n1024 max abs err vs rust oracle: {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-2, "numerics mismatch");
    println!("selftest OK");
    Ok(())
}

/// Parse `--lengths 1000,1536,4096` strictly: a typo'd token is an error,
/// not a silently smaller menu. `Ok(None)` when the flag is absent.
fn lengths_arg(args: &Args) -> Result<Option<Vec<u64>>> {
    let Some(ls) = args.get("lengths") else {
        return Ok(None);
    };
    let menu: Vec<u64> = ls
        .split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad length '{}' in --lengths", s.trim()))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!menu.is_empty(), "--lengths parsed to an empty menu");
    Ok(Some(menu))
}

/// Fleet spec: `--gpus v100,p4,...` (heterogeneous) or `--cards N` copies
/// of `--gpu`.
fn fleet_arg(args: &Args, governor: &GovernorKind) -> Result<Vec<CardConfig>> {
    let specs: Vec<GpuSpec> = if let Some(list) = args.get("gpus") {
        list.split(',')
            .map(|name| {
                gpu_by_name(name.trim()).with_context(|| format!("unknown gpu '{name}'"))
            })
            .collect::<Result<_>>()?
    } else {
        let gpu = gpu_arg(args)?;
        vec![gpu; args.usize_or("cards", 1).max(1)]
    };
    Ok(specs
        .into_iter()
        .map(|spec| CardConfig::new(spec, governor.clone()))
        .collect())
}

/// Write/print telemetry for a finished engine run: `--telemetry-out`
/// writes the typed snapshot as JSON, `--prom` prints Prometheus text.
fn emit_telemetry(args: &Args, snapshot: &fftsweep::telemetry::FleetSnapshot) -> Result<()> {
    if let Some(path) = args.get("telemetry-out") {
        std::fs::write(path, fftsweep::telemetry::snapshot_json(snapshot).render() + "\n")
            .with_context(|| format!("writing telemetry snapshot to {path}"))?;
        println!("wrote telemetry snapshot to {path}");
    }
    if args.has("prom") {
        print!("{}", fftsweep::telemetry::prometheus_text(snapshot));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.usize_or("jobs", 256);
    let governor = governor_arg(args, "fixed")?;
    let fleet = fleet_arg(args, &governor)?;
    let n_cards = fleet.len();
    let power_budget_w = args.parse_typed::<f64>("power-budget-w")?;
    if let Some(w) = power_budget_w {
        anyhow::ensure!(w > 0.0, "--power-budget-w must be positive, got {w}");
    }
    // Chaos & recovery knobs: an injected fault schedule plus the retry,
    // queue-bound and quarantine policies that keep the fleet serving
    // through it (every accepted job resolves to a result or typed error).
    let fault_plan = match args.get("chaos") {
        Some(spec) => FaultPlan::parse(spec).context("parsing --chaos")?,
        None => FaultPlan::default(),
    };
    let mut retry = RetryPolicy::default();
    if let Some(r) = args.parse_typed::<u32>("retries")? {
        retry.max_retries = r;
    }
    if let Some(ms) = args.parse_typed::<u64>("retry-backoff-ms")? {
        retry.backoff_base = Duration::from_millis(ms.max(1));
    }
    let queue_bound = args.parse_typed::<u64>("queue-bound")?;
    if let Some(b) = queue_bound {
        anyhow::ensure!(b > 0, "--queue-bound must be positive, got {b}");
    }
    let mut health = HealthPolicy::default();
    if let Some(k) = args.parse_typed::<u32>("quarantine-errors")? {
        anyhow::ensure!(k > 0, "--quarantine-errors must be positive, got {k}");
        health.errors_to_quarantine = k;
    }
    let cfg = EngineConfig {
        governor_ctx: GovernorContext {
            deadline_s: args.parse_typed::<f64>("deadline-ms")?.map(|ms| ms * 1e-3),
            freq_stride: args.usize_or("freq-stride", 2),
            ..GovernorContext::default()
        },
        power_budget_w,
        fault_plan,
        retry,
        queue_bound,
        health,
        trace: TraceConfig {
            enabled: !args.has("no-trace"),
            jsonl_out: args.get("trace-out").map(PathBuf::from),
            ..TraceConfig::default()
        },
        ..EngineConfig::default()
    };
    let backend = backend_arg(args)?;
    let chaos_note = if cfg.fault_plan.is_empty() {
        String::new()
    } else {
        format!(", chaos {} fault(s)", cfg.fault_plan.faults.len())
    };
    println!(
        "serving on {n_cards} card(s), governor {}{}{chaos_note} (backend {}: {})",
        governor.label(),
        power_budget_w
            .map(|w| format!(", power budget {w} W"))
            .unwrap_or_default(),
        backend.name(),
        backend.platform()
    );
    let engine = Engine::start(backend, fleet, cfg)?;

    let mut rng = Rng::new(7);
    // `--lengths` restricts traffic to the given lengths; each one is
    // admission-checked against the router so a typo surfaces the typed
    // error taxonomy (with the routable set) instead of 0-job silence.
    // Pre-warm the plan cache before accepting jobs (admission check
    // included): the first batch per length pays no plan-build latency.
    // rfft artifacts of the same lengths ride along. An explicit
    // --lengths menu fails loud (a typo'd or corrupt length should stop
    // the serve); the default all-supported menu warms best-effort so one
    // bad on-disk artifact cannot take down the healthy lengths (loads
    // stay lazy per-batch for anything that failed to warm).
    let (lengths, warmed): (Vec<u64>, usize) = match lengths_arg(args)? {
        Some(menu) => {
            let warmed = engine.prewarm(&menu, "f32")?;
            (menu, warmed)
        }
        None => {
            let menu = engine.router().supported_lengths("f32");
            let mut warmed = 0usize;
            for &n in &menu {
                match engine.prewarm(&[n], "f32") {
                    Ok(w) => warmed += w,
                    Err(e) => eprintln!("warning: pre-warm of n={n} failed: {e:#}"),
                }
            }
            (menu, warmed)
        }
    };
    anyhow::ensure!(!lengths.is_empty(), "no routable lengths");
    println!(
        "plan cache pre-warmed: {warmed} artifact(s) across {} length(s)",
        lengths.len()
    );
    // `--conv-taps t` mixes FFT-domain FIR jobs into the traffic: every
    // fourth job filters a random real row through a conv artifact
    // carrying those taps. Checked up front so a taps value with no
    // routable artifact fails loud with the (n, taps) pairs that ARE
    // servable, instead of per-job rejections.
    let conv_taps = args.parse_typed::<u64>("conv-taps")?;
    let conv_lengths: Vec<u64> = match conv_taps {
        Some(t) => {
            let pairs = engine.router().supported_kernels("f32");
            let ns: Vec<u64> = pairs
                .iter()
                .filter(|&&(_, taps)| taps == t)
                .map(|&(n, _)| n)
                .collect();
            anyhow::ensure!(
                !ns.is_empty(),
                "no conv artifact with taps={t} (routable (n, taps): {pairs:?})"
            );
            ns
        }
        None => Vec::new(),
    };
    // QoS: class tagging (validated up front), the per-job deadline (the
    // same --deadline-ms the governors see), and the chaos arrival
    // schedule — materialised deterministically before the first submit.
    let tenant_class = args.str_or("tenant-class", "batch");
    anyhow::ensure!(
        tenant_class == "mixed" || TenantClass::from_label(tenant_class).is_some(),
        "--tenant-class '{tenant_class}' (realtime|batch|scavenger|mixed)"
    );
    let class_of = |j: usize| -> TenantClass {
        match tenant_class {
            // 25% realtime / 50% batch / 25% scavenger, round-robin.
            "mixed" => match j % 4 {
                0 => TenantClass::Realtime,
                3 => TenantClass::Scavenger,
                _ => TenantClass::Batch,
            },
            label => TenantClass::from_label(label).expect("validated above"),
        }
    };
    let job_deadline = args
        .parse_typed::<f64>("deadline-ms")?
        .map(|ms| Duration::from_secs_f64(ms * 1e-3));
    let arrivals: Option<Vec<Arrival>> = match args.get("chaos-arrivals") {
        Some(spec) => {
            let plan = ArrivalPlan::parse(spec).context("parsing --chaos-arrivals")?;
            // Fleet capacity from the backend's own time estimator: jobs/s
            // absorbed at boost for the first menu length, summed over
            // cards; the offered rate is --offered-load times that.
            let route = engine.router().route(lengths[0], "f32")?.clone();
            let wl = fftsweep::types::FftWorkload::new(
                route.n,
                Precision::Fp32,
                route.device_batch * route.n * Precision::Fp32.complex_bytes(),
            );
            let cap_jobs_per_s: f64 = engine
                .cards()
                .iter()
                .map(|c| {
                    route.device_batch as f64
                        / engine.backend().estimate_time_s(&c.spec, &wl).max(1e-9)
                })
                .sum();
            let mult = args.f64_or("offered-load", 1.0);
            anyhow::ensure!(mult > 0.0, "--offered-load must be positive, got {mult}");
            let rate = mult * cap_jobs_per_s;
            println!(
                "chaos arrivals: {spec} at {} jobs/s ({mult}x estimated capacity)",
                fnum(rate, 0)
            );
            Some(plan.schedule(rate, jobs as u64, lengths.len()))
        }
        None => {
            anyhow::ensure!(
                !args.has("offered-load"),
                "--offered-load needs --chaos-arrivals (closed-loop serving has no arrival rate)"
            );
            None
        }
    };
    // Under overload, admission refusals are the system WORKING: count
    // them instead of aborting the serve. Anything that is not a typed
    // shed (config errors like an unroutable length) still fails loud.
    let overload_shed = |e: &anyhow::Error| {
        matches!(
            e.downcast_ref::<CoordError>(),
            Some(
                CoordError::QueueFull { .. }
                    | CoordError::DeadlineInfeasible { .. }
                    | CoordError::BrownoutShed { .. }
                    | CoordError::RateLimited { .. }
            )
        )
    };
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut conv_jobs = 0usize;
    let mut shed = 0usize;
    for j in 0..jobs {
        let arrival = arrivals.as_ref().map(|a| a[j]);
        if let Some(a) = arrival {
            if a.gap_us > 0 {
                std::thread::sleep(Duration::from_micros(a.gap_us));
            }
        }
        if !conv_lengths.is_empty() && j % 4 == 3 {
            let n = conv_lengths[rng.below(conv_lengths.len() as u64) as usize] as usize;
            let x: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
            match engine.submit_conv(x, conv_taps.unwrap()) {
                Ok(rx) => {
                    rxs.push(rx);
                    conv_jobs += 1;
                }
                Err(e) if overload_shed(&e) => shed += 1,
                Err(e) => return Err(e),
            }
        } else {
            // Adversarial arrivals override the seeded length pick.
            let n = match arrival.and_then(|a| a.length_idx) {
                Some(idx) => lengths[idx] as usize,
                None => lengths[rng.below(lengths.len() as u64) as usize] as usize,
            };
            let re: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
            let im: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
            match engine.submit_qos(re, im, class_of(j), job_deadline) {
                Ok(rx) => rxs.push(rx),
                Err(e) if overload_shed(&e) => shed += 1,
                Err(e) => return Err(e),
            }
        }
    }
    let report = engine.drain(Duration::from_secs(120));
    if !report.complete {
        eprintln!(
            "warning: drain timed out with {} job(s) unresolved (per card: {:?})",
            report.remaining_total(),
            report.remaining
        );
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    let conv_note = if conv_jobs > 0 {
        format!(" ({conv_jobs} conv)")
    } else {
        String::new()
    };
    let shed_note = if shed > 0 {
        format!(", {shed} shed at admission")
    } else {
        String::new()
    };
    println!(
        "served {ok}/{jobs} jobs{conv_note}{shed_note} in {:.3} s",
        dt.as_secs_f64()
    );
    let snapshot = engine.snapshot();
    println!("{}", snapshot.render());
    emit_telemetry(args, &snapshot)?;
    if let Some(tr) = &snapshot.trace {
        if tr.enabled {
            println!(
                "trace: {} ok span(s), {} shed, ring holds {}",
                tr.ok_spans, tr.shed_spans, tr.ring_len
            );
        }
    }
    println!("{}", engine.shutdown());
    if let Some(path) = args.get("trace-out") {
        println!("wrote trace journal to {path}");
    }
    Ok(())
}

/// `fftsweep trace`: replay a `serve --trace-out` JSONL journal into the
/// per-percentile queue/batch-wait/exec latency+energy breakdown, split
/// capped vs uncapped when the journal holds both.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: fftsweep trace <journal.jsonl>")?;
    let spans = fftsweep::analysis::trace::load_spans(std::path::Path::new(path))?;
    anyhow::ensure!(!spans.is_empty(), "trace journal {path} holds no spans");
    println!("{}", fftsweep::analysis::trace::breakdown_table(&spans, path).to_ascii());
    Ok(())
}

/// `fftsweep telemetry`: replay one seeded job trace through an uncapped
/// and a capped fleet and tabulate what the watt ceiling costs and buys.
fn cmd_telemetry(args: &Args) -> Result<()> {
    let governor = governor_arg(args, "boost")?;
    let specs: Vec<GpuSpec> = fleet_arg(args, &governor)?
        .into_iter()
        .map(|c| c.spec)
        .collect();
    let jobs = args.usize_or("jobs", 256);
    let seed = args.u64_or("seed", 7);
    let lengths: Vec<u64> = lengths_arg(args)?.unwrap_or_else(|| vec![1024, 4096]);
    // Default cap: half the fleet's aggregate TDP — deep enough to bite
    // on any governor, feasible on every card mix.
    let budget_w = match args.parse_typed::<f64>("power-budget-w")? {
        Some(w) => {
            anyhow::ensure!(w > 0.0, "--power-budget-w must be positive, got {w}");
            w
        }
        None => 0.5 * specs.iter().map(|s| s.tdp_w).sum::<f64>(),
    };
    let backend = backend_arg(args)?;
    println!("{}", backend.capabilities().summary());
    let (stats, table) = fftsweep::analysis::telemetry::budget_comparison(
        backend, &specs, &governor, jobs, &lengths, seed, budget_w,
    )?;
    println!("{}", table.to_ascii());
    let capped = stats.last().expect("capped run present");
    for c in &capped.snapshot.cards {
        let share = c
            .power_share_w
            .map(|w| format!("{w:.0}"))
            .unwrap_or_else(|| "inf".into());
        println!(
            "  capped card{} {} [{}]: share {share} W, 1s draw {:.1} W, {} transitions",
            c.index, c.gpu, c.health, c.avg_1s_w, c.clock_transitions,
        );
    }
    emit_telemetry(args, &capped.snapshot)?;
    Ok(())
}

fn cmd_govern(args: &Args) -> Result<()> {
    let gpu = gpu_arg(args)?;
    // The governor comparison prices batches through the sim's exec
    // model, but the serving stack it stands in for is backend-scoped:
    // print which backend (and capability envelope) the comparison
    // applies to, so replayed output is attributable.
    println!("{}", backend_arg(args)?.capabilities().summary());
    let quick = args.has("quick");
    let batches = args.usize_or("batches", if quick { 24 } else { 96 });
    let seed = args.u64_or("seed", 7);
    let fixed_mhz = args
        .parse_typed::<f64>("clock")?
        .or_else(|| tables::table3_paper_mhz(gpu.name, Precision::Fp32))
        .unwrap_or(gpu.f_knee_mhz);
    let budget_w = args.parse_typed::<f64>("budget-w")?;
    if let Some(w) = budget_w {
        anyhow::ensure!(w > 0.0, "--budget-w must be positive, got {w}");
    }
    let ctx = GovernorContext {
        freq_stride: args.usize_or("freq-stride", if quick { 8 } else { 2 }),
        power_budget_w: budget_w,
        ..GovernorContext::default()
    };
    let mut menu =
        lengths_arg(args)?.unwrap_or_else(|| govern::DEFAULT_TRACE_MENU.to_vec());
    // `--conv-taps t` prices filterbank traffic: each menu length n maps
    // to the overlap-save FFT block length the conv plan runs for
    // (n, t), so governors choose clocks for the transforms the conv
    // workload actually executes rather than the nominal signal length.
    if let Some(taps) = args.parse_typed::<u64>("conv-taps")? {
        anyhow::ensure!(taps >= 1, "--conv-taps must be >= 1, got {taps}");
        for n in &mut menu {
            anyhow::ensure!(
                taps <= *n,
                "--conv-taps {taps} exceeds trace length {n} (kernel must fit the signal)"
            );
            *n = dsp::planner::conv_block_len(*n as usize, taps as usize) as u64;
        }
        menu.sort_unstable();
        menu.dedup();
    }
    let trace = govern::synthetic_trace_with_menu(&gpu, batches, seed, &menu);
    let kinds = GovernorKind::all(fixed_mhz);
    let (outcomes, table) = govern::comparison(&gpu, &trace, &kinds, &ctx);
    println!("{}", table.to_ascii());
    for o in &outcomes {
        if !o.all_deadlines_met() {
            println!(
                "note: {} missed {} deadline(s) — static policies cannot see per-batch slack",
                o.label,
                o.batches - o.deadlines_met
            );
        }
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let n = fftsweep::runtime::validation::validate_dir(&dir)?;
    println!("{n} artifacts validated OK (digests, HLO text, no elided constants)");
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let gpu = gpu_arg(args)?;
    let n = args.u64_or("n", 16384);
    println!("{}", fftsweep::analysis::ablation::ablation_table(&gpu, n).to_ascii());
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    use fftsweep::governor::choose_clock;
    use fftsweep::sim::run_batch;
    use fftsweep::types::FftWorkload;
    let gpu = gpu_arg(args)?;
    let n = args.u64_or("n", 16384);
    let mult = args.f64_or("deadline-mult", 1.5);
    let w = FftWorkload::new(n, precision_arg(args)?, gpu.working_set_bytes);
    let boost_t = run_batch(&gpu, &w, gpu.boost_clock_mhz).timing.total_s;
    let c = choose_clock(&gpu, &w, boost_t * mult, 2)?;
    println!(
        "{} N={n}: deadline {:.3} ms ({}x boost time)\n  chose {} MHz: {:.3} ms ({:.0}% slack), energy {:.0}% of boost",
        gpu.name,
        boost_t * mult * 1e3,
        mult,
        fnum(c.f_mhz, 0),
        c.time_s * 1e3,
        c.slack * 100.0,
        c.energy_vs_boost * 100.0
    );
    Ok(())
}

fn cmd_roofline(args: &Args) -> Result<()> {
    use fftsweep::analysis::roofline::{estimate_fft_kernel, max_tile_b, tpu_v4};
    let n = args.u64_or("n", 8192);
    let precision = precision_arg(args)?;
    let target = tpu_v4();
    let tile = args.u64_or("tile-b", 16);
    let e = estimate_fft_kernel(tile, n, precision, &target);
    println!("Pallas fft_c2c BlockSpec estimate on {} (tile_b={tile}, N={n}, {precision}):", target.name);
    println!("  VMEM: {} KiB ({:.2}% of budget)", e.vmem_bytes / 1024, e.vmem_frac * 100.0);
    println!("  HBM per grid step: {} KiB", e.hbm_bytes / 1024);
    println!("  VPU ops per grid step: {}", e.vpu_ops);
    println!("  intensity {:.2} ops/byte → {}", e.intensity, if e.hbm_bound { "HBM-bound" } else { "VPU-bound (→ MXU formulation on real TPUs)" });
    println!("  roofline time per step: {:.2} µs", e.t_roofline_s * 1e6);
    println!("  max tile_b at 50% VMEM: {}", max_tile_b(n, precision, &target, 0.5));

    // GPU-side plan roofline: what the governors' regime rule sees for
    // this length on the chosen card (DESIGN.md §4g).
    let gpu = gpu_arg(args)?;
    let pr = fftsweep::analysis::roofline::classify_plan(&gpu, n, precision);
    println!("GPU plan roofline on {} (N={n}, {precision}):", gpu.name);
    println!(
        "  algorithm {:?}: {} radix-2-equivalent stages in {} pass(es), {} KiB moved",
        pr.algorithm,
        fnum(pr.radix2_stages, 1),
        pr.passes,
        pr.bytes_moved / 1024
    );
    println!(
        "  t_compute {:.3} µs vs t_memory {:.3} µs → {:?}",
        pr.t_compute_s * 1e6,
        pr.t_memory_s * 1e6,
        pr.regime
    );
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    use fftsweep::analysis::cost::{cost_table, Deployment};
    use fftsweep::types::FftWorkload;
    let gpu = gpu_arg(args)?;
    let w = FftWorkload::new(args.u64_or("n", 16384), precision_arg(args)?, gpu.working_set_bytes);
    let mut dep = Deployment::default();
    dep.gpus = args.u64_or("gpus", dep.gpus);
    dep.duty_cycle = args.f64_or("duty", dep.duty_cycle);
    dep.price_per_kwh = args.f64_or("price", dep.price_per_kwh);
    dep.co2_kg_per_kwh = args.f64_or("co2", dep.co2_kg_per_kwh);
    println!("{}", cost_table(&gpu, &w, args.f64_or("clock", 945.0), &dep).to_ascii());
    Ok(())
}

fn cmd_thermal(args: &Args) -> Result<()> {
    use fftsweep::sim::thermal::{steady_state, ThermalParams};
    use fftsweep::types::FftWorkload;
    let gpu = gpu_arg(args)?;
    let w = FftWorkload::new(args.u64_or("n", 16384), precision_arg(args)?, gpu.working_set_bytes);
    let mut params = ThermalParams::default();
    params.t_ambient_c = args.f64_or("ambient", params.t_ambient_c);
    println!("sustained operation, {} at {:.0}°C ambient:", gpu.name, params.t_ambient_c);
    for f in [gpu.boost_clock_mhz, args.f64_or("clock", 945.0)] {
        let s = steady_state(&gpu, &w, f, &params);
        println!(
            "  {:>7} MHz: {:>5}°C, {:>6} W{}  (sustained throughput {:.2}x)",
            fnum(f, 0),
            fnum(s.temperature_c, 1),
            fnum(s.power_w, 1),
            if s.throttled { ", THROTTLED" } else { "" },
            s.sustained_throughput
        );
    }
    Ok(())
}

#[allow(dead_code)]
fn unused_manifest_helper(m: &Manifest) -> usize {
    m.entries.len()
}
