//! Job types flowing through the coordinator.

use std::sync::mpsc;
use std::time::Duration;

use crate::coordinator::admission::TenantClass;
use crate::telemetry::Stamps;

/// A single C2C FFT request: one transform of length `n` (re/im planes).
#[derive(Debug, Clone)]
pub struct FftJob {
    pub id: u64,
    pub n: u64,
    pub dtype: &'static str,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
    /// Retries consumed so far (0 on first admission). The retry
    /// supervisor bumps this each time a failed batch's job is re-routed,
    /// and sheds the job with [`CoordError::RetriesExhausted`] once it
    /// passes the policy cap.
    pub attempts: u32,
    /// QoS class the job was admitted under (backpressure evicts lower
    /// classes first; the brownout ladder sheds them first).
    pub class: TenantClass,
    /// Optional end-to-end deadline: admission sheds the job with a
    /// typed `DeadlineInfeasible` when predicted queue-wait + exec time
    /// already exceeds it.
    pub deadline: Option<Duration>,
}

impl FftJob {
    pub fn new(id: u64, re: Vec<f32>, im: Vec<f32>) -> Self {
        assert_eq!(re.len(), im.len(), "re/im plane mismatch");
        Self {
            id,
            n: re.len() as u64,
            dtype: "f32",
            re,
            im,
            attempts: 0,
            class: TenantClass::default(),
            deadline: None,
        }
    }

    /// Builder: tag the job with a QoS class.
    pub fn with_class(mut self, class: TenantClass) -> Self {
        self.class = class;
        self
    }

    /// Builder: attach an end-to-end deadline for admission feasibility.
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }
}

/// The result of one job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub out_re: Vec<f32>,
    pub out_im: Vec<f32>,
    /// Wall-clock microseconds the batch execution took (shared across the
    /// jobs batched together).
    pub exec_us: u64,
    /// Simulated on-card batch time at the governed clock, s — the
    /// latency a capped/governed clock actually costs (wall-clock here is
    /// host compute and does not move with the simulated DVFS setting).
    pub sim_batch_s: f64,
    /// How many jobs shared the executed batch.
    pub batch_occupancy: usize,
}

/// A job paired with its reply channel and its trace stamps.
pub struct Envelope {
    pub job: FftJob,
    pub reply: mpsc::Sender<anyhow::Result<JobResult>>,
    /// Stage timestamps the coordinator fills as the job moves through
    /// admit → batch-seal → dispatch (see `telemetry::trace`).
    pub stamps: Stamps,
}

impl Envelope {
    /// Wrap a job at submit time: all stamps start at "now".
    pub fn new(job: FftJob, reply: mpsc::Sender<anyhow::Result<JobResult>>) -> Self {
        Self {
            job,
            reply,
            stamps: Stamps::now(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_records_length() {
        let j = FftJob::new(7, vec![0.0; 256], vec![0.0; 256]);
        assert_eq!(j.n, 256);
        assert_eq!(j.dtype, "f32");
        assert_eq!(j.attempts, 0, "fresh jobs have consumed no retries");
        assert_eq!(j.class, TenantClass::Batch, "default QoS class is batch");
        assert!(j.deadline.is_none(), "no deadline unless asked for");
    }

    #[test]
    fn qos_builders_tag_class_and_deadline() {
        let j = FftJob::new(1, vec![0.0; 8], vec![0.0; 8])
            .with_class(TenantClass::Realtime)
            .with_deadline(Some(Duration::from_millis(20)));
        assert_eq!(j.class, TenantClass::Realtime);
        assert_eq!(j.deadline, Some(Duration::from_millis(20)));
    }

    #[test]
    #[should_panic(expected = "plane mismatch")]
    fn mismatched_planes_rejected() {
        FftJob::new(0, vec![0.0; 4], vec![0.0; 8]);
    }
}
