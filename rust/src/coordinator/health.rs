//! Per-card health tracking for the serving fleet.
//!
//! Workers feed the [`HealthMonitor`] batch outcomes (ok / error /
//! stalled / clock-lock fault) and the engine's supervisor thread ticks
//! it for probe re-admission. Each card walks a three-state machine:
//!
//! ```text
//!   Healthy --batch error/stall/clock fault--> Degraded
//!   Degraded --N consecutive errors----------> Quarantined
//!   Degraded --M consecutive successes-------> Healthy
//!   Quarantined --cooldown elapsed (probe)---> Degraded
//! ```
//!
//! Quarantined cards are excluded from routing entirely; Degraded cards
//! stay in rotation but carry a virtual load penalty and a clock derate
//! (applied through the same cap machinery the power-budget arbiter
//! uses). Each re-quarantine doubles the probe cooldown (capped), so a
//! hard-failed card costs a geometrically shrinking probe rate instead
//! of a steady stream of doomed batches. Every transition is recorded
//! with a reason and surfaced through `FleetSnapshot`.

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// The three health states, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    Degraded,
    Quarantined,
}

impl HealthState {
    /// Stable lowercase label for snapshots / JSON / the telemetry table.
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Quarantined => "quarantined",
        }
    }

    /// Numeric code for the Prometheus gauge (0/1/2).
    pub fn code(self) -> f64 {
        match self {
            HealthState::Healthy => 0.0,
            HealthState::Degraded => 1.0,
            HealthState::Quarantined => 2.0,
        }
    }
}

/// Thresholds and penalties for the state machine. The defaults are
/// tuned for the sim fleet's millisecond-scale batches; `serve` exposes
/// the quarantine threshold and probe cooldown as CLI knobs.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Consecutive batch errors that quarantine a card.
    pub errors_to_quarantine: u32,
    /// Consecutive stalled batches that degrade a card.
    pub stalls_to_degrade: u32,
    /// Consecutive successes that promote Degraded back to Healthy.
    pub successes_to_recover: u32,
    /// Base quarantine cooldown before a probe re-admit.
    pub probe_cooldown: Duration,
    /// Ceiling for the doubling cooldown.
    pub probe_cooldown_cap: Duration,
    /// Virtual jobs added to a Degraded card's load when routing.
    pub degraded_load_penalty: u64,
    /// Clock ceiling for Degraded cards, as a fraction of boost.
    pub degraded_clock_frac: f64,
    /// Heartbeat staleness (with work in flight) that counts as a stall.
    pub stall_after: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            errors_to_quarantine: 3,
            stalls_to_degrade: 2,
            successes_to_recover: 8,
            probe_cooldown: Duration::from_millis(50),
            probe_cooldown_cap: Duration::from_secs(2),
            degraded_load_penalty: 8,
            degraded_clock_frac: 0.7,
            stall_after: Duration::from_secs(1),
        }
    }
}

/// One recorded state change, with the observation that caused it.
#[derive(Debug, Clone)]
pub struct HealthTransition {
    pub card: usize,
    pub from: HealthState,
    pub to: HealthState,
    pub reason: String,
}

#[derive(Debug)]
struct CardHealth {
    state: HealthState,
    consecutive_errors: u32,
    consecutive_successes: u32,
    consecutive_stalls: u32,
    quarantined_at: Option<Instant>,
    cooldown: Duration,
    transitions: u64,
}

/// Shared fleet health state: one mutexed record per card plus the
/// transition log. All locks recover from poisoning — a panicking
/// worker must not take the health plane down with it.
pub struct HealthMonitor {
    policy: HealthPolicy,
    cards: Vec<Mutex<CardHealth>>,
    log: Mutex<Vec<HealthTransition>>,
}

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl HealthMonitor {
    pub fn new(policy: HealthPolicy, n_cards: usize) -> Self {
        let base = policy.probe_cooldown;
        Self {
            policy,
            cards: (0..n_cards)
                .map(|_| {
                    Mutex::new(CardHealth {
                        state: HealthState::Healthy,
                        consecutive_errors: 0,
                        consecutive_successes: 0,
                        consecutive_stalls: 0,
                        quarantined_at: None,
                        cooldown: base,
                        transitions: 0,
                    })
                })
                .collect(),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn policy(&self) -> &HealthPolicy {
        &self.policy
    }

    pub fn n_cards(&self) -> usize {
        self.cards.len()
    }

    pub fn state(&self, card: usize) -> HealthState {
        relock(&self.cards[card]).state
    }

    /// Routable at all? Quarantined cards are excluded from dispatch.
    pub fn eligible(&self, card: usize) -> bool {
        self.state(card) != HealthState::Quarantined
    }

    /// Virtual load added to this card when picking the least-loaded.
    pub fn load_penalty(&self, card: usize) -> u64 {
        match self.state(card) {
            HealthState::Degraded => self.policy.degraded_load_penalty,
            _ => 0,
        }
    }

    /// Clock ceiling fraction (of boost) while the card is Degraded.
    pub fn clock_frac(&self, card: usize) -> Option<f64> {
        match self.state(card) {
            HealthState::Degraded => Some(self.policy.degraded_clock_frac),
            _ => None,
        }
    }

    /// A batch on `card` completed cleanly.
    pub fn on_batch_ok(&self, card: usize) {
        let mut c = relock(&self.cards[card]);
        c.consecutive_errors = 0;
        c.consecutive_stalls = 0;
        if c.state == HealthState::Degraded {
            c.consecutive_successes += 1;
            if c.consecutive_successes >= self.policy.successes_to_recover {
                c.cooldown = self.policy.probe_cooldown;
                self.set_state(card, &mut c, HealthState::Healthy, "recovered");
            }
        }
    }

    /// A batch on `card` errored (injected or genuine).
    pub fn on_batch_error(&self, card: usize) {
        let mut c = relock(&self.cards[card]);
        c.consecutive_successes = 0;
        c.consecutive_errors += 1;
        match c.state {
            HealthState::Quarantined => {}
            _ if c.consecutive_errors >= self.policy.errors_to_quarantine => {
                c.quarantined_at = Some(Instant::now());
                let reason = format!("{} consecutive batch errors", c.consecutive_errors);
                self.set_state(card, &mut c, HealthState::Quarantined, &reason);
            }
            HealthState::Healthy => {
                self.set_state(card, &mut c, HealthState::Degraded, "batch error");
            }
            HealthState::Degraded => {}
        }
    }

    /// A batch on `card` took pathologically long (injected stall or a
    /// stale heartbeat with work in flight).
    pub fn on_stall(&self, card: usize) {
        let mut c = relock(&self.cards[card]);
        c.consecutive_successes = 0;
        c.consecutive_stalls += 1;
        if c.state == HealthState::Healthy && c.consecutive_stalls >= self.policy.stalls_to_degrade
        {
            self.set_state(card, &mut c, HealthState::Degraded, "stalled batches");
        }
    }

    /// `set_gpu_locked_clocks` failed on `card`: clock control is gone,
    /// so degrade (the card still computes, just unmanaged).
    pub fn on_clock_fault(&self, card: usize) {
        let mut c = relock(&self.cards[card]);
        c.consecutive_successes = 0;
        if c.state == HealthState::Healthy {
            self.set_state(card, &mut c, HealthState::Degraded, "clock-lock error");
        }
    }

    /// Probe re-admission: a quarantined card whose cooldown has elapsed
    /// re-enters rotation as Degraded (on probation). The next quarantine
    /// doubles the cooldown, capped by the policy. Returns true if the
    /// card was re-admitted by this call.
    pub fn maybe_readmit(&self, card: usize) -> bool {
        let mut c = relock(&self.cards[card]);
        if c.state != HealthState::Quarantined {
            return false;
        }
        let elapsed_ok = c
            .quarantined_at
            .map(|t| t.elapsed() >= c.cooldown)
            .unwrap_or(true);
        if !elapsed_ok {
            return false;
        }
        c.cooldown = (c.cooldown * 2).min(self.policy.probe_cooldown_cap);
        c.consecutive_errors = 0;
        c.consecutive_successes = 0;
        self.set_state(card, &mut c, HealthState::Degraded, "probe re-admit");
        true
    }

    /// Run probe re-admission across the fleet (the supervisor's tick).
    pub fn tick(&self) {
        for card in 0..self.cards.len() {
            self.maybe_readmit(card);
        }
    }

    /// Total transitions recorded for `card`.
    pub fn transition_count(&self, card: usize) -> u64 {
        relock(&self.cards[card]).transitions
    }

    /// Snapshot of the full transition log.
    pub fn transitions(&self) -> Vec<HealthTransition> {
        relock(&self.log).clone()
    }

    /// Number of cards currently quarantined.
    pub fn quarantined_count(&self) -> u64 {
        (0..self.cards.len())
            .filter(|&i| self.state(i) == HealthState::Quarantined)
            .count() as u64
    }

    fn set_state(&self, card: usize, c: &mut CardHealth, to: HealthState, reason: &str) {
        let from = c.state;
        if from == to {
            return;
        }
        c.state = to;
        c.transitions += 1;
        relock(&self.log).push(HealthTransition {
            card,
            from,
            to,
            reason: reason.to_string(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_policy() -> HealthPolicy {
        HealthPolicy {
            errors_to_quarantine: 3,
            stalls_to_degrade: 2,
            successes_to_recover: 2,
            probe_cooldown: Duration::from_millis(5),
            probe_cooldown_cap: Duration::from_millis(20),
            ..HealthPolicy::default()
        }
    }

    #[test]
    fn errors_escalate_to_quarantine() {
        let m = HealthMonitor::new(fast_policy(), 2);
        assert_eq!(m.state(0), HealthState::Healthy);
        m.on_batch_error(0);
        assert_eq!(m.state(0), HealthState::Degraded, "first error degrades");
        assert_eq!(m.load_penalty(0), m.policy().degraded_load_penalty);
        assert_eq!(m.clock_frac(0), Some(m.policy().degraded_clock_frac));
        m.on_batch_error(0);
        assert_eq!(m.state(0), HealthState::Degraded);
        m.on_batch_error(0);
        assert_eq!(m.state(0), HealthState::Quarantined, "third consecutive error");
        assert!(!m.eligible(0));
        assert!(m.eligible(1), "other card untouched");
        assert_eq!(m.quarantined_count(), 1);
        let log = m.transitions();
        assert_eq!(log.len(), 2);
        assert_eq!(log[1].to, HealthState::Quarantined);
        assert_eq!(m.transition_count(0), 2);
    }

    #[test]
    fn successes_between_errors_reset_the_streak() {
        let m = HealthMonitor::new(fast_policy(), 1);
        m.on_batch_error(0);
        m.on_batch_error(0);
        m.on_batch_ok(0);
        m.on_batch_error(0);
        m.on_batch_error(0);
        assert_eq!(m.state(0), HealthState::Degraded, "streak was broken");
    }

    #[test]
    fn degraded_recovers_after_consecutive_successes() {
        let m = HealthMonitor::new(fast_policy(), 1);
        m.on_batch_error(0);
        assert_eq!(m.state(0), HealthState::Degraded);
        m.on_batch_ok(0);
        m.on_batch_ok(0);
        assert_eq!(m.state(0), HealthState::Healthy);
        assert_eq!(m.load_penalty(0), 0);
        assert_eq!(m.clock_frac(0), None);
    }

    #[test]
    fn probe_readmit_after_cooldown_then_requarantine_doubles() {
        let m = HealthMonitor::new(fast_policy(), 1);
        for _ in 0..3 {
            m.on_batch_error(0);
        }
        assert_eq!(m.state(0), HealthState::Quarantined);
        assert!(!m.maybe_readmit(0), "cooldown not elapsed yet");
        std::thread::sleep(Duration::from_millis(8));
        assert!(m.maybe_readmit(0));
        assert_eq!(m.state(0), HealthState::Degraded, "probation");
        // the probe fails: errors re-quarantine with a doubled cooldown
        for _ in 0..3 {
            m.on_batch_error(0);
        }
        assert_eq!(m.state(0), HealthState::Quarantined);
        std::thread::sleep(Duration::from_millis(8));
        assert!(!m.maybe_readmit(0), "doubled cooldown (10ms) not elapsed");
        std::thread::sleep(Duration::from_millis(6));
        m.tick();
        assert_eq!(m.state(0), HealthState::Degraded, "tick re-admits");
        let kinds: Vec<&str> = m.transitions().iter().map(|t| t.reason.as_str()).collect();
        assert!(kinds.contains(&"probe re-admit"));
    }

    #[test]
    fn stalls_and_clock_faults_degrade_only() {
        let m = HealthMonitor::new(fast_policy(), 2);
        m.on_stall(0);
        assert_eq!(m.state(0), HealthState::Healthy, "one stall tolerated");
        m.on_stall(0);
        assert_eq!(m.state(0), HealthState::Degraded);
        for _ in 0..10 {
            m.on_stall(0);
        }
        assert_eq!(m.state(0), HealthState::Degraded, "stalls never quarantine");
        m.on_clock_fault(1);
        assert_eq!(m.state(1), HealthState::Degraded);
    }
}
