//! The L3 coordinator: an FFT-serving engine in the vLLM-router shape.
//!
//! Requests (single transforms) are routed to the artifact that serves
//! their (length, dtype), packed by the dynamic batcher into the artifact's
//! fixed device batch, executed on worker threads through the PJRT runtime,
//! and split back per request. A simulated NVML clock controller accounts
//! the DVFS energy saving of every executed batch — the serving-loop
//! integration of the paper's result (section 5.3).
//!
//! No tokio in the offline crate set: std threads + mpsc channels.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod router;

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, PackedBatch};
use crate::coordinator::job::{Envelope, FftJob, JobResult};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::pipeline::nvml::SimNvml;
use crate::runtime::Runtime;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub workers: usize,
    pub max_batch_wait: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch_wait: Duration::from_millis(2),
        }
    }
}

/// The serving engine.
pub struct Engine {
    runtime: Arc<Runtime>,
    router: Router,
    batcher: Arc<Mutex<Batcher>>,
    batch_tx: mpsc::Sender<PackedBatch>,
    pub metrics: Arc<Metrics>,
    /// Simulated DVFS controller for the energy accounting.
    pub nvml: Arc<SimNvml>,
    sim_gpu: GpuSpec,
    workers: Vec<JoinHandle<()>>,
    flusher: Option<JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Start the engine: spawns worker threads and the batch-timeout flusher.
    pub fn start(runtime: Arc<Runtime>, sim_gpu: GpuSpec, cfg: EngineConfig) -> Result<Self> {
        let router = Router::from_manifest(runtime.manifest());
        anyhow::ensure!(!router.is_empty(), "no fft artifacts in manifest");
        let batcher = Arc::new(Mutex::new(Batcher::new(cfg.max_batch_wait)));
        let metrics = Arc::new(Metrics::default());
        let nvml = Arc::new(SimNvml::new(&sim_gpu));
        let (batch_tx, batch_rx) = mpsc::channel::<PackedBatch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let mut workers = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let rt = runtime.clone();
            let m = metrics.clone();
            let nv = nvml.clone();
            let gpu = sim_gpu.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fftsweep-worker-{w}"))
                    .spawn(move || worker_loop(rx, rt, m, nv, gpu))?,
            );
        }

        // Timeout flusher: emits partial batches so low request rates are
        // never starved.
        let flusher = {
            let batcher = batcher.clone();
            let tx = batch_tx.clone();
            let stop = shutdown.clone();
            let tick = cfg.max_batch_wait.max(Duration::from_micros(500)) / 2;
            Some(std::thread::Builder::new().name("fftsweep-flusher".into()).spawn(
                move || {
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        for b in batcher.lock().unwrap().flush(false) {
                            let _ = tx.send(b);
                        }
                    }
                },
            )?)
        };

        Ok(Self {
            runtime,
            router,
            batcher,
            batch_tx,
            metrics,
            nvml,
            sim_gpu,
            workers,
            flusher,
            shutdown,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit one transform; returns the receiver for its result.
    pub fn submit(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<JobResult>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = FftJob::new(id, re, im);
        let route = self.router.route(job.n, job.dtype)?.clone();
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let env = Envelope { job, reply: tx };
        let full = {
            let mut b = self.batcher.lock().unwrap();
            b.push(&route.artifact, route.n, route.device_batch, env)
        };
        if let Some(batch) = full {
            let _ = self.batch_tx.send(batch);
        }
        Ok(rx)
    }

    /// Force-flush pending partial batches (used before blocking waits).
    pub fn flush(&self) {
        for b in self.batcher.lock().unwrap().flush(true) {
            let _ = self.batch_tx.send(b);
        }
    }

    /// Submit-and-wait convenience.
    pub fn execute(&self, re: Vec<f32>, im: Vec<f32>) -> Result<JobResult> {
        let rx = self.submit(re, im)?;
        self.flush();
        let result = rx.recv()??;
        Ok(result)
    }

    /// Wait until every submitted job completed (or `timeout`).
    pub fn drain(&self, timeout: Duration) -> bool {
        self.flush();
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            let sub = self.metrics.jobs_submitted.load(Ordering::Relaxed);
            let done = self.metrics.jobs_completed.load(Ordering::Relaxed)
                + self.metrics.jobs_failed.load(Ordering::Relaxed);
            if done >= sub {
                return true;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        false
    }

    /// Stop workers and flusher.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.flush();
        drop(self.batch_tx);
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn sim_gpu(&self) -> &GpuSpec {
        &self.sim_gpu
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<PackedBatch>>>,
    runtime: Arc<Runtime>,
    metrics: Arc<Metrics>,
    nvml: Arc<SimNvml>,
    gpu: GpuSpec,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // channel closed: shutdown
            }
        };
        let occupancy = batch.occupancy();
        let rows_total = batch.device_batch;
        let t0 = Instant::now();
        let result = runtime
            .load(&batch.artifact)
            .and_then(|m| {
                let (re, im) = batch.planes();
                m.run_f32(&[&re, &im])
            });
        let exec_us = t0.elapsed().as_micros() as u64;
        metrics.record_batch(occupancy, rows_total, exec_us);

        // DVFS energy accounting: what this batch would cost on the
        // simulated GPU at the locked clock vs at boost.
        let w = FftWorkload::new(
            batch.n,
            Precision::Fp32,
            batch.device_batch * batch.n * Precision::Fp32.complex_bytes(),
        );
        let locked = nvml.current_clock_mhz();
        let e_locked = crate::sim::run_batch(&gpu, &w, locked).energy_j;
        let e_boost = crate::sim::run_batch(&gpu, &w, gpu.boost_clock_mhz).energy_j;
        metrics.record_energy(e_locked, e_boost);

        match result {
            Ok(outputs) => {
                let out_re = &outputs[0];
                let out_im = &outputs[1];
                let n = batch.n as usize;
                for (i, env) in batch.envelopes.into_iter().enumerate() {
                    let off = i * n;
                    let res = JobResult {
                        id: env.job.id,
                        out_re: out_re[off..off + n].to_vec(),
                        out_im: out_im[off..off + n].to_vec(),
                        exec_us,
                        batch_occupancy: occupancy,
                    };
                    metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(Ok(res));
                }
            }
            Err(e) => {
                for env in batch.envelopes {
                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(Err(anyhow::anyhow!("{e:#}")));
                }
            }
        }
    }
}
