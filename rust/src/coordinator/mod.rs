//! The L3 coordinator: an FFT-serving fleet engine in the vLLM-router shape.
//!
//! Requests (single transforms) are routed to the artifact that serves
//! their (length, dtype), dispatched least-loaded across N simulated cards
//! (heterogeneous specs allowed), packed by the dynamic batcher into the
//! artifact's fixed device batch per card, executed on per-card worker
//! threads through the [`ExecBackend`] the engine was started with, and
//! split back per request.
//!
//! Every worker owns its own simulated NVML handle and its own
//! [`crate::governor::ClockGovernor`] instance: the governor picks the
//! clock each batch runs at, the simulator prices the batch at that clock
//! vs boost, and [`Metrics`] accounts energy/latency/occupancy per card
//! and fleet-wide — the serving-loop integration of the paper's DVFS
//! result (section 5.3) generalized to swappable clock policies.
//!
//! No tokio in the offline crate set: std threads + mpsc channels.
//!
//! Fault tolerance (DESIGN.md §4f): workers feed a per-card
//! [`HealthMonitor`] with batch outcomes, a supervisor thread retries
//! failed batches' jobs with capped exponential backoff onto healthy
//! cards, quarantined cards leave the routing set until a probe
//! re-admits them, and the engine invariant is that **every accepted
//! job terminates in a `JobResult` or a typed error** under any
//! injected [`FaultPlan`] schedule.

pub mod admission;
pub mod batcher;
pub mod health;
pub mod job;
pub mod metrics;
pub mod router;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::admission::{
    AdmissionController, AdmissionPolicy, AdmitDecision, ShedReason, TenantClass,
};
use crate::coordinator::batcher::{Batcher, PackedBatch};
use crate::coordinator::health::{HealthMonitor, HealthPolicy, HealthTransition};
use crate::coordinator::job::{Envelope, FftJob, JobResult};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::governor::{BatchFeedback, ClockGovernor, GovernorContext, GovernorKind};
use crate::pipeline::nvml::{ClockState, SimNvml};
use crate::runtime::{ExecBackend, ExecModule, IntoBackend};
use crate::sim::fault::{FaultPlan, FaultState};
use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::telemetry::{
    budget_key, clock_cap_for_budget, share_bounds_w, CardSnapshot, FleetSnapshot, PowerBudget,
    PowerRecorder, RecorderConfig, ShareCell, Span, SpanOutcome, Stamps, TraceConfig, Tracer,
};
use crate::types::{FftWorkload, Precision};
use crate::util::rng::Rng;

/// The serving error taxonomy: every way a job can be refused admission,
/// as a typed error callers can match on (downcastable from the
/// `anyhow::Error` that `submit`/`execute` surface). Jobs are rejected at
/// submit time — an unsupported length never reaches a worker thread,
/// so it can never surface as a worker panic.
#[derive(Debug, thiserror::Error)]
pub enum CoordError {
    /// No artifact in the manifest serves this (length, dtype).
    #[error("no artifact serves n={n} dtype={dtype} (supported: {supported:?})")]
    UnsupportedLength {
        n: u64,
        dtype: String,
        supported: Vec<u64>,
    },
    /// The transform length has no execution-plan support at all
    /// (the planner serves every n >= 1, so this means n = 0 or a
    /// corrupt manifest entry).
    #[error("transform length {n} has no plan support")]
    PlanUnsupported { n: u64 },
    /// A job reached a batch slot packing a different length
    /// (route/artifact mismatch — the slot is left intact).
    #[error("batcher: artifact '{artifact}' packs n={expected}, got a job with n={got}")]
    LengthMismatch {
        artifact: String,
        expected: u64,
        got: u64,
    },
    /// No conv artifact serves this (signal length, kernel taps) pair —
    /// or the tap count itself is invalid (zero, or longer than the
    /// signal). Names the routable kernels so callers can self-correct.
    #[error("no conv artifact serves n={n} taps={taps} (supported (n, taps): {supported:?})")]
    UnsupportedKernel {
        n: u64,
        taps: u64,
        supported: Vec<(u64, u64)>,
    },
    /// No card can take the job right now: the engine is shutting down,
    /// every card is draining, or the whole fleet is quarantined.
    #[error("no card available: {reason}")]
    CardUnavailable { reason: String },
    /// The job failed on every attempt the retry policy allows; it is
    /// shed with the count of retries burned.
    #[error("job {id} (n={n}): retries exhausted after {attempts} retries")]
    RetriesExhausted { id: u64, n: u64, attempts: u32 },
    /// Backpressure: every eligible card already has `bound` or more
    /// jobs in flight (`inflight` is the least-loaded card's depth).
    #[error("queue full: card {card} has {inflight} jobs in flight (bound {bound})")]
    QueueFull { card: usize, inflight: u64, bound: u64 },
    /// Admission shed: the predicted queue-wait + exec time on the least
    /// loaded card already exceeds the job's deadline — completing it
    /// would only burn joules on a result nobody can use (the SKA power
    /// argument applied to overload).
    #[error(
        "job {id} (n={n}, class {class}): deadline {deadline_ms:.3} ms infeasible \
         (predicted {predicted_ms:.3} ms)"
    )]
    DeadlineInfeasible {
        id: u64,
        n: u64,
        class: &'static str,
        deadline_ms: f64,
        predicted_ms: f64,
    },
    /// The brownout ladder is shedding this class under sustained
    /// overload (level 2 sheds scavenger, level 3 sheds batch; realtime
    /// is never brownout-shed).
    #[error("brownout level {level}: class {class} admissions are shed")]
    BrownoutShed { class: &'static str, level: u8 },
    /// The class is over its token-bucket admission rate.
    #[error("class {class} is over its admission rate limit")]
    RateLimited { class: &'static str },
}

impl CoordError {
    /// The short reason string stamped into a shed span's `reason` field
    /// (`scripts/check_trace.py` requires it to be non-empty on every
    /// shed outcome).
    pub fn shed_reason(&self) -> &'static str {
        match self {
            CoordError::UnsupportedLength { .. } => "unsupported length",
            CoordError::PlanUnsupported { .. } => "plan unsupported",
            CoordError::LengthMismatch { .. } => "length mismatch",
            CoordError::UnsupportedKernel { .. } => "unsupported kernel",
            CoordError::CardUnavailable { .. } => "no card available",
            CoordError::RetriesExhausted { .. } => "retries exhausted",
            CoordError::QueueFull { .. } => "queue full",
            CoordError::DeadlineInfeasible { .. } => ShedReason::DeadlineInfeasible.label(),
            CoordError::BrownoutShed { .. } => ShedReason::BrownoutShed.label(),
            CoordError::RateLimited { .. } => ShedReason::RateLimited.label(),
        }
    }
}

/// Recover a mutex guard even if a previous holder panicked: the data a
/// poisoned coordinator mutex protects (batch slots, counters) stays
/// structurally valid, and limping on beats aborting the whole engine.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Retry policy for jobs whose batch failed: capped exponential backoff,
/// then a typed shed.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Re-dispatch attempts per job after its first failure.
    pub max_retries: u32,
    /// Backoff before retry k is `backoff_base * 2^(k-1)`, capped below.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Deterministic jitter: retry k actually sleeps
    /// `backoff_for(k) * (1 + U[0, jitter_frac))`, so a cohort of jobs
    /// that failed together de-synchronizes instead of re-spiking the
    /// recovering card in lockstep. 0.0 disables jitter (the exact
    /// capped-exponential schedule).
    pub jitter_frac: f64,
    /// Seed for the supervisor's jitter stream — fixed so every run of a
    /// given fault schedule replays the same retry timing.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 3,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(50),
            jitter_frac: 0.5,
            jitter_seed: 0x5EED_BACC_0FF5,
        }
    }
}

impl RetryPolicy {
    fn backoff_for(&self, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(1).min(16);
        (self.backoff_base * (1u32 << exp)).min(self.backoff_cap)
    }

    /// The capped exponential backoff with the deterministic jitter
    /// applied: uniform in `[backoff, backoff * (1 + jitter_frac))`,
    /// drawn from the caller's seeded stream.
    pub fn jittered_backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let base = self.backoff_for(attempt);
        if self.jitter_frac <= 0.0 || base.is_zero() {
            return base;
        }
        base.mul_f64(1.0 + rng.f64() * self.jitter_frac)
    }
}

/// What [`Engine::drain`] observed: whether every accepted job reached a
/// terminal state, and how many were still in flight per card when the
/// call returned (all zeros on a complete drain).
#[derive(Debug, Clone)]
pub struct DrainReport {
    pub complete: bool,
    pub remaining: Vec<u64>,
}

impl DrainReport {
    pub fn remaining_total(&self) -> u64 {
        self.remaining.iter().sum()
    }
}

/// One card in the fleet: a simulated GPU plus the clock policy governing it.
#[derive(Debug, Clone)]
pub struct CardConfig {
    pub spec: GpuSpec,
    pub governor: GovernorKind,
}

impl CardConfig {
    pub fn new(spec: GpuSpec, governor: GovernorKind) -> Self {
        Self { spec, governor }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch_wait: Duration,
    /// Deadline/stride/tolerance knobs threaded to every governor.
    pub governor_ctx: GovernorContext,
    /// Global fleet watt ceiling (`serve --power-budget-w`); `None` runs
    /// uncapped. When set, the arbiter thread periodically redistributes
    /// per-card shares proportional to offered load and every worker caps
    /// its governor through the `GovernorContext` budget hint.
    pub power_budget_w: Option<f64>,
    /// How often the arbiter recomputes shares.
    pub arbiter_period: Duration,
    /// Per-card telemetry recorder sizing.
    pub recorder: RecorderConfig,
    /// Injected-fault schedule (`serve --chaos`); empty = no faults.
    pub fault_plan: FaultPlan,
    /// Health state-machine thresholds and penalties.
    pub health: HealthPolicy,
    /// Retry/backoff policy for jobs whose batch failed.
    pub retry: RetryPolicy,
    /// Per-card in-flight bound; submits are refused with a typed
    /// [`CoordError::QueueFull`] once every eligible card is at the
    /// bound. `None` = unbounded (the pre-robustness behavior).
    pub queue_bound: Option<u64>,
    /// Per-job request tracing (span ring, latency/energy histograms,
    /// optional JSONL journal via `serve --trace-out`). On by default;
    /// the bench `observability` section gates its overhead at <5%.
    pub trace: TraceConfig,
    /// QoS admission policy: per-class token buckets, deadline
    /// feasibility, and the brownout ladder (DESIGN.md §4i). The default
    /// is fully permissive, so pre-QoS behaviour is unchanged unless an
    /// operator opts in.
    pub admission: AdmissionPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch_wait: Duration::from_millis(2),
            governor_ctx: GovernorContext::default(),
            power_budget_w: None,
            arbiter_period: Duration::from_millis(20),
            recorder: RecorderConfig::default(),
            fault_plan: FaultPlan::default(),
            health: HealthPolicy::default(),
            retry: RetryPolicy::default(),
            queue_bound: None,
            trace: TraceConfig::default(),
            admission: AdmissionPolicy::default(),
        }
    }
}

/// Runtime state of one fleet card, exposed for inspection.
pub struct Card {
    pub spec: GpuSpec,
    pub governor_label: String,
    /// The card's simulated NVML handle (clock-lock trace inspection).
    pub nvml: Arc<SimNvml>,
    /// Per-card serving metrics.
    pub metrics: Arc<Metrics>,
    /// Per-card power telemetry (draw series, cumulative joules,
    /// per-length attribution, deadline misses).
    pub recorder: Arc<PowerRecorder>,
    /// The arbiter's current watt share for this card.
    share: Arc<ShareCell>,
    /// Jobs routed to this card and not yet completed.
    inflight: Arc<AtomicU64>,
    /// Routable? Cleared by [`Engine::drain_card`], restored by
    /// [`Engine::readmit_card`].
    accepting: Arc<AtomicBool>,
    /// Worker heartbeat: ms since engine start at the last batch start.
    beat: Arc<AtomicU64>,
}

impl Card {
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The card's current watt share (`None` = uncapped).
    pub fn power_share_w(&self) -> Option<f64> {
        self.share.get()
    }

    /// False while the card is drained out of the routing set.
    pub fn is_accepting(&self) -> bool {
        self.accepting.load(Ordering::Relaxed)
    }
}

/// The serving engine: a fleet of N governed cards behind one router.
pub struct Engine {
    backend: Arc<dyn ExecBackend>,
    router: Router,
    batcher: Arc<Mutex<Batcher>>,
    cards: Vec<Card>,
    batch_txs: Vec<mpsc::Sender<PackedBatch>>,
    /// Fleet-aggregate metrics (every card also records its own).
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    flusher: Option<JoinHandle<()>>,
    arbiter: Option<JoinHandle<()>>,
    supervisor: Option<JoinHandle<()>>,
    /// The engine's own clone of the retry channel (workers hold the
    /// others); dropped at shutdown so the channel can disconnect.
    retry_tx: Option<mpsc::Sender<FailedJob>>,
    health: Arc<HealthMonitor>,
    tracer: Arc<Tracer>,
    admission: Arc<AdmissionController>,
    power_budget_w: Option<f64>,
    queue_bound: Option<u64>,
    shutdown: Arc<AtomicBool>,
    next_id: AtomicU64,
}

/// Stamp every member job's `dispatch` trace time and hand the batch to
/// its card's channel — the single chokepoint every dispatch site
/// (enqueue, flushes, the timeout flusher, retry re-dispatch) goes
/// through, so no span can miss its dispatch stamp.
fn send_batch(tx: &mpsc::Sender<PackedBatch>, mut batch: PackedBatch) {
    let now = Instant::now();
    for env in &mut batch.envelopes {
        env.stamps.dispatch = now;
    }
    let _ = tx.send(batch);
}

impl Engine {
    /// Start a fleet: one worker thread per card, each owning its own
    /// `SimNvml` and governor instance, plus the batch-timeout flusher.
    pub fn start(backend: impl IntoBackend, fleet: Vec<CardConfig>, cfg: EngineConfig) -> Result<Self> {
        let backend = backend.into_backend();
        anyhow::ensure!(!fleet.is_empty(), "fleet needs at least one card");
        let router = Router::from_manifest(backend.manifest());
        anyhow::ensure!(!router.is_empty(), "no fft artifacts in manifest");
        let batcher = Arc::new(Mutex::new(Batcher::new(
            cfg.max_batch_wait,
            backend.capabilities(),
        )));
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let health = Arc::new(HealthMonitor::new(cfg.health.clone(), fleet.len()));
        let (retry_tx, retry_rx) = mpsc::channel::<FailedJob>();
        let epoch = Instant::now();
        let tracer = Arc::new(Tracer::new(&cfg.trace, fleet.len(), epoch)?);
        let admission = Arc::new(AdmissionController::new(cfg.admission.clone()));

        // Initial watt shares: an even split of the cap (clamped to each
        // card's physical bounds) BEFORE any worker starts, so a capped
        // fleet is capped from its very first batch — the arbiter then
        // refines shares toward offered load.
        let n_cards = fleet.len();
        let initial_share = |spec: &GpuSpec| -> Arc<ShareCell> {
            match cfg.power_budget_w {
                Some(total) => {
                    let (floor, ceil) = share_bounds_w(spec);
                    Arc::new(ShareCell::with_share(
                        (total / n_cards as f64).clamp(floor, ceil),
                    ))
                }
                None => Arc::new(ShareCell::unlimited()),
            }
        };

        let mut cards = Vec::new();
        let mut batch_txs = Vec::new();
        let mut workers = Vec::new();
        for (i, cc) in fleet.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PackedBatch>();
            let card_metrics = Arc::new(Metrics::default());
            let nvml = Arc::new(SimNvml::new(&cc.spec));
            let inflight = Arc::new(AtomicU64::new(0));
            let recorder = Arc::new(PowerRecorder::new(
                crate::sim::power::idle_power_w(&cc.spec),
                cfg.recorder.clone(),
            ));
            let share = initial_share(&cc.spec);
            let governor = cc.governor.make();
            let accepting = Arc::new(AtomicBool::new(true));
            let beat = Arc::new(AtomicU64::new(0));
            let fault = FaultState::for_card(&cfg.fault_plan, i);
            let worker = WorkerState {
                gpu: cc.spec.clone(),
                card: i,
                backend: backend.clone(),
                fleet_metrics: metrics.clone(),
                card_metrics: card_metrics.clone(),
                nvml: nvml.clone(),
                inflight: inflight.clone(),
                recorder: recorder.clone(),
                share: share.clone(),
                ctx: cfg.governor_ctx.clone(),
                health: health.clone(),
                retry_tx: retry_tx.clone(),
                beat: beat.clone(),
                epoch,
                tracer: tracer.clone(),
                admission: admission.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fftsweep-card-{i}"))
                    .spawn(move || worker_loop(rx, worker, governor, fault))?,
            );
            cards.push(Card {
                spec: cc.spec,
                governor_label: cc.governor.label(),
                nvml,
                metrics: card_metrics,
                recorder,
                share,
                inflight,
                accepting,
                beat,
            });
            batch_txs.push(tx);
        }

        // Timeout flusher: emits partial batches so low request rates are
        // never starved. The tick is capped so shutdown() never waits a
        // full max_batch_wait for the flusher to notice the stop flag.
        let flusher = {
            let batcher = batcher.clone();
            let txs = batch_txs.clone();
            let stop = shutdown.clone();
            let tick = (cfg.max_batch_wait / 2).clamp(
                Duration::from_micros(500),
                Duration::from_millis(50),
            );
            Some(std::thread::Builder::new().name("fftsweep-flusher".into()).spawn(
                move || {
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        for b in lock_recover(&batcher).flush(false) {
                            send_batch(&txs[b.card], b);
                        }
                    }
                },
            )?)
        };

        // Power-budget arbiter: periodically resplit the global cap into
        // per-card shares proportional to offered load, with deadband
        // hysteresis so quiet load wobble never moves shares — and
        // therefore never moves clocks. Offered load = inflight jobs
        // (routed, not yet completed) + still-queued partial-batch jobs:
        // the queued subset counts twice on purpose, pulling watts toward
        // cards with backlog so they can clock up and drain it.
        let arbiter = if let Some(total_w) = cfg.power_budget_w {
            let policy = PowerBudget::new(total_w);
            let period = cfg.arbiter_period.max(Duration::from_millis(1));
            let stop = shutdown.clone();
            let batcher = batcher.clone();
            let shares: Vec<Arc<ShareCell>> = cards.iter().map(|c| c.share.clone()).collect();
            let inflights: Vec<Arc<AtomicU64>> =
                cards.iter().map(|c| c.inflight.clone()).collect();
            let bounds: Vec<(f64, f64)> = cards.iter().map(|c| share_bounds_w(&c.spec)).collect();
            Some(
                std::thread::Builder::new()
                    .name("fftsweep-power-arbiter".into())
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            std::thread::sleep(period);
                            let loads: Vec<f64> = {
                                let b = lock_recover(&batcher);
                                inflights
                                    .iter()
                                    .enumerate()
                                    .map(|(i, inf)| {
                                        inf.load(Ordering::Relaxed) as f64
                                            + b.pending_jobs_for_card(i) as f64
                                    })
                                    .collect()
                            };
                            let prev: Vec<Option<f64>> =
                                shares.iter().map(|s| s.get()).collect();
                            for (cell, share) in shares
                                .iter()
                                .zip(policy.redistribute(&loads, &bounds, &prev))
                            {
                                cell.set(Some(share));
                            }
                        }
                    })?,
            )
        } else {
            None
        };

        // Retry supervisor: receives every failed batch's envelopes from
        // the workers, re-dispatches them (capped exponential backoff,
        // health-aware card choice) or sheds them typed, detects stalled
        // workers via heartbeats, and drives quarantine probe re-admits.
        let supervisor = {
            let state = SupervisorState {
                stop: shutdown.clone(),
                health: health.clone(),
                batcher: batcher.clone(),
                txs: batch_txs.clone(),
                inflights: cards.iter().map(|c| c.inflight.clone()).collect(),
                acceptings: cards.iter().map(|c| c.accepting.clone()).collect(),
                card_metrics: cards.iter().map(|c| c.metrics.clone()).collect(),
                fleet_metrics: metrics.clone(),
                retry: cfg.retry.clone(),
                beats: cards.iter().map(|c| c.beat.clone()).collect(),
                epoch,
                tracer: tracer.clone(),
                admission: admission.clone(),
                queue_bound: cfg.queue_bound,
            };
            Some(
                std::thread::Builder::new()
                    .name("fftsweep-supervisor".into())
                    .spawn(move || supervisor_loop(state, retry_rx))?,
            )
        };

        Ok(Self {
            backend,
            router,
            batcher,
            cards,
            batch_txs,
            metrics,
            workers,
            flusher,
            arbiter,
            supervisor,
            retry_tx: Some(retry_tx),
            health,
            tracer,
            admission,
            power_budget_w: cfg.power_budget_w,
            queue_bound: cfg.queue_bound,
            shutdown,
            next_id: AtomicU64::new(1),
        })
    }

    /// Single-card convenience (the pre-fleet call shape).
    pub fn start_single(
        backend: impl IntoBackend,
        spec: GpuSpec,
        governor: GovernorKind,
        cfg: EngineConfig,
    ) -> Result<Self> {
        Self::start(backend, vec![CardConfig::new(spec, governor)], cfg)
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn backend(&self) -> &Arc<dyn ExecBackend> {
        &self.backend
    }

    pub fn cards(&self) -> &[Card] {
        &self.cards
    }

    /// Submit one transform; returns the receiver for its result.
    /// Equivalent to [`Engine::submit_qos`] at the default (batch) class
    /// with no deadline.
    pub fn submit(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<JobResult>>> {
        self.submit_routed(re, im).map(|(rx, ..)| rx)
    }

    /// Submit one transform under a QoS class with an optional end-to-end
    /// deadline. Admission may refuse it typed: `RateLimited` and
    /// `BrownoutShed` at the class gate, `QueueFull` from backpressure
    /// (unless a lower-class queued job can be evicted to make room),
    /// `DeadlineInfeasible` when the predicted queue-wait + exec time
    /// already exceeds the deadline.
    pub fn submit_qos(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
        class: TenantClass,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<JobResult>>> {
        self.submit_routed_qos(re, im, class, deadline).map(|(rx, ..)| rx)
    }

    /// Submit, also reporting where the job was packed and whether the
    /// push already dispatched a full batch — `execute` uses this to flush
    /// only its own (artifact, card) slot, and only when needed.
    #[allow(clippy::type_complexity)]
    fn submit_routed(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<(mpsc::Receiver<Result<JobResult>>, Arc<str>, usize, bool)> {
        self.submit_routed_qos(re, im, TenantClass::default(), None)
    }

    #[allow(clippy::type_complexity)]
    fn submit_routed_qos(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
        class: TenantClass,
        deadline: Option<Duration>,
    ) -> Result<(mpsc::Receiver<Result<JobResult>>, Arc<str>, usize, bool)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = FftJob::new(id, re, im).with_class(class).with_deadline(deadline);
        let route = self.router.route(job.n, job.dtype)?.clone();
        self.enqueue(job, route)
    }

    /// Submit one filterbank row (real samples) against the conv artifact
    /// serving (len, taps); returns the receiver for its result. The
    /// filtered row comes back in `out_re` (`out_im` is all zeros — the
    /// workload is real-to-real).
    pub fn submit_conv(
        &self,
        x: Vec<f32>,
        taps: u64,
    ) -> Result<mpsc::Receiver<Result<JobResult>>> {
        self.submit_conv_routed(x, taps).map(|(rx, ..)| rx)
    }

    #[allow(clippy::type_complexity)]
    fn submit_conv_routed(
        &self,
        x: Vec<f32>,
        taps: u64,
    ) -> Result<(mpsc::Receiver<Result<JobResult>>, Arc<str>, usize, bool)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let n = x.len();
        // The imaginary plane rides along zeroed: conv batches pack
        // through the same (artifact, card) slots as fft batches, and the
        // worker ignores the plane for conv kinds.
        let job = FftJob::new(id, x, vec![0.0; n]);
        let route = self.router.route_conv(job.n, taps, job.dtype)?.clone();
        self.enqueue(job, route)
    }

    /// Health-aware card choice for a new submit: quarantined and
    /// draining cards are excluded, degraded cards carry a virtual load
    /// penalty, and (when a queue bound is set) cards at their in-flight
    /// bound are skipped. Typed errors, never a panic: an empty or fully
    /// unavailable fleet is [`CoordError::CardUnavailable`], a fleet
    /// that is only *full* is [`CoordError::QueueFull`].
    fn pick_card(&self) -> Result<usize, CoordError> {
        let loads: Vec<u64> = self
            .cards
            .iter()
            .enumerate()
            .map(|(i, c)| c.inflight() + self.health.load_penalty(i))
            .collect();
        let eligible: Vec<bool> = self
            .cards
            .iter()
            .enumerate()
            .map(|(i, c)| c.is_accepting() && self.health.eligible(i))
            .collect();
        let routable = Router::least_loaded_among(&loads, &eligible).ok_or_else(|| {
            CoordError::CardUnavailable {
                reason: if self.cards.is_empty() {
                    "fleet is empty".into()
                } else {
                    "every card is draining or quarantined".into()
                },
            }
        })?;
        if let Some(bound) = self.queue_bound {
            let open: Vec<bool> = eligible
                .iter()
                .enumerate()
                .map(|(i, &e)| e && self.cards[i].inflight() < bound)
                .collect();
            return Router::least_loaded_among(&loads, &open).ok_or(CoordError::QueueFull {
                card: routable,
                inflight: self.cards[routable].inflight(),
                bound,
            });
        }
        Ok(routable)
    }

    /// One zero-width shed span for a job refused (or evicted) before it
    /// could occupy a card: exec is pinned to "now", energy and occupancy
    /// are zero, and the reason + class ride along — the invariants
    /// `scripts/check_trace.py` enforces on shed outcomes. No accounting
    /// happens here; admission refusals were never accepted.
    #[allow(clippy::too_many_arguments)]
    fn record_shed_span(
        &self,
        job_id: u64,
        artifact: &str,
        n: u64,
        card: usize,
        stamps: Option<&Stamps>,
        attempts: u32,
        class: TenantClass,
        reason: ShedReason,
    ) {
        if !self.tracer.enabled() {
            return;
        }
        let now = Instant::now();
        let (enq, adm, seal, disp) = match stamps {
            Some(st) => (st.enqueue, st.admit, st.seal, st.dispatch),
            None => (now, now, now, now),
        };
        self.tracer.record(Span {
            job_id,
            artifact: artifact.to_string(),
            n,
            card,
            enqueue_us: self.tracer.micros(enq),
            admit_us: self.tracer.micros(adm),
            seal_us: self.tracer.micros(seal),
            dispatch_us: self.tracer.micros(disp),
            exec_start_us: self.tracer.micros(now),
            exec_end_us: self.tracer.micros(now),
            complete_us: self.tracer.micros(now),
            requested_mhz: 0.0,
            granted_mhz: 0.0,
            batch_occupancy: 0,
            attempts,
            energy_j: 0.0,
            sim_batch_s: 0.0,
            outcome: SpanOutcome::Shed,
            class: class.label().to_string(),
            reason: reason.label().to_string(),
        });
    }

    /// Class-ordered backpressure: a full card sheds one queued job that
    /// `job.class` strictly outranks (scavenger before batch; realtime is
    /// never evicted) so the higher class gets the slot. The victim gets
    /// the full shed treatment — accounting closed, typed `QueueFull`
    /// reply, traced span with the eviction reason. Returns true when
    /// room was made.
    fn evict_for(&self, job: &FftJob, card: usize) -> bool {
        let victim = lock_recover(&self.batcher).evict_lower_class(card, job.class);
        let Some((artifact, victim)) = victim else {
            return false;
        };
        self.cards[card].inflight.fetch_sub(1, Ordering::Relaxed);
        self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
        self.cards[card].metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        self.cards[card].metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
        self.admission.record_eviction();
        self.record_shed_span(
            victim.job.id,
            &artifact,
            victim.job.n,
            card,
            Some(&victim.stamps),
            victim.job.attempts,
            victim.job.class,
            ShedReason::Evicted,
        );
        let _ = victim.reply.send(Err(CoordError::QueueFull {
            card,
            inflight: self.cards[card].inflight(),
            bound: self.queue_bound.unwrap_or(0),
        }
        .into()));
        true
    }

    /// Route-independent tail of submission: QoS admission (class gates,
    /// class-ordered backpressure, deadline feasibility), health-aware
    /// least-loaded dispatch, accounting, and the batcher push (shared by
    /// fft and conv jobs). Refused typed — never queued on a dead
    /// channel — once shutdown has begun. Every admission refusal
    /// happens BEFORE accounting, so `jobs_submitted` only ever counts
    /// accepted work.
    #[allow(clippy::type_complexity)]
    fn enqueue(
        &self,
        job: FftJob,
        route: router::RouteEntry,
    ) -> Result<(mpsc::Receiver<Result<JobResult>>, Arc<str>, usize, bool)> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(CoordError::CardUnavailable {
                reason: "engine is shutting down".into(),
            }
            .into());
        }
        // Class gates first (brownout rung, token bucket): the cheapest
        // checks, and no card state is touched yet.
        if let AdmitDecision::Shed(reason) =
            self.admission.admit_class(job.class, Instant::now())
        {
            self.record_shed_span(
                job.id, &route.artifact, job.n, 0, None, job.attempts, job.class, reason,
            );
            let class = job.class.label();
            let err = match reason {
                ShedReason::BrownoutShed => CoordError::BrownoutShed {
                    class,
                    level: self.admission.brownout.level(),
                },
                _ => CoordError::RateLimited { class },
            };
            return Err(err.into());
        }
        // Card choice, with class-ordered backpressure: when every open
        // card is at its bound, try to evict one queued lower-class job
        // from the least-loaded routable card before refusing.
        let card = match self.pick_card() {
            Ok(c) => c,
            Err(CoordError::QueueFull { card, .. }) if self.evict_for(&job, card) => card,
            Err(e) => return Err(e.into()),
        };
        // Deadline feasibility on the routed card: predicted queue-wait +
        // exec time from the backend's own estimator vs the deadline.
        if let Some(deadline) = job.deadline {
            let workload = FftWorkload::new(
                route.n,
                Precision::Fp32,
                route.device_batch * route.n * Precision::Fp32.complex_bytes(),
            );
            let est = self.backend.estimate_time_s(&self.cards[card].spec, &workload);
            let predicted = AdmissionController::predicted_s(
                est,
                self.cards[card].inflight(),
                route.device_batch,
            );
            if let AdmitDecision::Shed(reason) =
                self.admission.feasible(deadline.as_secs_f64(), predicted)
            {
                self.record_shed_span(
                    job.id, &route.artifact, job.n, card, None, job.attempts, job.class, reason,
                );
                return Err(CoordError::DeadlineInfeasible {
                    id: job.id,
                    n: job.n,
                    class: job.class.label(),
                    deadline_ms: deadline.as_secs_f64() * 1e3,
                    predicted_ms: predicted * 1e3,
                }
                .into());
            }
        }
        self.admission.record_admit(job.class);
        self.cards[card].inflight.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.cards[card].metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);

        let (tx, rx) = mpsc::channel();
        let mut env = Envelope::new(job, tx);
        // Routing succeeded and accounting is done: the job is admitted.
        env.stamps.admit = Instant::now();
        let pushed = {
            let mut b = lock_recover(&self.batcher);
            b.push(&route.artifact, route.n, route.device_batch, card, env)
        };
        let mut dispatched_full = false;
        match pushed {
            Ok(Some(batch)) => {
                send_batch(&self.batch_txs[card], batch);
                dispatched_full = true;
            }
            Ok(None) => {}
            Err(e) => {
                // The job never entered a batch: undo its accounting so
                // drain()/occupancy stay truthful, then surface the error.
                self.cards[card].inflight.fetch_sub(1, Ordering::Relaxed);
                self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                self.cards[card].metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        }
        Ok((rx, route.artifact, card, dispatched_full))
    }

    /// Force-flush ALL pending partial batches, fleet-wide (drain/shutdown
    /// path — prefer `flush_slot` for per-request nudging).
    pub fn flush(&self) {
        for b in lock_recover(&self.batcher).flush(true) {
            send_batch(&self.batch_txs[b.card], b);
        }
    }

    /// Flush only one (artifact, card) slot, leaving unrelated partial
    /// batches to keep packing toward full occupancy.
    pub fn flush_slot(&self, artifact: &Arc<str>, card: usize) {
        let batch = lock_recover(&self.batcher).flush_slot(artifact, card);
        if let Some(b) = batch {
            send_batch(&self.batch_txs[b.card], b);
        }
    }

    /// Submit-and-wait convenience. Only the caller's own (artifact, card)
    /// slot is flushed: concurrent traffic on other artifacts/cards keeps
    /// batching instead of being force-flushed fleet-wide per call.
    pub fn execute(&self, re: Vec<f32>, im: Vec<f32>) -> Result<JobResult> {
        let (rx, artifact, card, dispatched_full) = self.submit_routed(re, im)?;
        // If the push completed a full batch, the job is already on its
        // way — flushing would only release someone else's fresh partial.
        if !dispatched_full {
            self.flush_slot(&artifact, card);
        }
        let result = rx.recv()??;
        Ok(result)
    }

    /// Submit-and-wait for one filterbank row (see [`Engine::submit_conv`]).
    pub fn execute_conv(&self, x: Vec<f32>, taps: u64) -> Result<JobResult> {
        let (rx, artifact, card, dispatched_full) = self.submit_conv_routed(x, taps)?;
        if !dispatched_full {
            self.flush_slot(&artifact, card);
        }
        let result = rx.recv()??;
        Ok(result)
    }

    /// Wait until every accepted job reached a terminal state (result,
    /// failure, or typed shed) — or `timeout`. The report carries the
    /// per-card in-flight counts at return; on timeout they are also
    /// logged so a stuck card is identifiable from the console.
    pub fn drain(&self, timeout: Duration) -> DrainReport {
        self.flush();
        let t0 = Instant::now();
        loop {
            let sub = self.metrics.jobs_submitted.load(Ordering::Relaxed);
            let done = self.metrics.jobs_completed.load(Ordering::Relaxed)
                + self.metrics.jobs_failed.load(Ordering::Relaxed);
            if done >= sub {
                return DrainReport {
                    complete: true,
                    remaining: self.cards.iter().map(|c| c.inflight()).collect(),
                };
            }
            if t0.elapsed() >= timeout {
                let remaining: Vec<u64> = self.cards.iter().map(|c| c.inflight()).collect();
                eprintln!(
                    "engine drain timed out after {timeout:?}: {} of {sub} jobs unresolved \
                     (in flight per card: {remaining:?})",
                    sub - done
                );
                return DrainReport {
                    complete: false,
                    remaining,
                };
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Gracefully drain one card: stop routing to it, flush its pending
    /// batch slots to its worker, and wait (up to `timeout`) for its
    /// in-flight jobs to resolve. No accepted job is dropped — jobs
    /// already packed for the card still execute (or fail into the retry
    /// path). Returns the jobs still in flight on the card at return
    /// (0 = fully quiesced). The card stays out of the routing set until
    /// [`Engine::readmit_card`].
    pub fn drain_card(&self, idx: usize, timeout: Duration) -> u64 {
        self.cards[idx].accepting.store(false, Ordering::Relaxed);
        for b in lock_recover(&self.batcher).flush_card(idx) {
            send_batch(&self.batch_txs[b.card], b);
        }
        let t0 = Instant::now();
        while self.cards[idx].inflight() > 0 && t0.elapsed() < timeout {
            std::thread::sleep(Duration::from_micros(200));
        }
        let remaining = self.cards[idx].inflight();
        if remaining > 0 {
            eprintln!(
                "card {idx} drain timed out after {timeout:?}: {remaining} jobs still in flight"
            );
        }
        remaining
    }

    /// Return a drained card to the routing set.
    pub fn readmit_card(&self, idx: usize) {
        self.cards[idx].accepting.store(true, Ordering::Relaxed);
    }

    /// Refuse all further submits (typed [`CoordError::CardUnavailable`])
    /// without joining any thread: accepted work keeps executing and can
    /// still be drained, and the eventual [`Engine::shutdown`] call does
    /// the joins. This is what makes submit-after-shutdown fail fast
    /// instead of hanging on a dead worker channel.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }

    /// The fleet health monitor (state queries, transition log).
    pub fn health(&self) -> &HealthMonitor {
        &self.health
    }

    /// Full health transition log (quarantines, probe re-admits, ...).
    pub fn health_transitions(&self) -> Vec<HealthTransition> {
        self.health.transitions()
    }

    /// The operator's global watt ceiling (`None` = uncapped).
    pub fn power_budget_w(&self) -> Option<f64> {
        self.power_budget_w
    }

    /// The fleet's request tracer: span ring, latency/energy histograms,
    /// optional JSONL journal.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The QoS admission controller (per-class stats, shed counters, the
    /// brownout ladder).
    pub fn admission(&self) -> &AdmissionController {
        &self.admission
    }

    /// Pre-warm the plan cache for an admissible length menu before
    /// accepting traffic: route each length, load (and thereby
    /// plan-compile) its artifact, and ride along any `rfft` and `conv`
    /// artifacts of the same lengths (conv loads also build their cached
    /// kernel spectrum). Loads land in the runtime's shared module cache,
    /// so the first batch per length on every card skips both the
    /// `runtime.load` and the plan-build latency. Returns the number of
    /// artifacts warmed; an unroutable length surfaces the usual typed
    /// [`CoordError::UnsupportedLength`].
    pub fn prewarm(&self, lengths: &[u64], dtype: &str) -> Result<usize> {
        let mut warmed = 0usize;
        for &n in lengths {
            let route = self.router.route(n, dtype)?.clone();
            self.backend.load(&route.artifact)?;
            warmed += 1;
        }
        for kind in ["rfft", "conv"] {
            for meta in self.backend.manifest().of_kind(kind) {
                if lengths.contains(&meta.n) && meta.dtype == dtype {
                    self.backend.load(&meta.name)?;
                    warmed += 1;
                }
            }
        }
        Ok(warmed)
    }

    /// Typed fleet state: per-card serving counters + power telemetry
    /// plus the fleet aggregate — what the exporters, benches and tests
    /// consume (the report string is [`FleetSnapshot::render`] on top).
    pub fn snapshot(&self) -> FleetSnapshot {
        let cards = self
            .cards
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let m = &c.metrics;
                CardSnapshot {
                    index: i,
                    gpu: c.spec.name.to_string(),
                    governor: c.governor_label.clone(),
                    jobs_submitted: m.jobs_submitted.load(Ordering::Relaxed),
                    jobs_completed: m.jobs_completed.load(Ordering::Relaxed),
                    jobs_failed: m.jobs_failed.load(Ordering::Relaxed),
                    batches: m.batches_executed.load(Ordering::Relaxed),
                    occupancy: m.occupancy(),
                    exec_s: m.exec_us_total.load(Ordering::Relaxed) as f64 / 1e6,
                    energy_j: m.energy_j(),
                    boost_energy_j: m.boost_energy_j(),
                    energy_saving: m.energy_saving(),
                    clock_transitions: c.nvml.transition_count() as u64,
                    current_clock_mhz: c.nvml.current_clock_mhz(),
                    instant_w: c.recorder.instant_w(),
                    avg_1s_w: c.recorder.avg_short_w(),
                    avg_10s_w: c.recorder.avg_long_w(),
                    busy_s: c.recorder.busy_s(),
                    energy_per_job_j: c.recorder.energy_per_job_j(),
                    deadline_misses: c.recorder.deadline_misses(),
                    power_share_w: c.share.get(),
                    inflight: c.inflight(),
                    health: self.health.state(i).label().to_string(),
                    health_transitions: self.health.transition_count(i),
                    jobs_retried: m.jobs_retried.load(Ordering::Relaxed),
                    jobs_shed: m.jobs_shed.load(Ordering::Relaxed),
                    batch_errors: m.batch_errors.load(Ordering::Relaxed),
                    accepting: c.is_accepting(),
                }
            })
            .collect();
        let mut snap = FleetSnapshot::from_cards(cards, self.power_budget_w);
        snap.trace = Some(self.tracer.summary());
        let stats = &self.admission.stats;
        snap.overload = Some(crate::telemetry::OverloadSnapshot {
            brownout_level: self.admission.brownout.level(),
            brownout_max_level: self.admission.brownout.max_level_seen(),
            brownout_escalations: self.admission.brownout.escalations(),
            admitted: std::array::from_fn(|i| stats.admitted[i].load(Ordering::Relaxed)),
            deadline_sheds: stats.deadline_sheds.load(Ordering::Relaxed),
            brownout_sheds: stats.brownout_sheds.load(Ordering::Relaxed),
            rate_limited: stats.rate_limited.load(Ordering::Relaxed),
            evictions: stats.evictions.load(Ordering::Relaxed),
        });
        snap
    }

    /// Per-card + fleet-aggregate report (the snapshot, rendered).
    pub fn fleet_report(&self) -> String {
        self.snapshot().render()
    }

    /// Stop the fleet deterministically: refuse new submits, flush, join
    /// the flusher / arbiter / retry supervisor (which sheds any retries
    /// still waiting on backoff with a typed error), close every card
    /// channel, join every worker. Batch failures during the final queue
    /// drain are terminally failed by the workers themselves (the
    /// supervisor is gone), so every accepted job still gets a reply.
    /// Returns the final fleet summary line (all counters quiescent once
    /// this returns).
    pub fn shutdown(mut self) -> String {
        self.begin_shutdown();
        self.flush();
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        if let Some(a) = self.arbiter.take() {
            let _ = a.join();
        }
        // The supervisor exits on the stop flag after shedding pending
        // retries; it must be joined BEFORE the card channels close, as
        // it holds clones of the batch senders.
        self.retry_tx.take();
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        // Dropping every sender closes each card's channel; workers drain
        // what was already queued and then exit.
        self.batch_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Every recorder is quiescent now: flush the trace journal so a
        // `--trace-out` file is complete the moment shutdown returns.
        self.tracer.flush();
        format!("final fleet: {}", self.snapshot().fleet_summary())
    }
}

/// A failed batch's envelope on its way through the retry supervisor,
/// with enough routing context to re-pack it on another card.
struct FailedJob {
    env: Envelope,
    artifact: Arc<str>,
    n: u64,
    device_batch: u64,
    from_card: usize,
    error: String,
}

/// Everything one card worker owns besides its governor.
struct WorkerState {
    gpu: GpuSpec,
    card: usize,
    backend: Arc<dyn ExecBackend>,
    fleet_metrics: Arc<Metrics>,
    card_metrics: Arc<Metrics>,
    nvml: Arc<SimNvml>,
    inflight: Arc<AtomicU64>,
    recorder: Arc<PowerRecorder>,
    share: Arc<ShareCell>,
    ctx: GovernorContext,
    health: Arc<HealthMonitor>,
    retry_tx: mpsc::Sender<FailedJob>,
    beat: Arc<AtomicU64>,
    epoch: Instant,
    tracer: Arc<Tracer>,
    admission: Arc<AdmissionController>,
}

/// Hand a failed batch's envelopes to the retry supervisor; if it is
/// already gone (shutdown tail), fail them terminally right here so the
/// accounting closes and every submitter still gets a reply.
fn forward_failed(w: &WorkerState, batch: PackedBatch, error: &str) {
    w.fleet_metrics.batch_errors.fetch_add(1, Ordering::Relaxed);
    w.card_metrics.batch_errors.fetch_add(1, Ordering::Relaxed);
    w.health.on_batch_error(w.card);
    let (artifact, n, device_batch) = (batch.artifact.clone(), batch.n, batch.device_batch);
    for env in batch.envelopes {
        let failed = FailedJob {
            env,
            artifact: artifact.clone(),
            n,
            device_batch,
            from_card: w.card,
            error: error.to_string(),
        };
        if let Err(mpsc::SendError(failed)) = w.retry_tx.send(failed) {
            w.fleet_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            w.card_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            let _ = failed.env.reply.send(Err(anyhow::anyhow!(
                "batch failed on card {} (no retry during shutdown): {}",
                w.card,
                failed.error
            )));
        }
    }
}

fn worker_loop(
    rx: mpsc::Receiver<PackedBatch>,
    w: WorkerState,
    mut governor: Box<dyn ClockGovernor>,
    mut fault: FaultState,
) {
    let table = freq_table(&w.gpu);
    let tesla_class = w.nvml.supports_locked_clocks();
    let boost_mhz = w.gpu.boost_clock_mhz;
    // Worker-owned steady-state caches: loaded modules per artifact (no
    // backend.load() per batch), reusable input/output planes (no per-batch
    // plane allocation), the boost-clock pricing baseline per
    // (n, device_batch) so energy accounting costs one model evaluation
    // per batch instead of two, and the last governed clock so NVML is
    // only driven (and the transition trace only grows) when the governor
    // actually changes its request.
    let mut modules: HashMap<Arc<str>, Arc<ExecModule>> = HashMap::new();
    let mut boost_runs: HashMap<(u64, u64), crate::sim::BatchRun> = HashMap::new();
    // Memoized watt→clock inversions per (n, device_batch, quarter-watt
    // share): the arbiter's deadband keeps shares piecewise-constant, so
    // steady state costs one HashMap hit per batch, not a table scan.
    let mut budget_caps: HashMap<(u64, u64, u64), f64> = HashMap::new();
    let mut in_re: Vec<f32> = Vec::new();
    let mut in_im: Vec<f32> = Vec::new();
    let mut out_re: Vec<f32> = Vec::new();
    let mut out_im: Vec<f32> = Vec::new();
    let mut last_requested = f64::NAN;
    let mut last_clock = boost_mhz;
    let mut lock_fault_armed = false;
    while let Ok(batch) = rx.recv() {
        // Heartbeat: the supervisor treats a stale beat with work in
        // flight as a stall signal.
        w.beat
            .store(w.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);

        // Injected-fault schedule (deterministic per-card batch sequence).
        let injected = fault.next_batch();
        if injected.fail {
            // Fail-stop / flap-down window: the card does no work; its
            // envelopes go to the retry supervisor for re-routing.
            let n_env = batch.envelopes.len() as u64;
            forward_failed(&w, batch, "injected fault: card offline");
            w.inflight.fetch_sub(n_env, Ordering::Relaxed);
            continue;
        }
        if injected.stall_ms > 0 {
            // Latency inflation: the batch still completes, late.
            w.health.on_stall(w.card);
            std::thread::sleep(Duration::from_millis(injected.stall_ms));
        }
        if injected.clock_lock != lock_fault_armed {
            // Arm/disarm the injected NVML lock error, and force the next
            // clock decision to actually drive NVML so the fault (or the
            // recovery) is observed instead of hiding behind the memo.
            lock_fault_armed = injected.clock_lock;
            w.nvml.set_lock_fault(lock_fault_armed);
            last_requested = f64::NAN;
        }

        let occupancy = batch.occupancy();
        let rows_total = batch.device_batch;

        // Clock policy: ask the governor, then drive the simulated NVML the
        // way the paper's pipeline brackets cuFFT calls (Tesla-class only;
        // other cards apply the snapped clock offline). A boost-or-above
        // request means "no DVFS": the card runs default clocks — no lock,
        // and no upward snap past boost (the P4's boost sits between table
        // entries; nearest-snap would price 'boost' above boost).
        let workload = FftWorkload::new(
            batch.n,
            Precision::Fp32,
            batch.device_batch * batch.n * Precision::Fp32.complex_bytes(),
        );
        // The arbiter's current watt share reaches the governor as the
        // context budget hint, and — for policies that ignore the hint —
        // is enforced here: the requested clock never prices above the
        // share. The cap is a frequency-table clock, so it snaps to
        // itself and share stability ⇒ request stability ⇒ no NVML
        // re-lock (bounded transition count under the arbiter).
        let share = w.share.get();
        let ctx = GovernorContext {
            power_budget_w: share,
            ..w.ctx.clone()
        };
        // The governor's own choice is kept apart from the budget/health
        // caps below: a span is "capped" iff the granted clock ended up
        // below what the policy itself wanted.
        let governor_choice = governor.choose(&w.gpu, &workload, &ctx).unwrap_or(boost_mhz);
        let mut requested = governor_choice;
        if let Some(budget_w) = share {
            let cap = *budget_caps
                .entry((batch.n, batch.device_batch, budget_key(budget_w)))
                .or_insert_with(|| {
                    clock_cap_for_budget(&w.gpu, &workload, budget_w, ctx.freq_stride)
                });
            requested = requested.min(cap);
        }
        if let Some(floor) = crate::governor::brownout_floor(
            boost_mhz,
            w.admission.brownout.level(),
            batch.envelopes.iter().any(|e| e.job.class == TenantClass::Realtime),
        ) {
            // Brownout step 1: a browned-out fleet spends watts to protect
            // the deadline class — batches carrying realtime work float up
            // to boost, overriding the governor and the budget cap (the
            // ladder's explicit latency-for-watts trade).
            requested = requested.max(floor);
        }
        if let Some(frac) = w.health.clock_frac(w.card) {
            // Degraded card: clock-derate through the same cap machinery
            // the power budget uses — snap a ceiling at ~frac × boost so
            // a flaky card runs cooler while it proves itself. The cap is
            // a table clock, so request stability is preserved. Applied
            // after the brownout floor: a sick card is never pushed.
            requested = requested.min(table.snap_at_most(boost_mhz, frac * boost_mhz));
        }
        let clock = if requested == last_requested {
            last_clock
        } else {
            last_requested = requested;
            last_clock = if requested >= boost_mhz {
                if tesla_class && matches!(w.nvml.state(), ClockState::Locked { .. }) {
                    w.nvml.reset_gpu_locked_clocks();
                }
                boost_mhz
            } else if tesla_class {
                match w.nvml.set_gpu_locked_clocks(requested, requested) {
                    Ok(()) => w.nvml.current_clock_mhz(),
                    Err(_) => {
                        // Clock control is gone (injected or genuine):
                        // degrade the card, run unmanaged at boost, and
                        // retry the lock on the next decision.
                        w.health.on_clock_fault(w.card);
                        last_requested = f64::NAN;
                        boost_mhz
                    }
                }
            } else {
                table.snap(requested)
            };
            last_clock
        };

        let t0 = Instant::now();
        let module = match modules.get(&batch.artifact) {
            Some(m) => Ok(m.clone()),
            None => w.backend.load(&batch.artifact).map(|m| {
                modules.insert(batch.artifact.clone(), m.clone());
                m
            }),
        };
        let result = module.and_then(|m| {
            batch.planes_into(&mut in_re, &mut in_im);
            if m.meta.kind == "conv" {
                // Real-to-real filterbank rows: the zeroed imaginary
                // plane is ignored and the output imaginary plane is
                // pinned to zeros so result splitting stays uniform.
                w.backend.run_conv_into(&m, &in_re, &mut out_re).map(|()| {
                    out_im.clear();
                    out_im.resize(out_re.len(), 0.0);
                })
            } else {
                w.backend
                    .run_fft_into(&m, &in_re, &in_im, &mut out_re, &mut out_im)
            }
        });
        let exec_end = Instant::now();
        let exec_us = exec_end.duration_since(t0).as_micros() as u64;
        w.fleet_metrics.record_batch(occupancy, rows_total, exec_us);
        w.card_metrics.record_batch(occupancy, rows_total, exec_us);

        // DVFS energy accounting: what this batch costs on the simulated
        // card at the governed clock vs at boost. The boost baseline is
        // clock-independent per (n, device_batch), so it is memoized.
        let boost = boost_runs
            .entry((batch.n, batch.device_batch))
            .or_insert_with(|| crate::sim::run_batch(&w.gpu, &workload, boost_mhz))
            .clone();
        let run = if clock == boost_mhz {
            boost.clone()
        } else {
            crate::sim::run_batch(&w.gpu, &workload, clock)
        };
        w.fleet_metrics.record_energy(run.energy_j, boost.energy_j);
        w.card_metrics.record_energy(run.energy_j, boost.energy_j);

        // Telemetry: one ring push per batch (instant draw, rolling
        // windows, cumulative joules, per-length attribution, misses).
        let deadline = w.ctx.effective_deadline_s(boost.timing.total_s);
        let deadline_missed = run.timing.total_s > deadline * (1.0 + 1e-9);
        w.recorder.record_batch(
            clock,
            run.timing.total_s,
            run.avg_power_w,
            run.energy_j,
            batch.n,
            occupancy as u64,
            deadline_missed,
        );

        // Close the feedback loop for adaptive policies.
        governor.observe(&BatchFeedback {
            n: batch.n,
            f_mhz: clock,
            time_s: run.timing.total_s,
            deadline_s: deadline,
            slack: 1.0 - run.timing.total_s / deadline,
            energy_j: run.energy_j,
        });

        let n_env = batch.envelopes.len() as u64;
        match result {
            Ok(()) => {
                w.health.on_batch_ok(w.card);
                let n = batch.n as usize;
                for (i, env) in batch.envelopes.into_iter().enumerate() {
                    let off = i * n;
                    let res = JobResult {
                        id: env.job.id,
                        out_re: out_re[off..off + n].to_vec(),
                        out_im: out_im[off..off + n].to_vec(),
                        exec_us,
                        sim_batch_s: run.timing.total_s,
                        batch_occupancy: occupancy,
                    };
                    w.fleet_metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    w.card_metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(Ok(res));
                    if w.tracer.enabled() {
                        w.tracer.record(Span {
                            job_id: env.job.id,
                            artifact: batch.artifact.to_string(),
                            n: batch.n,
                            card: w.card,
                            enqueue_us: w.tracer.micros(env.stamps.enqueue),
                            admit_us: w.tracer.micros(env.stamps.admit),
                            seal_us: w.tracer.micros(env.stamps.seal),
                            dispatch_us: w.tracer.micros(env.stamps.dispatch),
                            exec_start_us: w.tracer.micros(t0),
                            exec_end_us: w.tracer.micros(exec_end),
                            complete_us: w.tracer.micros(Instant::now()),
                            requested_mhz: governor_choice,
                            granted_mhz: clock,
                            batch_occupancy: occupancy as u64,
                            attempts: env.job.attempts,
                            // The job's share of the batch joules — the
                            // same attribution PowerRecorder totals use.
                            energy_j: run.energy_j / occupancy.max(1) as f64,
                            sim_batch_s: run.timing.total_s,
                            outcome: SpanOutcome::Ok,
                            class: env.job.class.label().to_string(),
                            reason: String::new(),
                        });
                    }
                }
            }
            Err(e) => {
                // Genuine execution failure: same recovery path as an
                // injected fault — the supervisor retries elsewhere.
                forward_failed(&w, batch, &format!("{e:#}"));
            }
        }
        w.inflight.fetch_sub(n_env, Ordering::Relaxed);
    }
}

/// Shared handles the retry supervisor works against.
struct SupervisorState {
    stop: Arc<AtomicBool>,
    health: Arc<HealthMonitor>,
    batcher: Arc<Mutex<Batcher>>,
    txs: Vec<mpsc::Sender<PackedBatch>>,
    inflights: Vec<Arc<AtomicU64>>,
    acceptings: Vec<Arc<AtomicBool>>,
    card_metrics: Vec<Arc<Metrics>>,
    fleet_metrics: Arc<Metrics>,
    retry: RetryPolicy,
    beats: Vec<Arc<AtomicU64>>,
    epoch: Instant,
    tracer: Arc<Tracer>,
    admission: Arc<AdmissionController>,
    queue_bound: Option<u64>,
}

/// One job waiting out its backoff before re-dispatch.
struct PendingRetry {
    due: Instant,
    job: FailedJob,
}

/// Terminal shed: account the failure on the card the job last failed
/// on, and reply with the typed error. This is the only place besides
/// the workers that closes a job's accounting, so the drain invariant
/// (`submitted == completed + failed`) always converges.
fn shed(s: &SupervisorState, f: FailedJob, err: CoordError) {
    s.fleet_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    s.fleet_metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
    let m = &s.card_metrics[f.from_card];
    m.jobs_failed.fetch_add(1, Ordering::Relaxed);
    m.jobs_shed.fetch_add(1, Ordering::Relaxed);
    if s.tracer.enabled() {
        // Shed spans carry the stamps the job accumulated before it died
        // (so queue time up to the shed is visible in the journal), with
        // the never-reached exec stages pinned to "now". They count in
        // the shed counter but not the latency/energy histograms.
        let now = Instant::now();
        let st = &f.env.stamps;
        s.tracer.record(Span {
            job_id: f.env.job.id,
            artifact: f.artifact.to_string(),
            n: f.n,
            card: f.from_card,
            enqueue_us: s.tracer.micros(st.enqueue),
            admit_us: s.tracer.micros(st.admit),
            seal_us: s.tracer.micros(st.seal),
            dispatch_us: s.tracer.micros(st.dispatch),
            exec_start_us: s.tracer.micros(now),
            exec_end_us: s.tracer.micros(now),
            complete_us: s.tracer.micros(now),
            requested_mhz: 0.0,
            granted_mhz: 0.0,
            batch_occupancy: 0,
            attempts: f.env.job.attempts,
            energy_j: 0.0,
            sim_batch_s: 0.0,
            outcome: SpanOutcome::Shed,
            class: f.env.job.class.label().to_string(),
            reason: err.shed_reason().to_string(),
        });
    }
    let _ = f.env.reply.send(Err(err.into()));
}

/// Admit a failed job into the backoff queue — or shed it typed if its
/// retries are spent or the engine is stopping. The backoff carries
/// seeded jitter (`RetryPolicy::jittered_backoff`) so a cohort of jobs
/// from one failed batch spreads out instead of re-spiking the
/// recovering card in lockstep.
fn admit_retry(
    s: &SupervisorState,
    pending: &mut Vec<PendingRetry>,
    mut f: FailedJob,
    stopping: bool,
    rng: &mut Rng,
) {
    if stopping {
        let reason = format!("engine is shutting down (last error: {})", f.error);
        shed(s, f, CoordError::CardUnavailable { reason });
        return;
    }
    if f.env.job.attempts >= s.retry.max_retries {
        let err = CoordError::RetriesExhausted {
            id: f.env.job.id,
            n: f.n,
            attempts: f.env.job.attempts,
        };
        shed(s, f, err);
        return;
    }
    f.env.job.attempts += 1;
    let backoff = s.retry.jittered_backoff(f.env.job.attempts, rng);
    pending.push(PendingRetry {
        due: Instant::now() + backoff,
        job: f,
    });
}

/// Re-dispatch one job whose backoff elapsed: health-aware least-loaded
/// pick that prefers any card other than the one it failed on. Slots the
/// retry lands in (without completing a batch) are collected in
/// `touched` and flushed at the end of the tick, so retried jobs of one
/// failed batch re-pack together instead of going out as singletons.
fn dispatch_retry(s: &SupervisorState, f: FailedJob, touched: &mut Vec<(Arc<str>, usize)>) {
    let loads: Vec<u64> = s
        .inflights
        .iter()
        .enumerate()
        .map(|(i, inf)| inf.load(Ordering::Relaxed) + s.health.load_penalty(i))
        .collect();
    let eligible: Vec<bool> = (0..loads.len())
        .map(|i| s.acceptings[i].load(Ordering::Relaxed) && s.health.eligible(i))
        .collect();
    let mut not_origin = eligible.clone();
    if f.from_card < not_origin.len() {
        not_origin[f.from_card] = false;
    }
    let card = Router::least_loaded_among(&loads, &not_origin)
        .or_else(|| Router::least_loaded_among(&loads, &eligible));
    let Some(card) = card else {
        let reason = format!("no healthy card for retry (last error: {})", f.error);
        shed(s, f, CoordError::CardUnavailable { reason });
        return;
    };
    s.inflights[card].fetch_add(1, Ordering::Relaxed);
    s.fleet_metrics.jobs_retried.fetch_add(1, Ordering::Relaxed);
    s.card_metrics[card].jobs_retried.fetch_add(1, Ordering::Relaxed);
    let artifact = f.artifact.clone();
    let pushed = lock_recover(&s.batcher).push(&f.artifact, f.n, f.device_batch, card, f.env);
    match pushed {
        Ok(Some(batch)) => {
            send_batch(&s.txs[batch.card], batch);
        }
        Ok(None) => touched.push((artifact, card)),
        Err(e) => {
            // Unreachable for an already-admitted route; keep the
            // accounting truthful anyway.
            s.inflights[card].fetch_sub(1, Ordering::Relaxed);
            s.fleet_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            s.card_metrics[card].jobs_failed.fetch_add(1, Ordering::Relaxed);
            eprintln!("retry re-admission failed: {e:#}");
        }
    }
}

/// The retry supervisor: backoff queue, health probe ticks, and
/// heartbeat stall detection. Exits when the engine stops (shedding
/// everything still pending, typed) or when every sender is gone.
fn supervisor_loop(s: SupervisorState, rx: mpsc::Receiver<FailedJob>) {
    let mut pending: Vec<PendingRetry> = Vec::new();
    let tick = Duration::from_millis(2);
    let stall_ms = (s.health.policy().stall_after.as_millis() as u64).max(1);
    // The jitter stream: one seeded generator per supervisor, so a given
    // fault schedule replays the exact same retry timing run after run.
    let mut rng = Rng::new(s.retry.jitter_seed);
    loop {
        let stopping = s.stop.load(Ordering::Relaxed);
        match rx.recv_timeout(tick) {
            Ok(f) => {
                admit_retry(&s, &mut pending, f, stopping, &mut rng);
                while let Ok(f) = rx.try_recv() {
                    admit_retry(&s, &mut pending, f, stopping, &mut rng);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for p in pending.drain(..) {
                    let reason = format!("engine shut down (last error: {})", p.job.error);
                    shed(&s, p.job, CoordError::CardUnavailable { reason });
                }
                return;
            }
        }

        if stopping {
            // Shed everything and leave; workers terminally fail any
            // later batch errors themselves once the receiver drops.
            while let Ok(f) = rx.try_recv() {
                admit_retry(&s, &mut pending, f, true, &mut rng);
            }
            for p in pending.drain(..) {
                let reason = format!("engine is shutting down (last error: {})", p.job.error);
                shed(&s, p.job, CoordError::CardUnavailable { reason });
            }
            return;
        }

        // Probe re-admission for quarantined cards.
        s.health.tick();

        // Brownout ladder tick: fleet queue pressure is the in-flight
        // fraction of bounded capacity. Unbounded engines never brown
        // out — there is no capacity to be a fraction of, and their
        // overload defense is the operator setting a bound.
        if let (Some(bound), Some(bp)) = (s.queue_bound, s.admission.policy.brownout.as_ref()) {
            let inflight: u64 = s.inflights.iter().map(|i| i.load(Ordering::Relaxed)).sum();
            let capacity = (bound * s.inflights.len() as u64).max(1);
            s.admission.brownout.tick(inflight as f64 / capacity as f64, bp);
        }

        // Heartbeat stall detection: work in flight but no batch started
        // recently. Resetting the beat restarts the staleness window so
        // one long stall counts once per window, not once per tick.
        let now_ms = s.epoch.elapsed().as_millis() as u64;
        for (i, beat) in s.beats.iter().enumerate() {
            if s.inflights[i].load(Ordering::Relaxed) == 0 {
                continue;
            }
            if now_ms.saturating_sub(beat.load(Ordering::Relaxed)) > stall_ms {
                s.health.on_stall(i);
                beat.store(now_ms, Ordering::Relaxed);
            }
        }

        // Fire everything whose backoff elapsed, then flush the slots
        // those retries landed in.
        let now = Instant::now();
        let mut touched: Vec<(Arc<str>, usize)> = Vec::new();
        let mut i = 0;
        while i < pending.len() {
            if pending[i].due <= now {
                let p = pending.swap_remove(i);
                dispatch_retry(&s, p.job, &mut touched);
            } else {
                i += 1;
            }
        }
        for (artifact, card) in touched {
            let batch = lock_recover(&s.batcher).flush_slot(&artifact, card);
            if let Some(b) = batch {
                send_batch(&s.txs[b.card], b);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::sim::gpu::tesla_v100;
    use std::path::Path;

    fn engine() -> Engine {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        Engine::start_single(
            rt,
            tesla_v100(),
            GovernorKind::FixedBoost,
            EngineConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn prewarm_loads_artifacts_before_traffic() {
        let e = engine();
        assert!(e.backend().loaded_names().is_empty(), "cold start");
        let warmed = e.prewarm(&[1024], "f32").unwrap();
        assert_eq!(warmed, 1);
        assert!(e
            .backend()
            .loaded_names()
            .contains(&"fft_f32_n1024_b64".to_string()));
        e.shutdown();
    }

    #[test]
    fn prewarm_rides_rfft_and_conv_artifacts_along() {
        let e = engine();
        // n=4096 has an fft, an rfft and a conv artifact in the synthetic
        // manifest: all three plans (and the conv kernel spectrum) compile
        // up front.
        let warmed = e.prewarm(&[4096], "f32").unwrap();
        assert_eq!(warmed, 3, "fft + rfft + conv artifacts for the same length");
        let names = e.backend().loaded_names();
        assert!(names.contains(&"rfft_f32_n4096_b16".to_string()));
        assert!(names.contains(&"conv_f32_n4096_t129_b16".to_string()));
        e.shutdown();
    }

    #[test]
    fn conv_jobs_round_trip_through_the_fleet() {
        let e = engine();
        let (n, taps) = (4096usize, 129u64);
        // A unit impulse: the filtered row is the kernel itself, the
        // sharpest possible end-to-end check of the FFT→multiply→iFFT
        // path through routing, batching and the worker.
        let mut x = vec![0.0f32; n];
        x[0] = 1.0;
        let res = e.execute_conv(x, taps).unwrap();
        assert_eq!(res.out_re.len(), n);
        let h = crate::dsp::planner::synthetic_kernel(taps as usize);
        for (j, &hj) in h.iter().enumerate() {
            assert!(
                (res.out_re[j] as f64 - hj).abs() < 1e-6,
                "tap {j}: {} vs {hj}",
                res.out_re[j]
            );
        }
        assert!(
            res.out_re[taps as usize..].iter().all(|&v| v.abs() < 1e-6),
            "impulse response must vanish past the kernel"
        );
        assert!(res.out_im.iter().all(|&v| v == 0.0), "conv output is real");
        e.shutdown();
    }

    #[test]
    fn conv_admission_rejects_unsupported_kernels_typed() {
        let e = engine();
        // No artifact serves taps=33 at n=4096.
        let err = e.execute_conv(vec![0.0; 4096], 33).unwrap_err();
        assert!(
            err.downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::UnsupportedKernel { n: 4096, taps: 33, .. }))
                .unwrap_or(false),
            "expected UnsupportedKernel, got {err:#}"
        );
        // Invalid tap counts are refused before routing: zero taps and a
        // kernel longer than the signal.
        for (len, taps) in [(4096usize, 0u64), (16, 129)] {
            let err = e.execute_conv(vec![0.0; len], taps).unwrap_err();
            assert!(
                err.downcast_ref::<CoordError>()
                    .map(|c| matches!(c, CoordError::UnsupportedKernel { .. }))
                    .unwrap_or(false),
                "len={len} taps={taps}: expected UnsupportedKernel, got {err:#}"
            );
        }
        // Admission rejections happen before any accounting: nothing was
        // submitted, nothing lingers, the fleet drains instantly.
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        assert!(e.drain(Duration::from_secs(1)).complete);
        e.shutdown();
    }

    #[test]
    fn queue_bound_rejects_typed_before_accounting() {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        // A huge batch wait disables the flusher for the test's duration,
        // so the first job deterministically sits in its partial slot and
        // holds the card at the 1-job bound.
        let cfg = EngineConfig {
            max_batch_wait: Duration::from_secs(3600),
            queue_bound: Some(1),
            ..EngineConfig::default()
        };
        let e = Engine::start_single(rt, tesla_v100(), GovernorKind::FixedBoost, cfg).unwrap();
        let n = 1024usize;
        let _rx1 = e.submit(vec![0.0; n], vec![0.0; n]).unwrap();
        let err = e.submit(vec![0.0; n], vec![0.0; n]).unwrap_err();
        assert!(
            err.downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::QueueFull { bound: 1, .. }))
                .unwrap_or(false),
            "expected QueueFull, got {err:#}"
        );
        // The rejection happened at admission: only the first job counts.
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 1);
        assert!(e.drain(Duration::from_secs(5)).complete, "flush releases the held job");
        e.shutdown();
    }

    #[test]
    fn begin_shutdown_refuses_submits_typed() {
        let e = engine();
        e.begin_shutdown();
        let err = e.submit(vec![0.0; 1024], vec![0.0; 1024]).unwrap_err();
        assert!(
            err.downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::CardUnavailable { .. }))
                .unwrap_or(false),
            "expected CardUnavailable, got {err:#}"
        );
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        e.shutdown();
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let p = RetryPolicy {
            max_retries: 10,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(5),
            ..RetryPolicy::default()
        };
        assert_eq!(p.backoff_for(1), Duration::from_millis(1));
        assert_eq!(p.backoff_for(2), Duration::from_millis(2));
        assert_eq!(p.backoff_for(3), Duration::from_millis(4));
        assert_eq!(p.backoff_for(4), Duration::from_millis(5), "capped");
        assert_eq!(p.backoff_for(60), Duration::from_millis(5), "shift stays bounded");
    }

    #[test]
    fn retry_jitter_is_seeded_bounded_and_spread() {
        let p = RetryPolicy {
            max_retries: 10,
            backoff_base: Duration::from_millis(4),
            backoff_cap: Duration::from_millis(50),
            jitter_frac: 0.5,
            ..RetryPolicy::default()
        };
        let base = p.backoff_for(3); // 16 ms, uncapped
        let mut rng = Rng::new(p.jitter_seed);
        let xs: Vec<Duration> = (0..64).map(|_| p.jittered_backoff(3, &mut rng)).collect();
        // Bounded: every draw sits in [base, base * (1 + jitter_frac)).
        for x in &xs {
            assert!(
                *x >= base && *x < base.mul_f64(1.0 + p.jitter_frac),
                "jitter out of bounds: {x:?} (base {base:?})"
            );
        }
        // Spread: a cohort of 64 synchronized failures de-synchronizes —
        // the draws are not clustered on a handful of values.
        let distinct: std::collections::HashSet<Duration> = xs.iter().copied().collect();
        assert!(
            distinct.len() >= 48,
            "expected a well-spread cohort, got {} distinct backoffs",
            distinct.len()
        );
        // Deterministic: the same seed replays the same schedule.
        let mut replay_rng = Rng::new(p.jitter_seed);
        let replay: Vec<Duration> =
            (0..64).map(|_| p.jittered_backoff(3, &mut replay_rng)).collect();
        assert_eq!(xs, replay, "seeded jitter must replay exactly");
        // Opting out restores the exact capped-exponential schedule.
        let p0 = RetryPolicy { jitter_frac: 0.0, ..p };
        assert_eq!(p0.jittered_backoff(3, &mut rng), base);
    }

    #[test]
    fn completed_jobs_record_monotone_spans_with_consistent_energy() {
        let e = engine();
        let n = 1024usize;
        for _ in 0..8 {
            e.execute(vec![1.0; n], vec![0.0; n]).unwrap();
        }
        assert!(e.drain(Duration::from_secs(5)).complete);
        let spans = e.tracer().recent(64);
        assert_eq!(spans.len(), 8, "one span per completed job");
        for s in &spans {
            assert!(s.monotone(), "span {} stamps out of order", s.job_id);
            let total =
                s.admit_s() + s.batch_wait_s() + s.dispatch_s() + s.exec_s() + s.reply_s();
            assert!(
                (total - s.e2e_s()).abs() < 1e-12,
                "stage segments must sum to the end-to-end latency"
            );
            assert_eq!(s.outcome, SpanOutcome::Ok);
            assert!(!s.capped(), "uncapped fleet never marks spans capped");
            assert_eq!(s.card, 0);
            assert!(s.energy_j > 0.0);
        }
        // Energy attribution closes: span joules sum to the metrics total
        // (occupancy-split shares recombine exactly per batch).
        let span_j: f64 = spans.iter().map(|s| s.energy_j).sum();
        let metrics_j = e.metrics.energy_j();
        assert!(
            (span_j - metrics_j).abs() <= 1e-9 * metrics_j.max(1.0),
            "span energy {span_j} vs metrics {metrics_j}"
        );
        let summary = e.snapshot().trace.expect("snapshot carries the trace summary");
        assert_eq!(summary.ok_spans, 8);
        assert_eq!(summary.shed_spans, 0);
        assert_eq!(summary.fleet().e2e_s.count, 8);
        e.shutdown();
    }

    #[test]
    fn disabled_tracing_records_no_spans() {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        let cfg = EngineConfig {
            trace: TraceConfig {
                enabled: false,
                ..TraceConfig::default()
            },
            ..EngineConfig::default()
        };
        let e = Engine::start_single(rt, tesla_v100(), GovernorKind::FixedBoost, cfg).unwrap();
        let n = 1024usize;
        e.execute(vec![1.0; n], vec![0.0; n]).unwrap();
        assert!(!e.tracer().enabled());
        let summary = e.snapshot().trace.unwrap();
        assert!(!summary.enabled);
        assert_eq!(summary.ok_spans, 0);
        assert_eq!(summary.ring_len, 0);
        assert!(summary.fleet().e2e_s.is_empty());
        e.shutdown();
    }

    #[test]
    fn prewarm_rejects_unroutable_lengths_typed() {
        let e = engine();
        let err = e.prewarm(&[1234], "f32").unwrap_err();
        assert!(
            err.downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::UnsupportedLength { n: 1234, .. }))
                .unwrap_or(false),
            "expected UnsupportedLength, got {err:#}"
        );
        e.shutdown();
    }

    #[test]
    fn rate_limited_class_rejects_typed_before_accounting() {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        let mut admission = AdmissionPolicy::default();
        // 0.001 tokens/s with a 1-token burst: the first scavenger job
        // spends the bank, and no realistic test runs the 1000 s a refill
        // would take — the limit is deterministic under any CI jitter.
        admission.rate_per_s[TenantClass::Scavenger.index()] = Some(1e-3);
        admission.burst[TenantClass::Scavenger.index()] = 1.0;
        let cfg = EngineConfig { admission, ..EngineConfig::default() };
        let e = Engine::start_single(rt, tesla_v100(), GovernorKind::FixedBoost, cfg).unwrap();
        let n = 1024usize;
        let _rx = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Scavenger, None)
            .unwrap();
        let err = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Scavenger, None)
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CoordError>(),
                Some(CoordError::RateLimited { class: "scavenger" })
            ),
            "expected RateLimited, got {err:#}"
        );
        // Other classes are not collaterally limited, and the refusal
        // happened before accounting: only the two accepted jobs count.
        let _rx2 = e.submit(vec![0.0; n], vec![0.0; n]).unwrap();
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 2);
        assert_eq!(e.admission().stats.rate_limited.load(Ordering::Relaxed), 1);
        let spans = e.tracer().recent(8);
        assert!(
            spans
                .iter()
                .any(|s| s.outcome == SpanOutcome::Shed
                    && s.reason == ShedReason::RateLimited.label()
                    && s.class == "scavenger"),
            "the rate-limit shed must leave a traced span with its reason"
        );
        assert!(e.drain(Duration::from_secs(5)).complete);
        e.shutdown();
    }

    #[test]
    fn impossible_deadlines_shed_typed_at_admission() {
        let e = engine();
        let n = 1024usize;
        let err = e
            .submit_qos(
                vec![0.0; n],
                vec![0.0; n],
                TenantClass::Realtime,
                Some(Duration::from_nanos(1)),
            )
            .unwrap_err();
        match err.downcast_ref::<CoordError>() {
            Some(CoordError::DeadlineInfeasible { class, deadline_ms, predicted_ms, .. }) => {
                assert_eq!(*class, "realtime");
                assert!(
                    predicted_ms > deadline_ms,
                    "the error must carry the losing prediction"
                );
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        assert_eq!(
            e.metrics.jobs_submitted.load(Ordering::Relaxed),
            0,
            "deadline sheds happen before accounting"
        );
        assert_eq!(e.admission().stats.deadline_sheds.load(Ordering::Relaxed), 1);
        // A feasible deadline admits and completes.
        let rx = e
            .submit_qos(
                vec![0.0; n],
                vec![0.0; n],
                TenantClass::Realtime,
                Some(Duration::from_secs(30)),
            )
            .unwrap();
        e.flush();
        rx.recv().unwrap().unwrap();
        let spans = e.tracer().recent(8);
        let shed = spans.iter().find(|s| s.outcome == SpanOutcome::Shed).expect("shed span");
        assert_eq!(shed.reason, ShedReason::DeadlineInfeasible.label());
        assert_eq!(shed.exec_start_us, shed.exec_end_us, "shed spans never execute");
        assert_eq!(shed.energy_j, 0.0);
        let ok = spans.iter().find(|s| s.outcome == SpanOutcome::Ok).expect("ok span");
        assert_eq!(ok.class, "realtime");
        e.shutdown();
    }

    #[test]
    fn backpressure_evicts_lower_classes_but_never_peers_or_better() {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        // A huge batch wait disables the flusher, so queued jobs sit in
        // their partial slot and hold the single card at its 1-job bound.
        let cfg = EngineConfig {
            max_batch_wait: Duration::from_secs(3600),
            queue_bound: Some(1),
            ..EngineConfig::default()
        };
        let e = Engine::start_single(rt, tesla_v100(), GovernorKind::FixedBoost, cfg).unwrap();
        let n = 1024usize;
        // A queued scavenger job fills the card...
        let rx_scav = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Scavenger, None)
            .unwrap();
        // ...and realtime pressure evicts it instead of bouncing off
        // QueueFull: the higher class takes the slot.
        let rx_rt = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Realtime, None)
            .unwrap();
        let evicted = rx_scav
            .recv_timeout(Duration::from_secs(2))
            .expect("eviction replies immediately")
            .unwrap_err();
        assert!(
            evicted
                .downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::QueueFull { .. }))
                .unwrap_or(false),
            "the evicted job must see the typed backpressure error, got {evicted:#}"
        );
        assert_eq!(e.admission().stats.evictions.load(Ordering::Relaxed), 1);
        // A batch-class submit cannot evict the queued realtime job (and
        // could never evict a peer): plain QueueFull, no second eviction.
        let err = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Batch, None)
            .unwrap_err();
        assert!(
            err.downcast_ref::<CoordError>()
                .map(|c| matches!(c, CoordError::QueueFull { bound: 1, .. }))
                .unwrap_or(false),
            "expected QueueFull, got {err:#}"
        );
        assert_eq!(e.admission().stats.evictions.load(Ordering::Relaxed), 1);
        // The realtime job completes; the eviction left a traced shed
        // span with its reason and the victim's class.
        e.flush();
        rx_rt.recv().unwrap().unwrap();
        assert!(e.drain(Duration::from_secs(5)).complete);
        let spans = e.tracer().recent(8);
        assert!(spans.iter().any(|s| s.outcome == SpanOutcome::Shed
            && s.reason == ShedReason::Evicted.label()
            && s.class == "scavenger"));
        // Accounting closes: 2 accepted, 1 completed, 1 failed (the
        // refused batch job was never accounted).
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 2);
        assert_eq!(e.metrics.jobs_completed.load(Ordering::Relaxed), 1);
        assert_eq!(e.metrics.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(e.metrics.jobs_shed.load(Ordering::Relaxed), 1);
        e.shutdown();
    }

    #[test]
    fn brownout_ladder_sheds_lower_classes_typed() {
        let e = engine();
        let bp = e.admission().policy.brownout.clone().expect("default carries a ladder");
        // Force the ladder to its top rung. The default engine has no
        // queue bound, so the supervisor never ticks the ladder — the
        // level this test sets is stable.
        for _ in 0..(bp.escalate_ticks as u64 * 3) {
            e.admission().brownout.tick(1.0, &bp);
        }
        assert_eq!(e.admission().brownout.level(), 3);
        let n = 1024usize;
        for class in [TenantClass::Scavenger, TenantClass::Batch] {
            let err = e.submit_qos(vec![0.0; n], vec![0.0; n], class, None).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<CoordError>(),
                    Some(CoordError::BrownoutShed { level: 3, .. })
                ),
                "class {} must be brownout-shed at level 3, got {err:#}",
                class.label()
            );
        }
        // Realtime is never brownout-shed.
        let rx = e
            .submit_qos(vec![0.0; n], vec![0.0; n], TenantClass::Realtime, None)
            .unwrap();
        e.flush();
        rx.recv().unwrap().unwrap();
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 1);
        assert_eq!(e.admission().stats.brownout_sheds.load(Ordering::Relaxed), 2);
        let spans = e.tracer().recent(8);
        let shed: Vec<_> =
            spans.iter().filter(|s| s.outcome == SpanOutcome::Shed).collect();
        assert_eq!(shed.len(), 2);
        assert!(shed
            .iter()
            .all(|s| s.reason == ShedReason::BrownoutShed.label() && s.energy_j == 0.0));
        e.shutdown();
    }

    #[test]
    fn drain_readmit_race_never_leaves_quarantined_card_dispatchable() {
        let rt = Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).unwrap());
        let cfg = EngineConfig {
            health: HealthPolicy {
                // An effectively infinite probe cooldown: during the race
                // the ONLY way card 0 could become dispatchable is the
                // bug this test hunts — operator readmit_card() calls
                // resurrecting a quarantined card past the health monitor.
                probe_cooldown: Duration::from_secs(3600),
                probe_cooldown_cap: Duration::from_secs(3600),
                ..HealthPolicy::default()
            },
            ..EngineConfig::default()
        };
        let e = Engine::start_single(rt, tesla_v100(), GovernorKind::FixedBoost, cfg).unwrap();
        for _ in 0..e.health().policy().errors_to_quarantine {
            e.health().on_batch_error(0);
        }
        assert_eq!(e.health().state(0), health::HealthState::Quarantined);
        let stop = AtomicBool::new(false);
        std::thread::scope(|sc| {
            // Operator churn: drain/readmit flip the accepting flag as
            // fast as they can...
            sc.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    e.drain_card(0, Duration::ZERO);
                    e.readmit_card(0);
                }
            });
            // ...racing probe re-admission ticks (the engine's own
            // supervisor is ticking concurrently too).
            sc.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    e.health().tick();
                }
            });
            for _ in 0..1000 {
                // The dispatch-level invariant: a quarantined card is
                // never routable, however the interleaving falls.
                assert!(!e.health().eligible(0), "quarantined card became eligible");
                let err = e.submit(vec![0.0; 1024], vec![0.0; 1024]).unwrap_err();
                assert!(
                    err.downcast_ref::<CoordError>()
                        .map(|c| matches!(c, CoordError::CardUnavailable { .. }))
                        .unwrap_or(false),
                    "submit must stay typed-refused while quarantined, got {err:#}"
                );
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert_eq!(e.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        // Probe re-admission is the only legal exit from quarantine, and
        // its cooldown has not elapsed.
        assert!(!e.health().maybe_readmit(0));
        assert_eq!(e.health().state(0), health::HealthState::Quarantined);
        e.shutdown();
    }
}
