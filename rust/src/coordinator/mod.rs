//! The L3 coordinator: an FFT-serving fleet engine in the vLLM-router shape.
//!
//! Requests (single transforms) are routed to the artifact that serves
//! their (length, dtype), dispatched least-loaded across N simulated cards
//! (heterogeneous specs allowed), packed by the dynamic batcher into the
//! artifact's fixed device batch per card, executed on per-card worker
//! threads through the runtime, and split back per request.
//!
//! Every worker owns its own simulated NVML handle and its own
//! [`crate::governor::ClockGovernor`] instance: the governor picks the
//! clock each batch runs at, the simulator prices the batch at that clock
//! vs boost, and [`Metrics`] accounts energy/latency/occupancy per card
//! and fleet-wide — the serving-loop integration of the paper's DVFS
//! result (section 5.3) generalized to swappable clock policies.
//!
//! No tokio in the offline crate set: std threads + mpsc channels.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod router;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, PackedBatch};
use crate::coordinator::job::{Envelope, FftJob, JobResult};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::governor::{BatchFeedback, ClockGovernor, GovernorContext, GovernorKind};
use crate::pipeline::nvml::{ClockState, SimNvml};
use crate::runtime::Runtime;
use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};

/// The serving error taxonomy: every way a job can be refused admission,
/// as a typed error callers can match on (downcastable from the
/// `anyhow::Error` that `submit`/`execute` surface). Jobs are rejected at
/// submit time — an unsupported length never reaches a worker thread,
/// so it can never surface as a worker panic.
#[derive(Debug, thiserror::Error)]
pub enum CoordError {
    /// No artifact in the manifest serves this (length, dtype).
    #[error("no artifact serves n={n} dtype={dtype} (supported: {supported:?})")]
    UnsupportedLength {
        n: u64,
        dtype: String,
        supported: Vec<u64>,
    },
    /// The transform length has no execution-plan support at all
    /// (the planner serves every n >= 1, so this means n = 0 or a
    /// corrupt manifest entry).
    #[error("transform length {n} has no plan support")]
    PlanUnsupported { n: u64 },
    /// A job reached a batch slot packing a different length
    /// (route/artifact mismatch — the slot is left intact).
    #[error("batcher: artifact '{artifact}' packs n={expected}, got a job with n={got}")]
    LengthMismatch {
        artifact: String,
        expected: u64,
        got: u64,
    },
}

/// One card in the fleet: a simulated GPU plus the clock policy governing it.
#[derive(Debug, Clone)]
pub struct CardConfig {
    pub spec: GpuSpec,
    pub governor: GovernorKind,
}

impl CardConfig {
    pub fn new(spec: GpuSpec, governor: GovernorKind) -> Self {
        Self { spec, governor }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch_wait: Duration,
    /// Deadline/stride/tolerance knobs threaded to every governor.
    pub governor_ctx: GovernorContext,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch_wait: Duration::from_millis(2),
            governor_ctx: GovernorContext::default(),
        }
    }
}

/// Runtime state of one fleet card, exposed for inspection.
pub struct Card {
    pub spec: GpuSpec,
    pub governor_label: String,
    /// The card's simulated NVML handle (clock-lock trace inspection).
    pub nvml: Arc<SimNvml>,
    /// Per-card serving metrics.
    pub metrics: Arc<Metrics>,
    /// Jobs routed to this card and not yet completed.
    inflight: Arc<AtomicU64>,
}

impl Card {
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }
}

/// The serving engine: a fleet of N governed cards behind one router.
pub struct Engine {
    runtime: Arc<Runtime>,
    router: Router,
    batcher: Arc<Mutex<Batcher>>,
    cards: Vec<Card>,
    batch_txs: Vec<mpsc::Sender<PackedBatch>>,
    /// Fleet-aggregate metrics (every card also records its own).
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    flusher: Option<JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
    next_id: AtomicU64,
}

impl Engine {
    /// Start a fleet: one worker thread per card, each owning its own
    /// `SimNvml` and governor instance, plus the batch-timeout flusher.
    pub fn start(runtime: Arc<Runtime>, fleet: Vec<CardConfig>, cfg: EngineConfig) -> Result<Self> {
        anyhow::ensure!(!fleet.is_empty(), "fleet needs at least one card");
        let router = Router::from_manifest(runtime.manifest());
        anyhow::ensure!(!router.is_empty(), "no fft artifacts in manifest");
        let batcher = Arc::new(Mutex::new(Batcher::new(cfg.max_batch_wait)));
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let mut cards = Vec::new();
        let mut batch_txs = Vec::new();
        let mut workers = Vec::new();
        for (i, cc) in fleet.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<PackedBatch>();
            let card_metrics = Arc::new(Metrics::default());
            let nvml = Arc::new(SimNvml::new(&cc.spec));
            let inflight = Arc::new(AtomicU64::new(0));
            let governor = cc.governor.make();
            let worker = WorkerState {
                gpu: cc.spec.clone(),
                runtime: runtime.clone(),
                fleet_metrics: metrics.clone(),
                card_metrics: card_metrics.clone(),
                nvml: nvml.clone(),
                inflight: inflight.clone(),
                ctx: cfg.governor_ctx.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fftsweep-card-{i}"))
                    .spawn(move || worker_loop(rx, worker, governor))?,
            );
            cards.push(Card {
                spec: cc.spec,
                governor_label: cc.governor.label(),
                nvml,
                metrics: card_metrics,
                inflight,
            });
            batch_txs.push(tx);
        }

        // Timeout flusher: emits partial batches so low request rates are
        // never starved. The tick is capped so shutdown() never waits a
        // full max_batch_wait for the flusher to notice the stop flag.
        let flusher = {
            let batcher = batcher.clone();
            let txs = batch_txs.clone();
            let stop = shutdown.clone();
            let tick = (cfg.max_batch_wait / 2).clamp(
                Duration::from_micros(500),
                Duration::from_millis(50),
            );
            Some(std::thread::Builder::new().name("fftsweep-flusher".into()).spawn(
                move || {
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        for b in batcher.lock().unwrap().flush(false) {
                            let _ = txs[b.card].send(b);
                        }
                    }
                },
            )?)
        };

        Ok(Self {
            runtime,
            router,
            batcher,
            cards,
            batch_txs,
            metrics,
            workers,
            flusher,
            shutdown,
            next_id: AtomicU64::new(1),
        })
    }

    /// Single-card convenience (the pre-fleet call shape).
    pub fn start_single(
        runtime: Arc<Runtime>,
        spec: GpuSpec,
        governor: GovernorKind,
        cfg: EngineConfig,
    ) -> Result<Self> {
        Self::start(runtime, vec![CardConfig::new(spec, governor)], cfg)
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    pub fn cards(&self) -> &[Card] {
        &self.cards
    }

    /// Submit one transform; returns the receiver for its result.
    pub fn submit(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<JobResult>>> {
        self.submit_routed(re, im).map(|(rx, ..)| rx)
    }

    /// Submit, also reporting where the job was packed and whether the
    /// push already dispatched a full batch — `execute` uses this to flush
    /// only its own (artifact, card) slot, and only when needed.
    #[allow(clippy::type_complexity)]
    fn submit_routed(
        &self,
        re: Vec<f32>,
        im: Vec<f32>,
    ) -> Result<(mpsc::Receiver<Result<JobResult>>, Arc<str>, usize, bool)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = FftJob::new(id, re, im);
        let route = self.router.route(job.n, job.dtype)?.clone();

        // Least-loaded dispatch across the fleet.
        let loads: Vec<u64> = self.cards.iter().map(|c| c.inflight()).collect();
        let card = Router::least_loaded(&loads).expect("fleet is non-empty");
        self.cards[card].inflight.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.cards[card].metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);

        let (tx, rx) = mpsc::channel();
        let env = Envelope { job, reply: tx };
        let pushed = {
            let mut b = self.batcher.lock().unwrap();
            b.push(&route.artifact, route.n, route.device_batch, card, env)
        };
        let mut dispatched_full = false;
        match pushed {
            Ok(Some(batch)) => {
                let _ = self.batch_txs[card].send(batch);
                dispatched_full = true;
            }
            Ok(None) => {}
            Err(e) => {
                // The job never entered a batch: undo its accounting so
                // drain()/occupancy stay truthful, then surface the error.
                self.cards[card].inflight.fetch_sub(1, Ordering::Relaxed);
                self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                self.cards[card].metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        }
        Ok((rx, route.artifact, card, dispatched_full))
    }

    /// Force-flush ALL pending partial batches, fleet-wide (drain/shutdown
    /// path — prefer `flush_slot` for per-request nudging).
    pub fn flush(&self) {
        for b in self.batcher.lock().unwrap().flush(true) {
            let _ = self.batch_txs[b.card].send(b);
        }
    }

    /// Flush only one (artifact, card) slot, leaving unrelated partial
    /// batches to keep packing toward full occupancy.
    pub fn flush_slot(&self, artifact: &Arc<str>, card: usize) {
        let batch = self.batcher.lock().unwrap().flush_slot(artifact, card);
        if let Some(b) = batch {
            let _ = self.batch_txs[b.card].send(b);
        }
    }

    /// Submit-and-wait convenience. Only the caller's own (artifact, card)
    /// slot is flushed: concurrent traffic on other artifacts/cards keeps
    /// batching instead of being force-flushed fleet-wide per call.
    pub fn execute(&self, re: Vec<f32>, im: Vec<f32>) -> Result<JobResult> {
        let (rx, artifact, card, dispatched_full) = self.submit_routed(re, im)?;
        // If the push completed a full batch, the job is already on its
        // way — flushing would only release someone else's fresh partial.
        if !dispatched_full {
            self.flush_slot(&artifact, card);
        }
        let result = rx.recv()??;
        Ok(result)
    }

    /// Wait until every submitted job completed (or `timeout`).
    pub fn drain(&self, timeout: Duration) -> bool {
        self.flush();
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            let sub = self.metrics.jobs_submitted.load(Ordering::Relaxed);
            let done = self.metrics.jobs_completed.load(Ordering::Relaxed)
                + self.metrics.jobs_failed.load(Ordering::Relaxed);
            if done >= sub {
                return true;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        false
    }

    /// Per-card + fleet-aggregate metrics report.
    pub fn fleet_report(&self) -> String {
        let mut out = String::new();
        for (i, c) in self.cards.iter().enumerate() {
            out.push_str(&format!(
                "card{i} {} [{}]: {} (clock transitions {})\n",
                c.spec.name,
                c.governor_label,
                c.metrics.summary(),
                c.nvml.transition_count()
            ));
        }
        out.push_str(&format!("fleet: {}", self.metrics.summary()));
        out
    }

    /// Stop the fleet deterministically: flush, join the flusher, close
    /// every card channel, join every worker. Returns the final fleet
    /// summary line (all counters quiescent once this returns).
    pub fn shutdown(mut self) -> String {
        self.shutdown.store(true, Ordering::Relaxed);
        self.flush();
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Dropping every sender closes each card's channel; workers drain
        // what was already queued and then exit.
        self.batch_txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        format!("final {}", self.fleet_report().lines().last().unwrap_or_default())
    }
}

/// Everything one card worker owns besides its governor.
struct WorkerState {
    gpu: GpuSpec,
    runtime: Arc<Runtime>,
    fleet_metrics: Arc<Metrics>,
    card_metrics: Arc<Metrics>,
    nvml: Arc<SimNvml>,
    inflight: Arc<AtomicU64>,
    ctx: GovernorContext,
}

fn worker_loop(
    rx: mpsc::Receiver<PackedBatch>,
    w: WorkerState,
    mut governor: Box<dyn ClockGovernor>,
) {
    let table = freq_table(&w.gpu);
    let tesla_class = w.nvml.supports_locked_clocks();
    let boost_mhz = w.gpu.boost_clock_mhz;
    // Worker-owned steady-state caches: loaded modules per artifact (no
    // runtime.load() per batch), reusable input/output planes (no per-batch
    // plane allocation), the boost-clock pricing baseline per
    // (n, device_batch) so energy accounting costs one model evaluation
    // per batch instead of two, and the last governed clock so NVML is
    // only driven (and the transition trace only grows) when the governor
    // actually changes its request.
    let mut modules: HashMap<Arc<str>, Arc<crate::runtime::LoadedModule>> = HashMap::new();
    let mut boost_runs: HashMap<(u64, u64), crate::sim::BatchRun> = HashMap::new();
    let mut in_re: Vec<f32> = Vec::new();
    let mut in_im: Vec<f32> = Vec::new();
    let mut out_re: Vec<f32> = Vec::new();
    let mut out_im: Vec<f32> = Vec::new();
    let mut last_requested = f64::NAN;
    let mut last_clock = boost_mhz;
    while let Ok(batch) = rx.recv() {
        let occupancy = batch.occupancy();
        let rows_total = batch.device_batch;

        // Clock policy: ask the governor, then drive the simulated NVML the
        // way the paper's pipeline brackets cuFFT calls (Tesla-class only;
        // other cards apply the snapped clock offline). A boost-or-above
        // request means "no DVFS": the card runs default clocks — no lock,
        // and no upward snap past boost (the P4's boost sits between table
        // entries; nearest-snap would price 'boost' above boost).
        let workload = FftWorkload::new(
            batch.n,
            Precision::Fp32,
            batch.device_batch * batch.n * Precision::Fp32.complex_bytes(),
        );
        let requested = governor
            .choose(&w.gpu, &workload, &w.ctx)
            .unwrap_or(boost_mhz);
        let clock = if requested == last_requested {
            last_clock
        } else {
            last_requested = requested;
            last_clock = if requested >= boost_mhz {
                if tesla_class && matches!(w.nvml.state(), ClockState::Locked { .. }) {
                    w.nvml.reset_gpu_locked_clocks();
                }
                boost_mhz
            } else if tesla_class {
                let _ = w.nvml.set_gpu_locked_clocks(requested, requested);
                w.nvml.current_clock_mhz()
            } else {
                table.snap(requested)
            };
            last_clock
        };

        let t0 = Instant::now();
        let module = match modules.get(&batch.artifact) {
            Some(m) => Ok(m.clone()),
            None => w.runtime.load(&batch.artifact).map(|m| {
                modules.insert(batch.artifact.clone(), m.clone());
                m
            }),
        };
        let result = module.and_then(|m| {
            batch.planes_into(&mut in_re, &mut in_im);
            m.run_fft_f32_into(&in_re, &in_im, &mut out_re, &mut out_im)
        });
        let exec_us = t0.elapsed().as_micros() as u64;
        w.fleet_metrics.record_batch(occupancy, rows_total, exec_us);
        w.card_metrics.record_batch(occupancy, rows_total, exec_us);

        // DVFS energy accounting: what this batch costs on the simulated
        // card at the governed clock vs at boost. The boost baseline is
        // clock-independent per (n, device_batch), so it is memoized.
        let boost = boost_runs
            .entry((batch.n, batch.device_batch))
            .or_insert_with(|| crate::sim::run_batch(&w.gpu, &workload, boost_mhz))
            .clone();
        let run = if clock == boost_mhz {
            boost.clone()
        } else {
            crate::sim::run_batch(&w.gpu, &workload, clock)
        };
        w.fleet_metrics.record_energy(run.energy_j, boost.energy_j);
        w.card_metrics.record_energy(run.energy_j, boost.energy_j);

        // Close the feedback loop for adaptive policies.
        let deadline = w.ctx.effective_deadline_s(boost.timing.total_s);
        governor.observe(&BatchFeedback {
            n: batch.n,
            f_mhz: clock,
            time_s: run.timing.total_s,
            deadline_s: deadline,
            slack: 1.0 - run.timing.total_s / deadline,
            energy_j: run.energy_j,
        });

        let n_env = batch.envelopes.len() as u64;
        match result {
            Ok(()) => {
                let n = batch.n as usize;
                for (i, env) in batch.envelopes.into_iter().enumerate() {
                    let off = i * n;
                    let res = JobResult {
                        id: env.job.id,
                        out_re: out_re[off..off + n].to_vec(),
                        out_im: out_im[off..off + n].to_vec(),
                        exec_us,
                        batch_occupancy: occupancy,
                    };
                    w.fleet_metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    w.card_metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(Ok(res));
                }
            }
            Err(e) => {
                for env in batch.envelopes {
                    w.fleet_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    w.card_metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(Err(anyhow::anyhow!("{e:#}")));
                }
            }
        }
        w.inflight.fetch_sub(n_env, Ordering::Relaxed);
    }
}
