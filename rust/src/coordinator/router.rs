//! Routing: map an incoming job to the AOT artifact that can serve it.
//!
//! Mirrors the vLLM-router shape: a static routing table derived from the
//! manifest, plus admission checks (supported length/dtype) with typed
//! rejections ([`CoordError`]).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::CoordError;
use crate::runtime::Manifest;

/// Routing table: (n, dtype) → artifact name + its fixed device batch,
/// plus a parallel table for `conv` (filterbank) artifacts keyed by
/// (n, taps, dtype) — one signal length can carry several kernel sizes.
#[derive(Debug, Clone, Default)]
pub struct Router {
    routes: BTreeMap<(u64, String), RouteEntry>,
    conv_routes: BTreeMap<(u64, u64, String), RouteEntry>,
}

#[derive(Debug, Clone)]
pub struct RouteEntry {
    /// Interned artifact name: cloning a route (or keying a batcher slot)
    /// bumps a refcount instead of copying the string.
    pub artifact: Arc<str>,
    /// Transform length the artifact serves.
    pub n: u64,
    /// The artifact's fixed batch dimension (the batcher packs up to this
    /// many transforms per execution).
    pub device_batch: u64,
}

impl Router {
    /// Build from every `fft` and `conv` artifact in the manifest.
    pub fn from_manifest(manifest: &Manifest) -> Self {
        let mut routes = BTreeMap::new();
        for a in manifest.of_kind("fft") {
            routes.insert(
                (a.n, a.dtype.clone()),
                RouteEntry {
                    artifact: Arc::from(a.name.as_str()),
                    n: a.n,
                    device_batch: a.batch,
                },
            );
        }
        let mut conv_routes = BTreeMap::new();
        for a in manifest.of_kind("conv") {
            conv_routes.insert(
                (a.n, a.harmonics, a.dtype.clone()),
                RouteEntry {
                    artifact: Arc::from(a.name.as_str()),
                    n: a.n,
                    device_batch: a.batch,
                },
            );
        }
        Self { routes, conv_routes }
    }

    /// Admission check: the artifact serving (n, dtype), or a typed
    /// [`CoordError::UnsupportedLength`] naming the lengths that ARE
    /// routable so callers can self-correct.
    pub fn route(&self, n: u64, dtype: &str) -> Result<&RouteEntry, CoordError> {
        self.routes
            .get(&(n, dtype.to_string()))
            .ok_or_else(|| CoordError::UnsupportedLength {
                n,
                dtype: dtype.to_string(),
                supported: self.supported_lengths(dtype),
            })
    }

    /// Admission check for conv jobs: an invalid tap count (0, or longer
    /// than the signal) is refused before any table lookup; otherwise the
    /// artifact serving (n, taps, dtype), or a typed
    /// [`CoordError::UnsupportedKernel`] naming the (n, taps) pairs that
    /// ARE routable.
    pub fn route_conv(&self, n: u64, taps: u64, dtype: &str) -> Result<&RouteEntry, CoordError> {
        if taps == 0 || taps > n {
            return Err(CoordError::UnsupportedKernel {
                n,
                taps,
                supported: self.supported_kernels(dtype),
            });
        }
        self.conv_routes
            .get(&(n, taps, dtype.to_string()))
            .ok_or_else(|| CoordError::UnsupportedKernel {
                n,
                taps,
                supported: self.supported_kernels(dtype),
            })
    }

    pub fn supported_lengths(&self, dtype: &str) -> Vec<u64> {
        self.routes
            .keys()
            .filter(|(_, d)| d == dtype)
            .map(|(n, _)| *n)
            .collect()
    }

    /// The (signal length, taps) pairs with a conv artifact for `dtype`.
    pub fn supported_kernels(&self, dtype: &str) -> Vec<(u64, u64)> {
        self.conv_routes
            .keys()
            .filter(|(_, _, d)| d == dtype)
            .map(|(n, taps, _)| (*n, *taps))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.routes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
    }

    /// Least-loaded dispatch across a fleet: the index of the card with the
    /// fewest in-flight jobs (ties broken toward the lowest index, so a
    /// cold fleet fills deterministically). `None` on an empty fleet.
    pub fn least_loaded(loads: &[u64]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .min_by_key(|&(i, &load)| (load, i))
            .map(|(i, _)| i)
    }

    /// Health-gated dispatch: least-loaded among the cards whose
    /// `eligible` flag is set (accepting, not quarantined). `None` when
    /// no card is eligible — the caller maps that to a typed
    /// [`CoordError::CardUnavailable`] instead of panicking.
    pub fn least_loaded_among(loads: &[u64], eligible: &[bool]) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .filter(|&(i, _)| eligible.get(i).copied().unwrap_or(false))
            .min_by_key(|&(i, &load)| (load, i))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\n\
            fft_f32_n256_b256\tf1\tfft\t256\t256\tf32\t0\tf32:256x256;f32:256x256\t2\td\n\
            fft_f32_n1024_b64\tf2\tfft\t1024\t64\tf32\t0\tf32:64x1024;f32:64x1024\t2\td\n\
            fft_f64_n1024_b64\tf3\tfft\t1024\t64\tf64\t0\tf64:64x1024;f64:64x1024\t2\td\n\
            pipeline_n16384_h8\tf4\tpipeline\t16384\t4\tf32\t8\tf32:4x16384;f32:4x16384\t3\td\n\
            conv_f32_n1024_t33_b16\tf5\tconv\t1024\t16\tf32\t33\tf32:16x1024\t1\td\n";
        Manifest::parse(Path::new("."), text).unwrap()
    }

    #[test]
    fn routes_ffts_only() {
        let r = Router::from_manifest(&manifest());
        assert_eq!(r.len(), 3);
        let e = r.route(1024, "f32").unwrap();
        assert_eq!(&*e.artifact, "fft_f32_n1024_b64");
        assert_eq!(e.device_batch, 64);
    }

    #[test]
    fn unsupported_length_rejected_with_taxonomy() {
        let r = Router::from_manifest(&manifest());
        match r.route(512, "f32") {
            Err(CoordError::UnsupportedLength { n, dtype, supported }) => {
                assert_eq!(n, 512);
                assert_eq!(dtype, "f32");
                assert_eq!(supported, vec![256, 1024], "must name the routable lengths");
            }
            other => panic!("expected UnsupportedLength, got {other:?}"),
        }
        assert!(r.route(1024, "f16").is_err());
    }

    #[test]
    fn conv_routes_by_length_and_taps() {
        let r = Router::from_manifest(&manifest());
        let e = r.route_conv(1024, 33, "f32").unwrap();
        assert_eq!(&*e.artifact, "conv_f32_n1024_t33_b16");
        assert_eq!(e.device_batch, 16);
        assert_eq!(r.supported_kernels("f32"), vec![(1024, 33)]);
        // conv artifacts never enter the complex-fft table
        assert!(r.route(1024, "f32").is_ok());
        assert_eq!(r.len(), 3, "fft routes only");
    }

    #[test]
    fn unsupported_kernel_rejected_with_taxonomy() {
        let r = Router::from_manifest(&manifest());
        // No artifact for these taps.
        match r.route_conv(1024, 65, "f32") {
            Err(CoordError::UnsupportedKernel { n, taps, supported }) => {
                assert_eq!((n, taps), (1024, 65));
                assert_eq!(supported, vec![(1024, 33)], "must name routable kernels");
            }
            other => panic!("expected UnsupportedKernel, got {other:?}"),
        }
        // Invalid tap counts are refused before the lookup: zero taps and
        // kernels longer than the signal.
        assert!(matches!(
            r.route_conv(1024, 0, "f32"),
            Err(CoordError::UnsupportedKernel { taps: 0, .. })
        ));
        assert!(matches!(
            r.route_conv(16, 33, "f32"),
            Err(CoordError::UnsupportedKernel { n: 16, taps: 33, .. })
        ));
    }

    #[test]
    fn supported_lengths_by_dtype() {
        let r = Router::from_manifest(&manifest());
        assert_eq!(r.supported_lengths("f32"), vec![256, 1024]);
        assert_eq!(r.supported_lengths("f64"), vec![1024]);
    }

    #[test]
    fn least_loaded_prefers_min_then_lowest_index() {
        assert_eq!(Router::least_loaded(&[3, 1, 2]), Some(1));
        assert_eq!(Router::least_loaded(&[2, 2, 2]), Some(0));
        assert_eq!(Router::least_loaded(&[5]), Some(0));
        assert_eq!(Router::least_loaded(&[]), None);
        // dispatching into the returned slot converges toward balance
        let mut loads = vec![4u64, 0, 2];
        for _ in 0..6 {
            let i = Router::least_loaded(&loads).unwrap();
            loads[i] += 1;
        }
        assert_eq!(loads, vec![4, 4, 4]);
    }

    #[test]
    fn least_loaded_among_respects_eligibility() {
        // the global minimum (index 1) is ineligible → next-best wins
        assert_eq!(
            Router::least_loaded_among(&[3, 1, 2], &[true, false, true]),
            Some(2)
        );
        // ties among eligible cards break toward the lowest index
        assert_eq!(
            Router::least_loaded_among(&[2, 2, 2], &[false, true, true]),
            Some(1)
        );
        // nobody eligible → None (typed error upstream, not a panic)
        assert_eq!(Router::least_loaded_among(&[1, 2], &[false, false]), None);
        assert_eq!(Router::least_loaded_among(&[], &[]), None);
        // a short eligibility slice treats missing entries as ineligible
        assert_eq!(Router::least_loaded_among(&[5, 0], &[true]), Some(0));
    }
}
