//! Dynamic batcher: packs single-transform jobs into the fixed device batch
//! of their artifact, padding partial batches with zeros. Batches are keyed
//! by (artifact, card) so a fleet engine can pack independently per card.
//!
//! Invariants (property-tested):
//!   * every submitted job appears in exactly one flushed batch,
//!   * jobs only share a batch with jobs of the same (n, dtype) on the
//!     same card,
//!   * a batch never exceeds the artifact's device batch,
//!   * flush-on-timeout emits partial batches (no starvation).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::job::Envelope;
use crate::coordinator::CoordError;
use crate::runtime::BackendCaps;

/// A packed batch ready for execution on one card.
pub struct PackedBatch {
    /// Interned artifact name (shared with the router's route entry).
    pub artifact: Arc<str>,
    pub n: u64,
    pub device_batch: u64,
    /// Fleet card index this batch was packed for.
    pub card: usize,
    /// The member jobs, in packing order (row i of the device batch).
    pub envelopes: Vec<Envelope>,
}

impl PackedBatch {
    /// Concatenated, zero-padded input planes (device_batch × n each).
    pub fn planes(&self) -> (Vec<f32>, Vec<f32>) {
        let mut re = Vec::new();
        let mut im = Vec::new();
        self.planes_into(&mut re, &mut im);
        (re, im)
    }

    /// Fill caller-owned plane buffers (resize + zero + pack): a worker
    /// reusing the same two `Vec`s per batch never reallocates once they
    /// reach the card's largest device batch.
    pub fn planes_into(&self, re: &mut Vec<f32>, im: &mut Vec<f32>) {
        let total = (self.device_batch * self.n) as usize;
        re.clear();
        re.resize(total, 0.0);
        im.clear();
        im.resize(total, 0.0);
        for (i, env) in self.envelopes.iter().enumerate() {
            let off = i * self.n as usize;
            re[off..off + self.n as usize].copy_from_slice(&env.job.re);
            im[off..off + self.n as usize].copy_from_slice(&env.job.im);
        }
    }

    pub fn occupancy(&self) -> usize {
        self.envelopes.len()
    }
}

struct Pending {
    artifact: Arc<str>,
    n: u64,
    device_batch: u64,
    card: usize,
    envelopes: Vec<Envelope>,
    oldest: Instant,
}

/// The batcher. Not thread-safe by itself; the engine owns it behind a lock.
pub struct Batcher {
    pending: BTreeMap<(Arc<str>, usize), Pending>,
    /// The serving backend's advertised envelope: admission is gated on
    /// what the backend says it can execute, not on planner internals.
    caps: BackendCaps,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_wait: Duration, caps: BackendCaps) -> Self {
        Self {
            pending: BTreeMap::new(),
            caps,
            max_wait,
        }
    }

    /// Add a job under its (route, card); returns `Ok(Some(batch))` when
    /// the slot reached the device batch. Rejections are typed
    /// ([`CoordError`]) and happen at submit time:
    ///   * a length outside the backend's advertised capability envelope
    ///     is refused before it can reach (and panic) a worker thread,
    ///   * a transform-length mismatch against an existing slot is a hard
    ///     error (in release builds it previously survived as a
    ///     `debug_assert` until `planes()` panicked mid-copy): the job is
    ///     rejected, the slot is left intact.
    pub fn push(
        &mut self,
        artifact: &Arc<str>,
        n: u64,
        device_batch: u64,
        card: usize,
        env: Envelope,
    ) -> anyhow::Result<Option<PackedBatch>> {
        if !self.caps.supports_len(n) {
            return Err(CoordError::PlanUnsupported { n }.into());
        }
        let key = (artifact.clone(), card);
        let slot = self.pending.entry(key.clone()).or_insert_with(|| Pending {
            artifact: artifact.clone(),
            n,
            device_batch,
            card,
            envelopes: Vec::new(),
            oldest: Instant::now(),
        });
        if slot.n != n {
            return Err(CoordError::LengthMismatch {
                artifact: artifact.to_string(),
                expected: slot.n,
                got: n,
            }
            .into());
        }
        if slot.envelopes.is_empty() {
            slot.oldest = Instant::now();
        }
        slot.envelopes.push(env);
        if slot.envelopes.len() as u64 >= slot.device_batch {
            return Ok(self.take(&key));
        }
        Ok(None)
    }

    /// Remove and return the pending batch for an (artifact, card) slot.
    /// This is the batch-seal point: every member job's `seal` trace
    /// stamp is set here, whether the batch closed full or was flushed.
    fn take(&mut self, key: &(Arc<str>, usize)) -> Option<PackedBatch> {
        self.pending.remove(key).map(|mut p| {
            let sealed = Instant::now();
            for env in &mut p.envelopes {
                env.stamps.seal = sealed;
            }
            PackedBatch {
                artifact: p.artifact,
                n: p.n,
                device_batch: p.device_batch,
                card: p.card,
                envelopes: p.envelopes,
            }
        })
    }

    /// Targeted flush of one (artifact, card) slot — lets a blocking caller
    /// release just its own partial batch while unrelated traffic keeps
    /// packing toward full batches. Takes the interned key for a direct
    /// map lookup (no scan over unrelated slots).
    pub fn flush_slot(&mut self, artifact: &Arc<str>, card: usize) -> Option<PackedBatch> {
        self.take(&(artifact.clone(), card))
    }

    /// Flush every pending batch older than `max_wait` (timer tick), or all
    /// of them when `force` (shutdown/drain).
    pub fn flush(&mut self, force: bool) -> Vec<PackedBatch> {
        let now = Instant::now();
        let due: Vec<(Arc<str>, usize)> = self
            .pending
            .iter()
            .filter(|(_, p)| force || now.duration_since(p.oldest) >= self.max_wait)
            .map(|(k, _)| k.clone())
            .collect();
        due.iter().filter_map(|k| self.take(k)).collect()
    }

    /// Flush every pending slot packed for one card, regardless of age —
    /// the drain path: a card leaving service must not strand partial
    /// batches in its slots. Other cards' slots keep packing.
    pub fn flush_card(&mut self, card: usize) -> Vec<PackedBatch> {
        let keys: Vec<(Arc<str>, usize)> = self
            .pending
            .iter()
            .filter(|(_, p)| p.card == card)
            .map(|(k, _)| k.clone())
            .collect();
        keys.iter().filter_map(|k| self.take(k)).collect()
    }

    /// Class-ordered backpressure: remove one queued envelope from
    /// `card`'s partial slots that `class` strictly outranks, preferring
    /// the lowest class present (scavenger before batch) and, within a
    /// class, the most recently queued job (oldest lower-class work has
    /// waited longest and is closest to sealing). Returns `None` when
    /// nothing on the card is outranked — the caller then refuses the
    /// new job with `QueueFull` instead of evicting a peer or better.
    /// The victim comes back with its artifact so the caller can stamp
    /// its shed span correctly (it may sit in a different slot than the
    /// job being admitted).
    pub fn evict_lower_class(
        &mut self,
        card: usize,
        class: crate::coordinator::admission::TenantClass,
    ) -> Option<(Arc<str>, Envelope)> {
        let mut victim: Option<((Arc<str>, usize), usize, usize)> = None;
        for (key, p) in self.pending.iter() {
            if p.card != card {
                continue;
            }
            for (i, env) in p.envelopes.iter().enumerate() {
                if !class.outranks(env.job.class) {
                    continue;
                }
                let rank = env.job.class.index();
                let better = match &victim {
                    None => true,
                    // Lower class first; within a class the later index
                    // (younger) is preferred, so >= keeps scanning.
                    Some((_, _, best_rank)) => rank >= *best_rank,
                };
                if better {
                    victim = Some((key.clone(), i, rank));
                }
            }
        }
        let (key, idx, _) = victim?;
        let slot = self.pending.get_mut(&key)?;
        let env = slot.envelopes.remove(idx);
        if slot.envelopes.is_empty() {
            self.pending.remove(&key);
        }
        Some((key.0, env))
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(|p| p.envelopes.len()).sum()
    }

    /// Jobs queued in partial batches for one fleet card — the "offered
    /// load" signal the power-budget arbiter folds into its shares.
    pub fn pending_jobs_for_card(&self, card: usize) -> usize {
        self.pending
            .values()
            .filter(|p| p.card == card)
            .map(|p| p.envelopes.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::FftJob;
    use std::sync::mpsc;

    fn env(id: u64, n: usize) -> (Envelope, mpsc::Receiver<anyhow::Result<crate::coordinator::job::JobResult>>) {
        let (tx, rx) = mpsc::channel();
        (
            Envelope::new(FftJob::new(id, vec![id as f32; n], vec![0.0; n]), tx),
            rx,
        )
    }

    fn name(s: &str) -> Arc<str> {
        Arc::from(s)
    }

    /// A wide-open capability envelope (every n >= 1), matching what the
    /// sim backend advertises — the admission behaviour these tests pin.
    fn caps() -> BackendCaps {
        BackendCaps {
            backend: "test",
            kinds: vec!["fft", "rfft", "conv"],
            min_n: 1,
            max_n: u64::MAX,
            pow2_only: false,
            precisions: vec![crate::types::Precision::Fp32],
            split_complex_planes: true,
            locked_clocks: true,
            nvml: false,
            device_mem_bytes: 0,
            l2_bytes: 256 * 1024,
            dev_bw_gbs: 0.0,
            shared_bw_gbs: 0.0,
        }
    }

    #[test]
    fn fills_batch_at_device_capacity() {
        let mut b = Batcher::new(Duration::from_millis(5), caps());
        let a = name("a");
        let mut got = None;
        for i in 0..4 {
            let (e, _rx) = env(i, 8);
            got = b.push(&a, 8, 4, 0, e).unwrap();
        }
        let batch = got.expect("4th push must flush");
        assert_eq!(batch.occupancy(), 4);
        assert_eq!(batch.card, 0);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn partial_batch_flushes_on_force() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e, _rx) = env(0, 8);
        assert!(b.push(&a, 8, 4, 0, e).unwrap().is_none());
        assert_eq!(b.pending_jobs(), 1);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].occupancy(), 1);
    }

    #[test]
    fn timeout_flush() {
        let mut b = Batcher::new(Duration::from_millis(1), caps());
        let a = name("a");
        let (e, _rx) = env(0, 8);
        b.push(&a, 8, 4, 0, e).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.flush(false).len(), 1);
    }

    #[test]
    fn separate_artifacts_never_mix() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 16);
        b.push(&name("a8"), 8, 4, 0, e1).unwrap();
        b.push(&name("a16"), 16, 4, 0, e2).unwrap();
        let batches = b.flush(true);
        assert_eq!(batches.len(), 2);
        for batch in &batches {
            let n = batch.n;
            assert!(batch.envelopes.iter().all(|e| e.job.n == n));
        }
    }

    #[test]
    fn separate_cards_never_mix() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 8);
        b.push(&a, 8, 4, 0, e1).unwrap();
        b.push(&a, 8, 4, 1, e2).unwrap();
        assert_eq!(b.pending_jobs(), 2);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 2, "same artifact, different cards");
        for batch in &batches {
            assert_eq!(batch.occupancy(), 1);
            assert_eq!(batch.envelopes[0].job.id as usize, batch.card + 1);
        }
    }

    #[test]
    fn length_mismatch_is_a_typed_error() {
        // Promoted from a debug_assert: a route/artifact mismatch must be
        // rejected in release builds too, before it can corrupt planes() —
        // and as a CoordError callers can match on.
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e1, _r1) = env(1, 8);
        assert!(b.push(&a, 8, 4, 0, e1).unwrap().is_none());
        let (e2, _r2) = env(2, 16);
        let err = b.push(&a, 16, 4, 0, e2).expect_err("mismatched n must error");
        match err.downcast_ref::<CoordError>() {
            Some(CoordError::LengthMismatch { artifact, expected, got }) => {
                assert_eq!(artifact.as_str(), "a");
                assert_eq!((*expected, *got), (8, 16));
            }
            other => panic!("expected LengthMismatch, got {other:?}"),
        }
        // The existing slot is untouched and still flushes its one job.
        assert_eq!(b.pending_jobs(), 1);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].envelopes[0].job.id, 1);
    }

    #[test]
    fn unplannable_length_rejected_at_submit_time() {
        // n=0 has no execution plan: the push must refuse it with a typed
        // error instead of letting a worker thread panic on it later.
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e, _rx) = env(1, 0);
        let err = b.push(&a, 0, 4, 0, e).expect_err("n=0 must be refused");
        match err.downcast_ref::<CoordError>() {
            Some(CoordError::PlanUnsupported { n }) => assert_eq!(*n, 0),
            other => panic!("expected PlanUnsupported, got {other:?}"),
        }
        // Nothing was queued: no slot, no pending jobs.
        assert_eq!(b.pending_jobs(), 0);
        assert!(b.flush(true).is_empty());
    }

    #[test]
    fn flush_slot_is_targeted() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let other = name("other");
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 8);
        let (e3, _r3) = env(3, 8);
        b.push(&a, 8, 4, 0, e1).unwrap();
        b.push(&a, 8, 4, 1, e2).unwrap();
        b.push(&other, 8, 4, 0, e3).unwrap();
        // Only (a, card 0) flushes; the other card's slot and the other
        // artifact keep packing.
        let batch = b.flush_slot(&a, 0).expect("slot had a partial batch");
        assert_eq!(batch.card, 0);
        assert_eq!(batch.envelopes[0].job.id, 1);
        assert_eq!(b.pending_jobs(), 2);
        assert!(b.flush_slot(&a, 0).is_none(), "slot already empty");
        assert!(b.flush_slot(&name("missing"), 0).is_none());
    }

    #[test]
    fn flush_card_drains_only_that_card() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let other = name("b");
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 8);
        let (e3, _r3) = env(3, 8);
        b.push(&a, 8, 4, 0, e1).unwrap();
        b.push(&other, 8, 4, 0, e2).unwrap();
        b.push(&a, 8, 4, 1, e3).unwrap();
        // Card 0 drains both its artifact slots; card 1 keeps packing.
        let drained = b.flush_card(0);
        assert_eq!(drained.len(), 2);
        assert!(drained.iter().all(|batch| batch.card == 0));
        assert_eq!(b.pending_jobs(), 1);
        assert_eq!(b.pending_jobs_for_card(1), 1);
        assert!(b.flush_card(0).is_empty(), "already drained");
        assert!(b.flush_card(9).is_empty(), "unknown card is a no-op");
    }

    #[test]
    fn pending_jobs_per_card_counts_only_that_card() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let other = name("b");
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 8);
        let (e3, _r3) = env(3, 8);
        b.push(&a, 8, 4, 0, e1).unwrap();
        b.push(&a, 8, 4, 1, e2).unwrap();
        b.push(&other, 8, 4, 0, e3).unwrap();
        assert_eq!(b.pending_jobs_for_card(0), 2);
        assert_eq!(b.pending_jobs_for_card(1), 1);
        assert_eq!(b.pending_jobs_for_card(2), 0);
        assert_eq!(b.pending_jobs(), 3);
    }

    #[test]
    fn eviction_is_class_ordered_and_never_touches_peers() {
        use crate::coordinator::admission::TenantClass;
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let mut push = |id: u64, card: usize, class: TenantClass| {
            let (tx, rx) = mpsc::channel();
            let env = Envelope::new(
                FftJob::new(id, vec![0.0; 8], vec![0.0; 8]).with_class(class),
                tx,
            );
            b.push(&a, 8, 16, card, env).unwrap();
            rx
        };
        let _r1 = push(1, 0, TenantClass::Scavenger);
        let _r2 = push(2, 0, TenantClass::Batch);
        let _r3 = push(3, 0, TenantClass::Scavenger);
        let _r4 = push(4, 1, TenantClass::Scavenger); // other card: untouchable

        // Realtime pressure evicts scavenger before batch, youngest first.
        let (art, v) = b.evict_lower_class(0, TenantClass::Realtime).expect("victim");
        assert_eq!((v.job.id, v.job.class), (3, TenantClass::Scavenger));
        assert_eq!(art.as_ref(), "a", "victim reports its own slot's artifact");
        let (_, v) = b.evict_lower_class(0, TenantClass::Realtime).expect("victim");
        assert_eq!((v.job.id, v.job.class), (1, TenantClass::Scavenger));
        // Scavenger exhausted on card 0: batch is next for realtime…
        let (_, v) = b.evict_lower_class(0, TenantClass::Realtime).expect("victim");
        assert_eq!(v.job.id, 2);
        // …but a batch job may never evict a batch peer, and nothing on
        // card 0 remains below realtime either.
        assert!(b.evict_lower_class(0, TenantClass::Batch).is_none());
        assert!(b.evict_lower_class(0, TenantClass::Realtime).is_none());
        // Card 1's scavenger job was never considered.
        assert_eq!(b.pending_jobs_for_card(1), 1);
        // Emptied slots are gone: card 0 flushes nothing.
        assert!(b.flush_card(0).is_empty());
    }

    #[test]
    fn planes_zero_padded() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let (e, _rx) = env(3, 4);
        b.push(&name("a"), 4, 3, 0, e).unwrap();
        let batch = b.flush(true).pop().unwrap();
        let (re, im) = batch.planes();
        assert_eq!(re.len(), 12);
        assert_eq!(&re[0..4], &[3.0; 4]);
        assert_eq!(&re[4..12], &[0.0; 8]);
        assert!(im.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn planes_into_reuses_and_rezeroes_buffers() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e, _rx) = env(7, 4);
        b.push(&a, 4, 3, 0, e).unwrap();
        let batch = b.flush(true).pop().unwrap();
        let mut re = Vec::new();
        let mut im = Vec::new();
        batch.planes_into(&mut re, &mut im);
        assert_eq!(&re[0..4], &[7.0; 4]);
        let ptr = re.as_ptr();
        // A smaller follow-up batch through the same buffers: padding must
        // be re-zeroed (no stale rows) and no reallocation happens.
        let (e2, _rx2) = env(0, 4);
        b.push(&a, 4, 3, 0, e2).unwrap();
        let batch2 = b.flush(true).pop().unwrap();
        batch2.planes_into(&mut re, &mut im);
        assert_eq!(re.as_ptr(), ptr, "reused buffers must not reallocate");
        assert!(re[4..].iter().all(|&x| x == 0.0), "padding re-zeroed");
    }

    #[test]
    fn take_stamps_batch_seal_on_every_member() {
        let mut b = Batcher::new(Duration::from_secs(10), caps());
        let a = name("a");
        let (e, _rx) = env(1, 8);
        let enqueue = e.stamps.enqueue;
        b.push(&a, 8, 4, 0, e).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let batch = b.flush(true).pop().unwrap();
        let stamps = batch.envelopes[0].stamps;
        assert!(
            stamps.seal.duration_since(enqueue) >= Duration::from_millis(2),
            "seal must be stamped at take time, not submit time"
        );
    }

    #[test]
    fn prop_every_job_flushed_exactly_once() {
        crate::util::prop::check(
            "batcher conservation",
            |rng| {
                let jobs = rng.range_u64(1, 40) as usize;
                let device_batch = rng.range_u64(1, 8);
                let cards = rng.range_u64(1, 4) as usize;
                (jobs, device_batch, cards)
            },
            |&(jobs, device_batch, cards)| {
                let mut b = Batcher::new(Duration::from_secs(100), caps());
                let a = name("a");
                let mut seen = Vec::new();
                let mut rxs = Vec::new();
                for i in 0..jobs {
                    let (e, rx) = env(i as u64, 8);
                    rxs.push(rx);
                    if let Some(batch) = b.push(&a, 8, device_batch, i % cards, e).unwrap() {
                        seen.extend(batch.envelopes.iter().map(|e| e.job.id));
                        if batch.occupancy() as u64 != device_batch {
                            return Err(format!(
                                "full batch had {} jobs, want {}",
                                batch.occupancy(),
                                device_batch
                            ));
                        }
                    }
                }
                for batch in b.flush(true) {
                    seen.extend(batch.envelopes.iter().map(|e| e.job.id));
                }
                seen.sort_unstable();
                let want: Vec<u64> = (0..jobs as u64).collect();
                if seen != want {
                    return Err(format!("jobs lost/duplicated: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
