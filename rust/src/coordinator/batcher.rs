//! Dynamic batcher: packs single-transform jobs into the fixed device batch
//! of their artifact, padding partial batches with zeros. Batches are keyed
//! by (artifact, card) so a fleet engine can pack independently per card.
//!
//! Invariants (property-tested):
//!   * every submitted job appears in exactly one flushed batch,
//!   * jobs only share a batch with jobs of the same (n, dtype) on the
//!     same card,
//!   * a batch never exceeds the artifact's device batch,
//!   * flush-on-timeout emits partial batches (no starvation).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::coordinator::job::Envelope;

/// A packed batch ready for execution on one card.
pub struct PackedBatch {
    pub artifact: String,
    pub n: u64,
    pub device_batch: u64,
    /// Fleet card index this batch was packed for.
    pub card: usize,
    /// The member jobs, in packing order (row i of the device batch).
    pub envelopes: Vec<Envelope>,
}

impl PackedBatch {
    /// Concatenated, zero-padded input planes (device_batch × n each).
    pub fn planes(&self) -> (Vec<f32>, Vec<f32>) {
        let total = (self.device_batch * self.n) as usize;
        let mut re = vec![0.0f32; total];
        let mut im = vec![0.0f32; total];
        for (i, env) in self.envelopes.iter().enumerate() {
            let off = i * self.n as usize;
            re[off..off + self.n as usize].copy_from_slice(&env.job.re);
            im[off..off + self.n as usize].copy_from_slice(&env.job.im);
        }
        (re, im)
    }

    pub fn occupancy(&self) -> usize {
        self.envelopes.len()
    }
}

struct Pending {
    artifact: String,
    n: u64,
    device_batch: u64,
    card: usize,
    envelopes: Vec<Envelope>,
    oldest: Instant,
}

/// The batcher. Not thread-safe by itself; the engine owns it behind a lock.
pub struct Batcher {
    pending: BTreeMap<(String, usize), Pending>,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_wait: Duration) -> Self {
        Self {
            pending: BTreeMap::new(),
            max_wait,
        }
    }

    /// Add a job under its (route, card); returns a batch if one became full.
    pub fn push(
        &mut self,
        artifact: &str,
        n: u64,
        device_batch: u64,
        card: usize,
        env: Envelope,
    ) -> Option<PackedBatch> {
        let key = (artifact.to_string(), card);
        let slot = self.pending.entry(key.clone()).or_insert_with(|| Pending {
            artifact: artifact.to_string(),
            n,
            device_batch,
            card,
            envelopes: Vec::new(),
            oldest: Instant::now(),
        });
        debug_assert_eq!(slot.n, n, "route/artifact length mismatch");
        if slot.envelopes.is_empty() {
            slot.oldest = Instant::now();
        }
        slot.envelopes.push(env);
        if slot.envelopes.len() as u64 >= slot.device_batch {
            return self.take(&key);
        }
        None
    }

    /// Remove and return the pending batch for an (artifact, card) slot.
    fn take(&mut self, key: &(String, usize)) -> Option<PackedBatch> {
        self.pending.remove(key).map(|p| PackedBatch {
            artifact: p.artifact,
            n: p.n,
            device_batch: p.device_batch,
            card: p.card,
            envelopes: p.envelopes,
        })
    }

    /// Flush every pending batch older than `max_wait` (timer tick), or all
    /// of them when `force` (shutdown/drain).
    pub fn flush(&mut self, force: bool) -> Vec<PackedBatch> {
        let now = Instant::now();
        let due: Vec<(String, usize)> = self
            .pending
            .iter()
            .filter(|(_, p)| force || now.duration_since(p.oldest) >= self.max_wait)
            .map(|(k, _)| k.clone())
            .collect();
        due.iter().filter_map(|k| self.take(k)).collect()
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.values().map(|p| p.envelopes.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::FftJob;
    use std::sync::mpsc;

    fn env(id: u64, n: usize) -> (Envelope, mpsc::Receiver<anyhow::Result<crate::coordinator::job::JobResult>>) {
        let (tx, rx) = mpsc::channel();
        (
            Envelope {
                job: FftJob::new(id, vec![id as f32; n], vec![0.0; n]),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fills_batch_at_device_capacity() {
        let mut b = Batcher::new(Duration::from_millis(5));
        let mut got = None;
        for i in 0..4 {
            let (e, _rx) = env(i, 8);
            got = b.push("a", 8, 4, 0, e);
        }
        let batch = got.expect("4th push must flush");
        assert_eq!(batch.occupancy(), 4);
        assert_eq!(batch.card, 0);
        assert_eq!(b.pending_jobs(), 0);
    }

    #[test]
    fn partial_batch_flushes_on_force() {
        let mut b = Batcher::new(Duration::from_secs(10));
        let (e, _rx) = env(0, 8);
        assert!(b.push("a", 8, 4, 0, e).is_none());
        assert_eq!(b.pending_jobs(), 1);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].occupancy(), 1);
    }

    #[test]
    fn timeout_flush() {
        let mut b = Batcher::new(Duration::from_millis(1));
        let (e, _rx) = env(0, 8);
        b.push("a", 8, 4, 0, e);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.flush(false).len(), 1);
    }

    #[test]
    fn separate_artifacts_never_mix() {
        let mut b = Batcher::new(Duration::from_secs(10));
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 16);
        b.push("a8", 8, 4, 0, e1);
        b.push("a16", 16, 4, 0, e2);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 2);
        for batch in &batches {
            let n = batch.n;
            assert!(batch.envelopes.iter().all(|e| e.job.n == n));
        }
    }

    #[test]
    fn separate_cards_never_mix() {
        let mut b = Batcher::new(Duration::from_secs(10));
        let (e1, _r1) = env(1, 8);
        let (e2, _r2) = env(2, 8);
        b.push("a", 8, 4, 0, e1);
        b.push("a", 8, 4, 1, e2);
        assert_eq!(b.pending_jobs(), 2);
        let batches = b.flush(true);
        assert_eq!(batches.len(), 2, "same artifact, different cards");
        for batch in &batches {
            assert_eq!(batch.occupancy(), 1);
            assert_eq!(batch.envelopes[0].job.id as usize, batch.card + 1);
        }
    }

    #[test]
    fn planes_zero_padded() {
        let mut b = Batcher::new(Duration::from_secs(10));
        let (e, _rx) = env(3, 4);
        b.push("a", 4, 3, 0, e);
        let batch = b.flush(true).pop().unwrap();
        let (re, im) = batch.planes();
        assert_eq!(re.len(), 12);
        assert_eq!(&re[0..4], &[3.0; 4]);
        assert_eq!(&re[4..12], &[0.0; 8]);
        assert!(im.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_every_job_flushed_exactly_once() {
        crate::util::prop::check(
            "batcher conservation",
            |rng| {
                let jobs = rng.range_u64(1, 40) as usize;
                let device_batch = rng.range_u64(1, 8);
                let cards = rng.range_u64(1, 4) as usize;
                (jobs, device_batch, cards)
            },
            |&(jobs, device_batch, cards)| {
                let mut b = Batcher::new(Duration::from_secs(100));
                let mut seen = Vec::new();
                let mut rxs = Vec::new();
                for i in 0..jobs {
                    let (e, rx) = env(i as u64, 8);
                    rxs.push(rx);
                    if let Some(batch) = b.push("a", 8, device_batch, i % cards, e) {
                        seen.extend(batch.envelopes.iter().map(|e| e.job.id));
                        if batch.occupancy() as u64 != device_batch {
                            return Err(format!(
                                "full batch had {} jobs, want {}",
                                batch.occupancy(),
                                device_batch
                            ));
                        }
                    }
                }
                for batch in b.flush(true) {
                    seen.extend(batch.envelopes.iter().map(|e| e.job.id));
                }
                seen.sort_unstable();
                let want: Vec<u64> = (0..jobs as u64).collect();
                if seen != want {
                    return Err(format!("jobs lost/duplicated: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
