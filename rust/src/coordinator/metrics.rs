//! Coordinator metrics: throughput, latency, batch occupancy, and the
//! simulated energy accounting that ties the serving loop back to the
//! paper's DVFS result.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Full-precision energy totals, J. An `f64` pair behind a mutex instead
/// of the old atomic-µJ counters: `(energy_j * 1e6) as u64` dropped the
/// fractional microjoule of *every* batch, a systematic undercount that
/// made low-power fleets look free (10k batches of 0.9 µJ summed to 0).
#[derive(Debug, Default, Clone, Copy)]
struct EnergyTotals {
    j: f64,
    boost_j: f64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Jobs re-dispatched after a batch error (counted on the card the
    /// retry landed on; the original submit keeps its one
    /// `jobs_submitted`).
    pub jobs_retried: AtomicU64,
    /// Jobs dropped with a typed error — retries exhausted, no eligible
    /// card, or shutdown — a subset of `jobs_failed`.
    pub jobs_shed: AtomicU64,
    /// Batches that errored (injected fault or execution failure) before
    /// their jobs went to the retry path.
    pub batch_errors: AtomicU64,
    pub batches_executed: AtomicU64,
    pub batch_rows_used: AtomicU64,
    pub batch_rows_total: AtomicU64,
    pub exec_us_total: AtomicU64,
    energy: Mutex<EnergyTotals>,
}

impl Metrics {
    /// Poison-recovering lock: metrics accumulation must survive a worker
    /// panicking mid-batch — an `f64` pair is valid under any interleaving,
    /// so the poison flag carries no information worth dying for.
    fn energy_guard(&self) -> MutexGuard<'_, EnergyTotals> {
        self.energy.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn record_batch(&self, rows_used: usize, rows_total: u64, exec_us: u64) {
        self.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.batch_rows_used.fetch_add(rows_used as u64, Ordering::Relaxed);
        self.batch_rows_total.fetch_add(rows_total, Ordering::Relaxed);
        self.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
    }

    pub fn record_energy(&self, energy_j: f64, boost_energy_j: f64) {
        let mut e = self.energy_guard();
        e.j += energy_j;
        e.boost_j += boost_energy_j;
    }

    /// Simulated GPU energy at the governed clocks, J (full precision).
    pub fn energy_j(&self) -> f64 {
        self.energy_guard().j
    }

    /// Simulated GPU energy had every batch run at boost, J.
    pub fn boost_energy_j(&self) -> f64 {
        self.energy_guard().boost_j
    }

    pub fn occupancy(&self) -> f64 {
        let total = self.batch_rows_total.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.batch_rows_used.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Energy saved by DVFS relative to boost (fraction).
    pub fn energy_saving(&self) -> f64 {
        let e = *self.energy_guard();
        if e.boost_j <= 0.0 {
            return 0.0;
        }
        1.0 - e.j / e.boost_j
    }

    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} ok ({} failed), batches {}, occupancy {:.1}%, exec {:.3} s, energy saving {:.1}%",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.occupancy() * 100.0,
            self.exec_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            self.energy_saving() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let m = Metrics::default();
        m.record_batch(3, 4, 100);
        m.record_batch(4, 4, 100);
        assert!((m.occupancy() - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn energy_saving_math() {
        let m = Metrics::default();
        m.record_energy(60.0, 100.0);
        assert!((m.energy_saving() - 0.4).abs() < 1e-3);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.energy_saving(), 0.0);
        assert!(m.summary().contains("jobs 0/0"));
    }

    #[test]
    fn many_sub_microjoule_batches_sum_exactly() {
        // The truncation regression: the old `(j * 1e6) as u64` counters
        // floored every batch to whole microjoules, so 10_000 batches of
        // 0.9 µJ (vs 1.9 µJ at boost) accounted as 0 J saved at 0 J spent.
        let m = Metrics::default();
        for _ in 0..10_000 {
            m.record_energy(0.9e-6, 1.9e-6);
        }
        assert!((m.energy_j() - 9.0e-3).abs() < 1e-12, "{}", m.energy_j());
        assert!((m.boost_energy_j() - 19.0e-3).abs() < 1e-12);
        assert!((m.energy_saving() - (1.0 - 9.0 / 19.0)).abs() < 1e-12);
    }

    #[test]
    fn robustness_counters_start_at_zero() {
        let m = Metrics::default();
        assert_eq!(m.jobs_retried.load(Ordering::Relaxed), 0);
        assert_eq!(m.jobs_shed.load(Ordering::Relaxed), 0);
        assert_eq!(m.batch_errors.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fractional_joules_survive_mixed_magnitudes() {
        let m = Metrics::default();
        m.record_energy(1234.5, 2000.25);
        m.record_energy(0.5, 0.75);
        assert_eq!(m.energy_j(), 1235.0);
        assert_eq!(m.boost_energy_j(), 2001.0);
    }
}
