//! Coordinator metrics: throughput, latency, batch occupancy, and the
//! simulated energy accounting that ties the serving loop back to the
//! paper's DVFS result.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub batches_executed: AtomicU64,
    pub batch_rows_used: AtomicU64,
    pub batch_rows_total: AtomicU64,
    pub exec_us_total: AtomicU64,
    /// Simulated GPU energy at the coordinator's current clock, microjoules.
    pub sim_energy_uj: AtomicU64,
    /// Simulated GPU energy had every batch run at boost, microjoules.
    pub sim_energy_boost_uj: AtomicU64,
}

impl Metrics {
    pub fn record_batch(&self, rows_used: usize, rows_total: u64, exec_us: u64) {
        self.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.batch_rows_used.fetch_add(rows_used as u64, Ordering::Relaxed);
        self.batch_rows_total.fetch_add(rows_total, Ordering::Relaxed);
        self.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
    }

    pub fn record_energy(&self, energy_j: f64, boost_energy_j: f64) {
        self.sim_energy_uj
            .fetch_add((energy_j * 1e6) as u64, Ordering::Relaxed);
        self.sim_energy_boost_uj
            .fetch_add((boost_energy_j * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn occupancy(&self) -> f64 {
        let total = self.batch_rows_total.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.batch_rows_used.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Energy saved by DVFS relative to boost (fraction).
    pub fn energy_saving(&self) -> f64 {
        let boost = self.sim_energy_boost_uj.load(Ordering::Relaxed);
        if boost == 0 {
            return 0.0;
        }
        1.0 - self.sim_energy_uj.load(Ordering::Relaxed) as f64 / boost as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} ok ({} failed), batches {}, occupancy {:.1}%, exec {:.3} s, energy saving {:.1}%",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.occupancy() * 100.0,
            self.exec_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            self.energy_saving() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_math() {
        let m = Metrics::default();
        m.record_batch(3, 4, 100);
        m.record_batch(4, 4, 100);
        assert!((m.occupancy() - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn energy_saving_math() {
        let m = Metrics::default();
        m.record_energy(60.0, 100.0);
        assert!((m.energy_saving() - 0.4).abs() < 1e-3);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.energy_saving(), 0.0);
        assert!(m.summary().contains("jobs 0/0"));
    }
}
