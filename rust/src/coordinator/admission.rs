//! QoS admission control and graceful brownout degradation (issue 10).
//!
//! The paper's energy result assumes the fleet runs *inside* its deadline
//! and power envelopes; this module is what keeps that true when offered
//! load exceeds capacity. Three mechanisms, all decided at enqueue time
//! (before a job is accounted as accepted) so every drop is a typed,
//! traced shed rather than a late completion or an unbounded queue:
//!
//!   * **Priority classes** — every job carries a [`TenantClass`]
//!     (`realtime` > `batch` > `scavenger`). Backpressure is
//!     class-ordered: a full card evicts scavenger work before batch,
//!     and never touches realtime to make room for lower classes.
//!   * **Token-bucket rate limits** — optional per-class arrival caps
//!     ([`AdmissionPolicy::rate_per_s`]); a class over its sustained
//!     rate + burst is refused with `CoordError::RateLimited`.
//!   * **Deadline feasibility** — a job with a deadline is checked
//!     against the backend's predicted queue-wait + exec time
//!     (`ExecBackend::estimate_time_s`); one that cannot make it is shed
//!     *now* (`CoordError::DeadlineInfeasible`) instead of burning a
//!     card on a result nobody can use.
//!
//! Coupled to admission is the [`Brownout`] ladder — the overload
//! analogue of `telemetry::budget`'s deadband hysteresis. Sustained
//! queue pressure escalates the fleet one rung at a time; falling
//! pressure de-escalates only after a longer quiet streak (hysteresis,
//! mirroring `PowerBudget`'s deadband) so the ladder never flaps:
//!
//!   * level 1: clocks float up to boost for batches carrying realtime
//!     work (spend watts to protect the deadline class);
//!   * level 2: scavenger admissions are shed (`BrownoutShed`);
//!   * level 3: batch admissions are shed too — realtime only.
//!
//! Realtime is never brownout-shed: its overload defenses are the
//! queue bound (typed `QueueFull`) and deadline feasibility.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// The QoS class a job is admitted under. Ordering is priority:
/// `Realtime` outranks `Batch` outranks `Scavenger`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TenantClass {
    Realtime,
    Batch,
    Scavenger,
}

pub const CLASSES: [TenantClass; 3] =
    [TenantClass::Realtime, TenantClass::Batch, TenantClass::Scavenger];

impl TenantClass {
    pub fn label(self) -> &'static str {
        match self {
            TenantClass::Realtime => "realtime",
            TenantClass::Batch => "batch",
            TenantClass::Scavenger => "scavenger",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "realtime" => Some(TenantClass::Realtime),
            "batch" => Some(TenantClass::Batch),
            "scavenger" => Some(TenantClass::Scavenger),
            _ => None,
        }
    }

    /// Dense index for per-class counter arrays (priority order).
    pub fn index(self) -> usize {
        match self {
            TenantClass::Realtime => 0,
            TenantClass::Batch => 1,
            TenantClass::Scavenger => 2,
        }
    }

    /// True when `self` outranks `other` (strictly higher priority).
    pub fn outranks(self, other: TenantClass) -> bool {
        self.index() < other.index()
    }
}

impl Default for TenantClass {
    fn default() -> Self {
        TenantClass::Batch
    }
}

/// A deterministic token bucket: `rate` tokens/s sustained, up to
/// `burst` banked. Fed explicitly with the caller's `Instant` so tests
/// replay it without sleeping.
#[derive(Debug)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_per_s: f64, burst: f64, now: Instant) -> Self {
        assert!(rate_per_s > 0.0 && burst >= 1.0, "degenerate token bucket");
        Self { rate_per_s, burst, tokens: burst, last: now }
    }

    /// Refill for elapsed time, then try to spend one token.
    pub fn admit(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate_per_s).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-class admission policy knobs. The default is fully permissive —
/// no rate limits, feasibility checked only for jobs that carry a
/// deadline — so the pre-QoS serving behaviour is unchanged unless an
/// operator opts in.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// Optional sustained admission rate per class (tokens/s), indexed
    /// by [`TenantClass::index`]. `None` = unlimited.
    pub rate_per_s: [Option<f64>; 3],
    /// Token bank per rate-limited class (>= 1).
    pub burst: [f64; 3],
    /// Headroom multiplier on the deadline-feasibility prediction: a job
    /// is shed when `predicted_s > deadline_s * slack`. 1.0 = exact.
    pub feasibility_slack: f64,
    /// Brownout ladder configuration; `None` disables the ladder (level
    /// stays 0 forever).
    pub brownout: Option<BrownoutPolicy>,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self {
            rate_per_s: [None, None, None],
            burst: [1.0, 1.0, 1.0],
            feasibility_slack: 1.0,
            brownout: Some(BrownoutPolicy::default()),
        }
    }
}

/// Brownout escalation thresholds. Pressure is the fleet's in-flight
/// fraction of its bounded queue capacity (`inflight / (cards * bound)`)
/// — only computable when a queue bound is set, so an unbounded engine
/// never browns out.
#[derive(Debug, Clone)]
pub struct BrownoutPolicy {
    /// Escalate one rung after this many consecutive supervisor ticks
    /// above `hi_pressure`.
    pub escalate_ticks: u32,
    /// De-escalate one rung after this many consecutive ticks below
    /// `lo_pressure` — deliberately longer than `escalate_ticks`
    /// (hysteresis, mirroring `budget.rs`'s deadband) so recovery is
    /// calm, not oscillating.
    pub deescalate_ticks: u32,
    pub hi_pressure: f64,
    pub lo_pressure: f64,
}

impl Default for BrownoutPolicy {
    fn default() -> Self {
        // The supervisor ticks every ~2 ms: ~20 ms of sustained pressure
        // escalates, ~100 ms of calm de-escalates one rung.
        Self { escalate_ticks: 10, deescalate_ticks: 50, hi_pressure: 0.85, lo_pressure: 0.50 }
    }
}

/// The fleet brownout ladder: an atomic level 0..=3 escalated/relaxed by
/// the supervisor's periodic tick and read lock-free by admission and
/// the workers' clock path.
#[derive(Debug, Default)]
pub struct Brownout {
    level: AtomicU8,
    hi_streak: AtomicU64,
    lo_streak: AtomicU64,
    /// Highest level ever reached (observability: did the run brown out?).
    max_level: AtomicU8,
    escalations: AtomicU64,
}

pub const BROWNOUT_MAX_LEVEL: u8 = 3;

impl Brownout {
    pub fn level(&self) -> u8 {
        self.level.load(Ordering::Relaxed)
    }

    pub fn max_level_seen(&self) -> u8 {
        self.max_level.load(Ordering::Relaxed)
    }

    pub fn escalations(&self) -> u64 {
        self.escalations.load(Ordering::Relaxed)
    }

    /// One supervisor tick: fold the current queue pressure into the
    /// ladder. Called from a single thread (the retry supervisor), so
    /// the streak counters need no stronger ordering.
    pub fn tick(&self, pressure: f64, policy: &BrownoutPolicy) {
        if pressure > policy.hi_pressure {
            self.lo_streak.store(0, Ordering::Relaxed);
            let hi = self.hi_streak.fetch_add(1, Ordering::Relaxed) + 1;
            if hi >= policy.escalate_ticks as u64 {
                self.hi_streak.store(0, Ordering::Relaxed);
                let lvl = self.level.load(Ordering::Relaxed);
                if lvl < BROWNOUT_MAX_LEVEL {
                    self.level.store(lvl + 1, Ordering::Relaxed);
                    self.escalations.fetch_add(1, Ordering::Relaxed);
                    self.max_level.fetch_max(lvl + 1, Ordering::Relaxed);
                }
            }
        } else if pressure < policy.lo_pressure {
            self.hi_streak.store(0, Ordering::Relaxed);
            let lo = self.lo_streak.fetch_add(1, Ordering::Relaxed) + 1;
            if lo >= policy.deescalate_ticks as u64 {
                self.lo_streak.store(0, Ordering::Relaxed);
                let lvl = self.level.load(Ordering::Relaxed);
                if lvl > 0 {
                    self.level.store(lvl - 1, Ordering::Relaxed);
                }
            }
        } else {
            // Deadband between lo and hi: both streaks reset, the ladder
            // holds its rung — the hysteresis that keeps it from flapping.
            self.hi_streak.store(0, Ordering::Relaxed);
            self.lo_streak.store(0, Ordering::Relaxed);
        }
    }

    /// Is this class currently shed by the ladder? (level 2 sheds
    /// scavenger, level 3 sheds batch too; realtime is never shed.)
    pub fn sheds(&self, class: TenantClass) -> bool {
        match class {
            TenantClass::Realtime => false,
            TenantClass::Batch => self.level() >= 3,
            TenantClass::Scavenger => self.level() >= 2,
        }
    }

    /// Should clocks float up to boost for a batch carrying realtime
    /// work? (level >= 1 — step one of the ladder spends watts before it
    /// sheds anyone.)
    pub fn boost_realtime(&self) -> bool {
        self.level() >= 1
    }
}

/// Why admission refused a job — mirrors the `CoordError` variant the
/// caller receives; kept as a small enum so counters stay cheap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    DeadlineInfeasible,
    BrownoutShed,
    RateLimited,
    /// A queued lower-class job evicted to make room for a higher class.
    Evicted,
}

impl ShedReason {
    pub fn label(self) -> &'static str {
        match self {
            ShedReason::DeadlineInfeasible => "deadline infeasible at admission",
            ShedReason::BrownoutShed => "brownout shed",
            ShedReason::RateLimited => "rate limited",
            ShedReason::Evicted => "evicted for higher-class admission",
        }
    }
}

/// Per-class / per-reason admission accounting, exported in the fleet
/// snapshot. All counters are monotone.
#[derive(Debug, Default)]
pub struct AdmissionStats {
    pub admitted: [AtomicU64; 3],
    pub deadline_sheds: AtomicU64,
    pub brownout_sheds: AtomicU64,
    pub rate_limited: AtomicU64,
    pub evictions: AtomicU64,
}

/// The admission controller: policy + token buckets + counters + the
/// brownout ladder. Owned by the engine; `admit`/`tick` are the only
/// entry points.
pub struct AdmissionController {
    pub policy: AdmissionPolicy,
    buckets: Mutex<[Option<TokenBucket>; 3]>,
    pub stats: AdmissionStats,
    pub brownout: Brownout,
}

/// The typed outcome of an admission check, pre-`CoordError`: the engine
/// maps these onto its error taxonomy (which lives in `coordinator`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    Admit,
    Shed(ShedReason),
}

impl AdmissionController {
    pub fn new(policy: AdmissionPolicy) -> Self {
        let now = Instant::now();
        let buckets = std::array::from_fn(|i| {
            policy.rate_per_s[i].map(|r| TokenBucket::new(r, policy.burst[i].max(1.0), now))
        });
        Self { policy, buckets: Mutex::new(buckets), stats: AdmissionStats::default(), brownout: Brownout::default() }
    }

    /// Class-level gates (brownout rung, token bucket). Card-level gates
    /// — deadline feasibility and the queue bound — need the routed
    /// card's state and stay in the engine's enqueue path, which calls
    /// [`Self::feasible`] once it has picked a card.
    pub fn admit_class(&self, class: TenantClass, now: Instant) -> AdmitDecision {
        if self.brownout.sheds(class) {
            self.stats.brownout_sheds.fetch_add(1, Ordering::Relaxed);
            return AdmitDecision::Shed(ShedReason::BrownoutShed);
        }
        let mut buckets = self.buckets.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(bucket) = buckets[class.index()].as_mut() {
            if !bucket.admit(now) {
                self.stats.rate_limited.fetch_add(1, Ordering::Relaxed);
                return AdmitDecision::Shed(ShedReason::RateLimited);
            }
        }
        AdmitDecision::Admit
    }

    /// Deadline feasibility: `est_batch_s` is the backend's predicted
    /// exec time for one device batch on the routed card; the job waits
    /// behind `inflight` queued jobs packed `device_batch` per batch.
    /// Returns the predicted completion time; the caller sheds when it
    /// is `Some(t)` with `t > deadline * slack`.
    pub fn predicted_s(est_batch_s: f64, inflight: u64, device_batch: u64) -> f64 {
        let batches_ahead = inflight as f64 / device_batch.max(1) as f64;
        est_batch_s * (batches_ahead + 1.0)
    }

    /// Apply the feasibility rule; counts the shed on refusal.
    pub fn feasible(&self, deadline_s: f64, predicted_s: f64) -> AdmitDecision {
        if predicted_s > deadline_s * self.policy.feasibility_slack {
            self.stats.deadline_sheds.fetch_add(1, Ordering::Relaxed);
            AdmitDecision::Shed(ShedReason::DeadlineInfeasible)
        } else {
            AdmitDecision::Admit
        }
    }

    pub fn record_admit(&self, class: TenantClass) {
        self.stats.admitted[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_eviction(&self) {
        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn class_order_is_priority_order() {
        assert!(TenantClass::Realtime.outranks(TenantClass::Batch));
        assert!(TenantClass::Batch.outranks(TenantClass::Scavenger));
        assert!(!TenantClass::Scavenger.outranks(TenantClass::Scavenger));
        for c in CLASSES {
            assert_eq!(TenantClass::from_label(c.label()), Some(c));
        }
        assert_eq!(TenantClass::from_label("bogus"), None);
    }

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 3.0, t0);
        // The burst bank admits 3 immediately, then the bucket is dry.
        assert!(b.admit(t0) && b.admit(t0) && b.admit(t0));
        assert!(!b.admit(t0));
        // 100 ms at 10/s refills exactly one token.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.admit(t1));
        assert!(!b.admit(t1));
        // A long idle period refills to the burst cap, never beyond.
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.admit(t2) && b.admit(t2) && b.admit(t2));
        assert!(!b.admit(t2));
    }

    #[test]
    fn rate_limited_class_is_shed_with_the_typed_reason() {
        let mut policy = AdmissionPolicy::default();
        policy.rate_per_s[TenantClass::Scavenger.index()] = Some(1.0);
        policy.burst[TenantClass::Scavenger.index()] = 2.0;
        let ctl = AdmissionController::new(policy);
        let now = Instant::now();
        assert_eq!(ctl.admit_class(TenantClass::Scavenger, now), AdmitDecision::Admit);
        assert_eq!(ctl.admit_class(TenantClass::Scavenger, now), AdmitDecision::Admit);
        assert_eq!(
            ctl.admit_class(TenantClass::Scavenger, now),
            AdmitDecision::Shed(ShedReason::RateLimited)
        );
        // Other classes are not collaterally limited.
        assert_eq!(ctl.admit_class(TenantClass::Realtime, now), AdmitDecision::Admit);
        assert_eq!(ctl.stats.rate_limited.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn feasibility_prediction_scales_with_queue_depth() {
        // Empty card: one batch time. 64 queued at device_batch 64: two.
        assert!((AdmissionController::predicted_s(1e-3, 0, 64) - 1e-3).abs() < 1e-12);
        assert!((AdmissionController::predicted_s(1e-3, 64, 64) - 2e-3).abs() < 1e-12);
        let ctl = AdmissionController::new(AdmissionPolicy::default());
        assert_eq!(ctl.feasible(2.5e-3, 2e-3), AdmitDecision::Admit);
        assert_eq!(
            ctl.feasible(1.5e-3, 2e-3),
            AdmitDecision::Shed(ShedReason::DeadlineInfeasible)
        );
        assert_eq!(ctl.stats.deadline_sheds.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn brownout_ladder_escalates_and_relaxes_with_hysteresis() {
        let policy = BrownoutPolicy {
            escalate_ticks: 3,
            deescalate_ticks: 6,
            hi_pressure: 0.8,
            lo_pressure: 0.4,
        };
        let b = Brownout::default();
        // Three hot ticks: one rung. Realtime clocks float; nobody shed yet.
        for _ in 0..3 {
            b.tick(0.95, &policy);
        }
        assert_eq!(b.level(), 1);
        assert!(b.boost_realtime());
        assert!(!b.sheds(TenantClass::Scavenger));
        // Three more: rung 2 sheds scavenger but not batch.
        for _ in 0..3 {
            b.tick(0.95, &policy);
        }
        assert_eq!(b.level(), 2);
        assert!(b.sheds(TenantClass::Scavenger) && !b.sheds(TenantClass::Batch));
        // Rung 3 sheds batch too; realtime is never shed, and the ladder
        // saturates at 3.
        for _ in 0..9 {
            b.tick(0.95, &policy);
        }
        assert_eq!(b.level(), 3);
        assert!(b.sheds(TenantClass::Batch) && !b.sheds(TenantClass::Realtime));
        assert_eq!(b.max_level_seen(), 3);
        // The deadband holds the rung: mid pressure resets both streaks.
        for _ in 0..100 {
            b.tick(0.6, &policy);
        }
        assert_eq!(b.level(), 3, "deadband must hold, not relax");
        // De-escalation needs the longer quiet streak (hysteresis): five
        // cool ticks are not enough, the sixth relaxes one rung.
        for _ in 0..5 {
            b.tick(0.1, &policy);
        }
        assert_eq!(b.level(), 3);
        b.tick(0.1, &policy);
        assert_eq!(b.level(), 2);
        // A hot tick mid-recovery resets the quiet streak.
        for _ in 0..5 {
            b.tick(0.1, &policy);
        }
        b.tick(0.95, &policy);
        for _ in 0..5 {
            b.tick(0.1, &policy);
        }
        assert_eq!(b.level(), 2, "hot tick must reset the de-escalation streak");
        b.tick(0.1, &policy);
        assert_eq!(b.level(), 1);
    }

    #[test]
    fn disabled_ladder_never_escalates() {
        let ctl = AdmissionController::new(AdmissionPolicy { brownout: None, ..Default::default() });
        // The engine only ticks the ladder when the policy carries one;
        // admission must stay permissive at level 0.
        assert_eq!(ctl.brownout.level(), 0);
        for c in CLASSES {
            assert_eq!(ctl.admit_class(c, Instant::now()), AdmitDecision::Admit);
        }
    }
}
