//! Optimal and mean-optimal core-clock frequency extraction (paper
//! section 5.1/5.2, Fig 9, Table 3).

use crate::cufft::plan::{plan, Algorithm};
use crate::harness::sweep::{GpuSweep, LengthSweep};
use crate::sim::GpuSpec;
use crate::util::stats;

/// The per-length optimum: the clock minimizing measured energy per batch.
#[derive(Debug, Clone)]
pub struct OptimalPoint {
    pub n: u64,
    pub f_opt_mhz: f64,
    /// f_opt as a fraction of the boost clock (Fig 9's y-axis).
    pub frac_of_boost: f64,
    pub energy_j: f64,
    /// Execution-time increase vs the boost clock (Fig 11).
    pub time_increase: f64,
    /// Efficiency increase vs boost (eq. 7, Fig 13).
    pub eff_increase_vs_boost: f64,
    /// Efficiency increase vs base clock (Fig 14).
    pub eff_increase_vs_base: f64,
    /// Uses the Bluestein algorithm (excluded from the Nano's mean; the
    /// Fig 13/15 peaks).
    pub bluestein: bool,
}

/// Moving-average smoothing (window 3) applied before the argmin, so the
/// sensor's run-to-run drift does not pick a random point on the flat
/// part of the energy curve (the paper's curves are visually smooth at
/// the same measurement error).
fn smooth3(xs: &[f64]) -> Vec<f64> {
    (0..xs.len())
        .map(|i| {
            let lo = i.saturating_sub(1);
            let hi = (i + 2).min(xs.len());
            xs[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect()
}

/// Find the energy-optimal clock for one length sweep.
pub fn optimal_for_length(gpu: &GpuSpec, sweep: &LengthSweep) -> OptimalPoint {
    let energies: Vec<f64> = smooth3(&sweep.points.iter().map(|p| p.energy_j).collect::<Vec<_>>());
    let imin = stats::argmin(&energies).expect("empty sweep");
    let opt = &sweep.points[imin];
    let boost = sweep.at(gpu.boost_clock_mhz);
    let base = sweep.at(gpu.base_clock_mhz);
    let algorithm = plan(sweep.n, sweep.precision).algorithm;
    OptimalPoint {
        n: sweep.n,
        f_opt_mhz: opt.f_mhz,
        frac_of_boost: opt.f_mhz / gpu.boost_clock_mhz,
        energy_j: opt.energy_j,
        time_increase: opt.time_s / boost.time_s - 1.0,
        eff_increase_vs_boost: opt.efficiency / boost.efficiency,
        eff_increase_vs_base: opt.efficiency / base.efficiency,
        bluestein: algorithm == Algorithm::Bluestein,
    }
}

/// Per-length optima for a whole gpu sweep.
pub fn optima(gpu: &GpuSpec, sweep: &GpuSweep) -> Vec<OptimalPoint> {
    sweep
        .lengths
        .iter()
        .map(|l| optimal_for_length(gpu, l))
        .collect()
}

/// Mean optimal frequency (Table 3): average of per-length optima.
/// Bluestein lengths are excluded on the Jetson Nano (paper section 4:
/// their measurement error is too large there).
pub fn mean_optimal_mhz(gpu: &GpuSpec, points: &[OptimalPoint]) -> f64 {
    let exclude_bluestein = gpu.name == "Jetson Nano";
    let freqs: Vec<f64> = points
        .iter()
        .filter(|p| !(exclude_bluestein && p.bluestein))
        .map(|p| p.f_opt_mhz)
        .collect();
    stats::mean(&freqs)
}

/// Efficiency increases when running every length at ONE clock
/// (the mean-optimal policy of Figs 15/16).
#[derive(Debug, Clone)]
pub struct FixedClockPoint {
    pub n: u64,
    pub eff_increase_vs_boost: f64,
    pub eff_increase_vs_base: f64,
    pub time_increase: f64,
}

pub fn at_fixed_clock(gpu: &GpuSpec, sweep: &GpuSweep, f_mhz: f64) -> Vec<FixedClockPoint> {
    sweep
        .lengths
        .iter()
        .map(|l| {
            let at = l.at(f_mhz);
            let boost = l.at(gpu.boost_clock_mhz);
            let base = l.at(gpu.base_clock_mhz);
            FixedClockPoint {
                n: l.n,
                eff_increase_vs_boost: at.efficiency / boost.efficiency,
                eff_increase_vs_base: at.efficiency / base.efficiency,
                time_increase: at.time_s / boost.time_s - 1.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::sweep::{sweep_gpu, SweepConfig};
    use crate::harness::Protocol;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    fn v100_sweep() -> (GpuSpec, GpuSweep) {
        let g = tesla_v100();
        let cfg = SweepConfig {
            lengths: vec![1024, 16384, 19321],
            freq_stride: 8,
            protocol: Protocol { reps_per_run: 4, runs: 3, seed: 7 },
        };
        let s = sweep_gpu(&g, Precision::Fp32, &cfg);
        (g, s)
    }

    #[test]
    fn optimum_is_below_boost_and_saves_energy() {
        let (g, s) = v100_sweep();
        for p in optima(&g, &s) {
            assert!(p.frac_of_boost < 0.85, "N={}: frac {}", p.n, p.frac_of_boost);
            assert!(p.eff_increase_vs_boost > 1.1, "N={}: {}", p.n, p.eff_increase_vs_boost);
        }
    }

    #[test]
    fn v100_time_cost_is_small() {
        let (g, s) = v100_sweep();
        for p in optima(&g, &s) {
            if !p.bluestein {
                assert!(p.time_increase < 0.15, "N={}: +{:.1}%", p.n, p.time_increase * 100.0);
            }
        }
    }

    #[test]
    fn bluestein_flagged() {
        let (g, s) = v100_sweep();
        let pts = optima(&g, &s);
        assert!(pts.iter().any(|p| p.bluestein && p.n == 19321));
        assert!(pts.iter().any(|p| !p.bluestein && p.n == 1024));
    }

    #[test]
    fn mean_optimal_near_table3_v100() {
        let (g, s) = v100_sweep();
        let pts = optima(&g, &s);
        let mean = mean_optimal_mhz(&g, &pts);
        assert!(
            (mean - 945.0).abs() < 120.0,
            "V100 FP32 mean optimal {mean} MHz vs paper 945"
        );
    }

    #[test]
    fn fixed_clock_close_to_per_length_optimum() {
        // Paper: using the mean optimal loses ~5-10 pp vs per-length tuning.
        let (g, s) = v100_sweep();
        let pts = optima(&g, &s);
        let mean = mean_optimal_mhz(&g, &pts);
        let fixed = at_fixed_clock(&g, &s, mean);
        for (f, o) in fixed.iter().zip(&pts) {
            assert!(
                o.eff_increase_vs_boost - f.eff_increase_vs_boost < 0.25,
                "N={}: optimal {} vs fixed {}",
                f.n,
                o.eff_increase_vs_boost,
                f.eff_increase_vs_boost
            );
        }
    }
}
