//! Regeneration of the paper's figures (F2-F20) as CSV series + ASCII
//! summaries. Each function returns one or more [`Table`]s whose rows are
//! the plotted series; the CLI and benches write them under `results/`.

use crate::analysis::optimal::{at_fixed_clock, mean_optimal_mhz, optima};
use crate::cufft::plan::plan;
use crate::cufft::profile::{fig20_lengths, profile_plan};
use crate::harness::logs::{merge, render_smi_log, KernelEvent};
use crate::harness::sweep::{sweep_gpu, GpuSweep, SweepConfig};
use crate::sim::sensor::{sample_timeline, SensorConfig};
use crate::sim::{batch_timeline, GpuSpec};
use crate::types::{FftWorkload, Precision};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

/// Fig 2: a log excerpt with the FFT kernels localized between the two
/// non-computing (copy) phases.
pub fn figure2(gpu: &GpuSpec, n: u64, f_mhz: f64, seed: u64) -> (Table, String) {
    let w = FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes);
    let (tl, _) = batch_timeline(gpu, &w, f_mhz, 10);
    let mut rng = Rng::new(seed);
    let samples = sample_timeline(
        &tl,
        &SensorConfig::for_gpu(gpu),
        gpu.effective_clock(f_mhz),
        gpu.mem_clock_mhz,
        &mut rng,
    );
    // kernel events for the merge
    let mut events = Vec::new();
    let mut t = 0.0;
    for &(d, _, c) in &tl.segments {
        if c {
            events.push(KernelEvent { name: "fft".into(), begin_s: t, end_s: t + d });
        }
        t += d;
    }
    let merged = merge(&samples, &events, f_mhz);
    let mut table = Table::new(
        &format!("Fig 2: power log, {} N={} @ {} MHz", gpu.name, n, f_mhz),
        &["timestamp_ms", "power_w", "core_clock_mhz", "is_compute"],
    );
    for s in &samples {
        let is_compute = merged
            .compute
            .iter()
            .any(|c| (c.timestamp_s - s.timestamp_s).abs() < 1e-12);
        table.push_row(vec![
            fnum(s.timestamp_s * 1e3, 1),
            fnum(s.power_w, 2),
            fnum(s.core_clock_mhz, 0),
            (is_compute as u8).to_string(),
        ]);
    }
    (table, render_smi_log(&samples))
}

/// Fig 3: measurement error (relative std) per length × clock.
pub fn figure3(gpu: &GpuSpec, sweep: &GpuSweep) -> Table {
    let mut t = Table::new(
        &format!("Fig 3: measurement error, {} {}", gpu.name, sweep.precision),
        &["n", "f_mhz", "rel_err_pct"],
    );
    for l in &sweep.lengths {
        for p in &l.points {
            t.push_row(vec![
                l.n.to_string(),
                fnum(p.f_mhz, 1),
                fnum(p.energy_rel_err * 100.0, 2),
            ]);
        }
    }
    t
}

/// Figs 4/5: execution time t_fix for a fixed amount of data vs N.
pub fn figure4_5(gpus: &[GpuSpec], precision: Precision, lengths: &[u64]) -> Table {
    let mut t = Table::new(
        &format!("Fig 4/5: t_fix vs FFT length ({precision})"),
        &["gpu", "n", "t_fix_ms", "kernels"],
    );
    for g in gpus {
        if !g.supports(precision) {
            continue;
        }
        for &n in lengths {
            if precision == Precision::Fp16 && n & (n - 1) != 0 {
                continue;
            }
            let w = FftWorkload::new(n, precision, g.working_set_bytes);
            let p = plan(n, precision);
            let run = crate::sim::run_batch_with_plan(g, &w, &p, g.boost_clock_mhz);
            t.push_row(vec![
                g.name.to_string(),
                n.to_string(),
                fnum(run.timing.total_s * 1e3, 3),
                p.kernel_count().to_string(),
            ]);
        }
    }
    t
}

/// Fig 6: t_f / t_d ratio per clock, one series per length.
pub fn figure6(gpu: &GpuSpec, sweep: &GpuSweep) -> Table {
    let mut t = Table::new(
        &format!("Fig 6: t_f/t_d vs clock, {}", gpu.name),
        &["n", "f_mhz", "t_ratio"],
    );
    for l in &sweep.lengths {
        let td = l.at(gpu.boost_clock_mhz).time_s;
        for p in &l.points {
            t.push_row(vec![
                l.n.to_string(),
                fnum(p.f_mhz, 1),
                fnum(p.time_s / td, 4),
            ]);
        }
    }
    t
}

/// Fig 7: energy per batch vs clock for N=16384 on every GPU.
pub fn figure7(gpus: &[GpuSpec], cfg: &SweepConfig) -> Table {
    let mut t = Table::new(
        "Fig 7: energy per FFT batch (N=16384, FP32) vs clock",
        &["gpu", "f_mhz", "energy_j", "is_optimal"],
    );
    for g in gpus {
        let mut c = cfg.clone();
        c.lengths = vec![16384];
        let sweep = sweep_gpu(g, Precision::Fp32, &c);
        let l = &sweep.lengths[0];
        let energies: Vec<f64> = l.points.iter().map(|p| p.energy_j).collect();
        let imin = crate::util::stats::argmin(&energies).unwrap();
        for (i, p) in l.points.iter().enumerate() {
            t.push_row(vec![
                g.name.to_string(),
                fnum(p.f_mhz, 1),
                fnum(p.energy_j, 3),
                ((i == imin) as u8).to_string(),
            ]);
        }
    }
    t
}

/// Fig 8: averaged power vs clock across lengths.
pub fn figure8(gpu: &GpuSpec, sweep: &GpuSweep) -> Table {
    let mut t = Table::new(
        &format!("Fig 8: averaged power vs clock, {}", gpu.name),
        &["n", "f_mhz", "avg_power_w"],
    );
    for l in &sweep.lengths {
        for p in &l.points {
            t.push_row(vec![
                l.n.to_string(),
                fnum(p.f_mhz, 1),
                fnum(p.avg_power_w, 2),
            ]);
        }
    }
    t
}

/// Figs 9-14: per-length optimal clock and the derived series.
pub fn figure9_to_14(gpu: &GpuSpec, sweep: &GpuSweep) -> Table {
    let pts = optima(gpu, sweep);
    let mut t = Table::new(
        &format!(
            "Figs 9-14: optimal clock metrics, {} {}",
            gpu.name, sweep.precision
        ),
        &[
            "n",
            "f_opt_mhz",
            "pct_of_boost",      // Fig 9
            "gflops_per_w",      // Fig 10
            "time_increase_pct", // Fig 11
            "gflops",            // Fig 12
            "eff_inc_vs_boost",  // Fig 13
            "eff_inc_vs_base",   // Fig 14
            "bluestein",
        ],
    );
    for (p, l) in pts.iter().zip(&sweep.lengths) {
        let at_opt = l.at(p.f_opt_mhz);
        t.push_row(vec![
            p.n.to_string(),
            fnum(p.f_opt_mhz, 1),
            fnum(p.frac_of_boost * 100.0, 1),
            fnum(at_opt.efficiency / 1e9, 2),
            fnum(p.time_increase * 100.0, 2),
            fnum(at_opt.perf_flops / 1e9, 1),
            fnum(p.eff_increase_vs_boost, 3),
            fnum(p.eff_increase_vs_base, 3),
            (p.bluestein as u8).to_string(),
        ]);
    }
    t
}

/// Figs 15/16: efficiency increase at the mean optimal clock.
pub fn figure15_16(gpu: &GpuSpec, sweep: &GpuSweep) -> (f64, Table) {
    let pts = optima(gpu, sweep);
    let mean_opt = mean_optimal_mhz(gpu, &pts);
    let fixed = at_fixed_clock(gpu, sweep, mean_opt);
    let mut t = Table::new(
        &format!(
            "Figs 15/16: efficiency increase at mean optimal ({} MHz), {} {}",
            fnum(mean_opt, 0),
            gpu.name,
            sweep.precision
        ),
        &["n", "eff_inc_vs_boost", "eff_inc_vs_base", "time_increase_pct"],
    );
    for f in &fixed {
        t.push_row(vec![
            f.n.to_string(),
            fnum(f.eff_increase_vs_boost, 3),
            fnum(f.eff_increase_vs_base, 3),
            fnum(f.time_increase * 100.0, 2),
        ]);
    }
    (mean_opt, t)
}

/// Figs 17/18: efficiency-increase vs time-increase trade-off heatmap —
/// every (length, clock) cell.
pub fn figure17_18(gpu: &GpuSpec, sweep: &GpuSweep) -> Table {
    let mut t = Table::new(
        &format!("Figs 17/18: trade-off heatmap, {}", gpu.name),
        &["n", "f_mhz", "eff_increase_pct", "time_increase_pct"],
    );
    for l in &sweep.lengths {
        let boost = l.at(gpu.boost_clock_mhz);
        for p in &l.points {
            t.push_row(vec![
                l.n.to_string(),
                fnum(p.f_mhz, 1),
                fnum((p.efficiency / boost.efficiency - 1.0) * 100.0, 1),
                fnum((p.time_s / boost.time_s - 1.0) * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fig 20: NVVP profiling bars for representative lengths.
pub fn figure20(gpu: &GpuSpec, f_mhz: f64) -> Table {
    let mut t = Table::new(
        &format!("Fig 20: kernel profiles, {} @ {} MHz", gpu.name, fnum(f_mhz, 0)),
        &[
            "n",
            "kernel",
            "compute_util_pct",
            "issue_slot_util_pct",
            "device_mbu_pct",
            "norm_time",
        ],
    );
    for n in fig20_lengths() {
        let w = FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes);
        let p = plan(n, Precision::Fp32);
        let prof = profile_plan(gpu, &w, &p, f_mhz);
        for k in &prof.kernels {
            t.push_row(vec![
                n.to_string(),
                format!("{}:{:?}", k.kernel_index, k.kind),
                fnum(k.compute_util * 100.0, 1),
                fnum(k.issue_slot_util * 100.0, 1),
                fnum(k.device_mbu * 100.0, 1),
                fnum(k.norm_time, 3),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Protocol;
    use crate::sim::gpu::{jetson_nano, tesla_v100};

    fn tiny_sweep(g: &GpuSpec) -> GpuSweep {
        let cfg = SweepConfig {
            lengths: vec![1024, 16384],
            freq_stride: 24,
            protocol: Protocol { reps_per_run: 3, runs: 3, seed: 2 },
        };
        sweep_gpu(g, Precision::Fp32, &cfg)
    }

    #[test]
    fn figure2_localizes_kernels() {
        let g = tesla_v100();
        let (t, log) = figure2(&g, 16384, 1020.0, 9);
        assert!(t.rows.len() > 10);
        assert!(t.rows.iter().any(|r| r[3] == "1"));
        assert!(t.rows.iter().any(|r| r[3] == "0"));
        assert!(log.starts_with("timestamp_ms"));
    }

    #[test]
    fn figure6_boost_row_is_unity() {
        let g = tesla_v100();
        let s = tiny_sweep(&g);
        let t = figure6(&g, &s);
        // the highest-clock row of each series must be ~1.0
        let first: f64 = t.rows[0][2].parse().unwrap();
        assert!((first - 1.0).abs() < 0.05, "{first}");
    }

    #[test]
    fn figure7_marks_one_optimum_per_gpu() {
        let g = [tesla_v100(), jetson_nano()];
        let cfg = SweepConfig {
            lengths: vec![16384],
            freq_stride: 24,
            protocol: Protocol { reps_per_run: 3, runs: 3, seed: 2 },
        };
        let t = figure7(&g, &cfg);
        let v100_opts = t
            .rows
            .iter()
            .filter(|r| r[0] == "Tesla V100" && r[3] == "1")
            .count();
        assert_eq!(v100_opts, 1);
    }

    #[test]
    fn figures9_to_18_have_rows() {
        let g = tesla_v100();
        let s = tiny_sweep(&g);
        assert_eq!(figure9_to_14(&g, &s).rows.len(), 2);
        let (mean_opt, t) = figure15_16(&g, &s);
        assert!(mean_opt > 500.0 && mean_opt < 1400.0);
        assert_eq!(t.rows.len(), 2);
        assert!(figure17_18(&g, &s).rows.len() > 4);
        assert!(figure3(&g, &s).rows.len() > 4);
        assert!(figure8(&g, &s).rows.len() > 4);
    }

    #[test]
    fn figure20_rows_match_kernel_counts() {
        let g = tesla_v100();
        let t = figure20(&g, g.boost_clock_mhz);
        // 8192→1, 16384→2, 2M→3 kernels = 6 rows
        assert_eq!(t.rows.len(), 6);
    }

    #[test]
    fn figure4_5_shows_staircase() {
        let g = [tesla_v100()];
        let t = figure4_5(&g, Precision::Fp32, &[32, 8192, 16384]);
        let times: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        assert!((times[1] / times[0] - 1.0).abs() < 0.3, "plateau {times:?}");
        assert!(times[2] > 1.5 * times[1], "jump {times:?}");
    }
}
