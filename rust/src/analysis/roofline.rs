//! L1 hardware-adaptation accounting (DESIGN.md §2): VMEM footprint and
//! MXU/VPU utilization estimates for the Pallas Stockham kernel's
//! BlockSpec, per TPU generation. `interpret=True` CPU timings say nothing
//! about TPU performance; this is the structural analysis EXPERIMENTS.md
//! §Perf records instead.

use crate::types::Precision;

/// A TPU-like target for the estimate.
#[derive(Debug, Clone)]
pub struct TpuTarget {
    pub name: &'static str,
    /// VMEM per core, bytes.
    pub vmem_bytes: u64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbs: f64,
    /// VPU throughput, G-lane-ops/s (8x128 lanes × clock).
    pub vpu_glanes: f64,
}

pub fn tpu_v4() -> TpuTarget {
    TpuTarget {
        name: "TPUv4-core",
        vmem_bytes: 128 << 20,
        hbm_gbs: 1200.0,
        vpu_glanes: 4000.0, // 8*128 lanes x 2 ALUs x ~2 ops @ ~1 GHz
    }
}

/// Static analysis of one `fft_c2c` pallas_call.
#[derive(Debug, Clone)]
pub struct KernelEstimate {
    pub tile_b: u64,
    pub n: u64,
    /// Bytes resident in VMEM for one grid step (in + out + ping-pong).
    pub vmem_bytes: u64,
    /// Fraction of VMEM used.
    pub vmem_frac: f64,
    /// HBM bytes moved per grid step (one read + one write of the tile).
    pub hbm_bytes: u64,
    /// VPU lane-operations per grid step (butterflies are elementwise
    /// mul/add over re/im planes — VPU work, not MXU matmuls).
    pub vpu_ops: u64,
    /// Arithmetic intensity, ops/byte.
    pub intensity: f64,
    /// Roofline-predicted time per grid step on the target, seconds.
    pub t_roofline_s: f64,
    /// true → HBM-bound (the desired regime: matches cuFFT's single-kernel
    /// memory-bound behaviour the paper measures).
    pub hbm_bound: bool,
}

/// Estimate the Stockham kernel at (tile_b, n) on a target.
pub fn estimate_fft_kernel(
    tile_b: u64,
    n: u64,
    precision: Precision,
    target: &TpuTarget,
) -> KernelEstimate {
    let eb = precision.real_bytes();
    let tile_elems = tile_b * n;
    // re+im planes, double-buffered across the stage loop: 4 planes live.
    let vmem = 4 * tile_elems * eb;
    // One HBM read of both planes in, one write out (all stages in-VMEM).
    let hbm = 4 * tile_elems * eb;
    let stages = (n as f64).log2().ceil() as u64;
    // Per stage per element: complex add + complex sub + complex mul ≈
    // 10 real ops, plus twiddle cos/sin amortized (precomputed per stage).
    let vpu_ops = 10 * tile_elems * stages;
    let t_mem = hbm as f64 / (target.hbm_gbs * 1e9);
    let t_vpu = vpu_ops as f64 / (target.vpu_glanes * 1e9);
    KernelEstimate {
        tile_b,
        n,
        vmem_bytes: vmem,
        vmem_frac: vmem as f64 / target.vmem_bytes as f64,
        hbm_bytes: hbm,
        vpu_ops,
        intensity: vpu_ops as f64 / hbm as f64,
        t_roofline_s: t_mem.max(t_vpu),
        hbm_bound: t_mem >= t_vpu,
    }
}

/// Pick the largest batch tile that keeps the kernel within a VMEM budget
/// (the BlockSpec sizing rule for `python/compile/kernels/fft.py`).
pub fn max_tile_b(n: u64, precision: Precision, target: &TpuTarget, budget_frac: f64) -> u64 {
    let eb = precision.real_bytes();
    let per_row = 4 * n * eb;
    ((target.vmem_bytes as f64 * budget_frac) / per_row as f64).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_tile_fits_vmem() {
        // the python kernel's DEFAULT_TILE_B=16 at the largest single-kernel
        // fp32 length must fit comfortably
        let e = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(e.vmem_frac < 0.05, "vmem frac {}", e.vmem_frac);
    }

    #[test]
    fn vpu_butterflies_are_not_hbm_bound_the_hardware_adaptation_finding() {
        // On the V100, 5·N·log2(N) flops against 900 GB/s leaves cuFFT
        // memory-bound (knee ≈ 17 flops/byte). The TPU's VPU knee is much
        // lower (≈ 3.3 ops/byte), so a pure-VPU Stockham kernel goes
        // compute-bound beyond tiny N — the DESIGN.md §2 argument for
        // expressing larger radix butterflies as MXU matmuls on real TPUs.
        let tiny = estimate_fft_kernel(16, 4, Precision::Fp32, &tpu_v4());
        assert!(tiny.hbm_bound, "intensity {}", tiny.intensity);
        let big = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(!big.hbm_bound, "intensity {}", big.intensity);
    }

    #[test]
    fn intensity_grows_with_log_n() {
        let a = estimate_fft_kernel(16, 256, Precision::Fp32, &tpu_v4());
        let b = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(b.intensity > a.intensity);
        // ratio = log2 ratio
        assert!((b.intensity / a.intensity - 13.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn max_tile_b_respects_budget() {
        let t = tpu_v4();
        let tile = max_tile_b(8192, Precision::Fp32, &t, 0.5);
        let e = estimate_fft_kernel(tile, 8192, Precision::Fp32, &t);
        assert!(e.vmem_frac <= 0.5);
        let e2 = estimate_fft_kernel(tile + 1, 8192, Precision::Fp32, &t);
        assert!(e2.vmem_frac > 0.5);
    }

    #[test]
    fn fp64_halves_tile() {
        let t = tpu_v4();
        let t32 = max_tile_b(4096, Precision::Fp32, &t, 0.5);
        let t64 = max_tile_b(4096, Precision::Fp64, &t, 0.5);
        assert_eq!(t32, 2 * t64);
    }
}
