//! Roofline accounting, two targets:
//!
//! * **GPU plans** ([`classify_plan`]): price each compiled FFT plan's
//!   issue cycles against the bandwidth tier its working set actually
//!   streams from, and classify it compute- vs memory-bound. This is the
//!   DESIGN.md §4g planner input — the governors derive off-grid clock
//!   choices from the regime (memory-bound plans tolerate deep downclock,
//!   compute-bound plans are floored at the voltage knee) instead of pure
//!   log₂N interpolation.
//! * **TPU kernels** ([`estimate_fft_kernel`]): L1 hardware-adaptation
//!   accounting (DESIGN.md §2) — VMEM footprint and MXU/VPU utilization
//!   for the Pallas Stockham kernel's BlockSpec, per TPU generation.
//!   `interpret=True` CPU timings say nothing about TPU performance; this
//!   is the structural analysis EXPERIMENTS.md §Perf records instead.

use crate::dsp::planner::{plan_for, PlanAlgorithm};
use crate::sim::gpu::GpuSpec;
use crate::types::Precision;

/// Which side of the roofline a compiled plan sits on, on a given card.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanRegime {
    ComputeBound,
    MemoryBound,
}

/// Per-plan roofline analysis on one card at boost clock. Times are per
/// complex element (batch-invariant — both sides scale linearly in rows).
#[derive(Debug, Clone)]
pub struct PlanRoofline {
    pub n: u64,
    pub algorithm: PlanAlgorithm,
    /// Demand bytes one transform moves ([`crate::dsp::planner::FftPlan::bytes_moved`],
    /// tables included) — the reporting figure.
    pub bytes_moved: u64,
    /// Equivalent radix-2 stages the schedule issues per element.
    pub radix2_stages: f64,
    /// Full-plane sweeps per transform.
    pub passes: usize,
    /// Issue-cycle time per complex element at boost, seconds.
    pub t_compute_s: f64,
    /// Plane-traffic time per complex element against the plan's
    /// bandwidth tier, seconds.
    pub t_memory_s: f64,
    pub regime: PlanRegime,
}

/// The residency budget deciding a plan's bandwidth tier: a monolithic
/// plan whose 4 live planes fit in this many bytes streams from
/// shared/L2, everything else pays device-memory bandwidth. Matches the
/// planner's own L2 blocking budget (`FFTSWEEP_FFT_BLOCK` docs).
pub const RESIDENCY_BYTES: u64 = 256 * 1024;

/// Classify the compiled plan for length `n` on `gpu` at boost clock.
///
/// Compute side: the sim's issue-cost model — `cycles_per_stage` per
/// equivalent radix-2 stage plus `cycles_base` per plane pass, per
/// complex element, over `cuda_cores` at boost. Memory side: each pass
/// reads and writes the complex plane once; monolithic mixed-radix plans
/// whose working set sits within [`RESIDENCY_BYTES`] stream at shared
/// bandwidth, four-step/Bluestein/oversized plans at device bandwidth.
/// Twiddle-table traffic is excluded from the regime decision (it is
/// broadcast-friendly and cache-resident per stage) but included in the
/// reported `bytes_moved`.
pub fn classify_plan(gpu: &GpuSpec, n: u64, precision: Precision) -> PlanRoofline {
    let plan = plan_for(n as usize);
    let r2e = plan.radix2_equiv_stages();
    let passes = plan.pass_count();
    let fp_ratio = match precision {
        Precision::Fp64 => gpu.fp64_ratio,
        Precision::Fp16 => gpu.fp16_ratio.unwrap_or(1.0),
        Precision::Fp32 => 1.0,
    };
    let issue_cycles = (gpu.cycles_per_stage * r2e + gpu.cycles_base * passes as f64) / fp_ratio;
    let t_compute = issue_cycles / (gpu.cuda_cores as f64 * gpu.boost_clock_mhz * 1e6);
    let resident = plan.algorithm() == PlanAlgorithm::MixedRadix
        && 4 * n * precision.real_bytes() <= RESIDENCY_BYTES;
    let bw_gbs = if resident {
        gpu.shared_bw_gbs
    } else {
        gpu.dev_bw_gbs
    };
    let t_memory = passes as f64 * 2.0 * precision.complex_bytes() as f64 / (bw_gbs * 1e9);
    PlanRoofline {
        n,
        algorithm: plan.algorithm(),
        bytes_moved: plan.bytes_moved(precision),
        radix2_stages: r2e,
        passes,
        t_compute_s: t_compute,
        t_memory_s: t_memory,
        regime: if t_memory > t_compute {
            PlanRegime::MemoryBound
        } else {
            PlanRegime::ComputeBound
        },
    }
}

/// A TPU-like target for the estimate.
#[derive(Debug, Clone)]
pub struct TpuTarget {
    pub name: &'static str,
    /// VMEM per core, bytes.
    pub vmem_bytes: u64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbs: f64,
    /// VPU throughput, G-lane-ops/s (8x128 lanes × clock).
    pub vpu_glanes: f64,
}

pub fn tpu_v4() -> TpuTarget {
    TpuTarget {
        name: "TPUv4-core",
        vmem_bytes: 128 << 20,
        hbm_gbs: 1200.0,
        vpu_glanes: 4000.0, // 8*128 lanes x 2 ALUs x ~2 ops @ ~1 GHz
    }
}

/// Static analysis of one `fft_c2c` pallas_call.
#[derive(Debug, Clone)]
pub struct KernelEstimate {
    pub tile_b: u64,
    pub n: u64,
    /// Bytes resident in VMEM for one grid step (in + out + ping-pong).
    pub vmem_bytes: u64,
    /// Fraction of VMEM used.
    pub vmem_frac: f64,
    /// HBM bytes moved per grid step (one read + one write of the tile).
    pub hbm_bytes: u64,
    /// VPU lane-operations per grid step (butterflies are elementwise
    /// mul/add over re/im planes — VPU work, not MXU matmuls).
    pub vpu_ops: u64,
    /// Arithmetic intensity, ops/byte.
    pub intensity: f64,
    /// Roofline-predicted time per grid step on the target, seconds.
    pub t_roofline_s: f64,
    /// true → HBM-bound (the desired regime: matches cuFFT's single-kernel
    /// memory-bound behaviour the paper measures).
    pub hbm_bound: bool,
}

/// Estimate the Stockham kernel at (tile_b, n) on a target.
pub fn estimate_fft_kernel(
    tile_b: u64,
    n: u64,
    precision: Precision,
    target: &TpuTarget,
) -> KernelEstimate {
    let eb = precision.real_bytes();
    let tile_elems = tile_b * n;
    // re+im planes, double-buffered across the stage loop: 4 planes live.
    let vmem = 4 * tile_elems * eb;
    // One HBM read of both planes in, one write out (all stages in-VMEM).
    let hbm = 4 * tile_elems * eb;
    let stages = (n as f64).log2().ceil() as u64;
    // Per stage per element: complex add + complex sub + complex mul ≈
    // 10 real ops, plus twiddle cos/sin amortized (precomputed per stage).
    let vpu_ops = 10 * tile_elems * stages;
    let t_mem = hbm as f64 / (target.hbm_gbs * 1e9);
    let t_vpu = vpu_ops as f64 / (target.vpu_glanes * 1e9);
    KernelEstimate {
        tile_b,
        n,
        vmem_bytes: vmem,
        vmem_frac: vmem as f64 / target.vmem_bytes as f64,
        hbm_bytes: hbm,
        vpu_ops,
        intensity: vpu_ops as f64 / hbm as f64,
        t_roofline_s: t_mem.max(t_vpu),
        hbm_bound: t_mem >= t_vpu,
    }
}

/// Pick the largest batch tile that keeps the kernel within a VMEM budget
/// (the BlockSpec sizing rule for `python/compile/kernels/fft.py`).
pub fn max_tile_b(n: u64, precision: Precision, target: &TpuTarget, budget_frac: f64) -> u64 {
    let eb = precision.real_bytes();
    let per_row = 4 * n * eb;
    ((target.vmem_bytes as f64 * budget_frac) / per_row as f64).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{jetson_nano, tesla_p4, tesla_v100, titan_v, titan_xp};

    #[test]
    fn small_pow2_plans_are_compute_bound_on_every_card() {
        // Cache-resident monolithic plans stream at shared bandwidth —
        // the paper's single-kernel lengths are issue-limited, which is
        // why their energy optimum sits at/above the voltage knee.
        for gpu in [tesla_v100(), tesla_p4(), titan_xp(), titan_v(), jetson_nano()] {
            for n in [256u64, 1024, 4096] {
                let r = classify_plan(&gpu, n, Precision::Fp32);
                assert_eq!(
                    r.regime,
                    PlanRegime::ComputeBound,
                    "{} n={n}: t_c {:.3e} t_m {:.3e}",
                    gpu.name,
                    r.t_compute_s,
                    r.t_memory_s
                );
            }
        }
    }

    #[test]
    fn four_step_and_bluestein_plans_are_memory_bound() {
        // Past the residency budget the plan pays device bandwidth for
        // every pass — 2^18 compiles to four-step, 2^22 likewise, and
        // Bluestein's padded double-transform is pure streaming.
        let gpu = tesla_v100();
        for n in [1u64 << 18, 1 << 22, 19321] {
            let r = classify_plan(&gpu, n, Precision::Fp32);
            assert_eq!(
                r.regime,
                PlanRegime::MemoryBound,
                "n={n}: t_c {:.3e} t_m {:.3e}",
                r.t_compute_s,
                r.t_memory_s
            );
        }
        let big = classify_plan(&gpu, 1 << 18, Precision::Fp32);
        assert_eq!(big.algorithm, PlanAlgorithm::FourStep);
        assert!(big.bytes_moved > 0);
    }

    #[test]
    fn residency_tier_flips_the_regime_at_the_l2_boundary() {
        // n=16384 fp32: 4 planes × 4 B × 16384 = 256 KiB exactly — the
        // last resident length on the V100; the next monolithic size up
        // would stream from device memory.
        let gpu = tesla_v100();
        let r = classify_plan(&gpu, 16384, Precision::Fp32);
        assert_eq!(r.regime, PlanRegime::ComputeBound);
        // Same length in fp64 doubles the working set past the budget
        // AND halves issue throughput; the V100's 1:2 fp64 keeps it
        // compute-heavy enough that only the bandwidth tier changes.
        let r64 = classify_plan(&gpu, 16384, Precision::Fp64);
        assert!(r64.t_memory_s > r.t_memory_s * 10.0, "tier must drop to device BW");
    }

    #[test]
    fn default_tile_fits_vmem() {
        // the python kernel's DEFAULT_TILE_B=16 at the largest single-kernel
        // fp32 length must fit comfortably
        let e = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(e.vmem_frac < 0.05, "vmem frac {}", e.vmem_frac);
    }

    #[test]
    fn vpu_butterflies_are_not_hbm_bound_the_hardware_adaptation_finding() {
        // On the V100, 5·N·log2(N) flops against 900 GB/s leaves cuFFT
        // memory-bound (knee ≈ 17 flops/byte). The TPU's VPU knee is much
        // lower (≈ 3.3 ops/byte), so a pure-VPU Stockham kernel goes
        // compute-bound beyond tiny N — the DESIGN.md §2 argument for
        // expressing larger radix butterflies as MXU matmuls on real TPUs.
        let tiny = estimate_fft_kernel(16, 4, Precision::Fp32, &tpu_v4());
        assert!(tiny.hbm_bound, "intensity {}", tiny.intensity);
        let big = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(!big.hbm_bound, "intensity {}", big.intensity);
    }

    #[test]
    fn intensity_grows_with_log_n() {
        let a = estimate_fft_kernel(16, 256, Precision::Fp32, &tpu_v4());
        let b = estimate_fft_kernel(16, 8192, Precision::Fp32, &tpu_v4());
        assert!(b.intensity > a.intensity);
        // ratio = log2 ratio
        assert!((b.intensity / a.intensity - 13.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn max_tile_b_respects_budget() {
        let t = tpu_v4();
        let tile = max_tile_b(8192, Precision::Fp32, &t, 0.5);
        let e = estimate_fft_kernel(tile, 8192, Precision::Fp32, &t);
        assert!(e.vmem_frac <= 0.5);
        let e2 = estimate_fft_kernel(tile + 1, 8192, Precision::Fp32, &t);
        assert!(e2.vmem_frac > 0.5);
    }

    #[test]
    fn fp64_halves_tile() {
        let t = tpu_v4();
        let t32 = max_tile_b(4096, Precision::Fp32, &t, 0.5);
        let t64 = max_tile_b(4096, Precision::Fp64, &t, 0.5);
        assert_eq!(t32, 2 * t64);
    }
}
