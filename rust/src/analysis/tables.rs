//! Regeneration of the paper's Tables 1-4 as CSV/ASCII.

use crate::analysis::optimal::{mean_optimal_mhz, optima};
use crate::harness::sweep::{sweep_gpu, SweepConfig};
use crate::sim::freq_table::freq_table;
use crate::sim::gpu::{all_gpus, GpuSpec};
use crate::types::Precision;
use crate::util::table::{fnum, Table};

/// Table 1: allowed core-clock frequency ranges and step sizes.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: allowed core clock frequencies",
        &["Card name", "f_max [MHz]", "f_min [MHz]", "f_step [MHz]", "#freqs"],
    );
    for g in all_gpus() {
        let ft = freq_table(&g);
        let steps = ft
            .steps_mhz
            .iter()
            .map(|s| fnum(*s, 1))
            .collect::<Vec<_>>()
            .join(", ");
        t.push_row(vec![
            g.name.to_string(),
            fnum(ft.f_max_mhz, 1),
            fnum(ft.f_min_mhz, 1),
            steps,
            ft.frequencies().len().to_string(),
        ]);
    }
    t
}

/// Table 2: GPU card specifications.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: GPU card specifications",
        &[
            "Spec", "Titan XP", "Tesla P4", "Titan V", "Tesla V100", "Jetson Nano",
        ],
    );
    let gs = all_gpus();
    let col = |f: &dyn Fn(&GpuSpec) -> String| -> Vec<String> {
        gs.iter().map(|g| f(g)).collect()
    };
    let rows: Vec<(&str, Vec<String>)> = vec![
        ("CUDA Cores", col(&|g| g.cuda_cores.to_string())),
        ("SMs", col(&|g| g.sms.to_string())),
        (
            "Base/Boost Core Clock [MHz]",
            col(&|g| {
                if g.has_base_clock() {
                    format!("{}/{}", fnum(g.base_clock_mhz, 0), fnum(g.boost_clock_mhz, 0))
                } else {
                    fnum(g.boost_clock_mhz, 1)
                }
            }),
        ),
        ("Memory Clock [MHz]", col(&|g| fnum(g.mem_clock_mhz, 0))),
        ("Dv. m. bandwidth [GB/s]", col(&|g| fnum(g.dev_bw_gbs, 1))),
        ("Memory modules", col(&|g| g.mem_kind.label().to_string())),
        ("Shared m. bandwidth [GB/s]", col(&|g| fnum(g.shared_bw_gbs, 0))),
        ("Memory size [GB]", col(&|g| (g.mem_bytes >> 30).to_string())),
        ("TDP [W]", col(&|g| fnum(g.tdp_w, 0))),
    ];
    for (name, cells) in rows {
        let mut row = vec![name.to_string()];
        row.extend(cells);
        t.push_row(row);
    }
    t
}

/// Table 3: mean optimal core-clock frequencies, derived by running the
/// full sweep per (gpu, precision).
pub fn table3(cfg: &SweepConfig) -> Table {
    let mut t = Table::new(
        "Table 3: mean optimal core clock frequencies [MHz]",
        &["Card name", "FP32", "FP64", "FP16"],
    );
    for g in all_gpus() {
        let mut cells = vec![g.name.to_string()];
        for p in [Precision::Fp32, Precision::Fp64, Precision::Fp16] {
            if !g.supports(p) {
                cells.push("NA".into());
                continue;
            }
            let sweep = sweep_gpu(&g, p, cfg);
            let pts = optima(&g, &sweep);
            cells.push(fnum(mean_optimal_mhz(&g, &pts), 0));
        }
        t.push_row(cells);
    }
    t
}

/// Paper Table 3 reference values for comparison columns / tests.
pub fn table3_paper_mhz(gpu: &str, p: Precision) -> Option<f64> {
    let v = match (gpu, p) {
        ("Tesla V100", Precision::Fp32) => 945.0,
        ("Tesla V100", Precision::Fp64) => 945.0,
        ("Tesla V100", Precision::Fp16) => 937.0,
        ("Tesla P4", Precision::Fp32) => 746.0,
        ("Tesla P4", Precision::Fp64) => 1126.0,
        ("Titan V", Precision::Fp32) => 952.0,
        ("Titan V", Precision::Fp64) => 967.0,
        ("Titan V", Precision::Fp16) => 1042.0,
        ("Titan XP", Precision::Fp32) => 1151.0,
        ("Titan XP", Precision::Fp64) => 1215.0,
        ("Jetson Nano", Precision::Fp32) => 460.8,
        ("Jetson Nano", Precision::Fp64) => 460.8,
        ("Jetson Nano", Precision::Fp16) => 460.8,
        _ => return None,
    };
    Some(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Protocol;

    #[test]
    fn table1_has_five_cards() {
        let t = table1();
        assert_eq!(t.rows.len(), 5);
        let csv = t.to_csv();
        assert!(csv.contains("Tesla V100,1530.0,135.0"));
        assert!(csv.contains("Jetson Nano,921.6,76.8"));
    }

    #[test]
    fn table2_matches_paper_cells() {
        let csv = table2().to_csv();
        assert!(csv.contains("CUDA Cores,3840,2560,5120,5120,128"));
        assert!(csv.contains("TDP [W],250,75,250,300,10"));
        assert!(csv.contains("GDDR5,GDDR5,HBM2,HBM2,LPDDR4"));
    }

    #[test]
    fn table3_paper_reference_complete() {
        assert_eq!(table3_paper_mhz("Tesla V100", Precision::Fp32), Some(945.0));
        assert_eq!(table3_paper_mhz("Tesla P4", Precision::Fp16), None);
        assert_eq!(table3_paper_mhz("Titan XP", Precision::Fp16), None);
    }

    #[test]
    fn table3_generates_na_for_unsupported() {
        let cfg = SweepConfig {
            lengths: vec![1024],
            freq_stride: 40,
            protocol: Protocol { reps_per_run: 2, runs: 2, seed: 5 },
        };
        let t = table3(&cfg);
        assert_eq!(t.rows.len(), 5);
        let p4 = t.rows.iter().find(|r| r[0] == "Tesla P4").unwrap();
        assert_eq!(p4[3], "NA");
        let v100 = t.rows.iter().find(|r| r[0] == "Tesla V100").unwrap();
        assert_ne!(v100[3], "NA");
    }
}
