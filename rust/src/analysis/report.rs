//! Full-report generation: runs the sweep grid and writes every table and
//! figure CSV under an output directory, plus a summary of paper-vs-measured
//! headline numbers (used by `fftsweep report` and EXPERIMENTS.md).

use std::path::Path;

use crate::analysis::optimal::{at_fixed_clock, mean_optimal_mhz, optima};
use crate::analysis::{figures, tables};
use crate::harness::campaign::sweep_gpu_parallel;
use crate::harness::sweep::SweepConfig;
use crate::sim::gpu::{all_gpus, GpuSpec};
use crate::types::Precision;
use crate::util::stats;
use crate::util::table::{fnum, Table};

/// Headline numbers for one (gpu, precision): what the paper's abstract
/// and conclusions quote.
#[derive(Debug, Clone)]
pub struct Headline {
    pub gpu: String,
    pub precision: Precision,
    pub mean_optimal_mhz: f64,
    pub paper_mean_optimal_mhz: Option<f64>,
    /// Mean eq.7 increase at the per-length optimal clock, vs boost.
    pub mean_eff_increase_boost: f64,
    /// Mean eq.7 increase at the per-length optimal clock, vs base.
    pub mean_eff_increase_base: f64,
    /// Mean eq.7 increase at the mean-optimal (single) clock, vs boost.
    pub mean_eff_increase_fixed_boost: f64,
    /// Mean execution-time increase at the optimal clock.
    pub mean_time_increase: f64,
}

/// Worker threads for report sweeps (the grid is embarrassingly parallel
/// and deterministic per point — see `harness::campaign`).
fn report_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Compute headlines for one gpu/precision.
pub fn headline(gpu: &GpuSpec, precision: Precision, cfg: &SweepConfig) -> Headline {
    let sweep = sweep_gpu_parallel(gpu, precision, cfg, report_threads());
    let pts = optima(gpu, &sweep);
    let mean_opt = mean_optimal_mhz(gpu, &pts);
    let non_bluestein: Vec<_> = pts.iter().filter(|p| !p.bluestein).collect();
    let fixed = at_fixed_clock(gpu, &sweep, mean_opt);
    Headline {
        gpu: gpu.name.to_string(),
        precision,
        mean_optimal_mhz: mean_opt,
        paper_mean_optimal_mhz: tables::table3_paper_mhz(gpu.name, precision),
        mean_eff_increase_boost: stats::mean(
            &non_bluestein
                .iter()
                .map(|p| p.eff_increase_vs_boost)
                .collect::<Vec<_>>(),
        ),
        mean_eff_increase_base: stats::mean(
            &non_bluestein
                .iter()
                .map(|p| p.eff_increase_vs_base)
                .collect::<Vec<_>>(),
        ),
        mean_eff_increase_fixed_boost: stats::mean(
            &fixed
                .iter()
                .map(|f| f.eff_increase_vs_boost)
                .collect::<Vec<_>>(),
        ),
        mean_time_increase: stats::mean(
            &non_bluestein
                .iter()
                .map(|p| p.time_increase)
                .collect::<Vec<_>>(),
        ),
    }
}

/// Render all headlines as the paper-vs-measured comparison table.
pub fn headline_table(headlines: &[Headline]) -> Table {
    let mut t = Table::new(
        "Paper vs measured: mean optimal clock and efficiency increases",
        &[
            "gpu",
            "precision",
            "mean_opt_mhz",
            "paper_mhz",
            "eff_inc@opt(boost)",
            "eff_inc@opt(base)",
            "eff_inc@mean_opt(boost)",
            "time_inc_pct",
        ],
    );
    for h in headlines {
        t.push_row(vec![
            h.gpu.clone(),
            h.precision.to_string(),
            fnum(h.mean_optimal_mhz, 0),
            h.paper_mean_optimal_mhz
                .map(|x| fnum(x, 0))
                .unwrap_or_else(|| "-".into()),
            fnum(h.mean_eff_increase_boost, 3),
            fnum(h.mean_eff_increase_base, 3),
            fnum(h.mean_eff_increase_fixed_boost, 3),
            fnum(h.mean_time_increase * 100.0, 1),
        ]);
    }
    t
}

/// Generate the complete report tree under `out_dir`.
pub fn full_report(out_dir: &Path, cfg: &SweepConfig) -> anyhow::Result<Vec<Headline>> {
    std::fs::create_dir_all(out_dir)?;
    let gpus = all_gpus();

    // Tables 1 & 2 are pure spec transcriptions.
    tables::table1().write_csv(&out_dir.join("table1.csv"))?;
    tables::table2().write_csv(&out_dir.join("table2.csv"))?;

    // Fig 4/5 exec-time staircases.
    figures::figure4_5(&gpus, Precision::Fp32, &cfg.lengths)
        .write_csv(&out_dir.join("fig4_tfix_fp32.csv"))?;
    figures::figure4_5(&gpus, Precision::Fp64, &cfg.lengths)
        .write_csv(&out_dir.join("fig5_tfix_fp64.csv"))?;
    figures::figure4_5(&gpus, Precision::Fp16, &cfg.lengths)
        .write_csv(&out_dir.join("fig5_tfix_fp16.csv"))?;

    // Fig 7 energy curves (all GPUs).
    figures::figure7(&gpus, cfg).write_csv(&out_dir.join("fig7_energy_n16384.csv"))?;

    // Fig 2 log excerpts (V100 + Titan V as in the paper).
    let v100 = crate::sim::gpu::tesla_v100();
    let titanv = crate::sim::gpu::titan_v();
    figures::figure2(&v100, 16384, 1020.0, 0xF16)
        .0
        .write_csv(&out_dir.join("fig2_v100_log.csv"))?;
    figures::figure2(&titanv, 16384, 1912.0, 0xF16)
        .0
        .write_csv(&out_dir.join("fig2_titanv_log.csv"))?;

    // Fig 20 kernel profiles.
    figures::figure20(&v100, v100.boost_clock_mhz)
        .write_csv(&out_dir.join("fig20_profiles.csv"))?;

    let mut headlines = Vec::new();
    for gpu in &gpus {
        for p in Precision::ALL {
            if !gpu.supports(p) {
                continue;
            }
            let sweep = sweep_gpu_parallel(gpu, p, cfg, report_threads());
            let tag = format!(
                "{}_{}",
                gpu.name.to_lowercase().replace(' ', "_"),
                p.label().to_lowercase()
            );
            figures::figure3(gpu, &sweep).write_csv(&out_dir.join(format!("fig3_{tag}.csv")))?;
            figures::figure6(gpu, &sweep).write_csv(&out_dir.join(format!("fig6_{tag}.csv")))?;
            figures::figure8(gpu, &sweep).write_csv(&out_dir.join(format!("fig8_{tag}.csv")))?;
            figures::figure9_to_14(gpu, &sweep)
                .write_csv(&out_dir.join(format!("fig9_14_{tag}.csv")))?;
            let (_, f15) = figures::figure15_16(gpu, &sweep);
            f15.write_csv(&out_dir.join(format!("fig15_16_{tag}.csv")))?;
            figures::figure17_18(gpu, &sweep)
                .write_csv(&out_dir.join(format!("fig17_18_{tag}.csv")))?;
            headlines.push(headline(gpu, p, cfg));
        }
    }

    // Table 3 from the headlines (already computed sweeps feed the figure
    // files; re-deriving keeps the CSV self-contained).
    let mut t3 = Table::new(
        "Table 3: mean optimal core clock frequencies [MHz]",
        &["Card name", "FP32", "FP64", "FP16"],
    );
    for gpu in &gpus {
        let mut row = vec![gpu.name.to_string()];
        for p in [Precision::Fp32, Precision::Fp64, Precision::Fp16] {
            let cell = headlines
                .iter()
                .find(|h| h.gpu == gpu.name && h.precision == p)
                .map(|h| fnum(h.mean_optimal_mhz, 0))
                .unwrap_or_else(|| "NA".into());
            row.push(cell);
        }
        t3.push_row(row);
    }
    t3.write_csv(&out_dir.join("table3.csv"))?;

    headline_table(&headlines).write_csv(&out_dir.join("headlines.csv"))?;

    // Machine-readable summary for downstream tooling.
    let mut root = crate::util::json::Json::obj();
    root.set("paper_doi", "10.1109/ACCESS.2021.3053409".into());
    let mut arr = crate::util::json::Json::Arr(vec![]);
    for h in &headlines {
        let mut o = crate::util::json::Json::obj();
        o.set("gpu", h.gpu.as_str().into());
        o.set("precision", h.precision.label().into());
        o.set("mean_optimal_mhz", h.mean_optimal_mhz.into());
        o.set(
            "paper_mean_optimal_mhz",
            h.paper_mean_optimal_mhz
                .map(crate::util::json::Json::Num)
                .unwrap_or(crate::util::json::Json::Null),
        );
        o.set("eff_increase_vs_boost", h.mean_eff_increase_boost.into());
        o.set("eff_increase_vs_base", h.mean_eff_increase_base.into());
        o.set("eff_increase_mean_opt", h.mean_eff_increase_fixed_boost.into());
        o.set("time_increase", h.mean_time_increase.into());
        arr.push(o);
    }
    root.set("headlines", arr);
    std::fs::write(out_dir.join("report.json"), root.render())?;
    Ok(headlines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Protocol;
    use crate::sim::gpu::tesla_v100;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            lengths: vec![1024, 16384],
            freq_stride: 24,
            protocol: Protocol { reps_per_run: 3, runs: 3, seed: 21 },
        }
    }

    #[test]
    fn headline_v100_fp32_reproduces_paper_shape() {
        let h = headline(&tesla_v100(), Precision::Fp32, &tiny_cfg());
        // paper: ~60% efficiency increase vs boost, <10% time increase
        assert!(
            h.mean_eff_increase_boost > 1.25,
            "eff increase {}",
            h.mean_eff_increase_boost
        );
        assert!(h.mean_time_increase < 0.10, "time inc {}", h.mean_time_increase);
        // mean optimal in the paper's neighbourhood
        assert!(
            (h.mean_optimal_mhz - 945.0).abs() < 150.0,
            "mean opt {}",
            h.mean_optimal_mhz
        );
    }

    #[test]
    fn headline_table_renders() {
        let h = headline(&tesla_v100(), Precision::Fp32, &tiny_cfg());
        let t = headline_table(&[h]);
        assert_eq!(t.rows.len(), 1);
        assert!(t.to_ascii().contains("Tesla V100"));
    }
}
