//! Operational cost and emissions model — the paper's closing motivation
//! ("These savings, when considered over years of operation, can yield
//! significant financial savings, but can also lead to a significant
//! reduction of greenhouse gas emissions").
//!
//! Converts a measured per-batch energy saving into fleet-level annual
//! kWh, currency and CO₂e numbers for an SKA-style continuously-running
//! deployment, including the cooling overhead (PUE) the paper's §6.1
//! operational-cost discussion mentions.

use crate::sim::{run_batch, GpuSpec};
use crate::types::FftWorkload;
use crate::util::table::{fnum, Table};

/// Deployment assumptions.
#[derive(Debug, Clone)]
pub struct Deployment {
    /// Number of GPUs running the FFT workload.
    pub gpus: u64,
    /// Fraction of wall-clock time the cards spend in the FFT kernels
    /// (duty cycle; an SKA real-time pipeline is near-continuous).
    pub duty_cycle: f64,
    /// Power usage effectiveness of the facility (cooling etc.).
    pub pue: f64,
    /// Electricity price, currency per kWh.
    pub price_per_kwh: f64,
    /// Grid carbon intensity, kg CO2e per kWh.
    pub co2_kg_per_kwh: f64,
}

impl Default for Deployment {
    fn default() -> Self {
        // A modest SKA-SDP-like slice: 500 accelerators, 80% duty,
        // PUE 1.4, 0.15/kWh, ~0.4 kg CO2e/kWh grid mix.
        Self {
            gpus: 500,
            duty_cycle: 0.8,
            pue: 1.4,
            price_per_kwh: 0.15,
            co2_kg_per_kwh: 0.4,
        }
    }
}

/// Annualized consumption of one clock policy.
#[derive(Debug, Clone)]
pub struct AnnualCost {
    pub avg_power_w: f64,
    pub mwh_per_year: f64,
    pub cost_per_year: f64,
    pub co2_tonnes_per_year: f64,
}

/// Savings from running the fleet's FFTs at `tuned_mhz` instead of boost.
#[derive(Debug, Clone)]
pub struct Savings {
    pub boost: AnnualCost,
    pub tuned: AnnualCost,
    pub mwh_saved: f64,
    pub cost_saved: f64,
    pub co2_tonnes_saved: f64,
    /// Throughput cost: extra time per batch at the tuned clock.
    pub time_increase: f64,
}

const HOURS_PER_YEAR: f64 = 8766.0;

fn annualize(dep: &Deployment, avg_power_w: f64) -> AnnualCost {
    let fleet_kw = avg_power_w * dep.gpus as f64 * dep.duty_cycle * dep.pue / 1e3;
    let kwh = fleet_kw * HOURS_PER_YEAR;
    AnnualCost {
        avg_power_w,
        mwh_per_year: kwh / 1e3,
        cost_per_year: kwh * dep.price_per_kwh,
        co2_tonnes_per_year: kwh * dep.co2_kg_per_kwh / 1e3,
    }
}

/// Evaluate the deployment on one workload with boost vs tuned clocks.
/// Energy-per-work at each clock converts to average power at a fixed
/// real-time work rate (the fleet must process the same data either way,
/// so the comparison holds work — not wall time — constant).
pub fn savings(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    tuned_mhz: f64,
    dep: &Deployment,
) -> Savings {
    let boost_run = run_batch(gpu, workload, gpu.boost_clock_mhz);
    let tuned_run = run_batch(gpu, workload, tuned_mhz);
    // Work rate is set by real time at boost: batches/s = duty / t_boost.
    // Average power of a policy = energy_per_batch * batch_rate.
    let batch_rate = 1.0 / boost_run.timing.total_s;
    let boost = annualize(dep, boost_run.energy_j * batch_rate);
    let tuned = annualize(dep, tuned_run.energy_j * batch_rate);
    Savings {
        mwh_saved: boost.mwh_per_year - tuned.mwh_per_year,
        cost_saved: boost.cost_per_year - tuned.cost_per_year,
        co2_tonnes_saved: boost.co2_tonnes_per_year - tuned.co2_tonnes_per_year,
        time_increase: tuned_run.timing.total_s / boost_run.timing.total_s - 1.0,
        boost,
        tuned,
    }
}

/// Render the deployment comparison as a table.
pub fn cost_table(gpu: &GpuSpec, workload: &FftWorkload, tuned_mhz: f64, dep: &Deployment) -> Table {
    let s = savings(gpu, workload, tuned_mhz, dep);
    let mut t = Table::new(
        &format!(
            "Annual fleet cost: {} × {}, N={}, FFT duty {:.0}%, PUE {}",
            dep.gpus, gpu.name, workload.n, dep.duty_cycle * 100.0, dep.pue
        ),
        &["policy", "avg W/gpu", "MWh/yr", "cost/yr", "tCO2e/yr"],
    );
    for (name, c) in [("boost", &s.boost), (&format!("{} MHz", fnum(tuned_mhz, 0)), &s.tuned)] {
        t.push_row(vec![
            name.to_string(),
            fnum(c.avg_power_w, 1),
            fnum(c.mwh_per_year, 1),
            fnum(c.cost_per_year, 0),
            fnum(c.co2_tonnes_per_year, 1),
        ]);
    }
    t.push_row(vec![
        "SAVED".into(),
        fnum(s.boost.avg_power_w - s.tuned.avg_power_w, 1),
        fnum(s.mwh_saved, 1),
        fnum(s.cost_saved, 0),
        fnum(s.co2_tonnes_saved, 1),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    fn setup() -> (GpuSpec, FftWorkload) {
        let g = tesla_v100();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        (g, w)
    }

    #[test]
    fn tuned_policy_saves_money_and_carbon() {
        let (g, w) = setup();
        let s = savings(&g, &w, 945.0, &Deployment::default());
        assert!(s.mwh_saved > 0.0);
        assert!(s.cost_saved > 0.0);
        assert!(s.co2_tonnes_saved > 0.0);
        // the saving fraction matches the per-batch energy saving
        let frac = 1.0 - s.tuned.mwh_per_year / s.boost.mwh_per_year;
        assert!((0.2..0.5).contains(&frac), "saving frac {frac}");
    }

    #[test]
    fn fleet_scale_magnitude_is_significant() {
        // The paper's "significant financial savings" claim: a 500-GPU
        // fleet at V100-like power should save O(100k)/yr at 0.15/kWh.
        let (g, w) = setup();
        let s = savings(&g, &w, 945.0, &Deployment::default());
        assert!(
            s.cost_saved > 50_000.0,
            "annual saving {} too small to be 'significant'",
            s.cost_saved
        );
        assert!(s.co2_tonnes_saved > 100.0, "tCO2e {}", s.co2_tonnes_saved);
    }

    #[test]
    fn linear_in_fleet_size_and_price() {
        let (g, w) = setup();
        let base = savings(&g, &w, 945.0, &Deployment::default());
        let mut big = Deployment::default();
        big.gpus *= 2;
        let doubled = savings(&g, &w, 945.0, &big);
        assert!((doubled.cost_saved / base.cost_saved - 2.0).abs() < 1e-9);
    }

    #[test]
    fn boost_policy_is_identity() {
        let (g, w) = setup();
        let s = savings(&g, &w, g.boost_clock_mhz, &Deployment::default());
        assert!(s.mwh_saved.abs() < 1e-9);
        assert_eq!(s.time_increase, 0.0);
    }

    #[test]
    fn table_renders_three_rows() {
        let (g, w) = setup();
        let t = cost_table(&g, &w, 945.0, &Deployment::default());
        assert_eq!(t.rows.len(), 3);
        assert!(t.to_ascii().contains("SAVED"));
    }
}
