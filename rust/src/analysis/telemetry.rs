//! No-cap vs capped serving comparison — the operator-facing analysis of
//! the power-budget subsystem (`fftsweep telemetry`).
//!
//! Replays one seeded job trace through two otherwise-identical fleets —
//! uncapped, then under `--power-budget-w` — and tabulates what the cap
//! costs and buys: energy per job, simulated p50/p99 batch latency, the
//! rolling 1 s fleet draw the cap constrains, NVML clock transitions
//! (bounded under the arbiter's hysteresis) and deadline misses. This is
//! the SKA-style "power monitoring and control" loop closed over the
//! paper's DVFS result: see the watts, cap the watts, read what it cost.

use anyhow::Result;

use crate::coordinator::{CardConfig, Engine, EngineConfig};
use crate::governor::GovernorKind;
use crate::runtime::IntoBackend;
use crate::sim::GpuSpec;
use crate::telemetry::{FleetSnapshot, LogHistogram};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

/// Outcome of serving one trace on one fleet configuration.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub label: String,
    pub budget_w: Option<f64>,
    pub jobs_ok: usize,
    /// Mean attributed energy per completed job, J.
    pub energy_per_job_j: f64,
    /// Σ over cards of the rolling 1 s draw at drain time, W.
    pub fleet_draw_1s_w: f64,
    /// Simulated on-card batch latency percentiles over the jobs, ms.
    pub p50_sim_ms: f64,
    pub p99_sim_ms: f64,
    pub energy_saving: f64,
    pub clock_transitions: u64,
    pub deadline_misses: u64,
    /// Jobs re-dispatched after a batch error (0 on a healthy fleet).
    pub jobs_retried: u64,
    /// Jobs dropped with a typed error (0 on a healthy fleet).
    pub jobs_shed: u64,
    /// The full typed snapshot (exporters render it further).
    pub snapshot: FleetSnapshot,
}

/// Serve `jobs` seeded random transforms (lengths drawn from `lengths`)
/// on a fresh fleet of `specs` under `governor`, optionally capped at
/// `budget_w`. The same `seed` reproduces the identical payload stream,
/// which is what makes the capped/uncapped rows comparable.
pub fn serve_trace(
    backend: impl IntoBackend,
    specs: &[GpuSpec],
    governor: &GovernorKind,
    jobs: usize,
    lengths: &[u64],
    seed: u64,
    budget_w: Option<f64>,
) -> Result<ServeStats> {
    anyhow::ensure!(!lengths.is_empty(), "telemetry trace needs at least one length");
    let fleet: Vec<CardConfig> = specs
        .iter()
        .map(|s| CardConfig::new(s.clone(), governor.clone()))
        .collect();
    let cfg = EngineConfig {
        power_budget_w: budget_w,
        ..EngineConfig::default()
    };
    let engine = Engine::start(backend, fleet, cfg)?;
    for &n in lengths {
        engine.router().route(n, "f32")?;
    }

    let mut rng = Rng::new(seed);
    let mut rxs = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let n = lengths[rng.below(lengths.len() as u64) as usize] as usize;
        let re: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        rxs.push(engine.submit(re, im)?);
    }
    let report = engine.drain(std::time::Duration::from_secs(120));
    anyhow::ensure!(
        report.complete,
        "telemetry trace drain timed out ({} jobs unresolved)",
        report.remaining_total()
    );
    let mut jobs_ok = 0usize;
    // Percentiles come from the serving stack's one histogram
    // implementation (log-bucketed, ~2.2% worst-case read error) rather
    // than a sort — same readout path as the tracer and the exporters.
    let sim_ms = LogHistogram::new();
    for rx in rxs {
        if let Ok(res) = rx.recv()? {
            jobs_ok += 1;
            sim_ms.record(res.sim_batch_s * 1e3);
        }
    }
    let sim_ms = sim_ms.snapshot();
    let snapshot = engine.snapshot();
    engine.shutdown();

    Ok(ServeStats {
        label: match budget_w {
            Some(w) => format!("capped @ {} W", fnum(w, 0)),
            None => "uncapped".into(),
        },
        budget_w,
        jobs_ok,
        energy_per_job_j: snapshot.fleet.energy_per_job_j,
        fleet_draw_1s_w: snapshot.fleet.draw_1s_w,
        p50_sim_ms: sim_ms.percentile(50.0),
        p99_sim_ms: sim_ms.percentile(99.0),
        energy_saving: snapshot.fleet.energy_saving,
        clock_transitions: snapshot.fleet.clock_transitions,
        deadline_misses: snapshot.fleet.deadline_misses,
        jobs_retried: snapshot.fleet.jobs_retried,
        jobs_shed: snapshot.fleet.jobs_shed,
        snapshot,
    })
}

/// Run the same trace uncapped and capped and build the comparison table.
#[allow(clippy::too_many_arguments)]
pub fn budget_comparison(
    backend: impl IntoBackend,
    specs: &[GpuSpec],
    governor: &GovernorKind,
    jobs: usize,
    lengths: &[u64],
    seed: u64,
    budget_w: f64,
) -> Result<(Vec<ServeStats>, Table)> {
    let backend = backend.into_backend();
    let uncapped = serve_trace(backend.clone(), specs, governor, jobs, lengths, seed, None)?;
    let capped = serve_trace(backend, specs, governor, jobs, lengths, seed, Some(budget_w))?;
    let cards: Vec<&str> = specs.iter().map(|s| s.name).collect();
    let mut t = Table::new(
        &format!(
            "Power budget: {jobs} jobs on [{}], governor {} (cap {} W)",
            cards.join(", "),
            governor.label(),
            fnum(budget_w, 0)
        ),
        &[
            "run",
            "jobs ok",
            "energy/job mJ",
            "saving %",
            "p50 sim ms",
            "p99 sim ms",
            "1s draw W",
            "transitions",
            "misses",
            "retried/shed",
        ],
    );
    for s in [&uncapped, &capped] {
        t.push_row(vec![
            s.label.clone(),
            format!("{}", s.jobs_ok),
            fnum(s.energy_per_job_j * 1e3, 3),
            fnum(s.energy_saving * 100.0, 1),
            fnum(s.p50_sim_ms, 3),
            fnum(s.p99_sim_ms, 3),
            fnum(s.fleet_draw_1s_w, 1),
            format!("{}", s.clock_transitions),
            format!("{}", s.deadline_misses),
            format!("{}/{}", s.jobs_retried, s.jobs_shed),
        ]);
    }
    Ok((vec![uncapped, capped], t))
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::sim::gpu::tesla_v100;
    use std::path::Path;
    use std::sync::Arc;

    fn sim_runtime() -> Arc<Runtime> {
        Arc::new(Runtime::new(Path::new("/nonexistent-artifacts")).expect("sim runtime"))
    }

    #[test]
    fn comparison_smoke_capped_draw_below_uncapped() {
        let rt = sim_runtime();
        let specs = vec![tesla_v100(), tesla_v100()];
        // Derive a budget that bites but keeps the capped clocks in the
        // knee region where energy/job still beats boost: 70% of the
        // measured uncapped draw.
        let open = serve_trace(
            rt.clone(),
            &specs,
            &GovernorKind::FixedBoost,
            96,
            &[1024],
            9,
            None,
        )
        .expect("uncapped trace");
        assert_eq!(open.jobs_ok, 96);
        assert!(open.fleet_draw_1s_w > 0.0);
        let budget = 0.7 * open.fleet_draw_1s_w;
        let (stats, table) = budget_comparison(
            rt,
            &specs,
            &GovernorKind::FixedBoost,
            96,
            &[1024],
            9,
            budget,
        )
        .expect("comparison");
        assert_eq!(stats.len(), 2);
        assert_eq!(table.rows.len(), 2);
        let (uncapped, capped) = (&stats[0], &stats[1]);
        assert_eq!(uncapped.jobs_ok, 96);
        assert_eq!(capped.jobs_ok, 96);
        assert!(
            capped.fleet_draw_1s_w <= budget + 1e-6,
            "capped draw {} W over budget {budget} W",
            capped.fleet_draw_1s_w
        );
        assert!(uncapped.fleet_draw_1s_w > capped.fleet_draw_1s_w);
        // capped runs lower clocks: cheaper jobs, slower sim latency
        assert!(capped.energy_per_job_j < uncapped.energy_per_job_j);
        assert!(uncapped.p99_sim_ms <= capped.p99_sim_ms + 1e-9);
    }
}
