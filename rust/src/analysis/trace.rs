//! Trace-journal replay — the analysis behind `fftsweep trace`.
//!
//! `serve --trace-out journal.jsonl` streams one [`Span`] per completed
//! job; this module loads the journal back and tabulates where the
//! latency went (queue vs batch-wait vs exec) and what each job cost in
//! joules, per percentile, split capped vs uncapped — the request-level
//! view of the paper's "what does a capped clock actually cost" question
//! that the fleet-aggregate `fftsweep telemetry` table cannot show.
//!
//! Percentiles come from the same [`LogHistogram`] the live tracer uses,
//! so an offline replay of a journal reads the same numbers a scrape of
//! the live histograms would have.

use std::path::Path;

use anyhow::{Context, Result};

use crate::telemetry::histogram::LogHistogram;
use crate::telemetry::{Span, SpanOutcome};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// Load every span from a JSONL trace journal. Blank lines are skipped;
/// a malformed line fails loud with its line number.
pub fn load_spans(path: &Path) -> Result<Vec<Span>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace journal {}", path.display()))?;
    let mut spans = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}: malformed JSON", path.display(), i + 1))?;
        spans.push(
            Span::from_json(&j)
                .with_context(|| format!("{}:{}: not a span", path.display(), i + 1))?,
        );
    }
    Ok(spans)
}

/// The latency/energy distributions of one span group.
struct Dists {
    queue_s: LogHistogram,
    batch_wait_s: LogHistogram,
    exec_s: LogHistogram,
    e2e_s: LogHistogram,
    energy_j: LogHistogram,
    count: usize,
}

impl Dists {
    fn new() -> Self {
        Self {
            queue_s: LogHistogram::new(),
            batch_wait_s: LogHistogram::new(),
            exec_s: LogHistogram::new(),
            e2e_s: LogHistogram::new(),
            energy_j: LogHistogram::new(),
            count: 0,
        }
    }

    fn observe(&mut self, s: &Span) {
        self.queue_s.record(s.queue_wait_s());
        self.batch_wait_s.record(s.batch_wait_s());
        self.exec_s.record(s.exec_s());
        self.e2e_s.record(s.e2e_s());
        self.energy_j.record(s.energy_j);
        self.count += 1;
    }
}

const PERCENTILES: [f64; 4] = [50.0, 95.0, 99.0, 99.9];

/// Build the per-percentile latency/energy breakdown over the journal:
/// one block of rows per group (`all`, and `uncapped`/`capped` whenever
/// both occur), percentiles p50/p95/p99/p99.9, columns splitting the
/// end-to-end latency into its pre-exec and exec parts. Shed spans are
/// counted in the title but excluded from the distributions (they never
/// executed).
pub fn breakdown_table(spans: &[Span], source: &str) -> Table {
    let ok: Vec<&Span> = spans
        .iter()
        .filter(|s| s.outcome == SpanOutcome::Ok)
        .collect();
    let shed = spans.len() - ok.len();
    let capped_n = ok.iter().filter(|s| s.capped()).count();

    let mut groups: Vec<(&str, Dists)> = vec![("all", Dists::new())];
    // The capped/uncapped split only clarifies when the journal holds
    // both kinds; an all-capped or all-uncapped run keeps one block.
    let split = capped_n > 0 && capped_n < ok.len();
    if split {
        groups.push(("uncapped", Dists::new()));
        groups.push(("capped", Dists::new()));
    }
    for s in &ok {
        groups[0].1.observe(s);
        if split {
            let idx = if s.capped() { 2 } else { 1 };
            groups[idx].1.observe(s);
        }
    }

    let mut t = Table::new(
        &format!(
            "Trace replay: {} ok spans ({} shed, {} capped) from {}",
            ok.len(),
            shed,
            capped_n,
            source
        ),
        &[
            "group",
            "spans",
            "pct",
            "queue ms",
            "batch-wait ms",
            "exec ms",
            "e2e ms",
            "energy mJ",
        ],
    );
    for (label, d) in &groups {
        let (queue, wait, exec, e2e, energy) = (
            d.queue_s.snapshot(),
            d.batch_wait_s.snapshot(),
            d.exec_s.snapshot(),
            d.e2e_s.snapshot(),
            d.energy_j.snapshot(),
        );
        for p in PERCENTILES {
            t.push_row(vec![
                label.to_string(),
                format!("{}", d.count),
                format!("p{p}"),
                fnum(queue.percentile(p) * 1e3, 3),
                fnum(wait.percentile(p) * 1e3, 3),
                fnum(exec.percentile(p) * 1e3, 3),
                fnum(e2e.percentile(p) * 1e3, 3),
                fnum(energy.percentile(p) * 1e3, 4),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(job_id: u64, e2e_us: u64, capped: bool) -> Span {
        Span {
            job_id,
            artifact: "fft_f32_n1024_b64".into(),
            n: 1024,
            card: 0,
            enqueue_us: 0,
            admit_us: 5,
            seal_us: 100,
            dispatch_us: 110,
            exec_start_us: 150,
            exec_end_us: e2e_us.saturating_sub(10),
            complete_us: e2e_us,
            requested_mhz: 945.0,
            granted_mhz: if capped { 700.0 } else { 945.0 },
            batch_occupancy: 64,
            attempts: 1,
            energy_j: if capped { 1.5e-4 } else { 2.5e-4 },
            sim_batch_s: 8.0e-4,
            outcome: SpanOutcome::Ok,
            class: "batch".into(),
            reason: String::new(),
        }
    }

    #[test]
    fn journal_round_trips_through_a_file() {
        let path = std::env::temp_dir().join(format!(
            "fftsweep_trace_replay_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        let mut text = String::new();
        for i in 0..6 {
            text.push_str(&span(i, 2000 + 100 * i, i % 2 == 0).to_jsonl_line());
            text.push('\n');
        }
        text.push('\n'); // trailing blank line is fine
        std::fs::write(&path, &text).unwrap();
        let spans = load_spans(&path).unwrap();
        assert_eq!(spans.len(), 6);
        assert_eq!(spans[5].job_id, 5);
        assert!(spans[0].capped() && !spans[1].capped());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_journal_lines_fail_with_line_numbers() {
        let path = std::env::temp_dir().join(format!(
            "fftsweep_trace_bad_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        let good = span(1, 2000, false).to_jsonl_line();
        std::fs::write(&path, format!("{good}\nnot json\n")).unwrap();
        let err = format!("{:#}", load_spans(&path).unwrap_err());
        assert!(err.contains(":2"), "error names the bad line: {err}");
        // valid JSON that is not a span also fails, naming its line
        std::fs::write(&path, format!("{good}\n{good}\n{{\"x\":1}}\n")).unwrap();
        let err = format!("{:#}", load_spans(&path).unwrap_err());
        assert!(err.contains(":3"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn breakdown_splits_capped_from_uncapped() {
        let mut spans: Vec<Span> = (0..8).map(|i| span(i, 2000, i < 3)).collect();
        let mut dead = span(99, 3000, false);
        dead.outcome = SpanOutcome::Shed;
        spans.push(dead);

        let t = breakdown_table(&spans, "test.jsonl");
        assert!(t.title.contains("8 ok spans (1 shed, 3 capped)"));
        assert_eq!(t.rows.len(), 3 * PERCENTILES.len(), "all + uncapped + capped");
        let capped_row = t.rows.iter().find(|r| r[0] == "capped").unwrap();
        assert_eq!(capped_row[1], "3");
        let uncapped_row = t.rows.iter().find(|r| r[0] == "uncapped").unwrap();
        assert_eq!(uncapped_row[1], "5");
        // energy split: capped jobs cost 0.15 mJ, uncapped 0.25 mJ — the
        // groups' p50 readouts stay within the histogram's bucket error
        let e_capped: f64 = capped_row[7].parse().unwrap();
        let e_uncapped: f64 = uncapped_row[7].parse().unwrap();
        assert!((e_capped / 0.15 - 1.0).abs() < 0.025, "{e_capped}");
        assert!((e_uncapped / 0.25 - 1.0).abs() < 0.025, "{e_uncapped}");
    }

    #[test]
    fn homogeneous_journals_keep_one_group() {
        let spans: Vec<Span> = (0..4).map(|i| span(i, 2000, false)).collect();
        let t = breakdown_table(&spans, "u.jsonl");
        assert_eq!(t.rows.len(), PERCENTILES.len(), "no capped/uncapped split");
        assert!(t.rows.iter().all(|r| r[0] == "all"));
        // stage sanity at p50: queue + exec ≈ e2e (reply tail is tiny)
        let q: f64 = t.rows[0][3].parse().unwrap();
        let x: f64 = t.rows[0][5].parse().unwrap();
        let e: f64 = t.rows[0][6].parse().unwrap();
        assert!(q + x <= e * 1.05 && q + x > e * 0.8, "q={q} x={x} e={e}");
    }
}
