//! Governor comparison: replay one traffic trace under every clock
//! governor and tabulate energy/latency/deadline outcomes — the analysis
//! that turns the paper's single-policy result into a policy menu
//! (`fftsweep govern`).

use crate::governor::{choose_with_budget, BatchFeedback, GovernorContext, GovernorKind};
use crate::sim::freq_table::freq_table;
use crate::sim::{run_batch, GpuSpec};
use crate::types::{FftWorkload, Precision};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

/// One batch of the replayed traffic: a workload plus its deadline.
#[derive(Debug, Clone)]
pub struct TraceBatch {
    pub workload: FftWorkload,
    pub deadline_s: f64,
}

/// A deterministic, seeded traffic trace.
#[derive(Debug, Clone)]
pub struct TrafficTrace {
    pub batches: Vec<TraceBatch>,
}

impl TrafficTrace {
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

/// Default trace menu: pow2 lengths spanning both planner tiers — up to
/// 65536 plans monolithic, 262144 crosses the four-step threshold.
pub const DEFAULT_TRACE_MENU: [u64; 5] = [1024, 8192, 16384, 65536, 262144];

/// Synthesize serving traffic for `gpu`: lengths drawn from a pow2 menu
/// (every card supports them), deadlines 1.15–3× the boost-clock batch
/// time — the "some slack, never infeasible" regime of paper §6.2.
pub fn synthetic_trace(gpu: &GpuSpec, batches: usize, seed: u64) -> TrafficTrace {
    synthetic_trace_with_menu(gpu, batches, seed, &DEFAULT_TRACE_MENU)
}

/// [`synthetic_trace`] with a caller-chosen length menu — arbitrary
/// lengths are allowed (the pricing model plans non-powers-of-two and
/// Bluestein lengths), which is how `fftsweep govern --lengths 1000,1536`
/// replays channelizer-shaped traffic.
pub fn synthetic_trace_with_menu(
    gpu: &GpuSpec,
    batches: usize,
    seed: u64,
    menu: &[u64],
) -> TrafficTrace {
    assert!(!menu.is_empty(), "trace needs at least one length");
    let mut rng = Rng::new(seed ^ 0x90E7_7AFF);
    let out = (0..batches)
        .map(|_| {
            let n = menu[rng.below(menu.len() as u64) as usize];
            let workload = FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes);
            let boost_t = run_batch(gpu, &workload, gpu.boost_clock_mhz).timing.total_s;
            let mult = rng.range_f64(1.15, 3.0);
            TraceBatch {
                workload,
                deadline_s: boost_t * mult,
            }
        })
        .collect();
    TrafficTrace { batches: out }
}

/// Aggregate outcome of one governor over one trace.
#[derive(Debug, Clone)]
pub struct GovernorOutcome {
    pub label: String,
    pub energy_j: f64,
    pub boost_energy_j: f64,
    pub time_s: f64,
    pub boost_time_s: f64,
    pub deadlines_met: usize,
    pub batches: usize,
    pub mean_clock_mhz: f64,
    /// Time-weighted mean batch draw, W (energy / governed time) — the
    /// quantity a `--budget-w` cap constrains.
    pub mean_power_w: f64,
    /// Peak per-batch mean draw over the trace, W (must sit at or below
    /// the cap when one is set).
    pub peak_power_w: f64,
}

impl GovernorOutcome {
    /// Energy saved vs running the same trace at boost (fraction).
    pub fn energy_saving(&self) -> f64 {
        1.0 - self.energy_j / self.boost_energy_j
    }

    /// Slowdown vs the boost-clock trace time (1.0 = none).
    pub fn slowdown(&self) -> f64 {
        self.time_s / self.boost_time_s
    }

    pub fn all_deadlines_met(&self) -> bool {
        self.deadlines_met == self.batches
    }
}

/// Replay `trace` under a fresh governor of `kind`. Each batch: the
/// governor chooses a clock (snapped to the card's table), the simulator
/// prices the batch at that clock, and the outcome is fed back.
pub fn replay(
    gpu: &GpuSpec,
    trace: &TrafficTrace,
    kind: &GovernorKind,
    ctx: &GovernorContext,
) -> GovernorOutcome {
    let mut gov = kind.make();
    let table = freq_table(gpu);
    let mut out = GovernorOutcome {
        label: kind.label(),
        energy_j: 0.0,
        boost_energy_j: 0.0,
        time_s: 0.0,
        boost_time_s: 0.0,
        deadlines_met: 0,
        batches: trace.len(),
        mean_clock_mhz: 0.0,
        mean_power_w: 0.0,
        peak_power_w: 0.0,
    };
    for b in &trace.batches {
        let batch_ctx = GovernorContext {
            deadline_s: Some(b.deadline_s),
            ..ctx.clone()
        };
        let boost = run_batch(gpu, &b.workload, gpu.boost_clock_mhz);
        // `choose_with_budget` enforces the context's `power_budget_w`
        // (the `govern --budget-w` cap) on top of whatever the policy
        // picks; with no budget set it is a plain `choose`.
        let clock = match choose_with_budget(gov.as_mut(), gpu, &b.workload, &batch_ctx) {
            Ok(f) => table.snap(f),
            // An infeasible verdict still has to serve: run at boost —
            // but the watt cap is a hard envelope and still binds.
            Err(_) => match batch_ctx.budget_cap_mhz(gpu, &b.workload) {
                Some(cap) => gpu.boost_clock_mhz.min(cap),
                None => gpu.boost_clock_mhz,
            },
        };
        let run = run_batch(gpu, &b.workload, clock);
        out.energy_j += run.energy_j;
        out.boost_energy_j += boost.energy_j;
        out.time_s += run.timing.total_s;
        out.boost_time_s += boost.timing.total_s;
        out.mean_clock_mhz += clock;
        out.peak_power_w = out.peak_power_w.max(run.avg_power_w);
        if run.timing.total_s <= b.deadline_s * (1.0 + 1e-9) {
            out.deadlines_met += 1;
        }
        gov.observe(&BatchFeedback {
            n: b.workload.n,
            f_mhz: clock,
            time_s: run.timing.total_s,
            deadline_s: b.deadline_s,
            slack: 1.0 - run.timing.total_s / b.deadline_s,
            energy_j: run.energy_j,
        });
    }
    if !trace.is_empty() {
        out.mean_clock_mhz /= trace.len() as f64;
    }
    if out.time_s > 0.0 {
        out.mean_power_w = out.energy_j / out.time_s;
    }
    out
}

/// Replay the trace under every `kind` and build the comparison table.
pub fn comparison(
    gpu: &GpuSpec,
    trace: &TrafficTrace,
    kinds: &[GovernorKind],
    ctx: &GovernorContext,
) -> (Vec<GovernorOutcome>, Table) {
    let outcomes: Vec<GovernorOutcome> =
        kinds.iter().map(|k| replay(gpu, trace, k, ctx)).collect();
    let budget_note = match ctx.power_budget_w {
        Some(w) => format!(", budget {} W", fnum(w, 0)),
        None => String::new(),
    };
    let mut t = Table::new(
        &format!(
            "Governor comparison: {} batches on {} (energy vs all-boost{budget_note})",
            trace.len(),
            gpu.name
        ),
        &[
            "governor",
            "mean MHz",
            "mean W",
            "peak W",
            "energy J",
            "saving %",
            "slowdown %",
            "deadlines",
        ],
    );
    for o in &outcomes {
        t.push_row(vec![
            o.label.clone(),
            fnum(o.mean_clock_mhz, 0),
            fnum(o.mean_power_w, 1),
            fnum(o.peak_power_w, 1),
            fnum(o.energy_j, 1),
            fnum(o.energy_saving() * 100.0, 1),
            fnum((o.slowdown() - 1.0) * 100.0, 1),
            format!("{}/{}", o.deadlines_met, o.batches),
        ]);
    }
    (outcomes, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;

    fn quick_ctx() -> GovernorContext {
        GovernorContext {
            freq_stride: 8,
            ..GovernorContext::default()
        }
    }

    #[test]
    fn trace_is_deterministic_and_feasible() {
        let g = tesla_v100();
        let a = synthetic_trace(&g, 16, 7);
        let b = synthetic_trace(&g, 16, 7);
        assert_eq!(a.len(), 16);
        for (x, y) in a.batches.iter().zip(&b.batches) {
            assert_eq!(x.workload.n, y.workload.n);
            assert_eq!(x.deadline_s, y.deadline_s);
            let boost_t = run_batch(&g, &x.workload, g.boost_clock_mhz).timing.total_s;
            assert!(x.deadline_s >= boost_t, "infeasible trace batch");
        }
    }

    #[test]
    fn acceptance_shape_deadline_and_adaptive_beat_boost() {
        // The `fftsweep govern --quick` acceptance criterion, as a test:
        // DeadlineAware/Adaptive energy ≤ FixedBoost with every deadline met.
        let g = tesla_v100();
        let trace = synthetic_trace(&g, 24, 7);
        let ctx = quick_ctx();
        let kinds = GovernorKind::all(945.0);
        let (outcomes, table) = comparison(&g, &trace, &kinds, &ctx);
        assert_eq!(outcomes.len(), 6);
        let by = |label: &str| {
            outcomes
                .iter()
                .find(|o| o.label.starts_with(label))
                .unwrap_or_else(|| panic!("no outcome {label}"))
        };
        let boost = by("boost");
        assert!(boost.all_deadlines_met(), "boost misses its own deadline");
        assert!((boost.energy_saving()).abs() < 1e-9);
        for label in ["deadline", "adaptive"] {
            let o = by(label);
            assert!(
                o.energy_j <= boost.energy_j + 1e-9,
                "{label} used more energy than boost"
            );
            assert!(o.all_deadlines_met(), "{label} missed a deadline");
        }
        // deadline-aware exploits per-batch slack: a real saving, not 0
        assert!(by("deadline").energy_saving() > 0.10);
        // the table carries one row per governor
        assert_eq!(table.rows.len(), 6);
    }

    #[test]
    fn off_grid_menu_replays_under_every_governor() {
        // `govern --lengths 1000,1536`: every governor must produce a
        // feasible, fully-served outcome on non-power-of-two traffic.
        let g = tesla_v100();
        let trace = synthetic_trace_with_menu(&g, 12, 7, &[1000, 1536]);
        assert!(trace.batches.iter().all(|b| !b.workload.n.is_power_of_two()));
        let ctx = quick_ctx();
        for kind in GovernorKind::all(945.0) {
            let o = replay(&g, &trace, &kind, &ctx);
            assert_eq!(o.batches, 12, "{}", o.label);
            assert!(o.energy_j > 0.0 && o.time_s > 0.0, "{}", o.label);
            assert!(
                o.energy_j <= o.boost_energy_j * 1.001,
                "{} used more energy than boost on off-grid traffic",
                o.label
            );
        }
    }

    #[test]
    fn budget_capped_replay_keeps_every_policy_under_the_cap() {
        // `govern --budget-w`: with a watt cap in the context, every
        // governor's peak per-batch draw sits at or below it, and the
        // boost row's saving turns positive (the cap forces boost off its
        // default clock). The table title advertises the cap.
        let g = tesla_v100();
        let trace = synthetic_trace(&g, 16, 7);
        let budget_w = 150.0;
        let ctx = GovernorContext {
            power_budget_w: Some(budget_w),
            ..quick_ctx()
        };
        let kinds = GovernorKind::all(945.0);
        let (outcomes, table) = comparison(&g, &trace, &kinds, &ctx);
        assert!(table.title.contains("budget 150 W"), "{}", table.title);
        for o in &outcomes {
            assert!(
                o.peak_power_w <= budget_w + 1e-6,
                "{}: peak {} W over the {budget_w} W cap",
                o.label,
                o.peak_power_w
            );
            assert!(o.mean_power_w <= o.peak_power_w + 1e-9);
            assert!(o.energy_saving() > 0.0, "{} saved nothing under the cap", o.label);
        }
        // Uncapped boost exceeds the cap — the cap is doing real work.
        let open = replay(&g, &trace, &GovernorKind::FixedBoost, &quick_ctx());
        assert!(open.peak_power_w > budget_w, "boost draw {} W", open.peak_power_w);
    }

    #[test]
    fn infeasible_deadline_fallback_still_respects_the_cap() {
        // An unreachable deadline makes DeadlineAware error and the replay
        // fall back to boost — the watt cap must still bind on that path.
        let g = tesla_v100();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let trace = TrafficTrace {
            batches: vec![TraceBatch {
                workload: w,
                deadline_s: boost_t * 0.5,
            }],
        };
        let budget_w = 150.0;
        let ctx = GovernorContext {
            power_budget_w: Some(budget_w),
            ..quick_ctx()
        };
        let o = replay(&g, &trace, &GovernorKind::DeadlineAware, &ctx);
        assert!(
            o.peak_power_w <= budget_w + 1e-6,
            "fallback breached the cap: {} W",
            o.peak_power_w
        );
        assert_eq!(o.deadlines_met, 0, "the deadline really was infeasible");
        // Uncapped, the same fallback runs at full boost power.
        let open = replay(&g, &trace, &GovernorKind::DeadlineAware, &quick_ctx());
        assert!(open.peak_power_w > budget_w);
    }

    #[test]
    fn common_clock_saves_but_may_miss_tight_deadlines() {
        let g = tesla_v100();
        let trace = synthetic_trace(&g, 24, 11);
        let o = replay(&g, &trace, &GovernorKind::CommonClock, &quick_ctx());
        assert!(o.energy_saving() > 0.15, "common saving {}", o.energy_saving());
        // runs well below boost; meeting every deadline is DeadlineAware's
        // job, not asserted here
        assert!(o.mean_clock_mhz < 0.8 * g.boost_clock_mhz);
    }
}
