//! Analysis layer: the "R script" of the paper — optimal/mean-optimal
//! frequency extraction, efficiency metrics and the regeneration of every
//! table (tables.rs) and figure (figures.rs).

pub mod ablation;
pub mod cost;
pub mod figures;
pub mod govern;
pub mod optimal;
pub mod roofline;
pub mod report;
pub mod tables;
pub mod telemetry;
pub mod trace;

pub use govern::{
    comparison, synthetic_trace, synthetic_trace_with_menu, GovernorOutcome, TrafficTrace,
};
pub use optimal::{at_fixed_clock, mean_optimal_mhz, optima, OptimalPoint};
