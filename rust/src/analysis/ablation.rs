//! Ablations over the simulator's design choices (DESIGN.md §Perf calls
//! these out): what happens to the reproduced results when a model
//! component is disabled. Each ablation answers "is this mechanism load-
//! bearing for the paper's phenomenon?".

use crate::cufft::plan::plan;
use crate::sim::exec_model::time_plan;
use crate::sim::freq_table::freq_table;
use crate::sim::power::kernel_power_w;
use crate::sim::GpuSpec;
use crate::types::{FftWorkload, Precision};
use crate::util::stats;
use crate::util::table::{fnum, Table};

/// Which mechanism to knock out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ablation {
    /// Full model.
    None,
    /// Voltage fixed at Vmax (no DVFS voltage scaling — power ∝ f only).
    NoVoltageScaling,
    /// No latency-hiding loss (bandwidth independent of clock).
    NoHidingLoss,
    /// No shared-memory roofline (case (c) disabled).
    NoSharedRoofline,
    /// No P-state cliff.
    NoPstateCliff,
}

impl Ablation {
    pub const ALL: [Ablation; 5] = [
        Ablation::None,
        Ablation::NoVoltageScaling,
        Ablation::NoHidingLoss,
        Ablation::NoSharedRoofline,
        Ablation::NoPstateCliff,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Ablation::None => "full model",
            Ablation::NoVoltageScaling => "no voltage scaling",
            Ablation::NoHidingLoss => "no latency-hiding loss",
            Ablation::NoSharedRoofline => "no shared-mem roofline",
            Ablation::NoPstateCliff => "no P-state cliff",
        }
    }

    /// Apply the knockout to a GpuSpec (the model reads everything from
    /// the spec, so ablations are spec surgery).
    pub fn apply(self, gpu: &GpuSpec) -> GpuSpec {
        let mut g = gpu.clone();
        match self {
            Ablation::None => {}
            Ablation::NoVoltageScaling => {
                g.v_min_frac = 1.0;
            }
            Ablation::NoHidingLoss => {
                g.mem_sat_frac = 1e-9;
            }
            Ablation::NoSharedRoofline => {
                g.shared_bw_gbs = 1e15;
            }
            Ablation::NoPstateCliff => {
                g.pstate_floor_mhz = 0.0;
                g.pstate_penalty = 1.0;
            }
        }
        g
    }
}

/// Ground-truth optimal frequency + saving under an ablation (no sensor
/// noise — this isolates the model, not the measurement).
#[derive(Debug, Clone)]
pub struct AblationResult {
    pub ablation: Ablation,
    pub f_opt_mhz: f64,
    pub energy_saving_vs_boost: f64,
    pub time_increase: f64,
}

pub fn run_ablation(gpu: &GpuSpec, n: u64, ablation: Ablation) -> AblationResult {
    let g = ablation.apply(gpu);
    let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
    let p = plan(n, Precision::Fp32);
    let freqs = freq_table(&g).stride(2);
    let mut energies = Vec::new();
    let mut times = Vec::new();
    for &f in &freqs {
        let t = time_plan(&g, &w, &p, f);
        let e: f64 = t
            .per_kernel
            .iter()
            .map(|k| kernel_power_w(&g, k, f) * k.t_total)
            .sum();
        energies.push(e);
        times.push(t.total_s);
    }
    let imin = stats::argmin(&energies).unwrap();
    let iboost = freqs
        .iter()
        .position(|&f| (f - g.boost_clock_mhz).abs() < 20.0)
        .unwrap_or(0);
    AblationResult {
        ablation,
        f_opt_mhz: freqs[imin],
        energy_saving_vs_boost: 1.0 - energies[imin] / energies[iboost],
        time_increase: times[imin] / times[iboost] - 1.0,
    }
}

/// The full ablation table for one GPU.
pub fn ablation_table(gpu: &GpuSpec, n: u64) -> Table {
    let mut t = Table::new(
        &format!("Ablations: {} N={n} FP32 (ground truth, no sensor)", gpu.name),
        &["ablation", "f_opt_mhz", "energy_saving_pct", "time_increase_pct"],
    );
    for a in Ablation::ALL {
        let r = run_ablation(gpu, n, a);
        t.push_row(vec![
            r.ablation.label().to_string(),
            fnum(r.f_opt_mhz, 0),
            fnum(r.energy_saving_vs_boost * 100.0, 1),
            fnum(r.time_increase * 100.0, 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;

    #[test]
    fn full_model_baseline() {
        let r = run_ablation(&tesla_v100(), 16384, Ablation::None);
        assert!(r.energy_saving_vs_boost > 0.2);
        assert!(r.f_opt_mhz < 1100.0);
    }

    #[test]
    fn no_voltage_scaling_kills_most_of_the_saving() {
        // The headline claim depends on the V(f) curve: without it the
        // energy saving collapses (power ∝ f cancels against t ∝ 1/f).
        let full = run_ablation(&tesla_v100(), 16384, Ablation::None);
        let abl = run_ablation(&tesla_v100(), 16384, Ablation::NoVoltageScaling);
        assert!(
            abl.energy_saving_vs_boost < 0.75 * full.energy_saving_vs_boost,
            "full {} vs ablated {}",
            full.energy_saving_vs_boost,
            abl.energy_saving_vs_boost
        );
    }

    #[test]
    fn no_hiding_loss_pushes_optimum_lower() {
        // Without the latency-hiding penalty, time stays flat to much lower
        // clocks, so the energy optimum slides down.
        let full = run_ablation(&tesla_v100(), 16384, Ablation::None);
        let abl = run_ablation(&tesla_v100(), 16384, Ablation::NoHidingLoss);
        assert!(
            abl.f_opt_mhz < full.f_opt_mhz,
            "full {} vs ablated {}",
            full.f_opt_mhz,
            abl.f_opt_mhz
        );
    }

    #[test]
    fn no_pstate_cliff_extends_the_curve() {
        // Without the cliff, very low clocks stay usable — optimum at or
        // below the full model's.
        let full = run_ablation(&tesla_v100(), 16384, Ablation::NoPstateCliff);
        assert!(full.f_opt_mhz <= 1000.0);
    }

    #[test]
    fn table_renders_all_ablations() {
        let t = ablation_table(&tesla_v100(), 16384);
        assert_eq!(t.rows.len(), Ablation::ALL.len());
    }
}
