//! Mixed-radix decomposition: how cuFFT factors a smooth length into the
//! radix passes its kernel zoo supports (radix 2..127, specialized kernels
//! for 2,3,4,5,7,8,11,13,16,32; composite radices built from them).
//!
//! Used by the plan model for smooth non-power-of-two lengths, where the
//! butterfly cost per element is sum(radix_cost) rather than log2(N), and
//! by the tests that pin the paper's observation that higher radices (7+)
//! carry extra measurement variance.

use crate::cufft::plan::factorize;

/// Radices with dedicated cuFFT kernels, largest first (greedy packing).
pub const NATIVE_RADICES: [u64; 10] = [32, 16, 13, 11, 8, 7, 5, 4, 3, 2];

/// One radix pass in the butterfly schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RadixPass {
    pub radix: u64,
}

impl RadixPass {
    /// Relative butterfly cost per element of a radix-r pass, in radix-2-
    /// equivalent stages: log2(r) for power-of-two radices; odd radices pay
    /// a small penalty (no perfectly balanced split).
    pub fn stage_cost(&self) -> f64 {
        let log2r = (self.radix as f64).log2();
        if self.radix.is_power_of_two() {
            log2r
        } else {
            log2r * 1.12
        }
    }
}

/// Greedy mixed-radix schedule for a smooth n: factorize, then pack prime
/// factors into the largest native radices available.
pub fn radix_schedule(n: u64) -> Vec<RadixPass> {
    assert!(n >= 2);
    let mut counts = std::collections::BTreeMap::new();
    for p in factorize(n) {
        *counts.entry(p).or_insert(0u32) += 1;
    }
    let mut passes = Vec::new();
    // 2^k packing: prefer radix 32/16/8/4/2.
    if let Some(&k) = counts.get(&2) {
        let mut k = k;
        for r in [32u64, 16, 8, 4, 2] {
            let bits = r.trailing_zeros();
            while k >= bits {
                passes.push(RadixPass { radix: r });
                k -= bits;
            }
        }
        counts.remove(&2);
    }
    // Other primes: native if supported, else as their own radix (cuFFT has
    // generic kernels up to 127).
    for (&p, &k) in &counts {
        for _ in 0..k {
            passes.push(RadixPass { radix: p });
        }
    }
    passes.sort_by(|a, b| b.radix.cmp(&a.radix));
    passes
}

/// Total radix-2-equivalent stage cost of a schedule.
pub fn total_stage_cost(passes: &[RadixPass]) -> f64 {
    passes.iter().map(|p| p.stage_cost()).sum()
}

/// Whether the schedule uses a "high" radix (7+): the paper observes these
/// carry up to 5% measurement error (section 4).
pub fn uses_high_radix(passes: &[RadixPass]) -> bool {
    passes
        .iter()
        .any(|p| !p.radix.is_power_of_two() && p.radix >= 7)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn product(passes: &[RadixPass]) -> u64 {
        passes.iter().map(|p| p.radix).product()
    }

    #[test]
    fn schedule_reconstructs_n() {
        for n in [2u64, 8, 96, 768, 1000, 19321 / 139 * 5, 1000000, 1 << 21] {
            let s = radix_schedule(n);
            assert_eq!(product(&s), n, "N={n}: {s:?}");
        }
    }

    #[test]
    fn pow2_prefers_large_radices() {
        let s = radix_schedule(1 << 21);
        // 21 bits → 32·32·32·32·2 = 4 radix-32 passes + 1 radix-2
        assert_eq!(s.iter().filter(|p| p.radix == 32).count(), 4);
        assert_eq!(s.iter().filter(|p| p.radix == 2).count(), 1);
    }

    #[test]
    fn stage_cost_matches_log2_for_pow2() {
        let s = radix_schedule(1 << 13);
        assert!((total_stage_cost(&s) - 13.0).abs() < 1e-12);
    }

    #[test]
    fn odd_radices_cost_more() {
        // 3^4 = 81 vs 2^6 = 64: per-element stage cost of 81 exceeds log2(81)
        let s3 = radix_schedule(81);
        assert!(total_stage_cost(&s3) > (81f64).log2());
    }

    #[test]
    fn high_radix_detection() {
        assert!(uses_high_radix(&radix_schedule(7 * 1024)));
        assert!(uses_high_radix(&radix_schedule(127)));
        assert!(!uses_high_radix(&radix_schedule(4096)));
        assert!(!uses_high_radix(&radix_schedule(96))); // 2^5·3
    }

    #[test]
    fn smooth_1e6_schedule() {
        // 10^6 = 2^6 · 5^6
        let s = radix_schedule(1_000_000);
        assert_eq!(product(&s), 1_000_000);
        assert_eq!(s.iter().filter(|p| p.radix == 5).count(), 6);
    }
}
