//! cuFFT plan model: which GPU kernels a transform of length N decomposes
//! into, and how much device-memory traffic each moves.
//!
//! The paper observes (via NVVP, sections 2.1/5/5.4) that:
//!   * N whose prime factors are all <= 127 use Cooley-Tukey;
//!     other N fall back to Bluestein's algorithm,
//!   * short transforms run as ONE kernel (shared-memory resident),
//!   * longer transforms split into multiple kernels — the cause of the
//!     execution-time staircase of Figs 4/5,
//!   * N = 139^2 (Bluestein) runs ELEVEN kernels on the Jetson,
//!   * every kernel is device-memory-bandwidth bound.
//!
//! This module reproduces that structure; `sim::exec_model` prices it.

use crate::types::Precision;

/// Algorithm selected by the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Mixed-radix Cooley-Tukey (prime factors <= 127).
    CooleyTukey,
    /// Bluestein chirp-z fallback (some prime factor > 127).
    Bluestein,
}

/// What a kernel in the plan does (affects its issue cost/utilization).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// FFT butterfly pass covering `stages` radix-2-equivalent stages.
    FftPass,
    /// Pointwise complex multiply (Bluestein chirp / convolution).
    Pointwise,
}

/// One GPU kernel launch within a plan.
#[derive(Debug, Clone)]
pub struct KernelDesc {
    pub kind: KernelKind,
    /// log2 of the sub-transform this pass advances (radix-2-equivalent
    /// butterfly stages executed per element while resident on-chip).
    pub stages: f64,
    /// Device-memory traffic multiplier in units of the *workload* data
    /// size (read + write = 2.0; Bluestein kernels work on padded data).
    pub traffic_factor: f64,
    /// Fraction of the pass's data that stays resident in shared memory
    /// between stages (drives the shared-memory roofline term).
    pub shared_resident: bool,
}

/// A full plan: the ordered kernels cuFFT would launch for one batch.
#[derive(Debug, Clone)]
pub struct FftPlan {
    pub n: u64,
    pub precision: Precision,
    pub algorithm: Algorithm,
    pub kernels: Vec<KernelDesc>,
    /// Bluestein pads to m = next_pow2(2N - 1); CT plans have m == n.
    pub padded_n: u64,
}

/// Single-kernel (shared-memory resident) capacity in complex elements.
/// FP64 tiles are twice the bytes (halved capacity); FP16 double.
pub fn single_kernel_capacity(p: Precision) -> u64 {
    match p {
        Precision::Fp32 => 1 << 13,
        Precision::Fp64 => 1 << 12,
        Precision::Fp16 => 1 << 14,
    }
}

/// Max radix-2-equivalent stages one multi-kernel pass covers (the
/// four-step/six-step pass granularity: ~2^7 points per pass).
const MAX_STAGES_PER_PASS: f64 = 7.0;

pub fn is_pow2(n: u64) -> bool {
    n != 0 && n & (n - 1) == 0
}

pub fn next_pow2(n: u64) -> u64 {
    let mut m = 1u64;
    while m < n {
        m <<= 1;
    }
    m
}

/// Prime factorization (small trial division; N fits in u64 and the paper's
/// lengths are tiny).
pub fn factorize(mut n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 2u64;
    while d * d <= n {
        while n % d == 0 {
            out.push(d);
            n /= d;
        }
        d += if d == 2 { 1 } else { 2 };
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// cuFFT uses Cooley-Tukey iff every prime factor is <= 127 (section 2.1).
pub fn is_smooth_127(n: u64) -> bool {
    factorize(n).into_iter().all(|p| p <= 127)
}

/// Number of FFT passes for a length-m (smooth) transform at precision p.
fn ct_passes(m: u64, p: Precision) -> u64 {
    if m <= single_kernel_capacity(p) {
        1
    } else {
        let log2m = (m as f64).log2();
        (log2m / MAX_STAGES_PER_PASS).ceil() as u64
    }
}

fn ct_kernels(m: u64, p: Precision, traffic_scale: f64) -> Vec<KernelDesc> {
    let passes = ct_passes(m, p);
    let log2m = (m as f64).log2();
    let stages_per_pass = log2m / passes as f64;
    (0..passes)
        .map(|_| KernelDesc {
            kind: KernelKind::FftPass,
            stages: stages_per_pass,
            traffic_factor: 2.0 * traffic_scale,
            shared_resident: true,
        })
        .collect()
}

/// Build the plan for a batched transform of length `n`.
pub fn plan(n: u64, precision: Precision) -> FftPlan {
    assert!(n >= 2, "FFT length must be >= 2");
    if precision == Precision::Fp16 {
        // cuFFT restricts FP16 to power-of-two lengths (paper section 5).
        assert!(is_pow2(n), "FP16 cuFFT supports only power-of-two lengths");
    }
    if is_smooth_127(n) {
        FftPlan {
            n,
            precision,
            algorithm: Algorithm::CooleyTukey,
            kernels: ct_kernels(n, precision, 1.0),
            padded_n: n,
        }
    } else {
        // Bluestein: chirp-premultiply + pad, forward FFT(m), pointwise
        // multiply with the precomputed chirp spectrum, inverse FFT(m),
        // chirp post-multiply + truncate. All conv kernels act on m points.
        let m = next_pow2(2 * n - 1);
        let scale = m as f64 / n as f64;
        let mut kernels = Vec::new();
        kernels.push(KernelDesc {
            kind: KernelKind::Pointwise,
            stages: 0.0,
            // read n, write m (zero-padded)
            traffic_factor: 1.0 + scale,
            shared_resident: false,
        });
        kernels.extend(ct_kernels(m, precision, scale)); // forward FFT(m)
        kernels.extend(ct_kernels(m, precision, scale)); // chirp-spectrum FFT
        kernels.push(KernelDesc {
            kind: KernelKind::Pointwise,
            stages: 0.0,
            traffic_factor: 2.0 * scale,
            shared_resident: false,
        });
        kernels.extend(ct_kernels(m, precision, scale)); // inverse FFT(m)
        kernels.push(KernelDesc {
            kind: KernelKind::Pointwise,
            stages: 0.0,
            // read m, write n
            traffic_factor: scale + 1.0,
            shared_resident: false,
        });
        FftPlan {
            n,
            precision,
            algorithm: Algorithm::Bluestein,
            kernels,
            padded_n: m,
        }
    }
}

impl FftPlan {
    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    /// Total device traffic in units of the batch data size.
    pub fn total_traffic_factor(&self) -> f64 {
        self.kernels.iter().map(|k| k.traffic_factor).sum()
    }

    /// Total radix-2-equivalent butterfly stages across all passes.
    pub fn total_stages(&self) -> f64 {
        self.kernels.iter().map(|k| k.stages).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorize_basics() {
        assert_eq!(factorize(1), Vec::<u64>::new());
        assert_eq!(factorize(12), vec![2, 2, 3]);
        assert_eq!(factorize(19321), vec![139, 139]);
        assert_eq!(factorize(127), vec![127]);
    }

    #[test]
    fn smoothness_threshold_is_127() {
        assert!(is_smooth_127(127 * 8));
        assert!(!is_smooth_127(131));
        assert!(!is_smooth_127(139 * 139));
        assert!(is_smooth_127(1000000)); // 2^6 * 5^6
    }

    #[test]
    fn small_pow2_is_single_kernel() {
        for log_n in 1..=13 {
            let p = plan(1 << log_n, Precision::Fp32);
            assert_eq!(p.kernel_count(), 1, "N=2^{log_n}");
            assert_eq!(p.algorithm, Algorithm::CooleyTukey);
        }
    }

    #[test]
    fn staircase_at_capacity_boundaries() {
        // fp32: 2^13 is the last single-kernel length (paper: the t_fix
        // plateau runs to N=8192, then jumps — Fig 4).
        assert_eq!(plan(1 << 13, Precision::Fp32).kernel_count(), 1);
        assert_eq!(plan(1 << 14, Precision::Fp32).kernel_count(), 2);
        // fp64 capacity is halved
        assert_eq!(plan(1 << 12, Precision::Fp64).kernel_count(), 1);
        assert_eq!(plan(1 << 13, Precision::Fp64).kernel_count(), 2);
        // fp16 capacity is doubled
        assert_eq!(plan(1 << 14, Precision::Fp16).kernel_count(), 1);
    }

    #[test]
    fn two_mega_point_fft_is_three_kernels() {
        // N = 2M = 2^21 → ceil(21/7) = 3 passes (paper Fig 20 shows multi-
        // kernel plans for the 2M case).
        assert_eq!(plan(1 << 21, Precision::Fp32).kernel_count(), 3);
    }

    #[test]
    fn bluestein_139_squared_is_eleven_kernels() {
        // Paper section 4: "for N = 139^2 eleven GPU kernels are used".
        let p = plan(139 * 139, Precision::Fp32);
        assert_eq!(p.algorithm, Algorithm::Bluestein);
        assert_eq!(p.padded_n, 65536);
        // 3 FFTs × ceil(16/7)=3 passes + pre/point/post = 9 + 3 = 12…
        // one pointwise fuses with an FFT pass in cuFFT; our model keeps
        // the count within the paper's observed 11 ± 1.
        assert!(
            (10..=12).contains(&p.kernel_count()),
            "got {} kernels",
            p.kernel_count()
        );
    }

    #[test]
    fn bluestein_traffic_exceeds_ct() {
        let ct = plan(16384, Precision::Fp32);
        let bl = plan(19321, Precision::Fp32);
        assert!(bl.total_traffic_factor() > 2.0 * ct.total_traffic_factor());
    }

    #[test]
    fn smooth_non_pow2_uses_ct() {
        let p = plan(1000000, Precision::Fp32); // 10^6 = 2^6 · 5^6
        assert_eq!(p.algorithm, Algorithm::CooleyTukey);
        assert_eq!(p.padded_n, 1000000);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn fp16_rejects_non_pow2() {
        plan(1000, Precision::Fp16);
    }

    #[test]
    fn traffic_factor_monotone_in_kernel_count() {
        let one = plan(4096, Precision::Fp32).total_traffic_factor();
        let two = plan(1 << 14, Precision::Fp32).total_traffic_factor();
        let three = plan(1 << 21, Precision::Fp32).total_traffic_factor();
        assert!(one < two && two < three);
        assert_eq!(one, 2.0);
        assert_eq!(two, 4.0);
    }

    #[test]
    fn next_pow2_and_is_pow2() {
        assert!(is_pow2(1024));
        assert!(!is_pow2(1000));
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(38641), 65536);
    }
}
