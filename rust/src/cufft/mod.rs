//! cuFFT library model: plan construction (kernel decomposition, algorithm
//! selection) and the NVVP-style per-kernel profile used for Fig 20.

pub mod plan;
pub mod profile;
pub mod radix;

pub use plan::{plan, Algorithm, FftPlan, KernelDesc, KernelKind};
