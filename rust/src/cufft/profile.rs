//! NVVP-style kernel profiling report (paper Fig 20).
//!
//! For a plan at a given clock, report per kernel: compute utilization,
//! issue-slot utilization, device-memory bandwidth utilization, and the
//! normalized execution time — the four bars the paper plots for
//! N ∈ {8192, 16k, 2M} on the V100.

use crate::cufft::plan::{FftPlan, KernelKind};
use crate::sim::exec_model::time_plan;
use crate::sim::gpu::GpuSpec;
use crate::types::FftWorkload;

#[derive(Debug, Clone)]
pub struct KernelProfile {
    pub kernel_index: usize,
    pub kind: KernelKind,
    pub compute_util: f64,
    pub issue_slot_util: f64,
    pub device_mbu: f64,
    pub time_s: f64,
    /// Execution time normalized to the slowest kernel in the comparison
    /// set (the paper normalizes "from fastest to slowest").
    pub norm_time: f64,
}

#[derive(Debug, Clone)]
pub struct PlanProfile {
    pub n: u64,
    pub f_mhz: f64,
    pub kernels: Vec<KernelProfile>,
}

/// Profile one plan at one clock.
pub fn profile_plan(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    plan: &FftPlan,
    f_mhz: f64,
) -> PlanProfile {
    let timing = time_plan(gpu, workload, plan, f_mhz);
    let t_max = timing
        .per_kernel
        .iter()
        .map(|k| k.t_total)
        .fold(0.0_f64, f64::max);
    let kernels = timing
        .per_kernel
        .iter()
        .enumerate()
        .map(|(i, k)| KernelProfile {
            kernel_index: i,
            kind: plan.kernels[i].kind,
            compute_util: k.compute_util,
            issue_slot_util: k.issue_util,
            device_mbu: k.mem_util,
            time_s: k.t_total,
            norm_time: if t_max > 0.0 { k.t_total / t_max } else { 0.0 },
        })
        .collect();
    PlanProfile {
        n: workload.n,
        f_mhz,
        kernels,
    }
}

/// The Fig 20 comparison set: representative lengths with 1, 2 and 3+
/// kernels, profiled across the sweep's frequency range.
pub fn fig20_lengths() -> [u64; 3] {
    [8192, 16384, 1 << 21]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cufft::plan::plan;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    #[test]
    fn profile_has_one_row_per_kernel() {
        let g = tesla_v100();
        for n in fig20_lengths() {
            let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
            let p = plan(n, Precision::Fp32);
            let prof = profile_plan(&g, &w, &p, g.boost_clock_mhz);
            assert_eq!(prof.kernels.len(), p.kernel_count());
        }
    }

    #[test]
    fn utilizations_are_fractions() {
        let g = tesla_v100();
        let w = FftWorkload::new(1 << 21, Precision::Fp32, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let prof = profile_plan(&g, &w, &p, 945.0);
        for k in &prof.kernels {
            assert!((0.0..=1.0).contains(&k.compute_util));
            assert!((0.0..=1.0).contains(&k.issue_slot_util));
            assert!((0.0..=1.0).contains(&k.device_mbu));
            assert!((0.0..=1.0).contains(&k.norm_time));
        }
        assert!(prof.kernels.iter().any(|k| (k.norm_time - 1.0).abs() < 1e-12));
    }

    #[test]
    fn memory_bound_signature_at_boost() {
        // Fig 20: device MBU high, issue slots mid, compute lowish.
        let g = tesla_v100();
        let w = FftWorkload::new(8192, Precision::Fp32, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let prof = profile_plan(&g, &w, &p, g.boost_clock_mhz);
        let k = &prof.kernels[0];
        assert!(k.device_mbu > 0.75, "mbu {}", k.device_mbu);
        assert!(k.issue_slot_util < k.device_mbu);
    }

    #[test]
    fn issue_saturates_at_low_clock() {
        // Section 6: at the critical frequency the issued-instruction slots
        // saturate — issue utilization rises as the clock falls.
        let g = tesla_v100();
        let w = FftWorkload::new(8192, Precision::Fp32, g.working_set_bytes);
        let p = plan(w.n, w.precision);
        let hi = profile_plan(&g, &w, &p, g.boost_clock_mhz).kernels[0].issue_slot_util;
        let lo = profile_plan(&g, &w, &p, 500.0).kernels[0].issue_slot_util;
        assert!(lo > hi, "issue util must rise as clock falls: {lo} vs {hi}");
    }
}
