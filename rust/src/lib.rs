//! # fftsweep
//!
//! Reproduction of "Efficiency Near the Edge: Increasing the Energy
//! Efficiency of FFTs on GPUs for Real-time Edge Computing"
//! (Adámek et al., 2020) as a three-layer rust + JAX + Pallas system:
//!
//! * **L1** (python, build-time): Pallas Stockham FFT / spectrum /
//!   harmonic-sum kernels,
//! * **L2** (python, build-time): JAX graphs AOT-lowered to HLO text,
//! * **L3** (this crate): PJRT runtime, request coordinator, the GPU DVFS
//!   simulator that substitutes for the paper's five NVIDIA cards, the
//!   measurement harness (energy eqs. 3-8) and the analysis that
//!   regenerates every table and figure.
//!
//! See DESIGN.md for the full system inventory and the experiment index.

pub mod analysis;
pub mod coordinator;
pub mod cufft;
pub mod dsp;
pub mod governor;
pub mod harness;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod types;
pub mod util;
