//! Adaptive clock policy: EWMA feedback on observed batch slack.
//!
//! Starts every length at boost and walks down the card's frequency table
//! one step at a time, but only while (a) an EWMA of the observed slack
//! says the slack is persistent, (b) the model predicts the next clock
//! still meets the deadline, and (c) the next clock still lowers energy
//! (the descent parks at the knee of the energy curve instead of falling
//! off the p-state cliff). A missed or near-missed deadline walks back up
//! immediately.
//!
//! Invariants (tested below): chosen clocks always meet the effective
//! deadline, and per-batch energy never exceeds the boost-clock energy.

use std::collections::HashMap;

use crate::governor::{BatchFeedback, ClockGovernor, GovernorContext, GovernorError};
use crate::sim::freq_table::freq_table;
use crate::sim::{run_batch, GpuSpec};
use crate::types::FftWorkload;

/// EWMA weight of the newest slack observation.
const ALPHA: f64 = 0.35;
/// Sustained-slack threshold that allows one step down the table.
const STEP_DOWN_SLACK: f64 = 0.08;
/// Slack below which we retreat toward boost.
const STEP_UP_SLACK: f64 = 0.02;

struct LengthState {
    /// Index into the descending frequency list (0 = f_max).
    idx: usize,
    ewma_slack: f64,
    observed: u64,
}

/// Per-card frequency list + per-length descent state.
struct CardState {
    freqs: Vec<f64>,
    /// Index of the boost clock in `freqs` — the ceiling of every descent
    /// (some tables run past boost, e.g. the P4's f_max 1531 vs boost 1063).
    start: usize,
    lengths: HashMap<u64, LengthState>,
    /// Memoized power-budget ceilings: (n, quarter-watt share) → index of
    /// the fastest in-budget clock. The arbiter's hint lowers the top of
    /// the descent range instead of fighting it from outside.
    budget_ceilings: HashMap<(u64, u64), usize>,
}

pub struct Adaptive {
    cards: HashMap<String, CardState>,
}

impl Adaptive {
    pub fn new() -> Self {
        Self { cards: HashMap::new() }
    }

    fn card_state<'a>(
        cards: &'a mut HashMap<String, CardState>,
        gpu: &GpuSpec,
    ) -> &'a mut CardState {
        cards.entry(gpu.name.to_string()).or_insert_with(|| {
            let freqs = freq_table(gpu).frequencies();
            let start = Self::boost_idx(&freqs, gpu.boost_clock_mhz);
            CardState {
                freqs,
                start,
                lengths: HashMap::new(),
                budget_ceilings: HashMap::new(),
            }
        })
    }

    /// Index of the boost clock in the descending table (first entry at or
    /// below boost — f_max can exceed boost on some cards).
    fn boost_idx(freqs: &[f64], boost_mhz: f64) -> usize {
        freqs
            .iter()
            .position(|&f| f <= boost_mhz + 1e-9)
            .unwrap_or(0)
    }
}

impl Default for Adaptive {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockGovernor for Adaptive {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let boost = run_batch(gpu, workload, gpu.boost_clock_mhz);
        let deadline = ctx.effective_deadline_s(boost.timing.total_s);
        if boost.timing.total_s > deadline {
            return Err(GovernorError::Infeasible(deadline, boost.timing.total_s));
        }
        let card = Self::card_state(&mut self.cards, gpu);
        let start = card.start;

        // The power-budget hint lowers the top of the descent range: the
        // ceiling is the fastest clock whose predicted draw fits the watt
        // share (memoized per quarter-watt so share wobble below the
        // arbiter's deadband never re-derives it).
        let ceiling = match ctx.power_budget_w {
            None => start,
            Some(budget_w) => {
                let key = (workload.n, crate::telemetry::budget_key(budget_w));
                match card.budget_ceilings.get(&key).copied() {
                    Some(i) => i,
                    None => {
                        let cap_mhz = crate::telemetry::clock_cap_for_budget(
                            gpu,
                            workload,
                            budget_w,
                            ctx.freq_stride.max(1),
                        );
                        let i = card
                            .freqs
                            .iter()
                            .position(|&f| f <= cap_mhz + 1e-9)
                            .unwrap_or(card.freqs.len() - 1)
                            .max(start);
                        card.budget_ceilings.insert(key, i);
                        i
                    }
                }
            }
        };

        let state = card
            .lengths
            .entry(workload.n)
            .or_insert_with(|| LengthState { idx: start, ewma_slack: 0.0, observed: 0 });
        if state.idx < ceiling {
            // The share tightened under us: snap below the new ceiling and
            // re-observe from there.
            state.idx = ceiling;
            state.ewma_slack = 0.0;
        }

        // Step down one table entry when the EWMA says the slack persists,
        // but only if the next clock is predicted feasible AND cheaper.
        if state.observed > 0 && state.ewma_slack > STEP_DOWN_SLACK {
            let next = state.idx + ctx.freq_stride.max(1);
            if next < card.freqs.len() {
                let here = run_batch(gpu, workload, card.freqs[state.idx]);
                let there = run_batch(gpu, workload, card.freqs[next]);
                if there.timing.total_s <= deadline && there.energy_j < here.energy_j {
                    state.idx = next;
                    state.ewma_slack = 0.0; // re-observe at the new clock
                }
            }
        }

        // Feasibility clamp: retreat toward boost until the prediction fits
        // the deadline (exact under the analytic model, so deadlines are
        // never missed by construction) — but never above the budget
        // ceiling: the watt share is a hard envelope, the deadline a soft
        // one, so an over-tight share surfaces as deadline misses in the
        // telemetry rather than as a budget breach.
        while state.idx > ceiling
            && run_batch(gpu, workload, card.freqs[state.idx]).timing.total_s > deadline
        {
            state.idx -= 1;
        }
        Ok(card.freqs[state.idx])
    }

    fn observe(&mut self, fb: &BatchFeedback) {
        for card in self.cards.values_mut() {
            if let Some(state) = card.lengths.get_mut(&fb.n) {
                state.observed += 1;
                state.ewma_slack = ALPHA * fb.slack + (1.0 - ALPHA) * state.ewma_slack;
                if fb.slack < STEP_UP_SLACK && state.idx > card.start {
                    // Deadline pressure: retreat immediately, but never
                    // above boost (the table may run past the boost clock).
                    state.idx = state.idx.saturating_sub(2).max(card.start);
                    state.ewma_slack = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    fn wl(n: u64) -> FftWorkload {
        let g = tesla_v100();
        FftWorkload::new(n, Precision::Fp32, g.working_set_bytes)
    }

    /// Drive the governor over `batches` identical batches, returning the
    /// clocks it chose. Feedback uses the analytic model, like the engine.
    fn drive(gov: &mut Adaptive, n: u64, deadline_mult: f64, batches: usize) -> Vec<f64> {
        let g = tesla_v100();
        let w = wl(n);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * deadline_mult),
            freq_stride: 4,
            ..GovernorContext::default()
        };
        let mut clocks = Vec::new();
        for _ in 0..batches {
            let f = gov.choose(&g, &w, &ctx).expect("feasible");
            let run = run_batch(&g, &w, f);
            let deadline = ctx.effective_deadline_s(boost_t);
            gov.observe(&BatchFeedback {
                n,
                f_mhz: f,
                time_s: run.timing.total_s,
                deadline_s: deadline,
                slack: 1.0 - run.timing.total_s / deadline,
                energy_j: run.energy_j,
            });
            clocks.push(f);
        }
        clocks
    }

    #[test]
    fn descends_under_persistent_slack() {
        let mut gov = Adaptive::new();
        let clocks = drive(&mut gov, 16384, 2.0, 40);
        let g = tesla_v100();
        assert_eq!(clocks[0], g.boost_clock_mhz, "starts at boost");
        let last = *clocks.last().unwrap();
        assert!(last < 0.8 * g.boost_clock_mhz, "never descended: {last}");
        // descent is monotone non-increasing under constant load
        for w in clocks.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn never_misses_deadline_and_never_beats_boost_energy() {
        let g = tesla_v100();
        for mult in [1.05, 1.3, 2.5] {
            let mut gov = Adaptive::new();
            let w = wl(16384);
            let boost = run_batch(&g, &w, g.boost_clock_mhz);
            let deadline = boost.timing.total_s * mult;
            for f in drive(&mut gov, 16384, mult, 30) {
                let run = run_batch(&g, &w, f);
                assert!(run.timing.total_s <= deadline + 1e-12, "missed at {f} MHz");
                assert!(run.energy_j <= boost.energy_j + 1e-9, "worse than boost at {f}");
            }
        }
    }

    #[test]
    fn parks_at_energy_knee_not_pstate_cliff() {
        // With a very loose deadline the descent must stop where energy
        // stops improving, not race to f_min.
        let g = tesla_v100();
        let mut gov = Adaptive::new();
        let clocks = drive(&mut gov, 16384, 6.0, 120);
        let last = *clocks.last().unwrap();
        assert!(
            last > 0.4 * g.boost_clock_mhz,
            "fell past the knee to {last} MHz"
        );
        assert!(last < 0.8 * g.boost_clock_mhz, "never reached the knee: {last}");
    }

    #[test]
    fn tight_deadline_keeps_boost() {
        let g = tesla_v100();
        let mut gov = Adaptive::new();
        let clocks = drive(&mut gov, 16384, 1.001, 10);
        for f in clocks {
            assert!(f > 0.9 * g.boost_clock_mhz, "over-cut to {f}");
        }
    }

    #[test]
    fn infeasible_deadline_is_an_error() {
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let mut gov = Adaptive::new();
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * 0.5),
            ..GovernorContext::default()
        };
        assert!(matches!(
            gov.choose(&g, &w, &ctx),
            Err(GovernorError::Infeasible(..))
        ));
    }

    #[test]
    fn retreat_never_exceeds_boost_when_table_runs_past_it() {
        // The P4's frequency table tops out at 1531 MHz, well above its
        // 1063 MHz boost; a deadline-pressure retreat must stop at boost.
        let g = crate::sim::gpu::tesla_p4();
        let w = FftWorkload::new(16384, Precision::Fp32, g.working_set_bytes);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let mut gov = Adaptive::new();
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * 1.01),
            freq_stride: 4,
            ..GovernorContext::default()
        };
        for _ in 0..5 {
            let f = gov.choose(&g, &w, &ctx).expect("feasible");
            assert!(f <= g.boost_clock_mhz + 1e-9, "retreated above boost: {f}");
            let run = run_batch(&g, &w, f);
            let deadline = boost_t * 1.01;
            gov.observe(&BatchFeedback {
                n: w.n,
                f_mhz: f,
                time_s: run.timing.total_s,
                deadline_s: deadline,
                slack: 1.0 - run.timing.total_s / deadline,
                energy_j: run.energy_j,
            });
        }
    }

    #[test]
    fn budget_ceiling_bounds_the_descent_range() {
        // Under a watt share the descent starts at the budget ceiling (not
        // boost), every governed clock prices within the share, and
        // deadline-pressure retreats stop at the ceiling instead of
        // breaching the budget.
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        // Budget that admits ~80% of boost: below boost power (so the
        // ceiling bites) but above the energy knee (so descent room
        // remains below the ceiling — `energy_minimum_below_boost_v100`
        // pins the optimum under 0.8×boost).
        let budget_w = run_batch(&g, &w, 0.8 * g.boost_clock_mhz).avg_power_w + 1.0;
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * 1.01), // tight: wants boost
            freq_stride: 4,
            power_budget_w: Some(budget_w),
            ..GovernorContext::default()
        };
        let mut gov = Adaptive::new();
        for _ in 0..8 {
            let f = gov.choose(&g, &w, &ctx).expect("boost-feasible deadline");
            let run = run_batch(&g, &w, f);
            assert!(
                run.avg_power_w <= budget_w + 1e-9,
                "{f} MHz draws {} W over the {budget_w} W share",
                run.avg_power_w
            );
            assert!(f < g.boost_clock_mhz, "ceiling must sit below boost");
            gov.observe(&BatchFeedback {
                n: w.n,
                f_mhz: f,
                time_s: run.timing.total_s,
                deadline_s: boost_t * 1.01,
                slack: 1.0 - run.timing.total_s / (boost_t * 1.01),
                energy_j: run.energy_j,
            });
        }
        // A loose deadline still lets the descent walk below the ceiling.
        let loose = GovernorContext {
            deadline_s: Some(boost_t * 6.0),
            freq_stride: 4,
            power_budget_w: Some(budget_w),
            ..GovernorContext::default()
        };
        let mut gov = Adaptive::new();
        let first = gov.choose(&g, &w, &loose).unwrap();
        for _ in 0..40 {
            let f = gov.choose(&g, &w, &loose).unwrap();
            let run = run_batch(&g, &w, f);
            assert!(run.avg_power_w <= budget_w + 1e-9);
            gov.observe(&BatchFeedback {
                n: w.n,
                f_mhz: f,
                time_s: run.timing.total_s,
                deadline_s: boost_t * 6.0,
                slack: 1.0 - run.timing.total_s / (boost_t * 6.0),
                energy_j: run.energy_j,
            });
        }
        let last = gov.choose(&g, &w, &loose).unwrap();
        assert!(last < first, "descent must continue below the ceiling: {last} vs {first}");
    }

    #[test]
    fn state_is_per_length() {
        let g = tesla_v100();
        let mut gov = Adaptive::new();
        drive(&mut gov, 16384, 3.0, 30);
        // a fresh length starts from boost again
        let w = wl(1024);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * 3.0),
            ..GovernorContext::default()
        };
        let f = gov.choose(&g, &w, &ctx).unwrap();
        assert_eq!(f, g.boost_clock_mhz);
    }
}
