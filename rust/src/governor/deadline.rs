//! Deadline-aware clock policy — the "integration into existing pipelines"
//! extension (paper §6.2), absorbed from the old `pipeline::scheduler`:
//! given a real-time deadline per batch, pick the lowest-energy supported
//! clock that still meets it. Workloads with slack get deeper frequency
//! cuts; tight deadlines stay near boost.

use crate::governor::{ClockGovernor, GovernorContext, GovernorError};
use crate::sim::freq_table::freq_table;
use crate::sim::{run_batch, GpuSpec};
use crate::types::FftWorkload;

/// A scheduling decision.
#[derive(Debug, Clone)]
pub struct ClockChoice {
    pub f_mhz: f64,
    pub time_s: f64,
    pub energy_j: f64,
    /// Energy relative to running the same batch at boost.
    pub energy_vs_boost: f64,
    /// Deadline slack that remains (fraction of the deadline).
    pub slack: f64,
}

/// Pick the energy-minimal supported clock whose batch time fits within
/// `deadline_s`. Scans the (subsampled) frequency table — the table is
/// small and the exec model analytic, so this is microseconds of work.
pub fn choose_clock(
    gpu: &GpuSpec,
    workload: &FftWorkload,
    deadline_s: f64,
    freq_stride: usize,
) -> Result<ClockChoice, GovernorError> {
    let boost = run_batch(gpu, workload, gpu.boost_clock_mhz);
    if boost.timing.total_s > deadline_s {
        return Err(GovernorError::Infeasible(deadline_s, boost.timing.total_s));
    }
    let mut best: Option<ClockChoice> = None;
    for f in freq_table(gpu).stride(freq_stride) {
        let run = run_batch(gpu, workload, f);
        if run.timing.total_s > deadline_s {
            continue;
        }
        let cand = ClockChoice {
            f_mhz: f,
            time_s: run.timing.total_s,
            energy_j: run.energy_j,
            energy_vs_boost: run.energy_j / boost.energy_j,
            slack: 1.0 - run.timing.total_s / deadline_s,
        };
        if best.as_ref().map(|b| cand.energy_j < b.energy_j).unwrap_or(true) {
            best = Some(cand);
        }
    }
    match best {
        Some(c) => Ok(c),
        // The table stride skipped every feasible clock; fall back to boost.
        None => Ok(ClockChoice {
            f_mhz: gpu.boost_clock_mhz,
            time_s: boost.timing.total_s,
            energy_j: boost.energy_j,
            energy_vs_boost: 1.0,
            slack: 1.0 - boost.timing.total_s / deadline_s,
        }),
    }
}

/// Schedule a heterogeneous queue of (workload, deadline) batches; returns
/// the per-batch choices plus the aggregate saving.
pub fn schedule_queue(
    gpu: &GpuSpec,
    queue: &[(FftWorkload, f64)],
    freq_stride: usize,
) -> Result<(Vec<ClockChoice>, f64), GovernorError> {
    let mut choices = Vec::with_capacity(queue.len());
    let mut e_tuned = 0.0;
    let mut e_boost = 0.0;
    for (w, d) in queue {
        let c = choose_clock(gpu, w, *d, freq_stride)?;
        e_tuned += c.energy_j;
        e_boost += c.energy_j / c.energy_vs_boost;
        choices.push(c);
    }
    Ok((choices, 1.0 - e_tuned / e_boost))
}

/// The governor wrapper: per batch, run [`choose_clock`] against the
/// context's deadline (explicit, or the tolerance-scaled boost time).
pub struct DeadlineAware {
    /// Most recent decision, kept for introspection.
    pub last_choice: Option<ClockChoice>,
}

impl DeadlineAware {
    pub fn new() -> Self {
        Self { last_choice: None }
    }
}

impl Default for DeadlineAware {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockGovernor for DeadlineAware {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let boost_t = run_batch(gpu, workload, gpu.boost_clock_mhz).timing.total_s;
        let deadline = ctx.effective_deadline_s(boost_t);
        let c = choose_clock(gpu, workload, deadline, ctx.freq_stride)?;
        let f = c.f_mhz;
        self.last_choice = Some(c);
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::types::Precision;

    fn wl(n: u64) -> FftWorkload {
        let g = tesla_v100();
        FftWorkload::new(n, Precision::Fp32, g.working_set_bytes)
    }

    #[test]
    fn loose_deadline_picks_low_clock() {
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let c = choose_clock(&g, &w, boost_t * 3.0, 4).unwrap();
        assert!(c.f_mhz < 0.8 * g.boost_clock_mhz, "chose {}", c.f_mhz);
        assert!(c.energy_vs_boost < 0.8);
        assert!(c.slack > 0.0);
    }

    #[test]
    fn tight_deadline_stays_near_boost() {
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let c = choose_clock(&g, &w, boost_t * 1.005, 4).unwrap();
        // must meet the deadline
        assert!(c.time_s <= boost_t * 1.005);
        // cannot cut very deep
        assert!(c.f_mhz > 0.55 * g.boost_clock_mhz);
    }

    #[test]
    fn infeasible_deadline_rejected() {
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        assert!(matches!(
            choose_clock(&g, &w, boost_t * 0.5, 4),
            Err(GovernorError::Infeasible(..))
        ));
    }

    #[test]
    fn governor_surfaces_infeasible_deadline() {
        // Error-path migration: the governor propagates Infeasible when the
        // context's explicit deadline is unreachable even at boost.
        let g = tesla_v100();
        let w = wl(16384);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let mut gov = DeadlineAware::new();
        let ctx = GovernorContext {
            deadline_s: Some(boost_t * 0.5),
            ..GovernorContext::default()
        };
        assert!(matches!(
            gov.choose(&g, &w, &ctx),
            Err(GovernorError::Infeasible(..))
        ));
        assert!(gov.last_choice.is_none());
    }

    #[test]
    fn deeper_slack_never_costs_more_energy() {
        let g = tesla_v100();
        let w = wl(1024);
        let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
        let mut last = f64::MAX;
        for mult in [1.01, 1.05, 1.2, 2.0, 4.0] {
            let mut gov = DeadlineAware::new();
            let ctx = GovernorContext {
                deadline_s: Some(boost_t * mult),
                freq_stride: 4,
                ..GovernorContext::default()
            };
            let f = gov.choose(&g, &w, &ctx).unwrap();
            let e = run_batch(&g, &w, f).energy_j;
            assert!(
                e <= last + 1e-9,
                "more slack must not cost energy (mult {mult})"
            );
            last = e;
        }
    }

    #[test]
    fn queue_schedule_aggregates() {
        let g = tesla_v100();
        let boost_t = run_batch(&g, &wl(16384), g.boost_clock_mhz).timing.total_s;
        let queue = vec![
            (wl(16384), boost_t * 2.0),
            (wl(1024), boost_t * 1.5),
            (wl(262144), boost_t * 8.0),
        ];
        let (choices, saving) = schedule_queue(&g, &queue, 8).unwrap();
        assert_eq!(choices.len(), 3);
        assert!(saving > 0.1, "aggregate saving {saving}");
    }

    #[test]
    fn prop_deadline_always_met() {
        let g = tesla_v100();
        crate::util::prop::check(
            "governor meets deadlines",
            |rng| {
                let n = 1u64 << rng.range_u64(8, 18);
                let mult = 1.0 + rng.f64() * 3.0;
                (n, mult)
            },
            |&(n, mult)| {
                let w = wl(n);
                let boost_t = run_batch(&g, &w, g.boost_clock_mhz).timing.total_s;
                let deadline = boost_t * mult;
                match choose_clock(&g, &w, deadline, 12) {
                    Ok(c) => {
                        if c.time_s > deadline {
                            return Err(format!("deadline violated: {} > {}", c.time_s, deadline));
                        }
                        if c.energy_vs_boost > 1.0 + 1e-9 {
                            return Err("worse than boost".into());
                        }
                        Ok(())
                    }
                    Err(e) => Err(format!("unexpected: {e}")),
                }
            },
        );
    }
}
