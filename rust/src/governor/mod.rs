//! Pluggable DVFS clock governors — the system's central policy axis.
//!
//! The paper's headline result (one locked clock ≈ −50% energy, <10%
//! slowdown) is the simplest of several clock policies a production
//! pipeline could run. This subsystem makes the policy a first-class,
//! swappable component: a [`ClockGovernor`] decides, per batch, which core
//! clock a simulated card should run at, and optionally adapts from
//! feedback about the batches it already governed.
//!
//! Implementations:
//!   * [`FixedBoost`] — the no-DVFS default (everything at boost),
//!   * [`FixedClock`] — one operator-chosen locked clock,
//!   * [`PerLengthOptimal`] — the per-N energy optimum (paper §5.1, Fig 9),
//!   * [`CommonClock`] — the paper's single mean-optimal clock for all
//!     lengths (Table 3, Figs 15/16),
//!   * [`DeadlineAware`] — lowest-energy clock that still meets a
//!     per-batch deadline (paper §6.2),
//!   * [`Adaptive`] — EWMA feedback on observed batch slack, descending
//!     the energy curve only while slack persists.
//!
//! Consumers: the multi-card [`crate::coordinator::Engine`], the pipeline
//! runner (`pipeline::runner`), the `fftsweep govern` replay table
//! (`analysis::govern`) and the benches.

pub mod adaptive;
pub mod deadline;
pub mod fixed;
pub mod optimal;

pub use adaptive::Adaptive;
pub use deadline::{choose_clock, schedule_queue, ClockChoice, DeadlineAware};
pub use fixed::{CommonClock, FixedBoost, FixedClock};
pub use optimal::PerLengthOptimal;

use crate::sim::GpuSpec;
use crate::types::FftWorkload;

/// Per-engine knobs a governor may consult when choosing a clock.
#[derive(Debug, Clone)]
pub struct GovernorContext {
    /// Soft per-batch deadline, seconds. `None` = throughput mode: policies
    /// that need a deadline derive one as `boost_time * slack_tolerance`.
    pub deadline_s: Option<f64>,
    /// Frequency-table stride used when a policy scans clocks.
    pub freq_stride: usize,
    /// Allowed slowdown vs boost when no explicit deadline is given
    /// (the paper's "<10%" envelope → 1.10).
    pub slack_tolerance: f64,
    /// Power-budget hint, W: the arbiter's watt share for this card.
    /// `None` = uncapped. Governors that keep descent/ceiling state
    /// honor it directly; [`choose_with_budget`] enforces it for all.
    pub power_budget_w: Option<f64>,
}

impl Default for GovernorContext {
    fn default() -> Self {
        Self {
            deadline_s: None,
            freq_stride: 2,
            slack_tolerance: 1.10,
            power_budget_w: None,
        }
    }
}

impl GovernorContext {
    /// The deadline a batch is judged against: the explicit one, or the
    /// tolerance-scaled boost time.
    pub fn effective_deadline_s(&self, boost_time_s: f64) -> f64 {
        self.deadline_s.unwrap_or(boost_time_s * self.slack_tolerance)
    }

    /// The fastest clock the power-budget hint permits for `workload`
    /// (`None` when uncapped). A table scan over the analytic model —
    /// callers on hot paths memoize by [`crate::telemetry::budget_key`].
    pub fn budget_cap_mhz(&self, gpu: &GpuSpec, workload: &FftWorkload) -> Option<f64> {
        self.power_budget_w
            .map(|w| crate::telemetry::clock_cap_for_budget(gpu, workload, w, self.freq_stride))
    }
}

/// Governor choice with the power budget enforced: whatever policy
/// `gov` runs, the returned clock never draws more than the context's
/// watt share. This is the single enforcement point the replay table
/// (`analysis::govern`) and any budget-unaware policy rely on; the
/// engine's workers apply the same cap with a memoized watt→clock map.
pub fn choose_with_budget(
    gov: &mut dyn ClockGovernor,
    gpu: &GpuSpec,
    workload: &FftWorkload,
    ctx: &GovernorContext,
) -> Result<f64, GovernorError> {
    let f = gov.choose(gpu, workload, ctx)?;
    Ok(match ctx.budget_cap_mhz(gpu, workload) {
        Some(cap) => f.min(cap),
        None => f,
    })
}

/// Brownout ladder step 1: under sustained overload (`Brownout` level
/// ≥ 1) batches carrying realtime work float up to the boost clock —
/// spend watts to protect the deadline class — while batch/scavenger
/// traffic keeps the governor's energy-optimal choice. Returns the clock
/// floor to apply, or `None` when the ladder is idle or the batch holds
/// no realtime work. Health derates still apply *after* this floor: a
/// sick card is never pushed to boost.
pub fn brownout_floor(boost_mhz: f64, level: u8, has_realtime: bool) -> Option<f64> {
    (level >= 1 && has_realtime).then_some(boost_mhz)
}

/// Outcome of one governed batch, fed back to the governor.
#[derive(Debug, Clone)]
pub struct BatchFeedback {
    pub n: u64,
    /// The clock the batch ran at, MHz.
    pub f_mhz: f64,
    /// Simulated batch time at that clock, s.
    pub time_s: f64,
    /// The deadline the batch was judged against, s.
    pub deadline_s: f64,
    /// Remaining slack as a fraction of the deadline (negative = missed).
    pub slack: f64,
    pub energy_j: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum GovernorError {
    #[error("deadline {0} s unreachable even at boost ({1} s needed)")]
    Infeasible(f64, f64),
}

/// A clock policy. One instance per worker/card: implementations may keep
/// mutable state (caches, EWMA) and are driven from a single thread.
pub trait ClockGovernor: Send {
    fn name(&self) -> &'static str;

    /// Pick the core clock (MHz) to run `workload` on `gpu` under `ctx`.
    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError>;

    /// Observe the outcome of a governed batch (no-op for static policies).
    fn observe(&mut self, _feedback: &BatchFeedback) {}
}

/// Constructible governor identity — what flows through configs and CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum GovernorKind {
    FixedBoost,
    FixedClock(f64),
    PerLengthOptimal,
    CommonClock,
    DeadlineAware,
    Adaptive,
}

impl GovernorKind {
    /// All six policies, with `fixed_mhz` parameterizing `FixedClock`
    /// (the `govern` comparison replays each of these over one trace).
    pub fn all(fixed_mhz: f64) -> Vec<GovernorKind> {
        vec![
            GovernorKind::FixedBoost,
            GovernorKind::FixedClock(fixed_mhz),
            GovernorKind::PerLengthOptimal,
            GovernorKind::CommonClock,
            GovernorKind::DeadlineAware,
            GovernorKind::Adaptive,
        ]
    }

    /// Parse a CLI spelling: `boost`, `fixed:<mhz>` (or a bare number),
    /// `optimal`, `common`, `deadline`, `adaptive`.
    pub fn parse(s: &str) -> anyhow::Result<GovernorKind> {
        let lower = s.trim().to_ascii_lowercase();
        if let Some(mhz) = lower.strip_prefix("fixed:") {
            let mhz: f64 = mhz
                .parse()
                .map_err(|_| anyhow::anyhow!("bad clock in governor spec '{s}'"))?;
            return Ok(GovernorKind::FixedClock(mhz));
        }
        if let Ok(mhz) = lower.parse::<f64>() {
            return Ok(GovernorKind::FixedClock(mhz));
        }
        match lower.as_str() {
            "boost" | "fixed-boost" | "default" => Ok(GovernorKind::FixedBoost),
            "optimal" | "per-length" | "per-length-optimal" => Ok(GovernorKind::PerLengthOptimal),
            "common" | "common-clock" | "mean-optimal" => Ok(GovernorKind::CommonClock),
            "deadline" | "deadline-aware" => Ok(GovernorKind::DeadlineAware),
            "adaptive" | "ewma" => Ok(GovernorKind::Adaptive),
            other => anyhow::bail!(
                "unknown governor '{other}' (try boost, fixed:<mhz>, optimal, common, deadline, adaptive)"
            ),
        }
    }

    /// Instantiate a fresh governor of this kind.
    pub fn make(&self) -> Box<dyn ClockGovernor> {
        match self {
            GovernorKind::FixedBoost => Box::new(FixedBoost),
            GovernorKind::FixedClock(mhz) => Box::new(FixedClock::new(*mhz)),
            GovernorKind::PerLengthOptimal => Box::new(PerLengthOptimal::new()),
            GovernorKind::CommonClock => Box::new(CommonClock::new()),
            GovernorKind::DeadlineAware => Box::new(DeadlineAware::new()),
            GovernorKind::Adaptive => Box::new(Adaptive::new()),
        }
    }

    pub fn label(&self) -> String {
        match self {
            GovernorKind::FixedBoost => "boost".into(),
            GovernorKind::FixedClock(mhz) => format!("fixed:{mhz:.0}"),
            GovernorKind::PerLengthOptimal => "optimal".into(),
            GovernorKind::CommonClock => "common".into(),
            GovernorKind::DeadlineAware => "deadline".into(),
            GovernorKind::Adaptive => "adaptive".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::sim::run_batch;
    use crate::types::Precision;

    fn wl(n: u64) -> FftWorkload {
        let g = tesla_v100();
        FftWorkload::new(n, Precision::Fp32, g.working_set_bytes)
    }

    #[test]
    fn parse_all_spellings() {
        assert_eq!(GovernorKind::parse("boost").unwrap(), GovernorKind::FixedBoost);
        assert_eq!(GovernorKind::parse("fixed:945").unwrap(), GovernorKind::FixedClock(945.0));
        assert_eq!(GovernorKind::parse("945").unwrap(), GovernorKind::FixedClock(945.0));
        assert_eq!(GovernorKind::parse("optimal").unwrap(), GovernorKind::PerLengthOptimal);
        assert_eq!(GovernorKind::parse("common").unwrap(), GovernorKind::CommonClock);
        assert_eq!(GovernorKind::parse("deadline").unwrap(), GovernorKind::DeadlineAware);
        assert_eq!(GovernorKind::parse("adaptive").unwrap(), GovernorKind::Adaptive);
        assert!(GovernorKind::parse("warp9").is_err());
        assert!(GovernorKind::parse("fixed:fast").is_err());
    }

    #[test]
    fn all_six_constructible() {
        let kinds = GovernorKind::all(945.0);
        assert_eq!(kinds.len(), 6);
        let g = tesla_v100();
        let w = wl(16384);
        let ctx = GovernorContext::default();
        for kind in &kinds {
            let mut gov = kind.make();
            let f = gov.choose(&g, &w, &ctx).expect("feasible default ctx");
            assert!(f > 0.0 && f <= g.boost_clock_mhz + 1.0, "{}: {f}", gov.name());
        }
    }

    #[test]
    fn fixed_boost_equivalent_to_boost_run_batch() {
        // Governor-equivalence: FixedBoost's decision prices identically to
        // a raw boost-clock run_batch.
        let g = tesla_v100();
        let ctx = GovernorContext::default();
        let mut gov = GovernorKind::FixedBoost.make();
        for n in [1024u64, 16384, 262144] {
            let w = wl(n);
            let f = gov.choose(&g, &w, &ctx).unwrap();
            let via_gov = run_batch(&g, &w, f);
            let via_boost = run_batch(&g, &w, g.boost_clock_mhz);
            assert_eq!(via_gov.energy_j, via_boost.energy_j, "N={n}");
            assert_eq!(via_gov.timing.total_s, via_boost.timing.total_s, "N={n}");
        }
    }

    #[test]
    fn budget_hint_caps_every_policy() {
        // choose_with_budget: under a tight watt share, every governor's
        // chosen clock prices at or below the share.
        let g = tesla_v100();
        let w = wl(16384);
        let ctx = GovernorContext {
            power_budget_w: Some(130.0),
            freq_stride: 4,
            ..GovernorContext::default()
        };
        for kind in GovernorKind::all(945.0) {
            let mut gov = kind.make();
            let f = choose_with_budget(gov.as_mut(), &g, &w, &ctx).expect("feasible");
            let p = run_batch(&g, &w, f).avg_power_w;
            assert!(
                p <= 130.0 + 1e-9,
                "{}: {f} MHz draws {p} W over the 130 W share",
                gov.name()
            );
        }
        // an uncapped context changes nothing
        let open = GovernorContext { freq_stride: 4, ..GovernorContext::default() };
        let mut gov = GovernorKind::FixedBoost.make();
        assert_eq!(
            choose_with_budget(gov.as_mut(), &g, &w, &open).unwrap(),
            g.boost_clock_mhz
        );
    }

    #[test]
    fn generous_budget_leaves_choices_alone() {
        let g = tesla_v100();
        let w = wl(16384);
        let rich = GovernorContext {
            power_budget_w: Some(10_000.0),
            ..GovernorContext::default()
        };
        let mut gov = GovernorKind::FixedClock(945.0).make();
        let capped = choose_with_budget(gov.as_mut(), &g, &w, &rich).unwrap();
        let mut gov2 = GovernorKind::FixedClock(945.0).make();
        let open = gov2.choose(&g, &w, &GovernorContext::default()).unwrap();
        assert_eq!(capped, open);
    }

    #[test]
    fn brownout_floor_boosts_only_realtime_under_overload() {
        assert_eq!(brownout_floor(1380.0, 0, true), None, "ladder idle");
        assert_eq!(brownout_floor(1380.0, 1, false), None, "no realtime aboard");
        assert_eq!(brownout_floor(1380.0, 1, true), Some(1380.0));
        assert_eq!(brownout_floor(1380.0, 3, true), Some(1380.0), "all rungs floor to boost");
    }

    #[test]
    fn effective_deadline_falls_back_to_tolerance() {
        let ctx = GovernorContext::default();
        assert!((ctx.effective_deadline_s(2.0) - 2.2).abs() < 1e-12);
        let ctx = GovernorContext { deadline_s: Some(0.5), ..GovernorContext::default() };
        assert_eq!(ctx.effective_deadline_s(2.0), 0.5);
    }
}
