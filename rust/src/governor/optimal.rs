//! Per-length optimal clock policy (paper §5.1, Fig 9): each FFT length
//! runs at its own measured energy optimum, backed by `analysis::optimal`.

use std::collections::HashMap;

use crate::analysis::optimal::optimal_for_length;
use crate::governor::{ClockGovernor, GovernorContext, GovernorError};
use crate::harness::sweep::{sweep_gpu, SweepConfig};
use crate::harness::Protocol;
use crate::sim::GpuSpec;
use crate::types::FftWorkload;

/// Per-(card, length) energy-optimal clocks, measured lazily and cached.
pub struct PerLengthOptimal {
    cache: HashMap<(String, u64), f64>,
}

impl PerLengthOptimal {
    pub fn new() -> Self {
        Self { cache: HashMap::new() }
    }

    fn derive(gpu: &GpuSpec, workload: &FftWorkload, ctx: &GovernorContext) -> f64 {
        let cfg = SweepConfig {
            lengths: vec![workload.n],
            freq_stride: ctx.freq_stride.max(4),
            protocol: Protocol::quick(),
        };
        let sweep = sweep_gpu(gpu, workload.precision, &cfg);
        optimal_for_length(gpu, &sweep.lengths[0]).f_opt_mhz
    }
}

impl Default for PerLengthOptimal {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockGovernor for PerLengthOptimal {
    fn name(&self) -> &'static str {
        "optimal"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let key = (gpu.name.to_string(), workload.n);
        if let Some(&f) = self.cache.get(&key) {
            return Ok(f);
        }
        let f = Self::derive(gpu, workload, ctx);
        self.cache.insert(key, f);
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::sim::run_batch;
    use crate::types::Precision;

    fn wl(gpu: &GpuSpec, n: u64) -> FftWorkload {
        FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes)
    }

    #[test]
    fn optimum_sits_below_boost_and_saves_energy() {
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        for n in [1024u64, 16384] {
            let w = wl(&g, n);
            let f = gov.choose(&g, &w, &ctx).unwrap();
            assert!(f < 0.85 * g.boost_clock_mhz, "N={n}: {f} not below boost");
            assert!(f > 0.4 * g.boost_clock_mhz, "N={n}: {f} implausibly low");
            let e_opt = run_batch(&g, &w, f).energy_j;
            let e_boost = run_batch(&g, &w, g.boost_clock_mhz).energy_j;
            assert!(e_opt < 0.90 * e_boost, "N={n}: {e_opt} vs boost {e_boost}");
        }
    }

    #[test]
    fn cache_makes_repeat_choices_identical() {
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        let w = wl(&g, 16384);
        let f1 = gov.choose(&g, &w, &ctx).unwrap();
        let f2 = gov.choose(&g, &w, &ctx).unwrap();
        assert_eq!(f1, f2);
    }
}
