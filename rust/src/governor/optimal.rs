//! Per-length optimal clock policy (paper §5.1, Fig 9): each FFT length
//! runs at its own measured energy optimum, backed by `analysis::optimal`.
//!
//! Off-grid lengths (smooth non-powers-of-two like 1000 or 1536) derive
//! their optimum from the interpolated time/power curves of
//! `sim::exec_model` instead of a fresh per-length measurement sweep:
//! two pow2-anchor model evaluations per candidate clock, snapped into
//! the card's frequency table and capped at boost. Bluestein lengths
//! (where interpolation between smooth anchors would be wrong — the
//! chirp-z convolution is far more expensive than its neighbours) keep
//! the exact sweep path.

use std::collections::HashMap;

use crate::analysis::optimal::optimal_for_length;
use crate::analysis::roofline::{classify_plan, PlanRegime};
use crate::cufft::plan::is_smooth_127;
use crate::governor::{ClockGovernor, GovernorContext, GovernorError};
use crate::harness::sweep::{sweep_gpu, SweepConfig};
use crate::harness::Protocol;
use crate::sim::exec_model::interp_time_power;
use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::types::FftWorkload;
use crate::util::stats::argmin;

/// Per-(card, length) energy-optimal clocks, measured lazily and cached.
pub struct PerLengthOptimal {
    cache: HashMap<(String, u64), f64>,
}

impl PerLengthOptimal {
    pub fn new() -> Self {
        Self { cache: HashMap::new() }
    }

    fn derive(gpu: &GpuSpec, workload: &FftWorkload, ctx: &GovernorContext) -> f64 {
        let n = workload.n;
        if !n.is_power_of_two() && is_smooth_127(n) {
            return Self::derive_interp(gpu, workload, ctx);
        }
        let cfg = SweepConfig {
            lengths: vec![workload.n],
            freq_stride: ctx.freq_stride.max(4),
            protocol: Protocol::quick(),
        };
        let sweep = sweep_gpu(gpu, workload.precision, &cfg);
        optimal_for_length(gpu, &sweep.lengths[0]).f_opt_mhz
    }

    /// Off-grid optimum: argmin of the interpolated energy curve over the
    /// table clocks at or below boost — always a supported clock, never an
    /// above-boost snap.
    ///
    /// The candidate set is roofline-informed (DESIGN.md §4g): memory-bound
    /// plans (four-step, Bluestein, anything past the residency budget)
    /// tolerate deep downclock — execution time is flat above the
    /// memory-saturation clock, so the unrestricted argmin finds the paper's
    /// near-knee optimum. Compute-bound plans slow down linearly with the
    /// clock, so their candidates are floored at the voltage knee (below
    /// it, voltage — and power — stop falling while time keeps rising:
    /// energy can only get worse).
    fn derive_interp(gpu: &GpuSpec, workload: &FftWorkload, ctx: &GovernorContext) -> f64 {
        let table = freq_table(gpu);
        let mut candidates: Vec<f64> = table
            .stride(ctx.freq_stride.max(4))
            .into_iter()
            .filter(|&f| f <= gpu.boost_clock_mhz + 1e-9)
            .collect();
        let regime = classify_plan(gpu, workload.n, workload.precision).regime;
        if regime == PlanRegime::ComputeBound {
            let knee = table.snap_at_most(gpu.f_knee_mhz, gpu.boost_clock_mhz);
            let floored: Vec<f64> =
                candidates.iter().copied().filter(|&f| f >= knee - 1e-9).collect();
            if !floored.is_empty() {
                candidates = floored;
            }
        }
        let energies: Vec<f64> = candidates
            .iter()
            .map(|&f| interp_time_power(gpu, workload, f).energy_j)
            .collect();
        match argmin(&energies) {
            Some(i) => candidates[i],
            None => table.snap_at_most(gpu.boost_clock_mhz, gpu.boost_clock_mhz),
        }
    }
}

impl Default for PerLengthOptimal {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockGovernor for PerLengthOptimal {
    fn name(&self) -> &'static str {
        "optimal"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let key = (gpu.name.to_string(), workload.n);
        if let Some(&f) = self.cache.get(&key) {
            return Ok(f);
        }
        let f = Self::derive(gpu, workload, ctx);
        self.cache.insert(key, f);
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::sim::run_batch;
    use crate::types::Precision;

    fn wl(gpu: &GpuSpec, n: u64) -> FftWorkload {
        FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes)
    }

    #[test]
    fn optimum_sits_below_boost_and_saves_energy() {
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        for n in [1024u64, 16384] {
            let w = wl(&g, n);
            let f = gov.choose(&g, &w, &ctx).unwrap();
            assert!(f < 0.85 * g.boost_clock_mhz, "N={n}: {f} not below boost");
            assert!(f > 0.4 * g.boost_clock_mhz, "N={n}: {f} implausibly low");
            let e_opt = run_batch(&g, &w, f).energy_j;
            let e_boost = run_batch(&g, &w, g.boost_clock_mhz).energy_j;
            assert!(e_opt < 0.90 * e_boost, "N={n}: {e_opt} vs boost {e_boost}");
        }
    }

    #[test]
    fn cache_makes_repeat_choices_identical() {
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        let w = wl(&g, 16384);
        let f1 = gov.choose(&g, &w, &ctx).unwrap();
        let f2 = gov.choose(&g, &w, &ctx).unwrap();
        assert_eq!(f1, f2);
    }

    #[test]
    fn off_grid_lengths_get_in_table_clocks_below_boost() {
        // The issue's off-grid acceptance: n=1000 and n=1536 must yield a
        // supported clock with no panic and no above-boost snap — on a card
        // whose boost equals f_max (V100) and on one whose table extends
        // past boost (Titan XP).
        let ctx = GovernorContext::default();
        for g in [tesla_v100(), crate::sim::gpu::titan_xp()] {
            let mut gov = PerLengthOptimal::new();
            let table = freq_table(&g);
            for n in [1000u64, 1536] {
                let f = gov.choose(&g, &wl(&g, n), &ctx).unwrap();
                assert!(table.contains(f), "{} n={n}: {f} not in table", g.name);
                assert!(
                    f <= g.boost_clock_mhz + 1e-9,
                    "{} n={n}: {f} above boost {}",
                    g.name,
                    g.boost_clock_mhz
                );
                assert!(f > 0.3 * g.boost_clock_mhz, "{} n={n}: {f} implausibly low", g.name);
            }
        }
    }

    #[test]
    fn large_n_off_grid_clock_choice_stays_within_frequency_table() {
        // Four-step-tier regression: now that the length grid extends to
        // 2^22, off-grid large lengths (3·2^20 and 5·2^19 sit between the
        // 2^21/2^22 and 2^20/2^21 pow2 anchors) must still resolve to a
        // supported table clock at or below boost through the
        // interpolated-curve path, and the pow2 top anchor itself must
        // resolve through the sweep path.
        let ctx = GovernorContext::default();
        for g in [tesla_v100(), crate::sim::gpu::titan_xp()] {
            let mut gov = PerLengthOptimal::new();
            let table = freq_table(&g);
            for n in [3u64 << 20, 5 << 19, 1 << 22] {
                let f = gov.choose(&g, &wl(&g, n), &ctx).unwrap();
                assert!(table.contains(f), "{} n={n}: {f} not in table", g.name);
                assert!(
                    f <= g.boost_clock_mhz + 1e-9,
                    "{} n={n}: {f} above boost {}",
                    g.name,
                    g.boost_clock_mhz
                );
                assert!(f > 0.3 * g.boost_clock_mhz, "{} n={n}: {f} implausibly low", g.name);
            }
        }
    }

    #[test]
    fn off_grid_optimum_saves_energy_vs_boost() {
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        for n in [1000u64, 1536] {
            let w = wl(&g, n);
            let f = gov.choose(&g, &w, &ctx).unwrap();
            assert!(f < 0.85 * g.boost_clock_mhz, "n={n}: {f} not below boost");
            let e_opt = run_batch(&g, &w, f).energy_j;
            let e_boost = run_batch(&g, &w, g.boost_clock_mhz).energy_j;
            assert!(e_opt < 0.95 * e_boost, "n={n}: {e_opt} vs boost {e_boost}");
        }
    }

    #[test]
    fn clock_choice_differs_by_roofline_regime() {
        // The §4g acceptance: a resident compute-bound plan (1536,
        // mixed-radix in L2) is floored at the voltage knee, while a
        // memory-bound four-step plan (3·2^20) downclocks past it — the
        // two regimes must produce different clocks on the same card.
        use crate::analysis::roofline::{classify_plan, PlanRegime};
        let g = tesla_v100();
        assert_eq!(
            classify_plan(&g, 1536, Precision::Fp32).regime,
            PlanRegime::ComputeBound
        );
        assert_eq!(
            classify_plan(&g, 3 << 20, Precision::Fp32).regime,
            PlanRegime::MemoryBound
        );
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        let knee = freq_table(&g).snap_at_most(g.f_knee_mhz, g.boost_clock_mhz);
        let f_compute = gov.choose(&g, &wl(&g, 1536), &ctx).unwrap();
        let f_memory = gov.choose(&g, &wl(&g, 3 << 20), &ctx).unwrap();
        assert!(
            f_compute >= knee - 1e-9,
            "compute-bound choice {f_compute} dipped below the knee {knee}"
        );
        assert!(
            f_memory < f_compute,
            "memory-bound choice {f_memory} should downclock past the compute-bound {f_compute}"
        );
    }

    #[test]
    fn bluestein_lengths_keep_the_exact_sweep_path() {
        // 19321 = 139² is not 127-smooth: the interpolated shortcut would
        // misprice the chirp-z convolution, so the sweep path must serve it
        // (and still return an in-table clock).
        let g = tesla_v100();
        let mut gov = PerLengthOptimal::new();
        let ctx = GovernorContext::default();
        let f = gov.choose(&g, &wl(&g, 19321), &ctx).unwrap();
        assert!(freq_table(&g).contains(f), "{f} not in table");
        assert!(f > 0.0 && f <= g.boost_clock_mhz + 1e-9);
    }
}
