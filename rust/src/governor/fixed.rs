//! Static clock policies: boost (no DVFS), one operator-chosen locked
//! clock, and the paper's common (mean-optimal) clock for all lengths.

use std::collections::HashMap;

use crate::analysis::{mean_optimal_mhz, optima};
use crate::governor::{ClockGovernor, GovernorContext, GovernorError};
use crate::harness::sweep::{quick_lengths, sweep_gpu, SweepConfig};
use crate::harness::Protocol;
use crate::sim::freq_table::freq_table;
use crate::sim::GpuSpec;
use crate::types::FftWorkload;

/// The no-DVFS default: every batch at the boost clock.
pub struct FixedBoost;

impl ClockGovernor for FixedBoost {
    fn name(&self) -> &'static str {
        "boost"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        _workload: &FftWorkload,
        _ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        Ok(gpu.boost_clock_mhz)
    }
}

/// Memoized power-budget clock cap, shared by the static policies: one
/// watt→clock inversion per (card, length, quarter-watt share), so the
/// per-batch cost of honoring the hint is a `HashMap` hit.
#[derive(Default)]
struct BudgetCaps {
    caps: HashMap<(String, u64, u64), f64>,
}

impl BudgetCaps {
    /// Apply the context's budget hint to a chosen clock (identity when
    /// uncapped).
    fn apply(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
        chosen_mhz: f64,
    ) -> f64 {
        let Some(budget_w) = ctx.power_budget_w else {
            return chosen_mhz;
        };
        let key = (
            gpu.name.to_string(),
            workload.n,
            crate::telemetry::budget_key(budget_w),
        );
        let cap = *self.caps.entry(key).or_insert_with(|| {
            crate::telemetry::clock_cap_for_budget(gpu, workload, budget_w, ctx.freq_stride)
        });
        chosen_mhz.min(cap)
    }
}

/// One operator-chosen locked clock, snapped to the card's frequency table
/// (what `nvmlDeviceSetGpuLockedClocks` would do with the raw request).
/// A power-budget hint lowers the lock to the share's fastest feasible
/// clock.
pub struct FixedClock {
    requested_mhz: f64,
    snapped: HashMap<String, f64>,
    budget: BudgetCaps,
}

impl FixedClock {
    pub fn new(mhz: f64) -> Self {
        Self {
            requested_mhz: mhz,
            snapped: HashMap::new(),
            budget: BudgetCaps::default(),
        }
    }
}

impl ClockGovernor for FixedClock {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let f = *self
            .snapped
            .entry(gpu.name.to_string())
            .or_insert_with(|| freq_table(gpu).snap(self.requested_mhz));
        Ok(self.budget.apply(gpu, workload, ctx, f))
    }
}

/// The paper's production policy (Table 3, Figs 15/16): one clock for every
/// length — the mean of the per-length optima. Derived once per card from a
/// quick measurement sweep and cached.
pub struct CommonClock {
    cache: HashMap<String, f64>,
    budget: BudgetCaps,
}

impl CommonClock {
    pub fn new() -> Self {
        Self {
            cache: HashMap::new(),
            budget: BudgetCaps::default(),
        }
    }

    fn derive(gpu: &GpuSpec) -> f64 {
        let cfg = SweepConfig {
            lengths: quick_lengths(),
            freq_stride: 6,
            protocol: Protocol::quick(),
        };
        let sweep = sweep_gpu(gpu, crate::types::Precision::Fp32, &cfg);
        let mut pts = optima(gpu, &sweep);
        // Roofline regime rule (DESIGN.md §4g): before averaging, floor the
        // compute-bound lengths' per-length optima at the voltage knee —
        // below it their energy can only get worse (voltage stops falling,
        // time keeps rising), so a sweep artifact on one of them must not
        // drag the fleet-wide common clock down for every length.
        let knee = freq_table(gpu).snap_at_most(gpu.f_knee_mhz, gpu.boost_clock_mhz);
        for p in &mut pts {
            let regime =
                crate::analysis::roofline::classify_plan(gpu, p.n, crate::types::Precision::Fp32)
                    .regime;
            if regime == crate::analysis::roofline::PlanRegime::ComputeBound {
                p.f_opt_mhz = p.f_opt_mhz.max(knee);
            }
        }
        let mean = mean_optimal_mhz(gpu, &pts);
        // Capped snap: the mean can never legitimately exceed boost, and
        // on cards whose boost sits between table entries a plain nearest
        // snap could round it upward past the default envelope.
        freq_table(gpu).snap_at_most(mean, gpu.boost_clock_mhz)
    }
}

impl Default for CommonClock {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockGovernor for CommonClock {
    fn name(&self) -> &'static str {
        "common"
    }

    fn choose(
        &mut self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
        ctx: &GovernorContext,
    ) -> Result<f64, GovernorError> {
        let f = *self
            .cache
            .entry(gpu.name.to_string())
            .or_insert_with(|| Self::derive(gpu));
        Ok(self.budget.apply(gpu, workload, ctx, f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::{tesla_p4, tesla_v100};
    use crate::types::Precision;

    fn wl(gpu: &GpuSpec, n: u64) -> FftWorkload {
        FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes)
    }

    #[test]
    fn fixed_clock_snaps_to_table() {
        let g = tesla_v100();
        let mut gov = FixedClock::new(946.3);
        let f = gov.choose(&g, &wl(&g, 1024), &GovernorContext::default()).unwrap();
        assert!(freq_table(&g).contains(f), "{f} not a table clock");
        assert!((f - 946.3).abs() <= 8.0);
    }

    #[test]
    fn common_clock_near_paper_table3() {
        // Governor-equivalence satellite: CommonClock lands in the paper's
        // Table 3 neighbourhood (V100 FP32: 945 MHz).
        let g = tesla_v100();
        let mut gov = CommonClock::new();
        let f = gov.choose(&g, &wl(&g, 16384), &GovernorContext::default()).unwrap();
        assert!((f - 945.0).abs() < 120.0, "V100 common clock {f} vs paper 945");
        // decision is length-independent and cached
        let f2 = gov.choose(&g, &wl(&g, 1024), &GovernorContext::default()).unwrap();
        assert_eq!(f, f2);
    }

    #[test]
    fn common_clock_sane_for_off_grid_lengths() {
        // The common clock is length-independent, so asking at the
        // off-grid serving lengths must neither panic nor produce a clock
        // outside the table or above boost — including the four-step tier
        // (3·2^20 sits off-grid between the 2^21 and 2^22 anchors).
        for g in [tesla_v100(), tesla_p4()] {
            let mut gov = CommonClock::new();
            for n in [1000u64, 1536, 3 << 20] {
                let f = gov.choose(&g, &wl(&g, n), &GovernorContext::default()).unwrap();
                assert!(freq_table(&g).contains(f), "{} n={n}: {f} not in table", g.name);
                assert!(
                    f <= g.boost_clock_mhz + 1e-9,
                    "{} n={n}: {f} above boost {}",
                    g.name,
                    g.boost_clock_mhz
                );
            }
        }
    }

    #[test]
    fn budget_hint_lowers_fixed_and_common_locks() {
        // The paper's production policies under an arbiter share: a tight
        // watt budget pulls the lock below the policy's own choice, and a
        // generous one leaves it alone. The cap is memoized: repeat
        // choices under the same share are identical.
        let g = tesla_v100();
        let w = wl(&g, 16384);
        let open = GovernorContext::default();
        let tight = GovernorContext {
            power_budget_w: Some(110.0),
            ..GovernorContext::default()
        };
        let rich = GovernorContext {
            power_budget_w: Some(10_000.0),
            ..GovernorContext::default()
        };
        let mut fixed = FixedClock::new(1400.0);
        let f_open = fixed.choose(&g, &w, &open).unwrap();
        let f_tight = fixed.choose(&g, &w, &tight).unwrap();
        assert!(f_tight < f_open, "{f_tight} !< {f_open}");
        assert!(
            crate::sim::run_batch(&g, &w, f_tight).avg_power_w <= 110.0 + 1e-9,
            "capped lock still over budget"
        );
        assert_eq!(fixed.choose(&g, &w, &tight).unwrap(), f_tight, "memoized");
        assert_eq!(fixed.choose(&g, &w, &rich).unwrap(), f_open);

        let mut common = CommonClock::new();
        let c_open = common.choose(&g, &w, &open).unwrap();
        let c_tight = common.choose(&g, &w, &tight).unwrap();
        assert!(c_tight <= c_open);
        assert!(crate::sim::run_batch(&g, &w, c_tight).avg_power_w <= 110.0 + 1e-9);
    }

    #[test]
    fn common_clock_is_per_card() {
        let mut gov = CommonClock::new();
        let v100 = tesla_v100();
        let p4 = tesla_p4();
        let fv = gov.choose(&v100, &wl(&v100, 16384), &GovernorContext::default()).unwrap();
        let fp = gov.choose(&p4, &wl(&p4, 16384), &GovernorContext::default()).unwrap();
        assert!(fv > fp, "V100 {fv} should clock above P4 {fp}");
        assert!((fp - 746.0).abs() < 120.0, "P4 common clock {fp} vs paper 746");
    }
}
