//! Shared domain types: precision, FFT workload descriptors.

use std::fmt;

/// Floating-point precision of a transform (paper: FP16 / FP32 / FP64).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Fp16,
    Fp32,
    Fp64,
}

impl Precision {
    pub const ALL: [Precision; 3] = [Precision::Fp32, Precision::Fp64, Precision::Fp16];

    /// Bytes per *complex* element (interleaved re/im).
    pub fn complex_bytes(self) -> u64 {
        match self {
            Precision::Fp16 => 4,
            Precision::Fp32 => 8,
            Precision::Fp64 => 16,
        }
    }

    pub fn real_bytes(self) -> u64 {
        self.complex_bytes() / 2
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp16 => "FP16",
            Precision::Fp32 => "FP32",
            Precision::Fp64 => "FP64",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fp16" | "f16" | "half" => Some(Precision::Fp16),
            "fp32" | "f32" | "float" | "single" => Some(Precision::Fp32),
            "fp64" | "f64" | "double" => Some(Precision::Fp64),
            _ => None,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// A batched 1D C2C FFT workload over a fixed amount of device memory
/// (the paper's measurement unit, section 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FftWorkload {
    /// Transform length N.
    pub n: u64,
    /// Precision of the transform.
    pub precision: Precision,
    /// Total bytes of input data processed per batch (paper: 2 GB, Jetson ¼).
    pub data_bytes: u64,
}

impl FftWorkload {
    pub fn new(n: u64, precision: Precision, data_bytes: u64) -> Self {
        Self { n, precision, data_bytes }
    }

    /// Number of transforms per batch: N_FFT = M / (N * B)  (paper eq. 6).
    pub fn n_fft(&self) -> u64 {
        (self.data_bytes / (self.n * self.precision.complex_bytes())).max(1)
    }

    /// Total complex elements per batch.
    pub fn elements(&self) -> u64 {
        self.n_fft() * self.n
    }

    /// FLOP count for one batch: 5 N log2 N * N_FFT  (paper eq. 5 numerator,
    /// with N_b = 1 run).
    pub fn flops(&self) -> f64 {
        5.0 * self.n as f64 * (self.n as f64).log2() * self.n_fft() as f64
    }
}

/// GiB → bytes.
pub const fn gib(x: u64) -> u64 {
    x * 1024 * 1024 * 1024
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complex_bytes_per_precision() {
        assert_eq!(Precision::Fp16.complex_bytes(), 4);
        assert_eq!(Precision::Fp32.complex_bytes(), 8);
        assert_eq!(Precision::Fp64.complex_bytes(), 16);
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(Precision::parse("f32"), Some(Precision::Fp32));
        assert_eq!(Precision::parse("double"), Some(Precision::Fp64));
        assert_eq!(Precision::parse("HALF"), Some(Precision::Fp16));
        assert_eq!(Precision::parse("int8"), None);
    }

    #[test]
    fn eq6_batch_count() {
        // 2 GiB of fp32 complex data, N = 16384 -> 16384 FFTs (paper sec. 5.1)
        let w = FftWorkload::new(16384, Precision::Fp32, gib(2));
        assert_eq!(w.n_fft(), 16384);
        assert_eq!(w.elements(), 16384 * 16384);
    }

    #[test]
    fn elements_constant_across_n() {
        let a = FftWorkload::new(256, Precision::Fp32, gib(2));
        let b = FftWorkload::new(65536, Precision::Fp32, gib(2));
        assert_eq!(a.elements(), b.elements());
    }

    #[test]
    fn flops_match_eq5() {
        let w = FftWorkload::new(1024, Precision::Fp32, 1024 * 8 * 4); // 4 FFTs
        assert_eq!(w.n_fft(), 4);
        let expect = 5.0 * 1024.0 * 10.0 * 4.0;
        assert!((w.flops() - expect).abs() < 1e-6);
    }
}
