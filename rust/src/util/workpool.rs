//! Persistent worker pool for row-parallel batch execution.
//!
//! The planner's first parallel design spawned scoped std threads per
//! `run_rows` call; at serving rates that is thousands of thread
//! creations per second sitting directly on the hot path. This pool
//! spawns its workers **once**, parks them on a condvar while idle, and
//! lets callers submit borrowed-closure task sets that the calling
//! thread blocks on (and helps execute) until completion — the scoped
//! execution model with none of the per-call spawn cost.
//!
//! Safety model: [`WorkPool::run_scope`] accepts closures borrowing the
//! caller's stack (`'scope` outlives the call, not the pool). The
//! closures are lifetime-erased to `'static` to cross the queue, which
//! is sound because `run_scope` does not return until its completion
//! latch counts every submitted task as finished — the borrows cannot
//! outlive the frame that owns them. Panics inside a task are caught so
//! the worker thread (and the latch) survive; the panic is re-raised on
//! the submitting thread.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A queued unit of work: the erased closure plus the latch its scope
/// is waiting on.
struct Task {
    run: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<Latch>,
}

/// Completion latch for one `run_scope` call: counts outstanding tasks
/// down to zero and wakes the waiting submitter.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Self {
        Self {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn complete_one(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    /// Workers park here when the queue is empty.
    available: Condvar,
    shutdown: AtomicBool,
    /// Total worker threads ever spawned by this pool — constant after
    /// construction; the "zero spawns per call" acceptance check.
    spawned_total: AtomicU64,
    /// Tasks executed over the pool's lifetime (workers + helping callers).
    executed_total: AtomicU64,
}

/// Pool introspection for tests, benches and telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads currently owned by the pool.
    pub workers: usize,
    /// OS threads ever spawned by the pool (== `workers` forever: the
    /// pool never respawns).
    pub spawned_total: u64,
    /// Tasks executed since construction.
    pub executed_total: u64,
}

/// A fixed-size persistent worker pool executing borrowed-closure scopes.
pub struct WorkPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkPool {
    /// Spawn `threads` named workers (clamped to at least 1). Workers
    /// start parked and stay alive until the pool is dropped.
    pub fn new(name: &str, threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            spawned_total: AtomicU64::new(0),
            executed_total: AtomicU64::new(0),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = shared.clone();
                shared.spawned_total.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers.len(),
            spawned_total: self.shared.spawned_total.load(Ordering::Relaxed),
            executed_total: self.shared.executed_total.load(Ordering::Relaxed),
        }
    }

    /// Execute every task, blocking until all have finished. Tasks may
    /// borrow from the caller's stack; the blocking wait is what makes
    /// the lifetime erasure sound (see module docs). The caller does not
    /// just wait: it helps drain its own scope's tasks, so a 1-worker
    /// pool still executes 2-wide and a task set never deadlocks on pool
    /// capacity — including when `run_scope` is re-entered from inside a
    /// task (the submitting worker drains its own scope inline).
    pub fn run_scope<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: `run_scope` blocks on `latch` until every task
                // submitted here has run to completion, so the borrows
                // inside `t` strictly outlive its execution.
                let run: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(t)
                };
                q.push_back(Task {
                    run,
                    latch: latch.clone(),
                });
            }
        }
        self.shared.available.notify_all();
        // Help drain — but only THIS scope's tasks. Executing a foreign
        // scope's (possibly much larger) chunk here would couple this
        // caller's latency to unrelated submitters; foreign tasks belong
        // to the workers. Draining our own tasks also keeps re-entrant
        // submission deadlock-free when every worker is busy.
        while !latch.is_done() {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                match q.iter().position(|t| Arc::ptr_eq(&t.latch, &latch)) {
                    Some(i) => q.remove(i),
                    None => None,
                }
            };
            match task {
                Some(t) => execute(&self.shared, t),
                None => break,
            }
        }
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("workpool: a scoped task panicked");
        }
    }
}

impl Drop for WorkPool {
    /// Clean shutdown: unpark every worker, let them observe the flag,
    /// and join them. Queued tasks from still-blocked scopes (there can
    /// be none at drop time — scopes hold `&self`) are not abandoned.
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // Park until run_scope or drop notifies.
                q = shared.available.wait(q).unwrap();
            }
        };
        execute(shared, task);
    }
}

fn execute(shared: &Shared, task: Task) {
    let latch = task.latch.clone();
    // Catch panics so the worker thread and the latch both survive; the
    // flag re-raises on the submitting thread.
    if catch_unwind(AssertUnwindSafe(task.run)).is_err() {
        latch.panicked.store(true, Ordering::SeqCst);
    }
    shared.executed_total.fetch_add(1, Ordering::Relaxed);
    latch.complete_one();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_every_task_and_blocks_until_done() {
        let pool = WorkPool::new("wp-test", 3);
        let mut results = vec![0u64; 64];
        {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = results
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    Box::new(move || *slot = (i as u64) * 3) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scope(tasks);
        }
        // run_scope returned ⇒ every borrowed write already happened.
        for (i, &v) in results.iter().enumerate() {
            assert_eq!(v, (i as u64) * 3);
        }
        let stats = pool.stats();
        assert_eq!(stats.workers, 3);
        assert_eq!(stats.spawned_total, 3, "no threads beyond construction");
        assert_eq!(stats.executed_total, 64);
    }

    #[test]
    fn reuse_across_scopes_spawns_nothing() {
        let pool = WorkPool::new("wp-reuse", 2);
        let counter = AtomicUsize::new(0);
        for _ in 0..10 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scope(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 40);
        assert_eq!(pool.stats().spawned_total, 2, "persistent workers only");
    }

    #[test]
    fn single_worker_pool_cannot_deadlock() {
        // Caller helps drain, so a 1-worker pool finishes a 8-task scope.
        let pool = WorkPool::new("wp-one", 1);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scope(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    #[should_panic(expected = "scoped task panicked")]
    fn task_panic_propagates_to_submitter() {
        let pool = WorkPool::new("wp-panic", 2);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| {})];
        pool.run_scope(tasks);
    }

    #[test]
    fn pool_survives_a_task_panic() {
        let pool = WorkPool::new("wp-survive", 2);
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            pool.run_scope(vec![Box::new(|| panic!("first scope dies"))]);
        }));
        assert!(panicked.is_err());
        // Workers caught the panic: the next scope still executes.
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scope(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 4);
        assert_eq!(pool.stats().spawned_total, 2, "no respawn after panic");
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = WorkPool::new("wp-drop", 4);
        pool.run_scope(vec![Box::new(|| {})]);
        drop(pool); // must not hang: workers observe shutdown and exit
    }

    #[test]
    fn empty_scope_is_a_noop() {
        let pool = WorkPool::new("wp-empty", 2);
        pool.run_scope(Vec::new());
        assert_eq!(pool.stats().executed_total, 0);
    }
}
