//! Micro-benchmark harness (offline substitute for `criterion`).
//!
//! `cargo bench` binaries (`harness = false`) use [`Bench`] to time closures
//! with warmup, report mean / σ / min / p50 / p95 and ns-per-iteration, and
//! optionally dump a CSV next to the figure outputs.

use std::time::{Duration, Instant};

use crate::util::stats;
use crate::util::table::Table;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput_meps(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / (self.mean_ns / 1e9) / 1e6)
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub min_time: Duration,
    results: Vec<BenchResult>,
    suite: String,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        Self {
            warmup: 3,
            iters: 30,
            min_time: Duration::from_millis(50),
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f`, which must consume/blackhole its own result.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_elements(name, None, &mut f)
    }

    pub fn run_with_elements<F: FnMut()>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut F,
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.iters);
        let start_all = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            // keep very fast benches honest, very slow benches bounded
            if start_all.elapsed() > Duration::from_secs(20) && samples_ns.len() >= 5 {
                break;
            }
        }
        let res = BenchResult {
            name: format!("{}/{}", self.suite, name),
            iters: samples_ns.len(),
            mean_ns: stats::mean(&samples_ns),
            std_ns: stats::std_dev(&samples_ns),
            min_ns: stats::min(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            elements,
        };
        println!("{}", format_result(&res));
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all collected results as an aligned table.
    pub fn summary(&self) -> String {
        let mut t = Table::new(
            &format!("bench suite: {}", self.suite),
            &["benchmark", "iters", "mean", "sigma", "min", "p95", "Melem/s"],
        );
        for r in &self.results {
            t.push_row(vec![
                r.name.clone(),
                r.iters.to_string(),
                human_ns(r.mean_ns),
                human_ns(r.std_ns),
                human_ns(r.min_ns),
                human_ns(r.p95_ns),
                r.throughput_meps()
                    .map(|x| format!("{x:.1}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t.to_ascii()
    }
}

pub fn format_result(r: &BenchResult) -> String {
    let tp = r
        .throughput_meps()
        .map(|x| format!("  {x:.1} Melem/s"))
        .unwrap_or_default();
    format!(
        "{:<52} {:>10}/iter (σ {:>9}, min {:>9}, n={}){}",
        r.name,
        human_ns(r.mean_ns),
        human_ns(r.std_ns),
        human_ns(r.min_ns),
        r.iters,
        tp
    )
}

pub fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (std-only black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_results() {
        let mut b = Bench::new("unit").with_iters(1, 5);
        b.run("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].mean_ns >= 0.0);
        assert_eq!(b.results()[0].iters, 5);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::new("unit").with_iters(0, 3);
        let r = b
            .run_with_elements("spin", Some(1000), &mut || {
                let mut s = 0u64;
                for i in 0..1000u64 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            })
            .clone();
        assert!(r.throughput_meps().unwrap() > 0.0);
    }

    #[test]
    fn human_ns_units() {
        assert_eq!(human_ns(500.0), "500 ns");
        assert!(human_ns(1500.0).ends_with("µs"));
        assert!(human_ns(2.5e6).ends_with("ms"));
        assert!(human_ns(3.2e9).ends_with(" s"));
    }

    #[test]
    fn summary_contains_all_rows() {
        let mut b = Bench::new("unit").with_iters(0, 2);
        b.run("a", || {});
        b.run("b", || {});
        let s = b.summary();
        assert!(s.contains("unit/a") && s.contains("unit/b"));
    }
}
