//! Minimal CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("missing required argument --{0}")]
    Missing(String),
    #[error("invalid value for --{key}: {value:?} ({why})")]
    Invalid {
        key: String,
        value: String,
        why: String,
    },
}

impl Args {
    /// Parse an iterator of raw arguments (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.entry(stripped.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn required(&self, key: &str) -> Result<&str, ArgError> {
        self.get(key).ok_or_else(|| ArgError::Missing(key.into()))
    }

    pub fn parse_typed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|e| ArgError::Invalid {
                key: key.into(),
                value: s.into(),
                why: e.to_string(),
            }),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_typed(key).ok().flatten().unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_typed(key).ok().flatten().unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_typed(key).ok().flatten().unwrap_or(default)
    }

    /// Comma-separated list, e.g. `--lengths 256,1024,4096`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(s) if !s.is_empty() => s.split(',').map(|x| x.trim().to_string()).collect(),
            _ => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = args(&["sweep", "--gpu", "v100", "--verbose", "--n=4096"]);
        assert_eq!(a.positional, vec!["sweep"]);
        assert_eq!(a.get("gpu"), Some("v100"));
        assert_eq!(a.get("n"), Some("4096"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_parsing() {
        let a = args(&["--x", "3.5", "--n", "42"]);
        assert_eq!(a.f64_or("x", 0.0), 3.5);
        assert_eq!(a.u64_or("n", 0), 42);
        assert_eq!(a.u64_or("missing", 7), 7);
    }

    #[test]
    fn invalid_typed_value_is_error() {
        let a = args(&["--n", "notanumber"]);
        assert!(a.parse_typed::<u64>("n").is_err());
    }

    #[test]
    fn required_missing() {
        let a = args(&[]);
        assert!(a.required("gpu").is_err());
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--lengths", "1, 2,4"]);
        assert_eq!(a.list_or("lengths", &[]), vec!["1", "2", "4"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn repeated_flags_last_wins_but_all_kept() {
        let a = args(&["--gpu", "v100", "--gpu", "nano"]);
        assert_eq!(a.get("gpu"), Some("nano"));
        assert_eq!(a.get_all("gpu"), vec!["v100", "nano"]);
    }
}
