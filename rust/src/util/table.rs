//! CSV and aligned-ASCII table writers (no `serde` in the offline crate set).
//!
//! Every figure/table the harness regenerates is emitted twice: a CSV file
//! for plotting and an aligned text rendering for the terminal/EXPERIMENTS.md.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        debug_assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Convenience: format heterogenous cells.
    pub fn row(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.push_row(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&csv_line(&self.columns));
        for r in &self.rows {
            out.push_str(&csv_line(r));
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// Aligned fixed-width rendering with a title rule.
    pub fn to_ascii(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, cell) in r.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len().saturating_sub(1);
        let _ = writeln!(out, "{}", self.title);
        let _ = writeln!(out, "{}", "=".repeat(total.max(self.title.len())));
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        let _ = writeln!(out, "{}", header.join(" | "));
        let _ = writeln!(out, "{}", "-".repeat(total.max(self.title.len())));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            let _ = writeln!(out, "{}", line.join(" | "));
        }
        out
    }
}

fn csv_line<S: AsRef<str>>(cells: &[S]) -> String {
    let quoted: Vec<String> = cells
        .iter()
        .map(|c| {
            let c = c.as_ref();
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        })
        .collect();
    format!("{}\n", quoted.join(","))
}

/// Format a float with a fixed number of decimals, trimming "-0.000".
pub fn fnum(x: f64, decimals: usize) -> String {
    let s = format!("{x:.decimals$}");
    if s.starts_with("-0.") && s[3..].chars().all(|c| c == '0') {
        s[1..].to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_basic() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn ascii_is_aligned() {
        let mut t = Table::new("Demo", &["name", "v"]);
        t.push_row(vec!["long-name".into(), "1".into()]);
        t.push_row(vec!["x".into(), "22".into()]);
        let a = t.to_ascii();
        let lines: Vec<&str> = a.lines().collect();
        assert!(lines[2].contains("name"));
        // all data lines same width
        assert_eq!(lines[4].len(), lines[5].len());
    }

    #[test]
    fn quote_escaping() {
        let mut t = Table::new("t", &["a"]);
        t.push_row(vec!["say \"hi\"".into()]);
        assert!(t.to_csv().contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn fnum_strips_negative_zero() {
        assert_eq!(fnum(-0.00001, 3), "0.000");
        assert_eq!(fnum(1.23456, 2), "1.23");
    }
}
