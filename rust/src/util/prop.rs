//! Mini property-testing framework (offline substitute for `proptest`).
//!
//! Runs a property over N generated cases from a seeded [`Rng`]; on failure
//! it reports the failing case's seed so the exact case can be replayed.
//! Used by the coordinator/sim invariant tests.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 256, seed: 0xfeed_beef }
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with the replay seed
/// on the first failure.
pub fn for_all<T, G, P>(cfg: PropConfig, name: &str, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case}/{} (replay seed {case_seed:#x}):\n  \
                 input: {input:?}\n  reason: {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience wrapper with the default config.
pub fn check<T, G, P>(name: &str, generate: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for_all(PropConfig::default(), name, generate, prop);
}

/// Assert helper producing `Result<(), String>` bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all(
            PropConfig { cases: 64, seed: 1 },
            "addition commutes",
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                count += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut first: Vec<u64> = Vec::new();
        for_all(
            PropConfig { cases: 16, seed: 9 },
            "collect",
            |r| r.next_u64(),
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<u64> = Vec::new();
        for_all(
            PropConfig { cases: 16, seed: 9 },
            "collect",
            |r| r.next_u64(),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
