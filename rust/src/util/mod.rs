//! Cross-cutting utilities: deterministic PRNG, statistics, CLI parsing,
//! CSV/ASCII tables, micro-bench harness and the mini property-testing
//! framework (offline substitutes for rand/clap/serde/criterion/proptest —
//! see DESIGN.md §1).

pub mod bench;
pub mod cliargs;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod workpool;
