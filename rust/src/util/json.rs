//! Minimal JSON writer + reader (no `serde` in the offline crate set):
//! build a [`Json`] value tree, render with proper escaping — pretty for
//! `report.json`-style artifacts, compact single-line for the trace
//! JSONL journal — and parse it back with a small recursive-descent
//! reader (`fftsweep trace` replays recorded span journals).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: Json) -> &mut Self {
        if let Json::Arr(v) = self {
            v.push(value);
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line rendering (no indentation or newlines) — one value per
    /// line is the JSONL contract the trace journal relies on.
    pub fn render_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write_compact(out);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
            // scalars never embed newlines (strings escape them)
            other => other.write(out, 0),
        }
    }

    /// Object field lookup; `None` on non-objects or absent keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric field as u64 (must be a non-negative integer value).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 1.9e19 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse one JSON value from `text` (the whole string must be
    /// consumed apart from trailing whitespace).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(
            pos == bytes.len(),
            "trailing garbage at byte {pos} of JSON input"
        );
        Ok(value)
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{}", "  ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                let _ = write!(out, "\n{}]", "  ".repeat(indent));
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{}", "  ".repeat(indent + 1));
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                let _ = write!(out, "\n{}}}", "  ".repeat(indent));
            }
        }
    }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, lit: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        bytes[*pos..].starts_with(lit.as_bytes()),
        "expected `{lit}` at byte {pos}"
    );
    *pos += lit.len();
    Ok(())
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(bytes, pos);
    anyhow::ensure!(*pos < bytes.len(), "unexpected end of JSON input");
    match bytes[*pos] {
        b'n' => expect(bytes, pos, "null").map(|_| Json::Null),
        b't' => expect(bytes, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(bytes, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(bytes, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => anyhow::bail!("expected `,` or `]` at byte {pos}"),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, ":")?;
                map.insert(key, parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => anyhow::bail!("expected `,` or `}}` at byte {pos}"),
                }
            }
        }
        _ => parse_number(bytes, pos),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(
        bytes.get(*pos) == Some(&b'"'),
        "expected string at byte {pos}"
    );
    *pos += 1;
    let mut out = String::new();
    // operate on the char level so multi-byte UTF-8 passes through intact
    let rest = std::str::from_utf8(&bytes[*pos..])?;
    let mut chars = rest.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => {
                *pos += i + 1;
                return Ok(out);
            }
            '\\' => {
                let (_, esc) = chars
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("dangling escape in string"))?;
                match esc {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    '/' => out.push('/'),
                    'n' => out.push('\n'),
                    'r' => out.push('\r'),
                    't' => out.push('\t'),
                    'b' => out.push('\u{8}'),
                    'f' => out.push('\u{c}'),
                    'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let (_, h) = chars
                                .next()
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            code = code * 16
                                + h.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad hex in \\u escape"))?;
                        }
                        // unpaired surrogates degrade to U+FFFD, not an error
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => anyhow::bail!("unsupported escape `\\{other}`"),
                }
            }
            c => out.push(c),
        }
    }
    anyhow::bail!("unterminated string")
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    anyhow::ensure!(*pos > start, "expected a JSON value at byte {start}");
    let text = std::str::from_utf8(&bytes[start..*pos])?;
    let x: f64 = text
        .parse()
        .map_err(|_| anyhow::anyhow!("bad number `{text}` at byte {start}"))?;
    Ok(Json::Num(x))
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structure() {
        let mut root = Json::obj();
        root.set("name", "v100".into());
        root.set("mhz", 945.0.into());
        let mut arr = Json::Arr(vec![]);
        arr.push(1.0.into());
        arr.push(Json::Null);
        root.set("values", arr);
        let s = root.render();
        assert!(s.contains("\"name\": \"v100\""));
        assert!(s.contains("\"mhz\": 945"));
        assert!(s.contains("null"));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj().render(), "{}");
    }

    #[test]
    #[should_panic(expected = "non-object")]
    fn set_on_array_panics() {
        Json::Arr(vec![]).set("k", Json::Null);
    }

    #[test]
    fn compact_rendering_is_single_line() {
        let mut root = Json::obj();
        root.set("a", 1.0.into());
        let mut arr = Json::Arr(vec![]);
        arr.push("x\ny".into());
        arr.push(Json::Null);
        root.set("list", arr);
        let s = root.render_compact();
        assert_eq!(s, "{\"a\":1,\"list\":[\"x\\ny\",null]}");
        assert!(!s.contains('\n'), "JSONL lines must be newline-free");
    }

    #[test]
    fn parse_roundtrips_compact_and_pretty() {
        let mut root = Json::obj();
        root.set("name", "Tesla \"V100\"".into());
        root.set("mhz", 945.5.into());
        root.set("count", 42u64.into());
        root.set("flag", true.into());
        root.set("nothing", Json::Null);
        let mut arr = Json::Arr(vec![]);
        arr.push(1.0.into());
        arr.push(2.5.into());
        root.set("xs", arr);
        for text in [root.render(), root.render_compact()] {
            let back = Json::parse(&text).expect("parse");
            assert_eq!(back, root);
        }
    }

    #[test]
    fn accessors_read_typed_fields() {
        let j = Json::parse(r#"{"s":"hi","n":3,"f":1.5,"b":false,"a":[1]}"#).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("hi"));
        assert_eq!(j.get("n").and_then(Json::as_u64), Some(3));
        assert_eq!(j.get("f").and_then(Json::as_f64), Some(1.5));
        assert_eq!(j.get("f").and_then(Json::as_u64), None, "fractional is not u64");
        assert_eq!(j.get("b").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("a").and_then(Json::as_array).map(<[Json]>::len), Some(1));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("s"), None);
    }

    #[test]
    fn parse_handles_escapes_and_whitespace() {
        let j = Json::parse(" { \"k\" : \"a\\\"b\\\\c\\nd\\u0041\" } ").unwrap();
        assert_eq!(j.get("k").and_then(Json::as_str), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in ["", "{", "[1,", "{\"k\":}", "tru", "1.2.3", "{} trailing"] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn parse_negative_and_exponent_numbers() {
        let j = Json::parse("[-1.5e3, 0.25, -7]").unwrap();
        let xs = j.as_array().unwrap();
        assert_eq!(xs[0].as_f64(), Some(-1500.0));
        assert_eq!(xs[1].as_f64(), Some(0.25));
        assert_eq!(xs[2].as_f64(), Some(-7.0));
    }
}
