//! Minimal JSON writer (no `serde` in the offline crate set): build a
//! [`Json`] value tree, render with proper escaping. Used for the
//! machine-readable `report.json` next to the CSV outputs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: Json) -> &mut Self {
        if let Json::Arr(v) = self {
            v.push(value);
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{}", "  ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                let _ = write!(out, "\n{}]", "  ".repeat(indent));
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{}", "  ".repeat(indent + 1));
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                let _ = write!(out, "\n{}}}", "  ".repeat(indent));
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structure() {
        let mut root = Json::obj();
        root.set("name", "v100".into());
        root.set("mhz", 945.0.into());
        let mut arr = Json::Arr(vec![]);
        arr.push(1.0.into());
        arr.push(Json::Null);
        root.set("values", arr);
        let s = root.render();
        assert!(s.contains("\"name\": \"v100\""));
        assert!(s.contains("\"mhz\": 945"));
        assert!(s.contains("null"));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj().render(), "{}");
    }

    #[test]
    #[should_panic(expected = "non-object")]
    fn set_on_array_panics() {
        Json::Arr(vec![]).set("k", Json::Null);
    }
}
