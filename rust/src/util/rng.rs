//! Deterministic PRNG (xorshift64* + splitmix seeding).
//!
//! The offline crate set has no `rand`, so the simulator's sensor noise,
//! the property-testing framework and the workload generators all share
//! this small, seedable generator. Determinism matters: every figure the
//! harness regenerates must be reproducible from a seed.

/// xorshift64* with splitmix64 seed scrambling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 step so that small consecutive seeds diverge.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Self { state: z.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (n > 0), Lemire-style without bias issues
    /// mattering at our scale.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn gauss_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Derive an independent stream (for per-run sensor seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_stays_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fork_is_independent() {
        let mut r = Rng::new(19);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(1);
        // forks taken at different parent states differ
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn range_u64_inclusive() {
        let mut r = Rng::new(23);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
