//! Small statistics helpers shared by the harness, analysis and benches.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Relative standard deviation (the paper's "measurement error"): sigma / mu.
pub fn rel_std(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m.abs()
    }
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Index of the minimum value (first on ties). None when empty.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Index of the maximum value (first on ties). None when empty.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Percentile via linear interpolation, p in [0, 100]. Exact but it
/// copies + sorts (O(n log n)) — analysis/offline use only. Serving-path
/// consumers (the request tracer, `analysis::telemetry`, the serving
/// bench) read percentiles from the one shared streaming implementation,
/// [`crate::telemetry::histogram::LogHistogram`], which keeps this exact
/// sort as its accuracy reference in tests.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Error propagation for a ratio of two quantities with equal relative
/// errors (paper eq. 8): sigma_R(I_ef) = sqrt(2) * sigma_R(E_ef).
pub fn ratio_rel_error(sigma_rel: f64) -> f64 {
    std::f64::consts::SQRT_2 * sigma_rel
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn std_dev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_std_scale_invariant() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!((rel_std(&xs) - rel_std(&ys)).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn argmin_argmax() {
        let xs = [3.0, 1.0, 2.0, 1.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn argmin_skips_nan() {
        let xs = [f64::NAN, 5.0, 2.0];
        assert_eq!(argmin(&xs), Some(2));
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn eq8_error_propagation() {
        // paper: 5% power error -> ~7% efficiency-increase error
        let e = ratio_rel_error(0.05);
        assert!((e - 0.0707).abs() < 1e-3);
    }
}
