//! Synthetic workload generation: noisy complex baseband data and the
//! injected-pulsar time series used by the end-to-end pipeline example.

use crate::dsp::fft::C64;
use crate::util::rng::Rng;

/// Gaussian complex noise, unit variance per component.
pub fn complex_noise(n: usize, rng: &mut Rng) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.gauss(), rng.gauss())).collect()
}

/// Parameters of an injected pulsar: a pulse train whose fundamental lands
/// on spectrum bin `fundamental_bin` with `harmonics` significant harmonics
/// of per-harmonic amplitude `amplitude` (relative to unit noise σ).
#[derive(Debug, Clone)]
pub struct PulsarParams {
    pub fundamental_bin: usize,
    pub harmonics: usize,
    pub amplitude: f64,
}

impl Default for PulsarParams {
    fn default() -> Self {
        Self { fundamental_bin: 321, harmonics: 8, amplitude: 0.08 }
    }
}

/// A pulsar-like periodic comb buried in gaussian noise.
pub fn pulsar_time_series(n: usize, params: &PulsarParams, rng: &mut Rng) -> Vec<C64> {
    let mut x = complex_noise(n, rng);
    for m in 1..=params.harmonics {
        let k = params.fundamental_bin * m;
        if k >= n {
            break;
        }
        let phase0 = 0.3 * m as f64;
        for (t, v) in x.iter_mut().enumerate() {
            let theta = 2.0 * std::f64::consts::PI * (k * t % n) as f64 / n as f64 + phase0;
            v.re += params.amplitude * theta.cos();
        }
    }
    x
}

/// Split a complex vector into (re, im) f32 planes, batch-major.
pub fn to_planes(x: &[C64]) -> (Vec<f32>, Vec<f32>) {
    (
        x.iter().map(|c| c.re as f32).collect(),
        x.iter().map(|c| c.im as f32).collect(),
    )
}

/// Candidate detection on a harmonic-summed spectrum: the peak bin above
/// `skip` (the DC/red-noise exclusion zone) plus its significance.
#[derive(Debug, Clone)]
pub struct Detection {
    pub bin: usize,
    pub snr: f64,
}

pub fn detect_peak(hs: &[f32], skip: usize) -> Option<Detection> {
    if hs.len() <= skip + 2 {
        return None;
    }
    let body = &hs[skip..];
    let (imax, _) = body
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())?;
    let peak = body[imax] as f64;
    let rest: Vec<f64> = body
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != imax)
        .map(|(_, v)| *v as f64)
        .collect();
    let mean = crate::util::stats::mean(&rest);
    let sd = crate::util::stats::std_dev(&rest).max(1e-12);
    Some(Detection {
        bin: imax + skip,
        snr: (peak - mean) / sd,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::fft::{fft, harmonic_sum, power_spectrum};

    #[test]
    fn noise_is_zero_mean_unit_var() {
        let mut rng = Rng::new(1);
        let x = complex_noise(50_000, &mut rng);
        let mean_re: f64 = x.iter().map(|c| c.re).sum::<f64>() / x.len() as f64;
        let var_re: f64 = x.iter().map(|c| c.re * c.re).sum::<f64>() / x.len() as f64;
        assert!(mean_re.abs() < 0.02);
        assert!((var_re - 1.0).abs() < 0.05);
    }

    #[test]
    fn injected_pulsar_detectable_via_harmonic_sum() {
        let n = 16384;
        let params = PulsarParams { fundamental_bin: 200, harmonics: 8, amplitude: 0.25 };
        let mut rng = Rng::new(7);
        let x = pulsar_time_series(n, &params, &mut rng);
        let spec = fft(&x);
        let (re, im): (Vec<f32>, Vec<f32>) = (
            spec.iter().map(|c| c.re as f32).collect(),
            spec.iter().map(|c| c.im as f32).collect(),
        );
        let p = power_spectrum(&re, &im);
        // normalize
        let (mean, sd) = crate::dsp::fft::moments(&p);
        let norm: Vec<f32> = p.iter().map(|v| (v - mean) / sd.max(1e-12)).collect();
        let hs = harmonic_sum(&norm, 8);
        let det = detect_peak(&hs, 8).unwrap();
        assert_eq!(det.bin, 200, "snr={}", det.snr);
        assert!(det.snr > 8.0);
    }

    #[test]
    fn to_planes_roundtrip() {
        let x = vec![C64::new(1.5, -2.5), C64::new(0.0, 3.0)];
        let (re, im) = to_planes(&x);
        assert_eq!(re, vec![1.5, 0.0]);
        assert_eq!(im, vec![-2.5, 3.0]);
    }

    #[test]
    fn detect_peak_respects_skip() {
        let mut hs = vec![0.0f32; 64];
        hs[2] = 100.0; // inside the exclusion zone
        hs[30] = 10.0;
        let det = detect_peak(&hs, 8).unwrap();
        assert_eq!(det.bin, 30);
    }

    #[test]
    fn detect_peak_none_for_tiny_input() {
        assert!(detect_peak(&[1.0, 2.0], 8).is_none());
    }
}
