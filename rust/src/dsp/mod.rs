//! Runtime-side DSP: a pure-rust FFT/spectrum/harmonic-sum oracle used to
//! validate the PJRT artifacts, plus synthetic signal generators for the
//! end-to-end pipeline example.

pub mod fft;
pub mod planner;
pub mod signal;

pub use fft::{fft, harmonic_sum, ifft, moments, power_spectrum, C64};
pub use planner::{
    fft_planned, plan_for, pool_stats, rfft_len, rfft_plan_for, run_rfft_rows, run_rows, Direction,
    FftPlan, FftScratch, PlanAlgorithm, PlanScalar, RfftPlan,
};
pub use signal::{detect_peak, pulsar_time_series, PulsarParams};
