//! Pure-rust Stockham FFT — the runtime-side numerical oracle.
//!
//! The PJRT artifacts are validated against this implementation (which is
//! itself validated against closed-form DFT cases), giving two independent
//! oracles for the same math: `kernels/ref.py` at build time, this module
//! at run time.

/// Complex number as (re, im); kept as a plain struct to avoid any
/// dependency on external num crates.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    pub fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }

    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }

    pub fn abs2(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    pub fn expi(theta: f64) -> C64 {
        C64::new(theta.cos(), theta.sin())
    }
}

/// In-place-ish radix-2 Stockham autosort FFT. `sign = -1` forward,
/// `+1` inverse (unnormalized). Panics unless `x.len()` is a power of two.
pub fn fft_stockham(x: &[C64], sign: f64) -> Vec<C64> {
    let n = x.len();
    assert!(n.is_power_of_two() && n >= 1, "length must be a power of two");
    if n == 1 {
        return x.to_vec();
    }
    let mut cur = x.to_vec();
    let mut next = vec![C64::default(); n];
    // State: viewed as (rows = n_cur, cols = s); n_cur halves, s doubles.
    let mut n_cur = n;
    let mut s = 1usize;
    while n_cur > 1 {
        let m = n_cur / 2;
        let theta0 = sign * 2.0 * std::f64::consts::PI / n_cur as f64;
        for p in 0..m {
            let w = C64::expi(theta0 * p as f64);
            for q in 0..s {
                let a = cur[p * s + q];
                let b = cur[(p + m) * s + q];
                next[(2 * p) * s + q] = a.add(b);
                next[(2 * p + 1) * s + q] = a.sub(b).mul(w);
            }
        }
        std::mem::swap(&mut cur, &mut next);
        n_cur = m;
        s *= 2;
    }
    cur
}

/// Forward DFT (matches `jnp.fft.fft` sign conventions).
pub fn fft(x: &[C64]) -> Vec<C64> {
    fft_stockham(x, -1.0)
}

/// Inverse DFT, normalized by 1/N.
pub fn ifft(x: &[C64]) -> Vec<C64> {
    let n = x.len() as f64;
    fft_stockham(x, 1.0).into_iter().map(|c| c.scale(1.0 / n)).collect()
}

/// Naive O(N²) DFT — the oracle's oracle, for tests only.
pub fn dft_naive(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    (0..n)
        .map(|l| {
            let mut acc = C64::default();
            for (k, &v) in x.iter().enumerate() {
                let w = C64::expi(-2.0 * std::f64::consts::PI * (k * l % n) as f64 / n as f64);
                acc = acc.add(v.mul(w));
            }
            acc
        })
        .collect()
}

/// Batched power spectrum |X|² of a real/imag plane pair (row-major B×N).
pub fn power_spectrum(re: &[f32], im: &[f32]) -> Vec<f32> {
    re.iter()
        .zip(im)
        .map(|(r, i)| (*r as f64 * *r as f64 + *i as f64 * *i as f64) as f32)
        .collect()
}

/// Harmonic sum over a single spectrum: out[k] = Σ_{h=1..H} p[h·k].
pub fn harmonic_sum(p: &[f32], harmonics: usize) -> Vec<f32> {
    let n_out = p.len() / harmonics;
    (0..n_out)
        .map(|k| (1..=harmonics).map(|h| p[k * h] as f64).sum::<f64>() as f32)
        .collect()
}

/// Mean and population std of a slice.
pub fn moments(p: &[f32]) -> (f32, f32) {
    let n = p.len() as f64;
    let mean = p.iter().map(|x| *x as f64).sum::<f64>() / n;
    let var = p.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / n;
    (mean as f32, var.sqrt() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x.re - y.re).abs() < tol && (x.im - y.im).abs() < tol,
                "{x:?} vs {y:?}"
            );
        }
    }

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut r = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| C64::new(r.gauss(), r.gauss())).collect()
    }

    #[test]
    fn matches_naive_dft() {
        for n in [2usize, 4, 8, 64, 256] {
            let x = rand_signal(n, n as u64);
            close(&fft(&x), &dft_naive(&x), 1e-8 * (n as f64));
        }
    }

    #[test]
    fn impulse_is_flat() {
        let mut x = vec![C64::default(); 16];
        x[0] = C64::new(1.0, 0.0);
        for c in fft(&x) {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn roundtrip() {
        let x = rand_signal(128, 5);
        close(&ifft(&fft(&x)), &x, 1e-10);
    }

    #[test]
    fn parseval() {
        let x = rand_signal(512, 9);
        let y = fft(&x);
        let et: f64 = x.iter().map(|c| c.abs2()).sum();
        let ef: f64 = y.iter().map(|c| c.abs2()).sum::<f64>() / 512.0;
        assert!((et - ef).abs() / et < 1e-12);
    }

    #[test]
    fn tone_lands_on_bin() {
        let n = 64;
        let k = 5;
        let x: Vec<C64> = (0..n)
            .map(|t| C64::expi(2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64))
            .collect();
        let y = fft(&x);
        assert!((y[k].re - n as f64).abs() < 1e-9);
        for (i, c) in y.iter().enumerate() {
            if i != k {
                assert!(c.abs2() < 1e-16, "leak at {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        fft(&vec![C64::default(); 12]);
    }

    #[test]
    fn harmonic_sum_basic() {
        let p: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let hs = harmonic_sum(&p, 2);
        assert_eq!(hs.len(), 8);
        assert_eq!(hs[3], 3.0 + 6.0);
    }

    #[test]
    fn moments_basic() {
        let (m, s) = moments(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-6);
        assert!((s - 2.0).abs() < 1e-6);
    }
}
