//! Planned FFT execution: the cuFFT-plan idea applied to the sim backend.
//!
//! `fft_stockham` (the numerical oracle in `dsp::fft`) recomputes every
//! twiddle with `sin`/`cos` per butterfly column per stage and allocates
//! two fresh `Vec<C64>` per transform. That is fine for an oracle and
//! fatal for a serving hot loop. An [`FftPlan`] hoists all of that out of
//! the row loop, exactly the way cuFFT plans do:
//!
//!   * per-stage twiddle tables (both directions) precomputed once per
//!     transform length and cached process-wide ([`plan_for`]),
//!   * execution in split re/im (SoA) `f64` scratch planes owned by a
//!     reusable [`FftScratch`] — **no trig and no heap allocation inside
//!     the per-row inner loop**,
//!   * row-parallel batch execution over std scoped threads
//!     ([`run_rows`]), bit-identical to the serial path because rows are
//!     independent and each thread runs the same per-row code.
//!
//! The butterfly schedule and operation order mirror `fft_stockham`
//! exactly, so planned output is bit-identical to the oracle in f64.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::dsp::fft::C64;

/// Transform direction. `Forward` matches `dsp::fft` (sign −1);
/// `Inverse` is the unnormalized adjoint (sign +1) — callers scale by
/// 1/N themselves, as with `fft_stockham(x, 1.0)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

/// Sample type a plan can execute on. The arithmetic is always f64 in the
/// scratch planes; this only governs the load/store conversion.
pub trait PlanScalar: Copy + Send + Sync {
    fn to_f64(self) -> f64;
    fn from_f64(x: f64) -> Self;
}

impl PlanScalar for f32 {
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
}

impl PlanScalar for f64 {
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
}

/// Twiddle table for one Stockham stage: `w[p] = expi(theta0 * p)` for
/// `p in 0..m`, split re/im.
struct StageTwiddles {
    re: Vec<f64>,
    im: Vec<f64>,
}

/// A reusable execution plan for one transform length: per-stage twiddle
/// tables for both directions. Immutable after construction; share it
/// freely across threads (the cache hands out `Arc<FftPlan>`).
pub struct FftPlan {
    n: usize,
    fwd: Vec<StageTwiddles>,
    inv: Vec<StageTwiddles>,
}

impl FftPlan {
    /// Build the plan for length `n` (power of two). Prefer [`plan_for`],
    /// which caches plans process-wide.
    pub fn new(n: usize) -> Self {
        assert!(
            n.is_power_of_two() && n >= 1,
            "length must be a power of two"
        );
        Self {
            n,
            fwd: Self::stages(n, -1.0),
            inv: Self::stages(n, 1.0),
        }
    }

    fn stages(n: usize, sign: f64) -> Vec<StageTwiddles> {
        let mut out = Vec::new();
        let mut n_cur = n;
        while n_cur > 1 {
            let m = n_cur / 2;
            // Same expression as fft_stockham so twiddles are bit-identical.
            let theta0 = sign * 2.0 * std::f64::consts::PI / n_cur as f64;
            let mut re = Vec::with_capacity(m);
            let mut im = Vec::with_capacity(m);
            for p in 0..m {
                let theta = theta0 * p as f64;
                re.push(theta.cos());
                im.push(theta.sin());
            }
            out.push(StageTwiddles { re, im });
            n_cur = m;
        }
        out
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// One Stockham pass (stage `k`): reads `cur`, writes `nxt`. The inner
    /// loop is pure loads, multiplies and adds — no trig, no allocation.
    #[inline]
    fn stage_pass(
        &self,
        k: usize,
        tw: &StageTwiddles,
        cur_re: &[f64],
        cur_im: &[f64],
        nxt_re: &mut [f64],
        nxt_im: &mut [f64],
    ) {
        let stride = 1usize << k;
        let m = self.n >> (k + 1);
        for p in 0..m {
            let wr = tw.re[p];
            let wi = tw.im[p];
            let ia = p * stride;
            let ib = (p + m) * stride;
            let io0 = 2 * p * stride;
            let io1 = io0 + stride;
            for q in 0..stride {
                let ar = cur_re[ia + q];
                let ai = cur_im[ia + q];
                let br = cur_re[ib + q];
                let bi = cur_im[ib + q];
                nxt_re[io0 + q] = ar + br;
                nxt_im[io0 + q] = ai + bi;
                let dr = ar - br;
                let di = ai - bi;
                nxt_re[io1 + q] = dr * wr - di * wi;
                nxt_im[io1 + q] = dr * wi + di * wr;
            }
        }
    }

    /// Transform one row already loaded into `scratch`'s A planes; returns
    /// `true` when the result ended in the A planes (even stage count).
    fn run_loaded(&self, dir: Direction, s: &mut FftScratch) -> bool {
        let stages = match dir {
            Direction::Forward => &self.fwd,
            Direction::Inverse => &self.inv,
        };
        let n = self.n;
        let (a_re, a_im, b_re, b_im) = s.planes(n);
        let mut in_a = true;
        for (k, tw) in stages.iter().enumerate() {
            if in_a {
                self.stage_pass(k, tw, a_re, a_im, b_re, b_im);
            } else {
                self.stage_pass(k, tw, b_re, b_im, a_re, a_im);
            }
            in_a = !in_a;
        }
        in_a
    }

    /// Transform one row: load `re_in`/`im_in` into scratch, run every
    /// stage, store into `out_re`/`out_im`. All slices must have length
    /// `self.n()`. Steady-state this performs zero heap allocation: the
    /// scratch planes are grown once and reused.
    pub fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert_eq!(re_in.len(), n, "re input length");
        assert_eq!(im_in.len(), n, "im input length");
        assert_eq!(out_re.len(), n, "re output length");
        assert_eq!(out_im.len(), n, "im output length");
        scratch.ensure(n);
        {
            let (a_re, a_im, _, _) = scratch.planes(n);
            for (dst, src) in a_re.iter_mut().zip(re_in) {
                *dst = src.to_f64();
            }
            for (dst, src) in a_im.iter_mut().zip(im_in) {
                *dst = src.to_f64();
            }
        }
        let in_a = self.run_loaded(dir, scratch);
        let (a_re, a_im, b_re, b_im) = scratch.planes(n);
        let (res_re, res_im): (&[f64], &[f64]) = if in_a { (a_re, a_im) } else { (b_re, b_im) };
        for (dst, src) in out_re.iter_mut().zip(res_re) {
            *dst = T::from_f64(*src);
        }
        for (dst, src) in out_im.iter_mut().zip(res_im) {
            *dst = T::from_f64(*src);
        }
    }

    /// Transform `rows` consecutive rows serially with one scratch.
    /// `re`/`im` and the outputs are row-major `rows × n`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        dir: Direction,
        re: &[T],
        im: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert!(re.len() >= rows * n && im.len() >= rows * n, "input planes too short");
        assert!(out_re.len() >= rows * n && out_im.len() >= rows * n, "output planes too short");
        for r in 0..rows {
            let off = r * n;
            self.run_row(
                dir,
                &re[off..off + n],
                &im[off..off + n],
                &mut out_re[off..off + n],
                &mut out_im[off..off + n],
                scratch,
            );
        }
    }
}

/// Reusable split re/im scratch planes (two ping-pong buffers). One per
/// worker/thread; grows monotonically to the largest `n` it has served and
/// never reallocates below that — callers can rely on pointer-stable
/// planes across executions of the same length.
#[derive(Default)]
pub struct FftScratch {
    a_re: Vec<f64>,
    a_im: Vec<f64>,
    b_re: Vec<f64>,
    b_im: Vec<f64>,
}

impl FftScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every plane to at least `n` elements (no-op once large enough).
    pub fn ensure(&mut self, n: usize) {
        if self.a_re.len() < n {
            self.a_re.resize(n, 0.0);
            self.a_im.resize(n, 0.0);
            self.b_re.resize(n, 0.0);
            self.b_im.resize(n, 0.0);
        }
    }

    /// Current plane capacity in elements.
    pub fn capacity(&self) -> usize {
        self.a_re.len()
    }

    /// Base pointer of the first plane — lets tests assert that repeated
    /// executions reuse the same buffers instead of reallocating.
    pub fn base_ptr(&self) -> *const f64 {
        self.a_re.as_ptr()
    }

    fn planes(&mut self, n: usize) -> (&mut [f64], &mut [f64], &mut [f64], &mut [f64]) {
        (
            &mut self.a_re[..n],
            &mut self.a_im[..n],
            &mut self.b_re[..n],
            &mut self.b_im[..n],
        )
    }
}

/// Process-wide plan cache: one immutable `Arc<FftPlan>` per length, built
/// on first use. The lock guards only the map — execution never holds it.
static PLAN_CACHE: OnceLock<Mutex<HashMap<u64, Arc<FftPlan>>>> = OnceLock::new();

/// The cached plan for length `n` (power of two), building it on first use.
/// A miss builds outside the lock (twiddle construction is O(n) trig) and
/// the entry API keeps whichever plan landed first, so concurrent
/// first-touch builds neither serialize other lengths nor diverge.
pub fn plan_for(n: usize) -> Arc<FftPlan> {
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&(n as u64)) {
        return plan.clone();
    }
    let built = Arc::new(FftPlan::new(n));
    cache
        .lock()
        .unwrap()
        .entry(n as u64)
        .or_insert(built)
        .clone()
}

/// Process-wide scratch pool so ad-hoc callers (module `run_f32`, the
/// row-parallel workers) reuse planes instead of allocating per call.
/// Bounded so a burst of threads cannot pin memory forever.
static SCRATCH_POOL: OnceLock<Mutex<Vec<FftScratch>>> = OnceLock::new();
const SCRATCH_POOL_CAP: usize = 16;

/// Borrow a pooled scratch for the duration of `f`, returning it after.
pub fn with_scratch<R>(f: impl FnOnce(&mut FftScratch) -> R) -> R {
    let pool = SCRATCH_POOL.get_or_init(|| Mutex::new(Vec::new()));
    let mut scratch = pool.lock().unwrap().pop().unwrap_or_default();
    let r = f(&mut scratch);
    let mut guard = pool.lock().unwrap();
    if guard.len() < SCRATCH_POOL_CAP {
        guard.push(scratch);
    }
    r
}

/// Worker threads used for row-parallel execution: capped small (this is
/// a simulation backend sharing the host with card worker threads).
/// Override with `FFTSWEEP_FFT_THREADS=1` to force serial execution.
pub fn pool_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("FFTSWEEP_FFT_THREADS") {
            if let Ok(t) = v.trim().parse::<usize>() {
                return t.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(4)
    })
}

/// Below this much work a batch runs serially — the scoped-thread spawn
/// (tens of µs per worker) would cost more than it saves. The threshold is
/// set so the standard serving batches (64×1024 and up) parallelize while
/// small/partial batches stay on the zero-spawn serial path.
const PAR_MIN_ROWS: usize = 2;
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Execute `rows` independent transforms, row-parallel across scoped std
/// threads when the batch is large enough, serial otherwise. Rows are
/// independent and each runs the identical per-row code, so the parallel
/// result is bit-identical to [`FftPlan::run_rows_serial`].
///
/// Deliberate tradeoff: workers are *scoped spawns per call*, not a
/// persistent pool. A persistent pool executing borrowed row slices needs
/// lifetime-erasing `unsafe` (no rayon/crossbeam in the offline crate
/// set); scoped spawn is safe, and the `PAR_MIN_ELEMS` cutoff keeps the
/// spawn cost well under the FFT work it buys. Per-row execution itself
/// stays allocation- and trig-free either way; `FFTSWEEP_FFT_THREADS=1`
/// forces the fully spawn-free serial path.
pub fn run_rows<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
) {
    run_rows_impl(plan, dir, re, im, rows, out_re, out_im, pool_threads(), PAR_MIN_ELEMS);
}

#[allow(clippy::too_many_arguments)]
fn run_rows_impl<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(dir, re, im, rows, out_re, out_im, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let chunks = out_re[..rows * n]
            .chunks_mut(chunk_rows * n)
            .zip(out_im[..rows * n].chunks_mut(chunk_rows * n))
            .enumerate();
        for (ci, (o_re, o_im)) in chunks {
            let start = ci * chunk_rows;
            let rows_here = o_re.len() / n;
            let re_chunk = &re[start * n..(start + rows_here) * n];
            let im_chunk = &im[start * n..(start + rows_here) * n];
            scope.spawn(move || {
                with_scratch(|s| {
                    plan.run_rows_serial(dir, re_chunk, im_chunk, rows_here, o_re, o_im, s)
                });
            });
        }
    });
}

/// Planned forward FFT of one `C64` row — drop-in for `dsp::fft` where the
/// caller wants plan-cache speed with the oracle's interface.
pub fn fft_planned(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    let plan = plan_for(n);
    let re: Vec<f64> = x.iter().map(|c| c.re).collect();
    let im: Vec<f64> = x.iter().map(|c| c.im).collect();
    let mut out_re = vec![0.0f64; n];
    let mut out_im = vec![0.0f64; n];
    with_scratch(|s| plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, s));
    out_re
        .into_iter()
        .zip(out_im)
        .map(|(r, i)| C64::new(r, i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::fft::{dft_naive, fft};
    use crate::util::rng::Rng;

    fn rand_row(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut r = Rng::new(seed);
        (
            (0..n).map(|_| r.gauss()).collect(),
            (0..n).map(|_| r.gauss()).collect(),
        )
    }

    #[test]
    fn plan_matches_naive_dft_all_lengths() {
        // The issue's acceptance grid: every power of two in 2..=4096.
        let mut n = 2usize;
        while n <= 4096 {
            let (re, im) = rand_row(n, n as u64);
            let x: Vec<C64> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| C64::new(r, i))
                .collect();
            let want = dft_naive(&x);
            let plan = plan_for(n);
            let mut out_re = vec![0.0f64; n];
            let mut out_im = vec![0.0f64; n];
            let mut s = FftScratch::new();
            plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, &mut s);
            let tol = 1e-8 * n as f64;
            for i in 0..n {
                assert!(
                    (out_re[i] - want[i].re).abs() < tol && (out_im[i] - want[i].im).abs() < tol,
                    "n={n} bin {i}: ({}, {}) vs {:?}",
                    out_re[i],
                    out_im[i],
                    want[i]
                );
            }
            n *= 2;
        }
    }

    #[test]
    fn plan_is_bit_identical_to_stockham_oracle() {
        for n in [2usize, 8, 64, 1024] {
            let (re, im) = rand_row(n, 7 + n as u64);
            let x: Vec<C64> = re.iter().zip(&im).map(|(&r, &i)| C64::new(r, i)).collect();
            let want = fft(&x);
            let got = fft_planned(&x);
            for i in 0..n {
                assert_eq!(got[i].re.to_bits(), want[i].re.to_bits(), "n={n} bin {i} re");
                assert_eq!(got[i].im.to_bits(), want[i].im.to_bits(), "n={n} bin {i} im");
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        let n = 256usize;
        let (re, im) = rand_row(n, 13);
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        let (mut fr, mut fi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
        let (mut br, mut bi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
        for i in 0..n {
            assert!((br[i] / n as f64 - re[i]).abs() < 1e-10, "bin {i}");
            assert!((bi[i] / n as f64 - im[i]).abs() < 1e-10, "bin {i}");
        }
    }

    #[test]
    fn plan_cache_returns_the_same_arc() {
        let a = plan_for(512);
        let b = plan_for(512);
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the cached plan");
        let c = plan_for(1024);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn scratch_is_pointer_stable_across_executions() {
        // The no-alloc acceptance check: run the scratch path twice (and
        // then at a smaller n) and assert the planes were not reallocated.
        let n = 1024usize;
        let plan = plan_for(n);
        let (re, im) = rand_row(n, 3);
        let (mut or1, mut oi1) = (vec![0.0; n], vec![0.0; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        let ptr = s.base_ptr();
        let cap = s.capacity();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        assert_eq!(s.base_ptr(), ptr, "second run must reuse the same planes");
        assert_eq!(s.capacity(), cap);
        // Smaller transform through the same scratch: still no realloc.
        let small = plan_for(64);
        let (sre, sim_) = rand_row(64, 4);
        let (mut sor, mut soi) = (vec![0.0; 64], vec![0.0; 64]);
        small.run_row(Direction::Forward, &sre, &sim_, &mut sor, &mut soi, &mut s);
        assert_eq!(s.base_ptr(), ptr, "smaller n must not shrink/realloc");
    }

    #[test]
    fn scratch_reuse_across_differing_batch_occupancies() {
        // One scratch serving batches of different row counts (the partial
        // vs full PackedBatch case) stays correct and allocation-stable.
        let n = 256usize;
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        for rows in [1usize, 3, 8, 2, 8] {
            let (re, im) = rand_row(rows * n, rows as u64);
            let re32: Vec<f32> = re.iter().map(|&v| v as f32).collect();
            let im32: Vec<f32> = im.iter().map(|&v| v as f32).collect();
            let mut or_ = vec![0.0f32; rows * n];
            let mut oi = vec![0.0f32; rows * n];
            plan.run_rows_serial(Direction::Forward, &re32, &im32, rows, &mut or_, &mut oi, &mut s);
            for r in 0..rows {
                let off = r * n;
                let x: Vec<C64> = (0..n)
                    .map(|i| C64::new(re32[off + i] as f64, im32[off + i] as f64))
                    .collect();
                let want = fft(&x);
                for i in 0..n {
                    assert!(
                        (or_[off + i] as f64 - want[i].re).abs() < 1e-2,
                        "rows={rows} r={r} bin {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_row_parallel_is_bit_identical_to_serial() {
        crate::util::prop::check(
            "planner row-parallel == serial",
            |rng| {
                let n = 1usize << rng.range_u64(3, 10); // 8..=1024
                let rows = rng.range_u64(1, 40) as usize;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rows, seed)
            },
            |&(n, rows, seed)| {
                let plan = plan_for(n);
                let mut r = Rng::new(seed);
                let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let mut ser_re = vec![0.0f32; rows * n];
                let mut ser_im = vec![0.0f32; rows * n];
                let mut s = FftScratch::new();
                plan.run_rows_serial(
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut ser_re,
                    &mut ser_im,
                    &mut s,
                );
                let mut par_re = vec![0.0f32; rows * n];
                let mut par_im = vec![0.0f32; rows * n];
                // min_elems = 0 forces the scoped-thread path even for the
                // small cases the generator produces.
                run_rows_impl(
                    &plan,
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut par_re,
                    &mut par_im,
                    4,
                    0,
                );
                for i in 0..rows * n {
                    if ser_re[i].to_bits() != par_re[i].to_bits()
                        || ser_im[i].to_bits() != par_im[i].to_bits()
                    {
                        return Err(format!(
                            "n={n} rows={rows} elem {i}: serial ({}, {}) vs parallel ({}, {})",
                            ser_re[i], ser_im[i], par_re[i], par_im[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn f64_rows_match_oracle() {
        let n = 512usize;
        let rows = 4usize;
        let (re, im) = rand_row(rows * n, 21);
        let plan = plan_for(n);
        let mut out_re = vec![0.0f64; rows * n];
        let mut out_im = vec![0.0f64; rows * n];
        run_rows(&plan, Direction::Forward, &re, &im, rows, &mut out_re, &mut out_im);
        for row in 0..rows {
            let off = row * n;
            let x: Vec<C64> = (0..n).map(|i| C64::new(re[off + i], im[off + i])).collect();
            let want = fft(&x);
            for i in 0..n {
                assert_eq!(out_re[off + i].to_bits(), want[i].re.to_bits(), "r{row} b{i}");
                assert_eq!(out_im[off + i].to_bits(), want[i].im.to_bits(), "r{row} b{i}");
            }
        }
    }

    #[test]
    fn length_one_plan_copies() {
        let plan = plan_for(1);
        let mut s = FftScratch::new();
        let (mut or_, mut oi) = (vec![0.0f64], vec![0.0f64]);
        plan.run_row(Direction::Forward, &[2.5], &[-1.5], &mut or_, &mut oi, &mut s);
        assert_eq!(or_[0], 2.5);
        assert_eq!(oi[0], -1.5);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        FftPlan::new(12);
    }
}
